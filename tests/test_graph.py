"""Layer-graph IR: geometry, tensor sizes, MAC counts, validation."""
import pytest

from repro.core.graph import Layer, LayerGraph
from repro.workloads import mobilenet_v3_large, resnet50, unet, vgg16


def test_conv_sizes():
    l = Layer(name="c", kind="conv", c=64, h=56, w=56, m=128, p=56, q=56,
              r=3, s=3, stride=(1, 1), padding=(1, 1))
    assert l.input_size == 64 * 56 * 56
    assert l.output_size == 128 * 56 * 56
    assert l.weight_size == 128 * 64 * 9
    assert l.macs == 128 * 56 * 56 * 64 * 9


def test_depthwise_sizes():
    l = Layer(name="d", kind="dwconv", c=32, h=28, w=28, m=32, p=28, q=28,
              r=3, s=3, groups=32)
    assert l.weight_size == 32 * 9
    assert l.macs == 32 * 28 * 28 * 9


def test_fc_sizes():
    l = Layer(name="f", kind="fc", c=2048, h=1, w=1, m=1000, p=1, q=1)
    assert l.weight_size == 2048 * 1000
    assert l.macs == 2048 * 1000


def test_unknown_kind_rejected():
    with pytest.raises(ValueError):
        Layer(name="x", kind="wat")


def test_duplicate_layer_rejected():
    g = LayerGraph("t")
    g.add(Layer(name="input", kind="input", m=3, p=8, q=8))
    with pytest.raises(ValueError):
        g.add(Layer(name="input", kind="input", m=3, p=8, q=8))


def test_unknown_producer_rejected():
    g = LayerGraph("t")
    with pytest.raises(ValueError):
        g.add(Layer(name="c", kind="conv", c=3, h=8, w=8, m=4, p=8, q=8,
                    r=3, s=3), ["nope"])


# ---- published MAC counts (batch 1) -----------------------------------------------

def test_resnet50_macs():
    g = resnet50()
    # ~4.1 GMACs (He et al. report 3.8 GFLOPs ~ 3.8-4.1 GMACs w/ fc+shortcuts)
    assert 3.8e9 < g.total_macs < 4.4e9
    assert 23e6 < g.total_weights < 27e6      # ~25.5 M params


def test_mobilenet_v3_macs():
    g = mobilenet_v3_large()
    # paper reports 219 MMAdds for MobileNetV3-Large @224
    assert 200e6 < g.total_macs < 240e6
    assert 4e6 < g.total_weights < 6.5e6


def test_vgg16_macs():
    g = vgg16()
    assert 15.2e9 < g.total_macs < 15.8e9     # 15.5 GMACs
    assert 130e6 < g.total_weights < 140e6


def test_unet_builds_and_validates():
    g = unet()
    assert g.total_macs > 1e9
    # decoder restores full resolution
    last_conv = [l for l in g.layers.values() if l.kind == "conv"][-1]
    assert last_conv.p == 256 and last_conv.q == 256


def test_edge_shapes_agree_everywhere():
    for build in (resnet50, mobilenet_v3_large, unet, vgg16):
        build().validate()
