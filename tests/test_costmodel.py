"""Cost model: DRAM accounting, fusion savings, capacity invalidation,
utilization, repartitioning."""
import math

import pytest

from repro.core.fusion import FusionState
from repro.core.graph import Layer, LayerGraph
from repro.costmodel import (DEFAULT_ENERGY, EYERISS, SIMBA, SIMBA2X2,
                             Evaluator, map_layer, spatial_utilization)
from tests.test_fusion import chain, skip_graph


def small_conv(m=16, c=16, hw=16, k=3):
    return Layer(name="c", kind="conv", c=c, h=hw, w=hw, m=m, p=hw, q=hw,
                 r=k, s=k, padding=(k // 2, k // 2))


def test_layer_dram_traffic_when_everything_fits():
    l = small_conv()
    cost = map_layer(l, SIMBA)
    assert cost.dram_read_words == l.input_size + l.weight_size
    assert cost.dram_write_words == l.output_size
    assert cost.act_write_events == 1


def test_onchip_inputs_remove_dram_reads():
    l = small_conv()
    off = map_layer(l, SIMBA, inputs_offchip=True, outputs_offchip=True)
    on = map_layer(l, SIMBA, inputs_offchip=False, outputs_offchip=False)
    assert on.dram_read_words == l.weight_size
    assert on.dram_write_words == 0
    assert on.energy_pj < off.energy_pj


def test_weight_tiling_when_oversized():
    # fc with weights far beyond the 512 KiB (256 Kwords) weight buffer
    l = Layer(name="f", kind="fc", c=4096, h=1, w=1, m=4096, p=1, q=1)
    cost = map_layer(l, SIMBA)
    assert cost.dram_read_words >= l.weight_size  # streamed at least once


def test_utilization_simba_full_vs_depthwise():
    full = spatial_utilization(small_conv(m=64, c=64), SIMBA)
    dw = spatial_utilization(
        Layer(name="d", kind="dwconv", c=64, h=16, w=16, m=64, p=16, q=16,
              r=3, s=3, groups=64), SIMBA)
    assert full > 0.9
    assert dw < 0.1          # depthwise starves SIMBA's C-parallel lanes


def test_utilization_eyeriss_pointwise_penalty():
    u3 = spatial_utilization(small_conv(k=3), EYERISS)
    # row-stationary packs 4x 3-row filters in 12 rows -> full vertical use
    assert u3 == pytest.approx(1.0 * spatial_utilization(small_conv(k=1), EYERISS) * 1.0, abs=1) or u3 > 0
    assert spatial_utilization(small_conv(k=3), EYERISS) >= \
        spatial_utilization(Layer(name="c", kind="conv", c=16, h=16, w=16,
                                  m=16, p=7, q=7, r=3, s=3), EYERISS)


def test_fusing_chain_reduces_energy_and_dram():
    g = chain(4)
    ev = Evaluator(g, SIMBA)
    base = ev.layerwise()
    fused = ev.evaluate(FusionState.fully_fused(g))
    assert fused is not None
    assert fused.energy_pj < base.energy_pj
    total = lambda c: c.dram_read_words + c.dram_write_words
    assert total(fused) < total(base)
    assert fused.act_write_events < base.act_write_events
    # compute work is schedule-invariant
    assert fused.macs == base.macs


def test_over_capacity_state_invalid():
    # giant channel count -> line buffers cannot fit the 64 KiB SIMBA buffer
    g = LayerGraph("big")
    i = g.add(Layer(name="input", kind="input", m=512, p=64, q=64))
    a = g.add(Layer(name="a", kind="conv", c=512, h=64, w=64, m=512,
                    p=64, q=64, r=3, s=3, padding=(1, 1)), [i])
    g.add(Layer(name="b", kind="conv", c=512, h=64, w=64, m=512,
                p=64, q=64, r=3, s=3, padding=(1, 1)), [a])
    ev = Evaluator(g, SIMBA)
    assert ev.evaluate(FusionState.fully_fused(g)) is None
    assert ev.fitness(FusionState.fully_fused(g)) == 0.0


def test_unschedulable_state_invalid():
    g = skip_graph()
    s = FusionState(g, frozenset({("a", "add")}))
    ev = Evaluator(g, SIMBA)
    assert ev.evaluate(s) is None


def test_fitness_layerwise_is_one():
    g = chain(3)
    ev = Evaluator(g, SIMBA)
    assert ev.fitness(FusionState.layerwise(g)) == pytest.approx(1.0)


def test_group_cost_memoization():
    g = chain(4)
    ev = Evaluator(g, SIMBA)
    s = FusionState(g, frozenset({(("c0", "c1"))}))
    ev.evaluate(s)
    n_cached = len(ev._group_cache)
    ev.evaluate(s.combine(("c2", "c3")))   # shares group {c0,c1}
    assert len(ev._group_cache) == n_cached + 1  # only the new pair added


def test_repartition_iso_capacity():
    acc = EYERISS.repartition(64)
    assert acc.act_buf_kib == 192 and acc.weight_buf_kib == 448
    assert acc.act_buf_kib + acc.weight_buf_kib == \
        EYERISS.act_buf_kib + EYERISS.weight_buf_kib


def test_edp_units():
    g = chain(3)
    ev = Evaluator(g, SIMBA)
    c = ev.layerwise()
    assert c.edp == pytest.approx(c.energy_pj * c.cycles)
    assert c.metric("edp") == c.edp
    assert c.metric("energy") == c.energy_pj


def test_schedule_cost_seconds_uses_arch_clock():
    """ScheduleCost.seconds must follow Accelerator.clock_mhz, not a
    hard-coded 200 MHz."""
    import dataclasses

    g = chain(3)
    fast_acc = dataclasses.replace(SIMBA, name="simba400", clock_mhz=400.0)
    base = Evaluator(g, SIMBA).layerwise()
    fast = Evaluator(g, fast_acc).layerwise()
    assert base.clock_hz == pytest.approx(200e6)
    assert fast.clock_hz == pytest.approx(400e6)
    assert base.seconds == pytest.approx(base.cycles / 200e6)
    assert fast.seconds == pytest.approx(fast.cycles / 400e6)
    # same schedule, double the clock => half the time (DRAM words/cycle
    # scale keeps the cost model's cycle counts comparable)
    assert fast.seconds < base.seconds
