"""repro.analysis.spacemap: static verdicts are sound against brute
force, regions confine every group, the per-region exhaustive composition
is exact, search operators respect the freeze, artifacts round-trip the
summary through ``repro verify``, and the checker stays engine-isolated."""
import dataclasses
import os
import random
import subprocess
import sys

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.analysis import SpaceMap, build_spacemap, verify_artifact
from repro.analysis.verify import _GraphView
from repro.core.fusion import FusionState
from repro.core.graph import Layer, LayerGraph
from repro.search import (OBJECTIVES, BackendError, ScheduleArtifact,
                          SearchSession, SearchSpec, build_accelerator,
                          register_objective, search)

# ---- graphs ----------------------------------------------------------------------
# simba's activation buffer is 32768 words: the `small` layers below
# (8ch, 16x16 maps) can all fuse freely, the `big` layers (64ch, 64x64
# maps, 3-row windows) provably cannot pair up — so hand-built graphs hit
# all three verdicts and factorize into >1 region.


def small_chain(n=4):
    g = LayerGraph("small_chain")
    prev = g.add(Layer(name="input", kind="input", m=8, p=16, q=16))
    for i in range(n):
        prev = g.add(Layer(name=f"c{i}", kind="conv", c=8, h=16, w=16,
                           m=8, p=16, q=16, r=3, s=3, padding=(1, 1)),
                     [prev])
    return g


def skip_graph():
    g = LayerGraph("skip_graph")
    i = g.add(Layer(name="input", kind="input", m=8, p=16, q=16))
    a = g.add(Layer(name="a", kind="conv", c=8, h=16, w=16, m=8, p=16,
                    q=16, r=3, s=3, padding=(1, 1)), [i])
    b = g.add(Layer(name="b", kind="conv", c=8, h=16, w=16, m=8, p=16,
                    q=16, r=3, s=3, padding=(1, 1)), [a])
    g.add(Layer(name="add", kind="add", c=8, h=16, w=16, m=8, p=16, q=16),
          [a, b])
    return g


def big_chain(n=3):
    """Every conv-conv pair over-fills the buffer: bits 1..n-1 freeze."""
    g = LayerGraph("big_chain")
    prev = g.add(Layer(name="input", kind="input", m=64, p=64, q=64))
    for i in range(n):
        prev = g.add(Layer(name=f"c{i}", kind="conv", c=64, h=64, w=64,
                           m=64, p=64, q=64, r=3, s=3, padding=(1, 1)),
                     [prev])
    return g


def mixed():
    """Small fusable head, big frozen tail: one frozen gene splits the
    graph into two regions."""
    g = LayerGraph("mixed")
    prev = g.add(Layer(name="input", kind="input", m=8, p=16, q=16))
    for i in range(3):
        prev = g.add(Layer(name=f"s{i}", kind="conv", c=8, h=16, w=16,
                           m=8, p=16, q=16, r=3, s=3, padding=(1, 1)),
                     [prev])
    prev = g.add(Layer(name="up", kind="conv", c=8, h=16, w=16, m=64,
                       p=64, q=64, r=3, s=3, padding=(1, 1)), [prev])
    for i in range(2):
        prev = g.add(Layer(name=f"b{i}", kind="conv", c=64, h=64, w=64,
                           m=64, p=64, q=64, r=3, s=3, padding=(1, 1)),
                     [prev])
    return g


def session_for(graph, *, backend="exhaustive", spacemap=True, **spec_kwargs):
    return SearchSession.from_objects(
        graph, build_accelerator("simba"), backend=backend,
        spacemap=spacemap, **spec_kwargs)


# ---- classification sanity -------------------------------------------------------


def test_hand_built_graphs_hit_all_three_verdicts():
    sm = build_spacemap(mixed(), "default", "simba")
    assert sm.frozen_indices == (5,)             # b0 -> b1 cannot pair
    assert [[r.lo, r.hi] for r in sm.regions] == [[0, 5], [6, 6]]
    assert sm.genome_length == sm.n_edges - 1 == 5
    sm = build_spacemap(big_chain(), "default", "simba")
    assert sm.frozen_indices == (1, 2)
    assert len(sm.regions) == 3
    sm = build_spacemap(small_chain(), "default", "simba")
    assert sm.frozen_indices == ()               # everything fits
    assert {v.verdict for v in sm.verdicts} == {"free"}


def test_unknown_costmodel_degrades_to_a_noop_map():
    sm = build_spacemap(big_chain(), "nosuchmodel", "simba")
    assert sm.capacity_words is None
    assert sm.frozen_indices == ()
    assert all(v.verdict == "undecided" for v in sm.verdicts)
    assert len(sm.regions) == 1                  # whole graph, one region


# ---- soundness against brute force (hypothesis) ----------------------------------


@st.composite
def random_dags(draw):
    """Small random conv chains, channels/spatial drawn so both the
    frozen and the free verdict occur across examples, plus an optional
    skip edge (a residual add over the last two convs)."""
    ch = draw(st.sampled_from([4, 8, 64]))
    hw = draw(st.sampled_from([16, 64]))
    n = draw(st.integers(min_value=2, max_value=4))
    with_skip = draw(st.booleans())
    g = LayerGraph(f"rand_c{ch}_s{hw}_n{n}_{int(with_skip)}")
    prev = g.add(Layer(name="input", kind="input", m=ch, p=hw, q=hw))
    convs = []
    for i in range(n):
        prev = g.add(Layer(name=f"c{i}", kind="conv", c=ch, h=hw, w=hw,
                           m=ch, p=hw, q=hw, r=3, s=3, padding=(1, 1)),
                     [prev])
        convs.append(prev)
    if with_skip and n >= 2:
        g.add(Layer(name="add", kind="add", c=ch, h=hw, w=hw, m=ch, p=hw,
                    q=hw), [convs[-2], convs[-1]])
    return g


@settings(max_examples=20, deadline=None)
@given(graph=random_dags())
def test_forced_off_illegal_and_free_legal_under_brute_force(graph):
    session = session_for(graph)
    sm, view = session.spacemap, _GraphView(graph)
    frozen = sm.frozen_mask
    # forced_off is sound: EVERY genome containing a frozen bit is invalid
    for mask in range(1 << view.m):
        if mask & frozen:
            assert session.problem.fitness(
                FusionState.from_mask(graph, mask)) == 0.0
    # free is sound: every subset of free bits whose condensation the
    # independent checker calls acyclic evaluates to a real cost
    free_bits = [v.index for v in sm.free]
    for sub in range(1 << len(free_bits)):
        mask = 0
        for j, i in enumerate(free_bits):
            if (sub >> j) & 1:
                mask |= 1 << i
        if view.condensation_acyclic(view.groups_of(mask)):
            state = FusionState.from_mask(graph, mask)
            assert session.evaluator.evaluate(state) is not None, \
                f"free-bit genome {mask:#x} scored invalid"


@settings(max_examples=20, deadline=None)
@given(graph=random_dags())
def test_regions_confine_every_group(graph):
    sm = build_spacemap(graph, "default", "simba")
    view = _GraphView(graph)
    spans = [(r.lo, r.hi) for r in sm.regions]
    for mask in range(1 << view.m):
        if mask & sm.frozen_mask:
            continue
        for members in view.groups_of(mask):
            lo, hi = min(members), max(members)
            assert any(rl <= lo and hi <= rh for rl, rh in spans), \
                f"group {members} of genome {mask:#x} straddles a cut"


# ---- per-region exhaustive == global brute force ---------------------------------


@pytest.mark.parametrize("objective", ["edp", "energy", "cycles", "dram"])
@pytest.mark.parametrize("builder", [small_chain, skip_graph, big_chain,
                                     mixed])
def test_per_region_composition_matches_flat_brute_force(builder, objective):
    graph = builder()
    flat = session_for(graph, spacemap=False, objective=objective)
    flat_art = flat.run()
    fact = session_for(graph, spacemap=True, objective=objective)
    fact_art = fact.run()
    assert fact_art.best_fitness == pytest.approx(
        flat_art.best_fitness, rel=1e-12)
    assert fact.result.best_state.mask & fact.spacemap.frozen_mask == 0
    # factorization never scores more states than the flat enumeration
    assert fact_art.evaluations <= flat_art.evaluations


def test_per_region_composition_matches_flat_on_tpu_costmodel():
    graph = mixed()
    flat = session_for(graph, spacemap=False, costmodel="tpu").run()
    fact = session_for(graph, spacemap=True, costmodel="tpu").run()
    assert fact.best_fitness == pytest.approx(flat.best_fitness, rel=1e-12)


def test_vgg16_solved_exactly_by_region_composition():
    """ROADMAP 5(b): the paper's 2^21 VGG-16 space, exactly — a few dozen
    evaluations instead of two million (fixed-seed pin)."""
    session = SearchSession(SearchSpec(
        workload="vgg16", backend="exhaustive", spacemap=True))
    art = session.run()
    sm = session.spacemap
    assert sm.raw_space_size() == 1 << 21
    assert sm.frozen_indices == (1, 4, 7, 8, 11, 12, 15, 16)
    assert len(sm.regions) == 9
    assert art.evaluations == 37
    assert session.result.best_state.mask == 0x1A4225
    assert art.best_fitness == pytest.approx(1.0273429656033972, rel=1e-12)
    report = verify_artifact(art)
    assert report.ok, report.describe()
    assert report.check("spacemap").ok


def test_fixed_seed_ga_with_spacemap_is_no_worse_than_baseline():
    def ga(spacemap):
        return search("vgg16", "simba", backend="ga", seed=0,
                      spacemap=spacemap,
                      backend_config={"preset": "fast", "generations": 8})
    base, frozen = ga(False), ga(True)
    assert frozen.best_fitness >= base.best_fitness
    # fixed-seed pins for BOTH trajectories: the spacemap path draws over
    # the active bits only, so it has its own pin rather than bit-identity
    assert base.best_fitness == pytest.approx(1.027324133811833, rel=1e-12)
    assert frozen.best_fitness == pytest.approx(1.0273429656033972,
                                                rel=1e-12)


# ---- exhaustive guards -----------------------------------------------------------


def test_guard_reports_largest_region_when_factorized_space_too_big():
    with pytest.raises(BackendError, match="largest spacemap region"):
        search("unet", backend="exhaustive", spacemap=True)


def test_guard_explains_why_custom_objectives_do_not_compose():
    name = "test_spacemap_cycles_objective"
    if name not in OBJECTIVES:
        @register_objective(name)
        def cycles_metric(cost):
            return cost.cycles
    with pytest.raises(BackendError,
                       match="not group-additive") as excinfo:
        search("unet", backend="exhaustive", objective=name, spacemap=True)
    assert "a spacemap factorizes this into" in str(excinfo.value)


# ---- operator masking ------------------------------------------------------------


def test_search_operators_never_set_frozen_bits():
    session = session_for(mixed(), backend="ga")
    problem, sm = session.problem, session.spacemap
    frozen = sm.frozen_mask
    assert frozen                                # the test needs teeth
    rng = random.Random(0)
    pop = [problem.random_genome(rng) for _ in range(16)]
    for _ in range(200):
        child = problem.mutate(
            problem.crossover(rng.choice(pop), rng.choice(pop), rng), rng)
        assert child.mask & frozen == 0
        pop.append(child)
    assert all(g.mask & frozen == 0 for g in pop)
    for nb in problem.neighbors(problem.initial()):
        assert nb.mask & frozen == 0
    assert problem.space_size() == 1 << len(sm.active_indices)
    masks = {g.mask for g in problem.enumerate()}
    assert len(masks) == problem.space_size()    # no duplicates, full cover
    assert all(m & frozen == 0 for m in masks)


def test_fully_decided_spacemap_leaves_operators_noops():
    """Zero active bits (every gene frozen): mutate must return the
    genome unchanged instead of looping forever, sampling and enumeration
    collapse to the single layerwise genome."""
    from repro.core.problem import FusionProblem
    graph = big_chain(2)
    session = session_for(graph, backend="ga")
    sm = build_spacemap(graph, "default", "simba")
    all_off = SpaceMap(
        graph_name=sm.graph_name, costmodel=sm.costmodel,
        accelerator=sm.accelerator, n_edges=sm.n_edges,
        capacity_words=sm.capacity_words, capacity_how=sm.capacity_how,
        verdicts=[dataclasses.replace(v, verdict="forced_off")
                  for v in sm.verdicts], regions=[])
    assert all_off.genome_length == 0
    problem = FusionProblem(graph, session.evaluator, "edp",
                            spacemap=all_off)
    g = problem.initial()
    assert problem.mutate(g, random.Random(0)).mask == g.mask
    assert problem.random_genome(random.Random(1)).mask == 0
    assert [s.mask for s in problem.enumerate()] == [0]
    assert problem.space_size() == 1


# ---- spec / artifact serialization -----------------------------------------------


def test_spec_spacemap_default_stays_off_the_wire():
    d = SearchSpec(workload="vgg16").to_dict()
    assert "spacemap" not in d                   # store keys unchanged
    assert SearchSpec.from_dict(d).spacemap is False
    d = SearchSpec(workload="vgg16", spacemap=True).to_dict()
    assert d["spacemap"] is True
    assert SearchSpec.from_dict(d).spacemap is True


def _spacemap_artifact():
    session = session_for(mixed())
    return session, session.run()


def test_artifact_roundtrips_spacemap_summary_and_verifies():
    session, art = _spacemap_artifact()
    assert art.spacemap == session.spacemap.summary()
    rt = ScheduleArtifact.from_json(art.to_json())
    assert rt.spacemap == art.spacemap
    report = verify_artifact(rt)
    assert report.ok, report.describe()
    assert "re-derived identically" in report.check("spacemap").detail


def test_spacemap_off_artifacts_carry_no_summary_or_check():
    session = session_for(mixed(), spacemap=False)
    art = session.run()
    assert art.spacemap is None
    assert "spacemap" not in art.to_dict()
    assert verify_artifact(art).check("spacemap") is None


def test_genome_setting_a_frozen_bit_fails_verification():
    session, art = _spacemap_artifact()
    bit = session.spacemap.frozen_indices[0]
    bad = dataclasses.replace(art,
                              genome_mask=art.genome_mask | (1 << bit))
    check = verify_artifact(bad).check("spacemap")
    assert not check.ok
    assert "forced-off" in check.detail


def test_tampered_spacemap_summary_fails_verification():
    _, art = _spacemap_artifact()
    forged = dict(art.spacemap)
    forged["forced_off"] = []
    check = verify_artifact(
        dataclasses.replace(art, spacemap=forged)).check("spacemap")
    assert not check.ok
    assert "disagrees" in check.detail


def test_stripped_spacemap_summary_fails_verification():
    _, art = _spacemap_artifact()
    check = verify_artifact(
        dataclasses.replace(art, spacemap=None)).check("spacemap")
    assert not check.ok
    assert "carries no" in check.detail


# ---- engine isolation ------------------------------------------------------------


def test_spacemap_imports_neither_fusion_nor_evaluator():
    """The acceptance pin (same rule ``repro lint`` enforces through the
    pyproject boundary table): the analyzer that prunes the engine's
    search space shares no code with the engine it prunes.  Source-level
    — ``repro.core``'s package init eagerly re-exports ``fusion``, so
    *transitive* loading is unavoidable; what is banned is this module
    naming either engine module in any import statement, lazy included."""
    import repro.analysis.spacemap as spacemap
    with open(spacemap.__file__) as f:
        src = f.read()
    imports = [ln for ln in src.splitlines()
               if ln.lstrip().startswith(("import ", "from "))]
    for ln in imports:
        assert "core.fusion" not in ln, ln
        assert "core import fusion" not in ln, ln
        assert "costmodel.evaluator" not in ln, ln
        assert "costmodel import evaluator" not in ln, ln


def test_spacemap_boundary_pin_survives_a_clean_interpreter():
    """`repro analyze` must work where only the analysis surface is
    imported: a fresh interpreter builds a spacemap and re-derives the
    same summary the in-process analyzer produced."""
    code = (
        "import json, sys\n"
        "from repro.analysis.spacemap import build_spacemap\n"
        "from repro.search.registry import build_workload\n"
        "sm = build_spacemap(build_workload('vgg16'), 'default', 'simba')\n"
        "json.dump(sm.summary(), sys.stdout)\n")
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    env["PYTHONPATH"] = os.path.abspath(src)
    out = subprocess.run([sys.executable, "-c", code], check=True, env=env,
                         capture_output=True, text=True)
    import json
    from repro.search.registry import build_workload
    expect = build_spacemap(build_workload("vgg16"), "default",
                            "simba").summary()
    assert json.loads(out.stdout) == expect
