"""`repro.hw`: hierarchical hardware descriptions, catalog round-trips,
repartition invariants, and the flexible-dataflow mapper support."""
import math

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.costmodel import EYERISS, SIMBA, SIMBA2X2
from repro.costmodel.mapper import resolve_dataflow, spatial_utilization
from repro.core.graph import Layer
from repro.hw import (ALL_SPECS, ComputeArray, EYERISS_HW, FLEXNN_HW,
                      HardwareError, HardwareSpec, MemLevel, SIMBA2X2_HW,
                      SIMBA_HW, get_spec)


# ---- catalog / flat-view equivalence ----------------------------------------------

def test_table_i_specs_round_trip_to_legacy_constants():
    """The hierarchical Table-I descriptions produce exactly the flat
    machines the evaluator always costed — the refactor changes how
    machines are *expressed*, not what they cost."""
    assert EYERISS_HW.to_accelerator() == EYERISS
    assert SIMBA_HW.to_accelerator() == SIMBA
    assert SIMBA2X2_HW.to_accelerator() == SIMBA2X2


def test_catalog_has_new_machines():
    assert {"eyeriss", "simba", "simba2x2", "simba4x4", "flexnn"} <= \
        set(ALL_SPECS)
    s4 = get_spec("simba4x4")
    assert s4.compute.pe_count == 16 * SIMBA_HW.compute.pe_count
    assert s4.level("act_buf").capacity_kib == \
        16 * SIMBA_HW.level("act_buf").capacity_kib
    assert FLEXNN_HW.dataflow == "flexible"
    with pytest.raises(KeyError, match="unknown hardware spec"):
        get_spec("nope")


def test_registry_serves_catalog_machines():
    from repro.search import ACCELERATORS, build_accelerator
    for name in ALL_SPECS:
        assert name in ACCELERATORS
        assert build_accelerator(name) == ALL_SPECS[name].to_accelerator()
    flex = build_accelerator("flexnn@act+32")
    assert flex.act_buf_kib == 160 and flex.weight_buf_kib == 480


def test_spec_dict_round_trip():
    for spec in ALL_SPECS.values():
        again = HardwareSpec.from_dict(spec.to_dict())
        assert again == spec


def test_register_accelerator_accepts_positional_factory():
    """The README's 20-line example form: register(name, factory) — not
    only the decorator form."""
    from repro.search import ACCELERATORS, build_accelerator, \
        register_accelerator
    name = "test_mychip"
    if name not in ACCELERATORS:
        import dataclasses
        spec = dataclasses.replace(SIMBA_HW, name=name)
        register_accelerator(name, spec.to_accelerator)
    assert build_accelerator(name).pe_count == SIMBA_HW.compute.pe_count


def test_to_accelerator_rejects_fractional_buffer_kib():
    import dataclasses
    frac = dataclasses.replace(
        SIMBA_HW, name="frac",
        levels=tuple(
            dataclasses.replace(lv, capacity_kib=lv.capacity_kib + 0.5)
            if lv.name == "act_buf" else lv
            for lv in SIMBA_HW.levels))
    with pytest.raises(HardwareError, match="whole KiB"):
        frac.to_accelerator()
    sub = dataclasses.replace(
        SIMBA_HW, name="sub",
        levels=tuple(
            dataclasses.replace(lv, capacity_kib=0.25)
            if lv.name == "act_buf" else lv
            for lv in SIMBA_HW.levels))
    with pytest.raises(HardwareError, match="whole KiB"):
        sub.to_accelerator()


# ---- validation -------------------------------------------------------------------

def _levels(**caps):
    base = {"dram": math.inf, "weight_buf": 512, "act_buf": 64}
    base.update(caps)
    return tuple(
        MemLevel(n, c, bandwidth_gbps=128.0 if n == "dram" else 0.0)
        for n, c in base.items())


def test_spec_requires_core_levels_and_valid_dataflow():
    good = HardwareSpec("m", ComputeArray(4, 4, 8), _levels(),
                        "weight_stationary")
    assert good.to_accelerator().pe_count == 16
    with pytest.raises(HardwareError, match="missing required"):
        HardwareSpec("m", ComputeArray(4, 4, 8), good.levels[:2],
                     "weight_stationary")
    with pytest.raises(HardwareError, match="unknown dataflow"):
        HardwareSpec("m", ComputeArray(4, 4, 8), _levels(), "zigzag")
    with pytest.raises(HardwareError, match="duplicate"):
        HardwareSpec("m", ComputeArray(4, 4, 8),
                     good.levels + (MemLevel("act_buf", 8),),
                     "weight_stationary")
    with pytest.raises(HardwareError, match="positive"):
        MemLevel("act_buf", 0)
    with pytest.raises(HardwareError, match="positive"):
        ComputeArray(0, 4, 8)
    with pytest.raises(HardwareError, match="bandwidth"):
        HardwareSpec(
            "m", ComputeArray(4, 4, 8),
            (MemLevel("dram", math.inf), MemLevel("weight_buf", 512),
             MemLevel("act_buf", 64)),
            "weight_stationary")


# ---- repartition invariants (satellite) -------------------------------------------

@given(st.integers(min_value=-500, max_value=1000))
@settings(max_examples=80, deadline=None)
def test_accelerator_repartition_preserves_capacity_or_rejects(delta):
    """Fig.-11 repartitioning is iso-capacity by construction; any delta
    that would drive a buffer non-positive must be refused, everything
    else must conserve total on-chip buffer KiB."""
    total = EYERISS.act_buf_kib + EYERISS.weight_buf_kib
    if (EYERISS.act_buf_kib + delta <= 0
            or EYERISS.weight_buf_kib - delta <= 0):
        with pytest.raises(ValueError, match="positive"):
            EYERISS.repartition(delta)
    else:
        re = EYERISS.repartition(delta)
        assert re.act_buf_kib + re.weight_buf_kib == total
        assert re.act_buf_kib > 0 and re.weight_buf_kib > 0


@given(st.sampled_from(sorted(ALL_SPECS)),
       st.integers(min_value=-3000, max_value=9000))
@settings(max_examples=80, deadline=None)
def test_hwspec_repartition_preserves_capacity_or_rejects(name, delta):
    spec = ALL_SPECS[name]
    act = spec.level("act_buf").capacity_kib
    wgt = spec.level("weight_buf").capacity_kib
    if act + delta <= 0 or wgt - delta <= 0:
        with pytest.raises(HardwareError):
            spec.repartition(delta)
    else:
        re = spec.repartition(delta)
        assert re.onchip_capacity_kib == spec.onchip_capacity_kib
        assert re.level("act_buf").capacity_kib == act + delta
        assert re.level("weight_buf").capacity_kib == wgt - delta
        # the flat view agrees with the flat repartition path
        assert re.to_accelerator() == \
            spec.to_accelerator().repartition(int(delta))


# ---- flexible dataflow ------------------------------------------------------------

FLEX = FLEXNN_HW.to_accelerator()


def _conv(m=64, c=64, hw=16, k=1, groups=1, kind="conv"):
    return Layer(name="l", kind=kind, c=c, h=hw, w=hw, m=m, p=hw, q=hw,
                 r=k, s=k, padding=(k // 2, k // 2), groups=groups)


def test_fixed_machines_resolve_their_own_dataflow():
    l = _conv()
    assert resolve_dataflow(l, SIMBA) == "weight_stationary"
    assert resolve_dataflow(l, EYERISS) == "row_stationary"


def test_flexible_picks_per_layer_and_dominates_fixed():
    import dataclasses
    ws = dataclasses.replace(FLEX, dataflow="weight_stationary")
    rs = dataclasses.replace(FLEX, dataflow="row_stationary")
    # depthwise starves the C-parallel MAC lanes -> row-stationary wins
    dw = _conv(m=64, c=64, k=3, groups=64, kind="dwconv")
    assert resolve_dataflow(dw, FLEX) == "row_stationary"
    # fat pointwise conv keeps every lane busy -> weight-stationary wins
    pw = _conv(m=64, c=64, k=1)
    assert resolve_dataflow(pw, FLEX) == "weight_stationary"
    for layer in (dw, pw, _conv(m=16, c=8, k=3)):
        u_flex = spatial_utilization(layer, FLEX)
        assert u_flex == pytest.approx(
            max(spatial_utilization(layer, ws),
                spatial_utilization(layer, rs)))


def test_flexnn_search_beats_or_matches_its_fixed_dataflows():
    """End-to-end: on MobileNet-v3 (depthwise-heavy) the flexible array's
    baseline EDP is no worse than the same array frozen to either fixed
    dataflow."""
    from repro.costmodel import Evaluator
    from repro.workloads import mobilenet_v3_large
    import dataclasses
    g = mobilenet_v3_large()
    edp = {}
    for df in ("flexible", "weight_stationary", "row_stationary"):
        acc = dataclasses.replace(FLEX, dataflow=df)
        edp[df] = Evaluator(g, acc).layerwise().edp
    assert edp["flexible"] <= edp["weight_stationary"] * (1 + 1e-12)
    assert edp["flexible"] <= edp["row_stationary"] * (1 + 1e-12)
