"""The perf-canary compare step (`benchmarks/compare.py`): pass/fail
thresholds, metric addressing, and error handling."""
import json
import sys

import pytest

sys.path.insert(0, ".")                      # benchmarks/ is not a package dir
from benchmarks.compare import compare, main  # noqa: E402


def _report(path, evals_per_sec, name="ga_convergence"):
    path.write_text(json.dumps({
        "meta": {}, "rows": [],
        "records": [{"name": name, "evals_per_sec": evals_per_sec,
                     "wall_s": 1.0}],
    }))
    return str(path)


def test_within_window_passes(tmp_path):
    base = _report(tmp_path / "base.json", 10000.0)
    now = _report(tmp_path / "now.json", 7500.0)      # -25% < 30% window
    res = compare(base, now)
    assert res["ok"] and res["change_frac"] == pytest.approx(-0.25)
    assert main([base, now]) == 0


def test_regression_beyond_window_fails(tmp_path):
    base = _report(tmp_path / "base.json", 10000.0)
    now = _report(tmp_path / "now.json", 6500.0)      # -35% > 30% window
    assert not compare(base, now)["ok"]
    assert main([base, now]) == 1
    # a wider window from the CLI lets it through
    assert main([base, now, "--max-regression", "0.5"]) == 0


def test_improvement_always_passes(tmp_path):
    base = _report(tmp_path / "base.json", 10000.0)
    now = _report(tmp_path / "now.json", 25000.0)
    assert compare(base, now)["ok"]


def test_lower_is_better_flips_direction(tmp_path):
    base = _report(tmp_path / "base.json", 1.0)
    now = _report(tmp_path / "now.json", 1.5)         # +50% wall time
    assert compare(base, now)["ok"]                   # higher-is-better: fine
    assert not compare(base, now, lower_is_better=True)["ok"]


def test_missing_record_or_field_is_a_clean_error(tmp_path):
    base = _report(tmp_path / "base.json", 10000.0)
    other = _report(tmp_path / "other.json", 1.0, name="kernels")
    assert main([base, other]) == 2
    assert main([base, other, "--metric", "ga_convergence"]) == 2  # no field
    with pytest.raises(KeyError, match="no record named"):
        compare(base, other)
    with pytest.raises(KeyError, match="no field"):
        compare(base, base, metric="ga_convergence:flops")


def test_committed_baseline_is_loadable_and_self_consistent():
    """BENCH_ga.json (the committed canary baseline) must stay parseable
    and compare clean against itself."""
    res = compare("BENCH_ga.json", "BENCH_ga.json")
    assert res["ok"] and res["change_frac"] == 0.0
    assert res["baseline"] > 0
