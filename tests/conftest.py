"""Test-suite bootstrap: a minimal ``hypothesis`` shim.

Several test modules use hypothesis property tests.  When the real package is
installed (see ``requirements-dev.txt``) this file does nothing.  When it is
absent (the CI container does not bake it in), we install a tiny deterministic
stand-in into ``sys.modules`` *before* test collection so the suite still
collects and the property tests still execute: each ``@given`` test runs
against a fixed number of pseudo-random examples drawn from seeded
``random.Random`` streams.

The shim implements exactly the strategy surface the suite uses —
``integers``, ``sampled_from``, ``booleans``, ``composite`` — plus
``given``/``settings``.  It does no shrinking and no database; it is a
degraded-but-honest fallback, not a hypothesis replacement.
"""
from __future__ import annotations

try:                                     # real hypothesis wins when present
    import hypothesis  # noqa: F401
except ImportError:
    import random
    import sys
    import types

    _MAX_EXAMPLES = 25                   # keep the fallback suite fast

    class _Strategy:
        """A sampling function ``rng -> value``."""

        def __init__(self, sample):
            self._sample = sample

    def integers(min_value=0, max_value=1 << 32):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    def sampled_from(elements):
        xs = list(elements)
        return _Strategy(lambda r: xs[r.randrange(len(xs))])

    def booleans():
        return _Strategy(lambda r: r.random() < 0.5)

    def composite(fn):
        def builder(*args, **kwargs):
            def sample(r):
                draw = lambda st: st._sample(r)     # noqa: E731
                return fn(draw, *args, **kwargs)
            return _Strategy(sample)
        return builder

    def given(*arg_strategies, **kw_strategies):
        def decorate(fn):
            def runner():
                n = getattr(runner, "_max_examples", _MAX_EXAMPLES)
                for case in range(n):
                    r = random.Random(0xC0FFEE + case)
                    vals = [s._sample(r) for s in arg_strategies]
                    kvals = {k: s._sample(r)
                             for k, s in kw_strategies.items()}
                    fn(*vals, **kvals)
            # copy identity by hand: functools.wraps would set __wrapped__,
            # which makes pytest read fn's signature and hunt for fixtures
            # named after the strategy-provided parameters
            runner.__name__ = fn.__name__
            runner.__qualname__ = fn.__qualname__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            runner.__dict__.update(fn.__dict__)
            return runner
        return decorate

    def settings(max_examples=_MAX_EXAMPLES, **_ignored):
        def decorate(fn):
            fn._max_examples = min(max_examples, _MAX_EXAMPLES)
            return fn
        return decorate

    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = integers
    _st.sampled_from = sampled_from
    _st.booleans = booleans
    _st.composite = composite

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = given
    _hyp.settings = settings
    _hyp.strategies = _st
    _hyp.__shim__ = True

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
