"""MoE layer: both dispatch implementations vs the dense oracle, capacity
semantics, gradients, load-balance loss."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models.moe import moe_apply, moe_init, moe_ref


def _cfg(**kw):
    base = dict(name="t", family="moe", n_layers=1, d_model=16, n_heads=2,
                n_kv_heads=1, d_ff=32, vocab=64, n_experts=4, top_k=2,
                capacity_factor=16.0)
    base.update(kw)
    return ModelConfig(**base)


@pytest.mark.parametrize("impl", ["a2a", "global"])
@pytest.mark.parametrize("topk,shared", [(2, 0), (1, 1), (4, 0)])
def test_moe_matches_dense_oracle(impl, topk, shared):
    cfg = _cfg(moe_impl=impl, top_k=topk, n_shared_experts=shared)
    p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    y, aux = moe_apply(p, x, cfg)
    yr = moe_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-5,
                               rtol=2e-5)
    assert float(aux) > 0


def test_a2a_and_global_agree():
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, 16))
    p = moe_init(jax.random.PRNGKey(0), _cfg(), jnp.float32)
    ya, _ = moe_apply(p, x, _cfg(moe_impl="a2a"))
    yg, _ = moe_apply(p, x, _cfg(moe_impl="global"))
    np.testing.assert_allclose(np.asarray(ya), np.asarray(yg), atol=2e-5,
                               rtol=2e-5)


@pytest.mark.parametrize("impl", ["a2a", "global"])
def test_capacity_drops_are_deterministic_and_finite(impl):
    cfg = _cfg(moe_impl=impl, capacity_factor=0.5)   # force drops
    p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 32, 16))
    y1, _ = moe_apply(p, x, cfg)
    y2, _ = moe_apply(p, x, cfg)
    assert bool(jnp.isfinite(y1).all())
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    # with drops, output differs from the no-drop oracle for some tokens
    yr = moe_ref(p, x, cfg)
    assert float(jnp.abs(y1 - yr).max()) > 1e-4


@pytest.mark.parametrize("impl", ["a2a", "global"])
def test_moe_gradients_flow_to_all_param_groups(impl):
    cfg = _cfg(moe_impl=impl, n_shared_experts=1)
    p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 16, 16))

    def loss(p_):
        y, aux = moe_apply(p_, x, cfg)
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(p)
    for path, leaf in jax.tree_util.tree_flatten_with_path(g)[0]:
        assert bool(jnp.isfinite(leaf).all()), path
        assert float(jnp.abs(leaf).max()) > 0, f"dead gradient at {path}"
