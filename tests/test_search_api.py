"""The `repro.search` facade: registries, spec/artifact round-trips,
backend sanity, and compatibility with the pre-facade entry points."""
import json
import subprocess
import sys

import pytest

from repro.core import GAConfig, optimize
from repro.core.fusion import FusionState
from repro.core.ga import run_ga
from repro.costmodel import SIMBA, Evaluator
from repro.search import (BACKENDS, OBJECTIVES, WORKLOADS, BackendError,
                          FingerprintMismatch, RegistryError,
                          ScheduleArtifact, SearchSession, SearchSpec,
                          build_accelerator, graph_fingerprint,
                          register_objective, search)
from repro.workloads import mobilenet_v3_large
from tests.test_fusion import chain, skip_graph
from tests.test_ga import brute_force_best


# ---- registries -------------------------------------------------------------------

def test_registry_unknown_name_lists_valid():
    with pytest.raises(RegistryError) as e:
        WORKLOADS.get("nope")
    msg = str(e.value)
    assert "nope" in msg and "mobilenet_v3" in msg and "vgg16" in msg


def test_registry_duplicate_requires_replace():
    with pytest.raises(RegistryError):
        WORKLOADS.register("mobilenet_v3", mobilenet_v3_large)
    WORKLOADS.register("mobilenet_v3", mobilenet_v3_large, replace=True)


def test_register_decorator_and_custom_objective():
    name = "test_ed2_objective"
    if name not in OBJECTIVES:
        @register_objective(name)
        def ed2(cost):
            return cost.energy_pj * cost.cycles ** 2
    art = search("mobilenet_v3", "simba", objective=name, backend="ga",
                 backend_config={"preset": "fast", "generations": 3}, seed=0)
    assert art.best_fitness >= 1.0
    assert art.spec.objective == name


def test_accelerator_repartition_spec():
    acc = build_accelerator("eyeriss@act+64")
    assert acc.act_buf_kib == 192 and acc.weight_buf_kib == 448
    acc = build_accelerator("eyeriss@act-32")
    assert acc.act_buf_kib == 96 and acc.weight_buf_kib == 544
    with pytest.raises(RegistryError):
        build_accelerator("notanarch@act+64")


# ---- spec -------------------------------------------------------------------------

def test_spec_json_round_trip():
    spec = SearchSpec(workload="resnet50", accelerator="eyeriss@act+64",
                      backend="hill_climb", backend_config={"max_steps": 5},
                      seed=3, budget=1000, patience=7)
    again = SearchSpec.from_json(spec.to_json())
    assert again == spec


def test_spec_rejects_unknown_fields():
    with pytest.raises(ValueError, match="unknown SearchSpec fields"):
        SearchSpec.from_dict({"workload": "resnet50", "turbo": True})


# ---- artifact ---------------------------------------------------------------------

def test_artifact_json_round_trip(tmp_path):
    art = search("mobilenet_v3", "simba", backend="ga", seed=0,
                 backend_config={"preset": "fast", "generations": 5})
    path = tmp_path / "a.json"
    art.save(str(path))
    loaded = ScheduleArtifact.load(str(path))
    assert loaded.genome_mask == art.genome_mask
    assert loaded.graph_fingerprint == art.graph_fingerprint
    assert loaded.best_fitness == art.best_fitness
    assert loaded.best.edp == art.best.edp
    assert loaded.baseline.edp == art.baseline.edp
    assert loaded.history == art.history
    assert loaded.spec == art.spec
    # genome re-binds onto a freshly built graph, no re-search
    state = loaded.rebuild_state()
    assert state.mask == art.genome_mask
    assert state.is_schedulable()


def test_artifact_fingerprint_mismatch_rejected():
    art = search("mobilenet_v3", "simba", backend="ga", seed=0,
                 backend_config={"preset": "fast", "generations": 2})
    with pytest.raises(FingerprintMismatch):
        art.state(chain(5))
    # same builder, different kwargs -> structurally different graph
    from repro.workloads import unet
    art_u = search("unet", "simba", backend="random", seed=0,
                   backend_config={"evaluations": 10})
    with pytest.raises(FingerprintMismatch):
        art_u.state(unet(hw=128))
    assert art_u.state(unet()).mask == art_u.genome_mask


def test_artifact_version_gate():
    art = search("mobilenet_v3", "simba", backend="random", seed=0,
                 backend_config={"evaluations": 5})
    d = art.to_dict()
    d["version"] = 999
    with pytest.raises(ValueError, match="version"):
        ScheduleArtifact.from_dict(d)


def test_fingerprint_is_structural():
    g1, g2 = mobilenet_v3_large(), mobilenet_v3_large()
    assert graph_fingerprint(g1) == graph_fingerprint(g2)
    assert graph_fingerprint(g1) != graph_fingerprint(chain(4))


# ---- backends ---------------------------------------------------------------------

def test_cross_backend_sanity_fixed_seed():
    """ga >= random >= baseline on MobileNet-v3 / SIMBA (GAConfig.fast)."""
    ga = search("mobilenet_v3", "simba", backend="ga", seed=0,
                backend_config={"preset": "fast", "generations": 25})
    rnd = search("mobilenet_v3", "simba", backend="random", seed=0,
                 backend_config={"evaluations": 500})
    assert ga.best_fitness >= rnd.best_fitness >= 1.0
    assert ga.edp_improvement > 1.2          # matches pre-facade GA quality


def test_exhaustive_matches_brute_force_on_small_graphs():
    for g in (chain(5), skip_graph()):
        ev = Evaluator(g, SIMBA)
        bf_f, _ = brute_force_best(g, ev)
        session = SearchSession.from_objects(g, SIMBA, backend="exhaustive")
        art = session.run()
        assert art.best_fitness == pytest.approx(bf_f, rel=1e-9)


def test_hill_climb_beats_baseline_and_is_monotone():
    session = SearchSession.from_objects(chain(6), SIMBA,
                                         backend="hill_climb")
    art = session.run()
    assert art.best_fitness >= 1.0
    h = art.history
    assert all(b >= a for a, b in zip(h, h[1:]))


def test_exhaustive_refuses_oversized_space():
    with pytest.raises(BackendError, match="exceeds the exhaustive limit"):
        search("mobilenet_v3", "simba", backend="exhaustive")


def test_exhaustive_guard_names_the_exact_limit_to_pass():
    """The guard error must hand the user the exact ``limit=`` that makes
    the run go (VGG-16: 21 fusion edges -> 2^21 states, not the paper's
    2^16 over conv layers)."""
    from repro.workloads import vgg16
    n_edges = vgg16().compiled().m
    size = 1 << n_edges
    with pytest.raises(BackendError) as e:
        search("vgg16", "simba", backend="exhaustive")
    msg = str(e.value)
    assert f"limit={size}" in msg
    assert f'{{"limit": {size}}}' in msg        # copy-pasteable config form
    # and passing that limit actually runs (budget keeps the test cheap)
    art = search("vgg16", "simba", backend="exhaustive", budget=256,
                 backend_config={"limit": size})
    assert art.best_fitness >= 1.0


def test_tpu_search_accepts_ga_backend_config():
    from repro.configs import get_config
    from repro.configs.base import SHAPES
    from repro.search.tpu import search_tpu_schedule
    res = search_tpu_schedule(
        get_config("stablelm-1.6b"), SHAPES["train_4k"], backend="ga",
        backend_config={"preset": "fast", "generations": 5})
    assert res.best_cost.edp <= res.baseline_cost.edp


def test_backend_rejects_unknown_config_keys():
    with pytest.raises(BackendError, match="unknown backend config"):
        search("mobilenet_v3", "simba", backend="random",
               backend_config={"evals": 5})
    with pytest.raises(BackendError, match="preset"):
        search("mobilenet_v3", "simba", backend="ga",
               backend_config={"preset": "warp"})


def test_session_rejects_seed_in_backend_config():
    with pytest.raises(BackendError, match="SearchSpec.seed"):
        SearchSession(SearchSpec(workload="mobilenet_v3",
                                 backend_config={"seed": 1}))
    with pytest.raises(BackendError, match="SearchSpec.objective"):
        search("mobilenet_v3", backend="ga",
               backend_config={"objective": "energy"})
    with pytest.raises(BackendError, match="conflicts with"):
        search("mobilenet_v3", backend="ga",
               backend_config={"ga_config": {"objective": "energy"}})
    with pytest.raises(BackendError, match="bad ga_config"):
        search("mobilenet_v3", backend="ga",
               backend_config={"ga_config": {"typo": 5}})
    with pytest.raises(BackendError, match="must be a GAConfig"):
        search("mobilenet_v3", backend="ga",
               backend_config={"ga_config": 5})


def test_ga_config_dict_honors_spec_seed():
    """A seed-less ga_config dict (JSON form) inherits SearchSpec.seed."""
    import dataclasses
    cfg = dataclasses.asdict(GAConfig.fast(generations=5))
    del cfg["seed"]
    arts = [search("mobilenet_v3", backend="ga", seed=s,
                   backend_config={"ga_config": dict(cfg)})
            for s in (0, 3)]
    assert arts[0].genome_mask != arts[1].genome_mask


# ---- session hooks ----------------------------------------------------------------

def test_session_budget_stops_early():
    spec = SearchSpec(workload="mobilenet_v3", accelerator="simba",
                      backend="ga", budget=200,
                      backend_config={"preset": "fast", "generations": 50})
    art = SearchSession(spec).run()
    # one generation of GAConfig.fast is 40 offspring (+ top-ups): the budget
    # must cut the run far below 50 generations' worth
    assert art.offspring_evaluated <= 400
    assert len(art.history) < 50


def test_session_progress_hook_sees_every_generation():
    ticks = []
    spec = SearchSpec(workload="mobilenet_v3", accelerator="simba",
                      backend="ga",
                      backend_config={"preset": "fast", "generations": 4})
    SearchSession(spec).run(progress=ticks.append)
    assert [t.step for t in ticks] == [0, 1, 2, 3]
    assert ticks[-1].best_fitness >= 1.0


def test_session_patience_stops_on_plateau():
    spec = SearchSpec(workload="mobilenet_v3", accelerator="simba",
                      backend="ga", patience=3,
                      backend_config={"preset": "fast", "generations": 200})
    art = SearchSession(spec).run()
    assert art.best_fitness >= 1.0
    assert len(art.history) < 200    # plateau cut the run well short


# ---- compatibility with pre-facade entry points -----------------------------------

def test_fixed_seed_search_pinned_across_cost_refactor():
    """The default cost path is pinned bit-for-bit to the pre-protocol
    evaluator: this exact genome/fitness/ScheduleCost was captured on the
    monolithic evaluator (MobileNet-v3 / SIMBA, GAConfig.fast, 10 gens,
    seed 0) immediately before the CostModel refactor.  If this test
    moves, the cost refactor changed the numbers — that is a bug, not a
    baseline to update."""
    art = search("mobilenet_v3", "simba", backend="ga", seed=0,
                 backend_config={"preset": "fast", "generations": 10})
    assert art.genome_mask == 0x201001041010040240204cb6
    assert art.best_fitness == pytest.approx(1.2652706202341535, rel=1e-12)
    best, base = art.best, art.baseline
    assert best.energy_pj == pytest.approx(1755041471.5753305, rel=1e-12)
    assert best.cycles == pytest.approx(1624290.35, rel=1e-12)
    assert (best.dram_read_words, best.dram_write_words) == (9325910, 3133582)
    assert (best.act_write_events, best.n_groups) == (74, 74)
    assert base.energy_pj == pytest.approx(2217672703.57533, rel=1e-12)
    assert base.cycles == pytest.approx(1626436.1562500002, rel=1e-12)
    assert (base.dram_read_words, base.dram_write_words) == \
        (11625270, 5432942)


def test_optimize_shim_matches_direct_ga_run():
    """core.schedule.optimize routes through repro.search and stays
    bit-identical to driving run_ga directly (fixed seed)."""
    g = mobilenet_v3_large()
    cfg = GAConfig.fast(generations=10, seed=0)
    direct = run_ga(g, Evaluator(g, SIMBA), cfg)
    shim = optimize(g, SIMBA, cfg)
    assert shim.best_state.mask == direct.best_state.mask
    assert shim.ga.best_fitness == direct.best_fitness
    assert shim.ga.history == direct.history


def test_artifact_reproduces_search_edp_without_rerun(tmp_path):
    """The acceptance flow: search -> artifact -> report-side reload gives
    the same best EDP with no re-search."""
    art = search("mobilenet_v3", "simba", backend="ga", seed=0,
                 backend_config={"preset": "fast", "generations": 10})
    path = tmp_path / "a.json"
    art.save(str(path))
    loaded = ScheduleArtifact.load(str(path))
    # the stored cost alone reproduces the EDP...
    assert loaded.best.edp == art.best.edp
    # ...and re-costing the stored genome on a rebuilt evaluator agrees
    state = loaded.rebuild_state()
    recosted = Evaluator(state.graph, SIMBA).evaluate(state)
    assert recosted.edp == pytest.approx(loaded.best.edp, rel=1e-12)


# ---- CLI --------------------------------------------------------------------------

def test_cli_search_then_report(tmp_path):
    from repro.__main__ import main
    out = tmp_path / "cli.json"
    rc = main(["search", "--workload", "mobilenet_v3", "--accel", "simba",
               "--backend", "ga", "--preset", "fast", "--generations", "3",
               "--out", str(out)])
    assert rc == 0 and out.exists()
    assert main(["report", str(out)]) == 0
    assert main(["report", str(out), "--schedule", "--history"]) == 0
    assert main(["search", "--workload", "nope", "--out", str(out)]) == 2
    assert main(["report", str(tmp_path / "missing.json")]) == 2


def test_cli_module_invocation(tmp_path):
    out = tmp_path / "m.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro", "search", "--workload",
         "mobilenet_v3", "--backend", "random", "--backend-config",
         '{"evaluations": 20}', "--out", str(out)],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stderr
    data = json.loads(out.read_text())
    assert data["spec"]["workload"] == "mobilenet_v3"
    assert int(data["genome_mask"], 16) >= 0
