"""Fusion states: grouping, schedulability, DRAM residency, mutation."""
import random

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.fusion import FusionState
from repro.core.graph import Layer, LayerGraph


def chain(n=4):
    """input -> c0 -> c1 -> ... -> c{n-1}"""
    g = LayerGraph("chain")
    prev = g.add(Layer(name="input", kind="input", m=8, p=16, q=16))
    for i in range(n):
        prev = g.add(Layer(name=f"c{i}", kind="conv", c=8, h=16, w=16,
                           m=8, p=16, q=16, r=3, s=3, padding=(1, 1)),
                     [prev])
    return g


def skip_graph():
    """input -> a -> b -> add(a_out, b_out) pattern (residual)."""
    g = LayerGraph("skip")
    i = g.add(Layer(name="input", kind="input", m=8, p=16, q=16))
    a = g.add(Layer(name="a", kind="conv", c=8, h=16, w=16, m=8, p=16, q=16,
                    r=3, s=3, padding=(1, 1)), [i])
    b = g.add(Layer(name="b", kind="conv", c=8, h=16, w=16, m=8, p=16, q=16,
                    r=3, s=3, padding=(1, 1)), [a])
    g.add(Layer(name="add", kind="add", c=8, h=16, w=16, m=8, p=16, q=16),
          [a, b])
    return g


def test_layerwise_all_singletons():
    g = chain(4)
    s = FusionState.layerwise(g)
    assert len(s.groups()) == len(g.names)
    assert s.is_schedulable()


def test_fully_fused_single_group():
    g = chain(4)
    s = FusionState.fully_fused(g)
    assert len(s.groups()) == 1
    assert s.is_schedulable()


def test_combine_separate_roundtrip():
    g = chain(4)
    s = FusionState.layerwise(g)
    e = ("c0", "c1")
    s2 = s.combine(e)
    assert s2.group_of("c0") == s2.group_of("c1")
    s3 = s2.separate(e)
    assert s3.fused == s.fused


def test_unschedulable_skip_fusion_detected():
    # fusing a->add (the skip) while splitting a->b and b->add makes
    # group{a,add} <-> group{b} cyclic in the condensation
    g = skip_graph()
    s = FusionState(g, frozenset({("a", "add")}))
    assert not s.is_schedulable()
    # fusing the whole residual block is fine
    s2 = FusionState(g, frozenset({("a", "b"), ("b", "add"), ("a", "add")}))
    assert s2.is_schedulable()
    assert len(s2.groups()) == 2  # {input}, {a,b,add}


def test_tensor_offchip_partial_consumers():
    g = skip_graph()
    # fuse a->b only: a's output still consumed by add (other group) => offchip
    s = FusionState(g, frozenset({("a", "b")}))
    assert s.tensor_offchip("a")
    assert s.tensor_offchip("b")   # b -> add crosses groups
    s2 = FusionState(g, frozenset({("a", "b"), ("b", "add"), ("a", "add")}))
    assert not s2.tensor_offchip("a")
    assert not s2.tensor_offchip("b")
    assert s2.tensor_offchip("add")  # model output


def test_group_schedule_respects_dependencies():
    g = skip_graph()
    s = FusionState(g, frozenset({("a", "b")}))
    sched = s.group_schedule(random.Random(0))
    flat = [n for grp in sched for n in grp]
    pos = {n: i for i, n in enumerate(flat)}
    for u, v in g.edges:
        assert pos[u] < pos[v]


@given(st.integers(min_value=0, max_value=10_000))
@settings(max_examples=50, deadline=None)
def test_mutation_preserves_genome_validity(seed):
    g = skip_graph()
    rng = random.Random(seed)
    s = FusionState.layerwise(g)
    for _ in range(12):
        s = s.mutate(rng)
        assert s.fused <= set(g.edges)
        # groups partition the node set
        nodes = [n for grp in s.groups() for n in grp]
        assert sorted(nodes) == sorted(g.names)


def test_mutate_is_single_edge_flip():
    g = chain(5)
    rng = random.Random(3)
    s = FusionState.layerwise(g)
    s2 = s.mutate(rng)
    assert len(s2.fused ^ s.fused) == 1
