"""End-to-end behaviour tests for the paper's system.

Covers: the paper's GA scheduling pipeline on a real workload; distributed
training (loop + checkpoint/restart exactly-once semantics + failure
injection); sharded-vs-single equivalence (subprocess with 8 fake devices);
batched serving consistency.
"""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import FusionState, GAConfig, optimize
from repro.costmodel import SIMBA, Evaluator
from repro.launch.train import TrainRunConfig, train_loop
from repro.models import transformer as T
from repro.runtime import FaultInjector
from repro.workloads import mobilenet_v3_large


# ---- the paper's pipeline ---------------------------------------------------------

def test_paper_pipeline_end_to_end():
    g = mobilenet_v3_large()
    res = optimize(g, SIMBA, GAConfig.fast(generations=25, seed=0))
    assert res.edp_improvement > 1.2
    assert res.energy_improvement > 1.2
    # the best schedule is coherent: every layer appears exactly once
    sched = res.best_state.group_schedule()
    flat = [n for grp in sched for n in grp]
    assert sorted(flat) == sorted(g.names)
    # fewer DRAM activation writes than layerwise (paper Fig. 9 claim shape)
    assert res.best.act_write_events < res.baseline.act_write_events


# ---- training + fault tolerance ----------------------------------------------------

def _tiny_run(tmp_path, name, **kw):
    cfg = dataclasses.replace(get_reduced("stablelm-1.6b"),
                              param_dtype="float32")
    defaults = dict(cfg=cfg, steps=24, global_batch=4, seq_len=32, lr=2e-3,
                    save_every=8, log_every=100,
                    ckpt_dir=os.path.join(str(tmp_path), name))
    defaults.update(kw)
    return TrainRunConfig(**defaults)


def test_training_learns(tmp_path):
    run = _tiny_run(tmp_path, "learn", steps=60, global_batch=8, seq_len=64,
                    lr=3e-3, ckpt_dir=None, log_every=20)
    out = train_loop(run, log=lambda *a: None)
    h = out["history"]["loss"]
    assert h[-1] < h[0] - 0.7, f"no learning: {h}"


def test_restart_equivalence_after_injected_failure(tmp_path):
    """A crash + restore run must produce the same final params as an
    uninterrupted run (checkpoint integrity + exactly-once data)."""
    run_a = _tiny_run(tmp_path, "a")
    out_a = train_loop(run_a, log=lambda *a: None)

    run_b = _tiny_run(tmp_path, "b")
    inj = FaultInjector(fail_at_steps=[13])
    out_b = train_loop(run_b, injector=inj, log=lambda *a: None)
    assert out_b["restarts"] == 1
    assert inj.fired == [13]

    pa = jax.tree.leaves(out_a["state"]["params"])
    pb = jax.tree.leaves(out_b["state"]["params"])
    for a, b in zip(pa, pb):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-5)


def test_grad_compression_training_still_learns(tmp_path):
    run = _tiny_run(tmp_path, "gc", steps=60, global_batch=8, seq_len=64,
                    lr=3e-3, grad_compression=True, ckpt_dir=None)
    out = train_loop(run, log=lambda *a: None)
    h = out["history"]["loss"]
    assert h[-1] < h[0] - 0.6, f"compressed run failed to learn: {h}"


def test_microbatched_matches_full_batch():
    cfg = dataclasses.replace(get_reduced("qwen2-7b"), param_dtype="float32")
    base = TrainRunConfig(cfg=cfg, steps=6, global_batch=8, seq_len=32,
                          lr=1e-3, log_every=1)
    out1 = train_loop(base, log=lambda *a: None)
    out2 = train_loop(dataclasses.replace(base, microbatches=4),
                      log=lambda *a: None)
    np.testing.assert_allclose(out1["history"]["loss"],
                               out2["history"]["loss"], rtol=2e-4, atol=2e-4)


# (Formerly xfailed on jax 0.4.37: the legacy non-partitionable threefry
# lowering made `jax.random` param init differ under sharded out_shardings,
# so sharded losses drifted ~5% from single-device.  Root cause audited and
# fixed: repro.models.common.use_mesh now enables
# jax_threefry_partitionable, version-aware — see
# ensure_sharding_invariant_rng().)
def test_sharded_training_matches_single_device():
    """DP(2) x TP(4) on 8 fake CPU devices == single device (subprocess so
    the device-count flag never leaks into this test process)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    script = os.path.join(os.path.dirname(__file__), "helpers",
                          "sharded_train_check.py")
    res = subprocess.run([sys.executable, script], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert "SHARDED_MATCHES_SINGLE" in res.stdout, \
        f"stdout:\n{res.stdout}\nstderr:\n{res.stderr[-3000:]}"


def test_elastic_remesh_restore_on_different_topology():
    """Crash on a (2,4) mesh, resume the same run on (4,2), match the
    uninterrupted oracle — checkpoints are mesh-agnostic (elastic scaling)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    script = os.path.join(os.path.dirname(__file__), "helpers",
                          "elastic_remesh_check.py")
    res = subprocess.run([sys.executable, script], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert "ELASTIC_REMESH_OK" in res.stdout, \
        f"stdout:\n{res.stdout}\nstderr:\n{res.stderr[-3000:]}"


# ---- serving ---------------------------------------------------------------------------

def test_batched_greedy_decode_matches_forward():
    cfg = dataclasses.replace(get_reduced("qwen2-7b"),
                              param_dtype="float32")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    B, S, gen = 4, 12, 4
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)

    # serve path: prefill + greedy decode
    logits, caches, enc_kv = T.prefill(params, cfg, {"tokens": toks},
                                       max_len=S + gen,
                                       cache_dtype=jnp.float32)
    out_tokens = []
    cur = jnp.argmax(logits[:, 0], axis=-1)[:, None]
    for i in range(gen):
        out_tokens.append(cur)
        lg, caches = T.decode_step(params, cfg, cur, jnp.int32(S + i),
                                   caches, enc_kv=enc_kv)
        cur = jnp.argmax(lg[:, 0], axis=-1)[:, None]
    served = jnp.concatenate(out_tokens, axis=1)

    # oracle: forward over the full (prompt + generated) sequence
    full = jnp.concatenate([toks, served], axis=1)
    flogits, _ = T.forward(params, cfg, {"tokens": full})
    for i in range(gen):
        expect = jnp.argmax(flogits[:, S - 1 + i], axis=-1)
        np.testing.assert_array_equal(np.asarray(served[:, i]),
                                      np.asarray(expect))
