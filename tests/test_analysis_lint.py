"""repro.analysis.lint: rule detection on synthetic modules, allowlist
semantics (match / stale / malformed), the pyproject mini-parser, and the
gate the CI job runs — src/repro is clean under the repo allowlist."""
import textwrap

from repro.analysis.lint import (RULES, check_boundaries,
                                 check_clock_seam, lint_file,
                                 load_pyproject_allow,
                                 load_pyproject_boundaries,
                                 load_pyproject_clock_seam,
                                 parse_allow_entries, run_lint)


def _lint(tmp_path, source, name="mod.py"):
    p = tmp_path / name
    p.write_text(textwrap.dedent(source))
    return lint_file(str(p), name)


def _rules(findings):
    return sorted((f.rule, f.symbol) for f in findings)


# ---- global-random ---------------------------------------------------------------


def test_global_random_module_calls_flagged(tmp_path):
    found = _lint(tmp_path, """\
        import random
        import numpy as np
        random.seed(0)
        x = random.randint(0, 7)
        y = np.random.rand(3)
    """)
    assert ("global-random", "random.seed") in _rules(found)
    assert ("global-random", "random.randint") in _rules(found)
    assert ("global-random", "numpy.random.rand") in _rules(found)


def test_seeded_constructors_are_not_flagged(tmp_path):
    found = _lint(tmp_path, """\
        import random
        import numpy as np
        rng = random.Random(0)
        g = np.random.default_rng(0)
        legacy = np.random.RandomState(0)
        x = rng.randint(0, 7) + g.integers(0, 7)
    """)
    assert found == []


def test_from_import_of_random_function_flagged(tmp_path):
    found = _lint(tmp_path, "from random import randint\n")
    assert _rules(found) == [("global-random", "random.randint")]


# ---- wall-clock ------------------------------------------------------------------


def test_wall_clock_sources_flagged(tmp_path):
    found = _lint(tmp_path, """\
        import os
        import time
        import uuid
        from datetime import datetime
        a = time.time()
        b = time.time_ns()
        c = datetime.now()
        d = os.urandom(16)
        e = uuid.uuid4()
    """)
    rules = _rules(found)
    for sym in ("time.time", "time.time_ns", "datetime.now", "os.urandom",
                "uuid.uuid4"):
        assert ("wall-clock", sym) in rules


def test_monotonic_clocks_are_fine(tmp_path):
    found = _lint(tmp_path, """\
        import time
        t0 = time.perf_counter()
        t1 = time.monotonic()
    """)
    assert found == []


# ---- unordered-iter --------------------------------------------------------------


def test_iteration_over_set_flagged(tmp_path):
    found = _lint(tmp_path, """\
        import os
        for x in {1, 2, 3}:
            pass
        ys = [y for y in set(range(4))]
        zs = list(os.listdir("."))
        for z in os.listdir("."):
            pass
    """)
    rules = [f.rule for f in found]
    assert rules.count("unordered-iter") == 3  # zs=list(...) is not iter'd


def test_sorted_wrapper_is_fine(tmp_path):
    found = _lint(tmp_path, """\
        import os
        for x in sorted({3, 1, 2}):
            pass
        for p in sorted(os.listdir(".")):
            pass
    """)
    assert found == []


# ---- mutable-default -------------------------------------------------------------


def test_mutable_defaults_flagged(tmp_path):
    found = _lint(tmp_path, """\
        def f(xs=[]):
            return xs
        def g(*, opts={}):
            return opts
        def h(s=set()):
            return s
        def ok(xs=None, n=3, t=()):
            return xs
    """)
    assert [f.symbol for f in found
            if f.rule == "mutable-default"] == ["f", "g", "h"]


# ---- parse errors are loud and unallowlistable -----------------------------------


def test_syntax_error_reported_not_swallowed(tmp_path):
    found = _lint(tmp_path, "def broken(:\n")
    assert len(found) == 1
    assert found[0].rule == "parse-error"
    assert "parse-error" not in RULES  # cannot be allowlisted


# ---- allowlist semantics ---------------------------------------------------------


def test_allow_entry_suppresses_exact_match(tmp_path):
    (tmp_path / "src" / "repro" / "core").mkdir(parents=True)
    mod = tmp_path / "src" / "repro" / "core" / "clocky.py"
    mod.write_text("import time\nT = time.time()\n")
    allow = ["src/repro/core/clocky.py::wall-clock::time.time::"
             "test fixture; value is discarded"]
    findings = run_lint(str(tmp_path), allow_raw=allow)
    assert findings == []


def test_unused_allow_entry_is_stale(tmp_path):
    (tmp_path / "src" / "repro" / "core").mkdir(parents=True)
    (tmp_path / "src" / "repro" / "core" / "clean.py").write_text("x = 1\n")
    findings = run_lint(str(tmp_path), allow_raw=[
        "src/repro/core/gone.py::wall-clock::time.time::was needed once"])
    assert [f.rule for f in findings] == ["stale-allow"]
    assert "gone.py" in findings[0].message


def test_malformed_allow_entries_are_bad(tmp_path):
    (tmp_path / "src" / "repro" / "core").mkdir(parents=True)
    (tmp_path / "src" / "repro" / "core" / "clean.py").write_text("x = 1\n")
    findings = run_lint(str(tmp_path), allow_raw=[
        "only::three::fields",                          # wrong arity
        "a.py::wall-clock::time.time::",                # empty justification
        "a.py::no-such-rule::x::because",               # unknown rule
    ])
    assert [f.rule for f in findings] == ["bad-allow"] * 3


def test_parse_allow_entries_roundtrip():
    entries, bad = parse_allow_entries(
        ["src/a.py::wall-clock::time.time::logging timestamps only"])
    assert bad == []
    (e,) = entries
    assert (e.path, e.rule, e.symbol) == ("src/a.py", "wall-clock",
                                          "time.time")
    assert e.justification.startswith("logging")


# ---- pyproject mini-parser -------------------------------------------------------


def test_load_pyproject_allow_reads_section(tmp_path):
    pj = tmp_path / "pyproject.toml"
    pj.write_text(textwrap.dedent("""\
        [tool.other]
        allow = ["decoy"]

        [tool.repro.lint]
        # comment line
        allow = [
            "src/a.py::wall-clock::time.time::why not",
            "src/b.py::global-random::random.seed::legacy",
        ]

        [tool.after]
        x = 1
    """))
    assert load_pyproject_allow(str(pj)) == [
        "src/a.py::wall-clock::time.time::why not",
        "src/b.py::global-random::random.seed::legacy",
    ]


def test_load_pyproject_allow_missing_section(tmp_path):
    pj = tmp_path / "pyproject.toml"
    pj.write_text("[project]\nname = 'x'\n")
    assert load_pyproject_allow(str(pj)) == []


# ---- import-boundary -------------------------------------------------------------


def test_boundary_violations_flagged_top_level_and_lazy(tmp_path):
    mod = tmp_path / "checker.py"
    mod.write_text(textwrap.dedent("""\
        import repro.core.fusion
        from repro.costmodel import something_else

        def lazy():
            from repro.costmodel.evaluator import Evaluator
            return Evaluator
    """))
    found = check_boundaries(str(tmp_path), {
        "checker.py": ["repro.core.fusion", "repro.costmodel.evaluator"]})
    assert _rules(found) == [
        ("import-boundary", "repro.core.fusion"),
        ("import-boundary", "repro.costmodel.evaluator"),  # lazy counts
    ]
    assert all(f.path == "checker.py" for f in found)


def test_boundary_matches_from_import_of_pinned_module(tmp_path):
    # `from repro.core import fusion` imports repro.core.fusion just the
    # same; `import repro.core.graph` must NOT match the fusion pin
    mod = tmp_path / "checker.py"
    mod.write_text("from repro.core import fusion\n"
                   "import repro.core.graph\n")
    found = check_boundaries(str(tmp_path),
                             {"checker.py": ["repro.core.fusion"]})
    assert _rules(found) == [("import-boundary", "repro.core.fusion")]


def test_clean_file_produces_no_boundary_findings(tmp_path):
    (tmp_path / "checker.py").write_text(
        "import repro.core.graph\nfrom repro.analysis import bounds\n")
    assert check_boundaries(str(tmp_path), {
        "checker.py": ["repro.core.fusion",
                       "repro.costmodel.evaluator"]}) == []


def test_boundary_row_naming_missing_file_is_a_finding(tmp_path):
    found = check_boundaries(str(tmp_path),
                             {"gone/nowhere.py": ["repro.core.fusion"]})
    assert [f.rule for f in found] == ["import-boundary"]
    assert found[0].path == "pyproject.toml"
    assert "no such file" in found[0].message


def test_boundaries_checked_on_every_run_regardless_of_paths(tmp_path):
    (tmp_path / "checker.py").write_text("import repro.core.fusion\n")
    findings = run_lint(str(tmp_path), paths=[],   # lint NO files...
                        allow_raw=[],
                        boundaries={"checker.py": ["repro.core.fusion"]})
    assert _rules(findings) == [  # ...the boundary table still fires
        ("import-boundary", "repro.core.fusion")]


def test_allow_entry_can_suppress_a_boundary_finding(tmp_path):
    (tmp_path / "checker.py").write_text("import repro.core.fusion\n")
    findings = run_lint(
        str(tmp_path), paths=[],
        allow_raw=["checker.py::import-boundary::repro.core.fusion::"
                   "transitional shim while the checker is split out"],
        boundaries={"checker.py": ["repro.core.fusion"]})
    assert findings == []


def test_load_pyproject_boundaries_reads_table(tmp_path):
    pj = tmp_path / "pyproject.toml"
    pj.write_text(textwrap.dedent("""\
        [tool.repro.lint]
        allow = []

        [tool.repro.lint.boundaries]
        # the checkers must not lean on the engine
        "src/a.py" = ["repro.core.fusion", "repro.costmodel.evaluator"]
        "src/b.py" = [
            "repro.core.fusion",
        ]

        [tool.after]
        x = 1
    """))
    assert load_pyproject_boundaries(str(pj)) == {
        "src/a.py": ["repro.core.fusion", "repro.costmodel.evaluator"],
        "src/b.py": ["repro.core.fusion"],
    }


def test_load_pyproject_boundaries_missing_section(tmp_path):
    pj = tmp_path / "pyproject.toml"
    pj.write_text("[project]\nname = 'x'\n")
    assert load_pyproject_boundaries(str(pj)) == {}
    assert load_pyproject_boundaries(str(tmp_path / "absent.toml")) == {}


def test_repo_boundary_table_pins_both_checkers():
    table = load_pyproject_boundaries("pyproject.toml")
    for rel in ("src/repro/analysis/verify.py",
                "src/repro/analysis/spacemap.py"):
        assert set(table[rel]) == {"repro.core.fusion",
                                   "repro.costmodel.evaluator"}, rel


# ---- the CI gate: the engine itself is clean -------------------------------------


def test_engine_packages_are_lint_clean_under_repo_allowlist():
    findings = run_lint(".")
    assert findings == [], "\n".join(f.describe() for f in findings)


def test_repo_allowlist_has_no_unexplained_suppressions():
    raw = load_pyproject_allow("pyproject.toml")
    entries, bad = parse_allow_entries(raw)
    assert bad == []
    for e in entries:
        # a real justification, not a placeholder
        assert len(e.justification.split()) >= 4, e.raw


# ---- clock-seam ------------------------------------------------------------------


def test_clock_seam_flags_all_time_calls_including_monotonic(tmp_path):
    (tmp_path / "inst.py").write_text(textwrap.dedent("""\
        import time
        import datetime
        t0 = time.perf_counter()
        now = time.time()
        stamp = datetime.datetime.now()
    """))
    found = check_clock_seam(str(tmp_path), ["inst.py"])
    assert _rules(found) == [("clock-seam", "datetime.now"),
                             ("clock-seam", "time.perf_counter"),
                             ("clock-seam", "time.time")]


def test_clock_seam_flags_from_time_import_at_the_import(tmp_path):
    (tmp_path / "inst.py").write_text(
        "from time import perf_counter\nx = perf_counter()\n")
    found = check_clock_seam(str(tmp_path), ["inst.py"])
    assert _rules(found) == [("clock-seam", "time.perf_counter")]


def test_clock_seam_clean_file_routing_through_the_seam(tmp_path):
    (tmp_path / "inst.py").write_text(textwrap.dedent("""\
        from repro.obs import clock
        t0 = clock.perf_counter()
        created = clock.unix_time()
    """))
    assert check_clock_seam(str(tmp_path), ["inst.py"]) == []


def test_clock_seam_row_naming_missing_file_is_a_finding(tmp_path):
    found = check_clock_seam(str(tmp_path), ["gone/nowhere.py"])
    assert [f.rule for f in found] == ["clock-seam"]
    assert found[0].path == "pyproject.toml"
    assert "no such file" in found[0].message


def test_clock_seam_checked_on_every_run_and_allowlistable(tmp_path):
    (tmp_path / "inst.py").write_text("import time\nt = time.time()\n")
    findings = run_lint(str(tmp_path), paths=[], allow_raw=[],
                        boundaries={}, clock_seam=["inst.py"])
    assert ("clock-seam", "time.time") in _rules(findings)
    findings = run_lint(
        str(tmp_path), paths=[],
        allow_raw=["inst.py::clock-seam::time.time::"
                   "transitional direct read while the seam lands"],
        boundaries={}, clock_seam=["inst.py"])
    assert findings == []


def test_load_pyproject_clock_seam_reads_paths(tmp_path):
    pj = tmp_path / "pyproject.toml"
    pj.write_text(textwrap.dedent("""\
        [tool.repro.lint]
        allow = []

        [tool.repro.lint.clock_seam]
        # time flows through repro.obs.clock only
        paths = [
            "src/a.py",
            "src/b.py",
        ]

        [tool.after]
        x = 1
    """))
    assert load_pyproject_clock_seam(str(pj)) == ["src/a.py", "src/b.py"]
    assert load_pyproject_clock_seam(str(tmp_path / "absent.toml")) == []


def test_repo_clock_seam_table_pins_the_instrumented_modules():
    paths = load_pyproject_clock_seam("pyproject.toml")
    for rel in ("src/repro/search/session.py",
                "src/repro/costmodel/evaluator.py",
                "src/repro/core/population.py",
                "src/repro/search/artifact.py"):
        assert rel in paths, rel
    # the seam itself must NOT be pinned against its own time.* reads
    assert "src/repro/obs/clock.py" not in paths
