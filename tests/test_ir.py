"""`repro.ir`: GraphIR round-trips, the canonicalization pipeline, workload
spec parsing, the parametric Workload protocol, embedded-IR artifacts, and
the JAX tracer."""
import json

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import repro.ir as ir
from repro.core.graph import Layer, LayerGraph
from repro.costmodel import SIMBA
from repro.ir import GraphIR, IRError, canonicalize
from repro.search import (RegistryError, ScheduleArtifact, SearchSession,
                          WorkloadParamError, build_workload, get_workload,
                          graph_fingerprint, parse_workload_spec)
from repro.workloads import mobilenet_v3_large, resnet50, unet, vgg16

try:
    import jax  # noqa: F401
    HAVE_JAX = True
except ImportError:
    HAVE_JAX = False


def small_chain(n=5, c0=3, hw=16) -> LayerGraph:
    g = LayerGraph("small_chain")
    prev = g.add(Layer(name="input", kind="input", m=c0, p=hw, q=hw))
    c = c0
    for i in range(n):
        prev = g.add(Layer(name=f"conv{i}", kind="conv", c=c, h=hw, w=hw,
                           m=8, p=hw, q=hw, r=3, s=3, padding=(1, 1)),
                     [prev])
        c = 8
    return g


# ---- round-trips ------------------------------------------------------------------

@pytest.mark.parametrize("builder,kw", [
    (vgg16, {"hw": 64}), (unet, {"hw": 64}),
    (mobilenet_v3_large, {}), (resnet50, {}),
])
def test_zoo_round_trip_preserves_structure_and_fingerprint(builder, kw):
    g = builder(**kw)
    text = g.to_ir().to_json()
    g2 = ir.loads(text).build()
    assert graph_fingerprint(g2) == graph_fingerprint(g)
    assert g2.compiled().edge_pairs == g.compiled().edge_pairs
    assert [tuple(sorted(l.__dict__.items())) for l in g2.layers.values()] \
        == [tuple(sorted(l.__dict__.items())) for l in g.layers.values()]
    # export of a canonical graph is byte-stable (file: round-trips clean)
    assert ir.loads(text).build().to_ir().to_json() == text


def test_from_ir_accepts_json_dict_and_object():
    g = small_chain()
    gir = g.to_ir()
    for form in (gir, gir.to_dict(), gir.to_json()):
        assert graph_fingerprint(LayerGraph.from_ir(form)) \
            == graph_fingerprint(g)


_KINDS = ("conv", "dwconv", "fc", "pool", "add", "concat", "upsample",
          "global_pool", "mul", "input")


@st.composite
def graph_irs(draw):
    """Arbitrary (not necessarily shape-consistent) DAGs in node order —
    the serialization layer must round-trip anything structurally sane."""
    n = draw(st.integers(min_value=1, max_value=8))
    nodes = []
    for i in range(n):
        n_in = 0 if i == 0 else draw(st.integers(min_value=0, max_value=2))
        inputs = sorted({f"n{draw(st.integers(min_value=0, max_value=i - 1))}"
                         for _ in range(n_in)}) if i else []
        node = {"name": f"n{i}", "kind": draw(st.sampled_from(_KINDS)),
                "inputs": inputs}
        if draw(st.booleans()):
            node["c"] = draw(st.integers(min_value=0, max_value=512))
            node["h"] = draw(st.integers(min_value=0, max_value=64))
        if draw(st.booleans()):
            node["stride"] = [draw(st.integers(min_value=1, max_value=3))] * 2
        nodes.append(node)
    return GraphIR(name="rand", nodes=nodes, outputs=[f"n{n - 1}"])


@settings(max_examples=40)
@given(graph_irs())
def test_hypothesis_serialize_parse_serialize_bit_stable(gir):
    text = gir.to_json()
    again = GraphIR.from_json(text)
    assert again.to_json() == text
    assert again.fingerprint() == gir.fingerprint()
    assert GraphIR.from_json(again.to_json()).canonical_json() \
        == gir.canonical_json()


def test_ir_rejects_unknown_fields_and_bad_version():
    g = small_chain(2)
    d = g.to_ir().to_dict()
    with pytest.raises(IRError, match="ir_version"):
        GraphIR.from_dict({**d, "ir_version": 99})
    with pytest.raises(IRError, match="unknown GraphIR fields"):
        GraphIR.from_dict({**d, "turbo": 1})
    bad = {**d, "nodes": [{**d["nodes"][0], "flux": 3}]}
    with pytest.raises(IRError, match="unknown fields"):
        GraphIR.from_dict(bad).build()
    with pytest.raises(IRError, match="expected an object"):
        GraphIR.from_dict({**d, "nodes": [3]})
    with pytest.raises(IRError, match="not valid JSON"):
        GraphIR.from_json("{nope")


# ---- canonicalization pipeline ----------------------------------------------------

def test_topo_sort_is_stable_and_fixes_order():
    g = small_chain(4)
    gir = g.to_ir()
    assert ir.topo_sort(gir).nodes == gir.nodes      # already sorted: no-op
    shuffled = GraphIR(name=gir.name, nodes=list(reversed(gir.nodes)),
                       outputs=gir.outputs)
    sorted_ir = ir.topo_sort(shuffled)
    assert [n["name"] for n in sorted_ir.nodes] \
        == [n["name"] for n in gir.nodes]
    # and the unsorted form cannot build directly
    with pytest.raises(IRError, match="topo-sort"):
        shuffled.build()


def test_topo_sort_rejects_cycles_and_unknown_inputs():
    nodes = [{"name": "a", "kind": "conv", "inputs": ["b"]},
             {"name": "b", "kind": "conv", "inputs": ["a"]}]
    with pytest.raises(IRError, match="cycle"):
        ir.topo_sort(GraphIR(name="x", nodes=nodes))
    with pytest.raises(IRError, match="unknown input"):
        ir.topo_sort(GraphIR(name="x", nodes=[
            {"name": "a", "kind": "conv", "inputs": ["ghost"]}]))
    with pytest.raises(IRError, match="duplicate"):
        ir.topo_sort(GraphIR(name="x", nodes=[
            {"name": "a", "kind": "conv", "inputs": []},
            {"name": "a", "kind": "conv", "inputs": []}]))


def test_fold_noops_removes_identity_glue():
    g = small_chain(2)
    gir = g.to_ir()
    # splice an identity pool between conv0 and conv1
    id_pool = {"name": "noop", "kind": "pool", "inputs": ["conv0"],
               "c": 8, "h": 16, "w": 16, "m": 8, "p": 16, "q": 16,
               "r": 1, "s": 1, "stride": [1, 1]}
    nodes = []
    for n in gir.nodes:
        nodes.append(dict(n))
        if n["name"] == "conv0":
            nodes.append(id_pool)
    nodes[-1]["inputs"] = ["noop"]
    spliced = GraphIR(name="g", nodes=nodes, outputs=["conv1"])
    folded = canonicalize(spliced)
    assert [n["name"] for n in folded.nodes] \
        == [n["name"] for n in gir.nodes]
    assert folded.build().preds("conv1") == ["conv0"]
    # a real pool (k=2) is NOT folded
    real = dict(id_pool, r=2, s=2, stride=[2, 2], p=8, q=8)
    kept = canonicalize(GraphIR(name="g", nodes=[
        *(dict(n) for n in gir.nodes[:2]), real], outputs=["noop"]))
    assert "noop" in [n["name"] for n in kept.nodes]


def test_eliminate_dead_drops_unreachable_branch():
    g = small_chain(3)
    gir = g.to_ir()
    dead = {"name": "dead_conv", "kind": "conv", "inputs": ["conv0"],
            "c": 8, "h": 16, "w": 16, "m": 4, "p": 16, "q": 16,
            "r": 1, "s": 1}
    spliced = GraphIR(name=gir.name, nodes=[*gir.nodes, dead],
                      outputs=["conv2"])
    pruned = canonicalize(spliced)
    assert "dead_conv" not in [n["name"] for n in pruned.nodes]
    assert pruned.fingerprint() == gir.fingerprint()
    # without declared outputs every sink survives
    assert "dead_conv" in [
        n["name"] for n in
        canonicalize(GraphIR(name=gir.name, nodes=[*gir.nodes,
                                                   dead])).nodes]


def test_eliminate_dead_rejects_unknown_output_names():
    """A typo'd output must raise, not silently prune the branch (or the
    whole graph) it was meant to keep alive."""
    gir = small_chain(3).to_ir()
    with pytest.raises(IRError, match="conv2_typo"):
        canonicalize(GraphIR(name=gir.name, nodes=gir.nodes,
                             outputs=["conv2_typo"]))
    with pytest.raises(IRError, match="aux_typo"):
        ir.loads(GraphIR(name=gir.name, nodes=gir.nodes,
                         outputs=["conv2", "aux_typo"]).to_json())


def test_non_sink_outputs_survive_round_trip():
    """Multi-head models declare an intermediate node as an output; the
    build->export round-trip must keep it (and the fingerprint) intact."""
    gir = small_chain(3).to_ir()
    multi = canonicalize(GraphIR(name=gir.name, nodes=gir.nodes,
                                 outputs=["conv1", "conv2"]))
    assert multi.outputs == ["conv1", "conv2"]
    g = multi.build()
    assert g.outputs == ["conv1", "conv2"]
    again = g.to_ir()
    assert again.outputs == ["conv1", "conv2"]
    assert again.fingerprint() == multi.fingerprint()
    assert ir.loads(multi.to_json()).build().to_ir().to_json() \
        == multi.to_json()
    # and the declared-output set is part of the identity
    assert multi.fingerprint() != gir.fingerprint()


def test_store_key_is_content_addressed_for_file_specs(tmp_path):
    """The same IR document under two filenames is one store object: the
    second submit must be a cache hit, not a second search."""
    from repro.search import SearchSpec
    from repro.serve import ArtifactStore, BatchScheduler
    a, b = tmp_path / "a.json", tmp_path / "sub" / "b.json"
    b.parent.mkdir()
    ir.save(small_chain(), str(a))
    b.write_text(a.read_text())
    store = ArtifactStore(str(tmp_path / "store"))
    cfg = {"evaluations": 5}
    sched = BatchScheduler(store)
    sched.submit(SearchSpec(workload=f"file:{a}", backend="random",
                            backend_config=cfg))
    out1 = sched.run()
    assert out1.jobs[0].outcome == "searched"
    sched2 = BatchScheduler(store)
    sched2.submit(SearchSpec(workload=f"file:{b}", backend="random",
                             backend_config=cfg))
    out2 = sched2.run()
    assert out2.jobs[0].outcome == "cache_hit"
    assert out2.jobs[0].key == out1.jobs[0].key
    assert len(store) == 1
    # and within ONE batch: two paths, same content -> one search
    store2 = ArtifactStore(str(tmp_path / "store2"))
    sched3 = BatchScheduler(store2)
    for path in (a, b):
        sched3.submit(SearchSpec(workload=f"file:{path}",
                                 backend="random", backend_config=cfg))
    out3 = sched3.run()
    assert [j.outcome for j in out3.jobs] == ["searched", "cache_hit"]
    assert sched3.searches_run == 1 and len(store2) == 1


def test_canonicalize_idempotent_on_zoo():
    gir = vgg16(hw=64).to_ir()
    once = canonicalize(gir)
    assert once.canonical_json() == gir.canonical_json()
    assert canonicalize(once).canonical_json() == once.canonical_json()


def test_validate_rejects_channel_mismatch():
    nodes = [{"name": "input", "kind": "input", "m": 3, "p": 8, "q": 8},
             {"name": "c1", "kind": "conv", "inputs": ["input"],
              "c": 3, "h": 8, "w": 8, "m": 8, "p": 8, "q": 8},
             {"name": "c2", "kind": "conv", "inputs": ["c1"],
              "c": 99, "h": 8, "w": 8, "m": 8, "p": 8, "q": 8}]
    with pytest.raises(IRError, match="channel mismatch"):
        canonicalize(GraphIR(name="bad", nodes=nodes))


# ---- fixed-seed pin: IR round-trip does not perturb search ------------------------

def test_search_on_reimported_zoo_graph_is_bit_identical():
    """Export->reimport must leave the searched structure untouched: a
    fixed-seed GA over the reimported graph returns the same genome,
    history, and fitness bit-for-bit."""
    g = vgg16(hw=64)
    g2 = ir.loads(g.to_ir().to_json()).build()
    runs = []
    for graph in (g, g2):
        art = SearchSession.from_objects(
            graph, SIMBA, backend="ga", seed=0,
            backend_config={"preset": "fast", "generations": 5}).run()
        runs.append(art)
    a, b = runs
    assert a.genome_mask == b.genome_mask
    assert a.best_fitness == b.best_fitness
    assert a.history == b.history
    assert a.graph_fingerprint == b.graph_fingerprint
    assert a.spec == b.spec            # ir:<fp> specs agree too


# ---- workload spec strings --------------------------------------------------------

def test_parse_workload_spec_forms():
    assert parse_workload_spec("vgg16") == ("vgg16", {})
    assert parse_workload_spec("mobilenet_v3@hw=160") \
        == ("mobilenet_v3", {"hw": "160"})
    assert parse_workload_spec("unet@hw=64,depth=2") \
        == ("unet", {"hw": "64", "depth": "2"})
    for bad in ("w@", "w@hw", "w@hw=", "w@=3", "w@hw=1,hw=2"):
        with pytest.raises(WorkloadParamError):
            parse_workload_spec(bad)


@settings(max_examples=30)
@given(st.sampled_from(["vgg16", "unet", "mobilenet_v3", "resnet50"]),
       st.integers(min_value=1, max_value=6))
def test_spec_param_round_trip_property(name, n):
    hw = 32 * n
    spec = f"{name}@hw={hw}"
    parsed_name, params = parse_workload_spec(spec)
    assert parsed_name == name and params == {"hw": str(hw)}
    # spec-string build == kwargs build, structurally
    assert graph_fingerprint(build_workload(spec)) \
        == graph_fingerprint(build_workload(name, hw=hw))


def test_build_workload_errors_list_schema_and_names():
    with pytest.raises(RegistryError, match="vgg16"):
        build_workload("not_a_net")
    with pytest.raises(WorkloadParamError) as e:
        build_workload("unet@res=64")
    msg = str(e.value)
    assert "hw=256 (int)" in msg and "depth=4 (int)" in msg
    assert "unet@hw=256" in msg               # copy-pasteable fix
    with pytest.raises(WorkloadParamError, match="cannot parse"):
        build_workload("unet@hw=big")
    with pytest.raises(WorkloadParamError, match="both in spec"):
        build_workload("unet@hw=64", hw=64)


def test_build_workload_file_spec(tmp_path):
    path = tmp_path / "m.json"
    ir.save(small_chain(), str(path))
    g = build_workload(f"file:{path}")
    assert graph_fingerprint(g) == graph_fingerprint(small_chain())
    with pytest.raises(WorkloadParamError, match="no params"):
        build_workload(f"file:{path}", hw=3)
    with pytest.raises(IRError, match="cannot read"):
        build_workload(f"file:{tmp_path / 'ghost.json'}")


def test_ir_spec_unresolvable_from_registry():
    with pytest.raises(RegistryError, match="embedded"):
        build_workload("ir:sha256:abc")


def test_function_workload_pep563_string_annotations_coerce():
    """Builders in `from __future__ import annotations` modules carry
    string annotations; the schema must still type (and coerce) them."""
    from repro.workloads import FunctionWorkload

    def builder(hw, depth=2):
        return small_chain(depth, hw=hw)
    builder.__annotations__ = {"hw": "int"}        # what PEP 563 produces
    wl = FunctionWorkload("pep563", builder)
    assert wl.params()["hw"].kind == "int"
    assert wl.params()["hw"].required
    g = wl.build(hw="24")                          # spec-string path
    assert g.layers["input"].p == 24               # int, not "24"


def test_function_workload_var_kwargs_passes_unknown_params():
    """A documented bare ``(**kwargs) -> LayerGraph`` builder must keep
    accepting arbitrary params (open schema), not reject everything."""
    from repro.workloads import FunctionWorkload
    wl = FunctionWorkload("open", lambda **kw: small_chain(**kw))
    assert wl.open_schema and wl.params() == {}
    assert wl.build(n=2, hw=8).layers["input"].p == 8
    assert wl.describe()["open_schema"] is True
    # explicit params still coerce; extras pass through beside them
    wl2 = FunctionWorkload("mixed",
                           lambda n=3, **kw: small_chain(n, **kw))
    g = wl2.build(n="2", hw=8)
    assert len(g.compute_layers()) == 2


def test_pre_ir_fingerprint_format_gets_distinct_error():
    g = small_chain()
    art = SearchSession.from_objects(
        g, SIMBA, backend="random",
        backend_config={"evaluations": 5}).run()
    assert art.graph_fingerprint.startswith("ir1:")
    from repro.search import FingerprintMismatch
    stale = ScheduleArtifact.from_dict(
        {**art.to_dict(), "graph_fingerprint": "sha256:" + "0" * 64})
    with pytest.raises(FingerprintMismatch, match="format"):
        stale.state(g)


def test_function_workload_schema_derivation():
    wl = get_workload("unet")
    schema = wl.params()
    assert schema["hw"].kind == "int" and schema["hw"].default == 256
    assert set(schema) == {"hw", "base_ch", "depth", "in_ch", "out_ch"}
    # string values coerce per schema (spec-string path)
    g = wl.build(hw="64", depth="2")
    assert g.name == "unet"
    d = wl.describe()
    assert d["params"]["depth"] == {"default": 4, "type": "int",
                                    "required": False}


# ---- embedded-IR artifacts --------------------------------------------------------

def test_direct_graph_artifact_is_reproducible_without_registry(tmp_path):
    """The session.py satellite: a direct-graph search must not fabricate
    a registry workload name; it records ir:<fp> and embeds the IR."""
    g = small_chain()
    art = SearchSession.from_objects(
        g, SIMBA, backend="random",
        backend_config={"evaluations": 10}).run()
    assert art.spec.workload == f"ir:{graph_fingerprint(g)}"
    assert art.graph_ir is not None
    path = tmp_path / "a.json"
    art.save(str(path))
    loaded = ScheduleArtifact.load(str(path))
    # rebind with no registry entry, no file, no builder code
    state = loaded.rebuild_state()
    assert state.mask == art.genome_mask
    # stripping the IR makes the failure explicit, not silent
    d = loaded.to_dict()
    del d["graph_ir"]
    with pytest.raises(ValueError, match="graph_ir"):
        ScheduleArtifact.from_dict(d).rebuild_graph()


def test_registry_artifact_embeds_ir_only_on_request(tmp_path):
    from repro.search import search
    art = search("vgg16", "simba", backend="random",
                 workload_kwargs={"hw": 64},
                 backend_config={"evaluations": 5})
    assert art.graph_ir is None           # registry spec: stays compact
    assert "graph_ir" not in art.to_dict()
    spec = art.spec
    sess = SearchSession(spec, embed_ir=True)
    art2 = sess.run()
    assert art2.graph_ir is not None
    rebuilt = ScheduleArtifact.from_json(art2.to_json()).rebuild_graph()
    assert graph_fingerprint(rebuilt) == art2.graph_fingerprint


def test_file_spec_artifact_embeds_ir_automatically(tmp_path):
    path = tmp_path / "m.json"
    ir.save(small_chain(), str(path))
    from repro.search import search
    art = search(f"file:{path}", "simba", backend="random",
                 backend_config={"evaluations": 5})
    assert art.graph_ir is not None
    path.unlink()                          # file gone: artifact still works
    assert art.rebuild_state().mask == art.genome_mask


# ---- CLI --------------------------------------------------------------------------

def test_cli_export_file_search_report(tmp_path):
    from repro.__main__ import main
    model = tmp_path / "vgg64.json"
    art = tmp_path / "a.json"
    assert main(["export", "--workload", "vgg16@hw=64",
                 "--out", str(model)]) == 0
    assert main(["search", "--workload", f"file:{model}",
                 "--backend", "random", "--backend-config",
                 '{"evaluations": 10}', "--out", str(art)]) == 0
    assert main(["report", str(art), "--schedule"]) == 0
    # export round-trips byte-identically through file:
    rt = tmp_path / "rt.json"
    assert main(["export", "--workload", f"file:{model}",
                 "--out", str(rt)]) == 0
    assert rt.read_text() == model.read_text()
    # bad spec strings exit 2 with the schema in the message
    assert main(["export", "--workload", "vgg16@res=64",
                 "--out", str(model)]) == 2


def test_cli_list_json_is_machine_readable(capsys):
    from repro.__main__ import main
    assert main(["list", "--json"]) == 0
    payload = json.loads(capsys.readouterr().out)
    assert set(payload) >= {"workloads", "accelerators", "objectives",
                            "backends", "costmodels"}
    assert payload["workloads"]["unet"]["params"]["hw"]["type"] == "int"
    assert "simba" in payload["accelerators"]
    assert "ga" in payload["backends"]
    assert payload["backends"]["island"]["doc"]


def test_cli_embed_ir_flag(tmp_path):
    from repro.__main__ import main
    out = tmp_path / "e.json"
    assert main(["search", "--workload", "vgg16", "--workload-kwargs",
                 '{"hw": 64}', "--backend", "random", "--backend-config",
                 '{"evaluations": 5}', "--embed-ir", "--out",
                 str(out)]) == 0
    assert ScheduleArtifact.load(str(out)).graph_ir is not None


# ---- JAX tracer -------------------------------------------------------------------

@pytest.mark.skipif(not HAVE_JAX, reason="jax not installed")
class TestFromJax:
    def _tiny(self):
        import jax.numpy as jnp
        from jax import lax

        def cnn(x, w1, w2, w3):
            y = lax.conv_general_dilated(x, w1, (1, 1), "SAME")
            y = jnp.maximum(y, 0.0)
            y = lax.reduce_window(y, -jnp.inf, lax.max,
                                  (1, 1, 2, 2), (1, 1, 2, 2), "VALID")
            y = lax.conv_general_dilated(y, w2, (1, 1), "SAME")
            y = jnp.maximum(y, 0.0)
            y = jnp.mean(y, axis=(2, 3))
            return y.reshape(1, -1) @ w3

        args = (jnp.zeros((1, 3, 32, 32)), jnp.zeros((8, 3, 3, 3)),
                jnp.zeros((16, 8, 3, 3)), jnp.zeros((16, 10)))
        return cnn, args

    def test_trace_maps_primitives_to_layer_kinds(self):
        fn, args = self._tiny()
        gir = ir.from_jax(fn, args, name="tiny")
        kinds = [n["kind"] for n in gir.nodes]
        assert kinds == ["input", "conv", "pool", "conv", "global_pool",
                         "fc"]
        g = gir.build()
        g.validate()
        conv = g.layers[gir.nodes[1]["name"]]
        assert (conv.c, conv.h, conv.w, conv.m, conv.r) == (3, 32, 32, 8, 3)
        fc = g.layers[gir.nodes[-1]["name"]]
        assert (fc.c, fc.m) == (16, 10)

    def test_trace_is_deterministic_and_searchable(self):
        fn, args = self._tiny()
        g1, g2 = (ir.from_jax(fn, args, name="t").build() for _ in range(2))
        assert graph_fingerprint(g1) == graph_fingerprint(g2)
        art = SearchSession.from_objects(
            g1, SIMBA, backend="exhaustive").run()
        assert art.best_fitness >= 1.0

    def test_trace_depthwise_and_residual(self):
        import jax.numpy as jnp
        from jax import lax

        def block(x, wdw, wpw):
            y = lax.conv_general_dilated(x, wdw, (1, 1), "SAME",
                                         feature_group_count=8)
            y = lax.conv_general_dilated(y, wpw, (1, 1), "SAME")
            return x + y

        gir = ir.from_jax(block, (jnp.zeros((1, 8, 16, 16)),
                                  jnp.zeros((8, 1, 3, 3)),
                                  jnp.zeros((8, 8, 1, 1))), name="res")
        kinds = [n["kind"] for n in gir.nodes]
        assert kinds == ["input", "dwconv", "conv", "add"]
        add = gir.nodes[-1]
        assert set(add["inputs"]) == {gir.nodes[0]["name"],
                                      gir.nodes[2]["name"]}

    def test_trace_through_jit_and_nhwc(self):
        import jax
        import jax.numpy as jnp
        from jax import lax

        def f(x, w):
            conv = jax.jit(lambda a: lax.conv_general_dilated(
                a, w, (2, 2), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC")))
            return jax.nn.relu(conv(x))

        gir = ir.from_jax(f, (jnp.zeros((1, 16, 16, 3)),
                              jnp.zeros((3, 3, 3, 4))), name="nhwc")
        assert [n["kind"] for n in gir.nodes] == ["input", "conv"]
        conv = gir.nodes[1]
        assert (conv["c"], conv["h"], conv["w"]) == (3, 16, 16)
        assert (conv["m"], conv["p"], conv["q"]) == (4, 8, 8)
        assert conv["stride"] == [2, 2]

    def test_trace_squeeze_excite_keeps_the_branch(self):
        """y * se(y) with se broadcasting from (1,C,1,1) is a real mul
        layer — the SE branch must not be silently dead-eliminated."""
        import jax.numpy as jnp
        from jax import lax

        def se_block(x, w, wfc1, wfc2):
            y = lax.conv_general_dilated(x, w, (1, 1), "SAME")
            s = jnp.mean(y, axis=(2, 3))               # (1, C) squeeze
            s = jax.nn.sigmoid((s @ wfc1) @ wfc2)
            return y * s.reshape(1, -1, 1, 1)          # broadcast excite

        import jax
        gir = ir.from_jax(se_block, (jnp.zeros((1, 4, 8, 8)),
                                     jnp.zeros((8, 4, 3, 3)),
                                     jnp.zeros((8, 2)),
                                     jnp.zeros((2, 8))), name="se")
        kinds = [n["kind"] for n in gir.nodes]
        assert kinds == ["input", "conv", "global_pool", "fc", "fc",
                         "mul"]
        mul = gir.nodes[-1]
        assert len(mul["inputs"]) == 2                 # conv + fc branch
        assert (mul["c"], mul["h"], mul["w"]) == (8, 8, 8)

    def test_trace_1d_pool_is_not_squared(self):
        import jax.numpy as jnp
        from jax import lax

        def f(x):
            return lax.reduce_window(x, -jnp.inf, lax.max,
                                     (1, 1, 1, 2), (1, 1, 1, 2), "VALID")

        gir = ir.from_jax(f, (jnp.zeros((1, 8, 32, 32)),), name="pool1d")
        pool = gir.nodes[-1]
        assert (pool["r"], pool["s"]) == (1, 2)
        assert (pool["p"], pool["q"]) == (32, 16)      # only W halves
        assert pool["stride"] == [1, 2]

    def test_trace_rejects_activation_x_activation_matmul(self):
        import jax.numpy as jnp
        from jax import lax
        from repro.ir.trace import TraceError

        def attn(x, wq, wk):
            a = lax.conv_general_dilated(x, wq, (1, 1), "SAME")
            b = lax.conv_general_dilated(x, wk, (1, 1), "SAME")
            return a.reshape(4, -1) @ b.reshape(-1, 4)

        with pytest.raises(TraceError, match="two traced activations"):
            ir.from_jax(attn, (jnp.zeros((1, 3, 8, 8)),
                               jnp.zeros((4, 3, 1, 1)),
                               jnp.zeros((4, 3, 1, 1))))

    def test_trace_nhwc_global_pool_and_concat(self):
        import jax.numpy as jnp
        from jax import lax
        from repro.ir.trace import TraceError
        dn = ("NHWC", "HWIO", "NHWC")

        def f(x, w1, w2):
            a = lax.conv_general_dilated(x, w1, (1, 1), "SAME",
                                         dimension_numbers=dn)
            b = lax.conv_general_dilated(x, w2, (1, 1), "SAME",
                                         dimension_numbers=dn)
            y = lax.concatenate([a, b], dimension=3)   # NHWC feature dim
            return jnp.mean(y, axis=(1, 2))            # NHWC global pool

        args = (jnp.zeros((1, 8, 8, 3)), jnp.zeros((3, 3, 3, 4)),
                jnp.zeros((3, 3, 3, 4)))
        gir = ir.from_jax(f, args, name="nhwc_cat")
        kinds = [n["kind"] for n in gir.nodes]
        assert kinds == ["input", "conv", "conv", "concat", "global_pool"]
        cat = gir.nodes[3]
        assert (cat["c"], cat["m"]) == (8, 8)          # 4 + 4 channels
        gp = gir.nodes[4]
        assert (gp["c"], gp["h"], gp["w"]) == (8, 8, 8)

        def g(x, w1, w2):
            a = lax.conv_general_dilated(x, w1, (1, 1), "SAME",
                                         dimension_numbers=dn)
            b = lax.conv_general_dilated(x, w2, (1, 1), "SAME",
                                         dimension_numbers=dn)
            return lax.concatenate([a, b], dimension=1)  # spatial (H)!

        with pytest.raises(TraceError, match="feature-dim"):
            ir.from_jax(g, args)

    def test_trace_same_padding_on_even_input_keeps_halo(self):
        """'SAME' stride-2 on an even input lowers to (lo,hi)=(0,1);
        the symmetric Layer.padding must keep the halo, not drop to 0 —
        for convs and pools alike."""
        import jax.numpy as jnp
        from jax import lax

        def f(x, w):
            y = lax.conv_general_dilated(x, w, (2, 2), "SAME")
            return lax.reduce_window(y, -jnp.inf, lax.max,
                                     (1, 1, 3, 3), (1, 1, 2, 2), "SAME")

        gir = ir.from_jax(f, (jnp.zeros((1, 3, 32, 32)),
                              jnp.zeros((8, 3, 3, 3))))
        conv, pool = gir.nodes[-2], gir.nodes[-1]
        assert conv["padding"] == [1, 1]
        assert (conv["p"], conv["q"]) == (16, 16)
        assert pool["padding"] == [1, 1]
        assert (pool["p"], pool["q"]) == (8, 8)

    def test_trace_raw_nhwc_pool_promotes_correct_channels(self):
        """Pooling an input that never went through a conv must promote
        it with the layout the window implies, not assume NCHW."""
        import jax.numpy as jnp
        from jax import lax

        def f(x):
            return lax.reduce_window(x, -jnp.inf, lax.max,
                                     (1, 2, 2, 1), (1, 2, 2, 1), "VALID")

        gir = ir.from_jax(f, (jnp.zeros((1, 32, 32, 8)),), name="rawpool")
        inp, pool = gir.nodes
        assert (inp["m"], inp["p"], inp["q"]) == (8, 32, 32)
        assert (pool["c"], pool["h"], pool["w"]) == (8, 32, 32)
        assert (pool["m"], pool["p"], pool["q"]) == (8, 16, 16)

    def test_trace_rejects_partial_spatial_reduction(self):
        import jax.numpy as jnp
        from jax import lax
        from repro.ir.trace import TraceError

        def f(x, w):
            y = lax.conv_general_dilated(x, w, (1, 1), "SAME")
            return jnp.sum(y, axis=2)                  # H only: no Layer

        with pytest.raises(TraceError, match="part of the spatial"):
            ir.from_jax(f, (jnp.zeros((1, 3, 8, 8)),
                            jnp.zeros((4, 3, 3, 3))))

    def test_trace_rejects_unsupported_primitive(self):
        import jax.numpy as jnp
        from repro.ir.trace import TraceError

        def weird(x):
            return jnp.sort(x, axis=-1)

        with pytest.raises(TraceError, match="sort"):
            ir.from_jax(weird, (jnp.zeros((1, 4, 8, 8)),))

    def test_trace_rejects_batched_input(self):
        import jax.numpy as jnp
        from jax import lax
        from repro.ir.trace import TraceError

        def cnn(x, w):
            return lax.conv_general_dilated(x, w, (1, 1), "SAME")

        with pytest.raises(TraceError, match="batch"):
            ir.from_jax(cnn, (jnp.zeros((4, 3, 8, 8)),
                              jnp.zeros((8, 3, 3, 3))))

    def test_traced_graph_round_trips_through_file(self, tmp_path):
        fn, args = self._tiny()
        gir = ir.from_jax(fn, args, name="tiny")
        path = tmp_path / "tiny.json"
        ir.save(gir, str(path))
        again = ir.load(str(path))
        assert again.fingerprint() == gir.fingerprint()
        assert build_workload(f"file:{path}").compiled().edge_pairs \
            == gir.build().compiled().edge_pairs
