"""Attention module: path equivalence (dense/blockwise/local), GQA
grouping, masks, numerical properties (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.models.attention import (attention, blockwise_attention,
                                    dense_attention, local_attention)


def _qkv(B, S, Hq, Hkv, D, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return (jax.random.normal(ks[0], (B, S, Hq, D), dtype),
            jax.random.normal(ks[1], (B, S, Hkv, D), dtype),
            jax.random.normal(ks[2], (B, S, Hkv, D), dtype))


@given(st.integers(min_value=8, max_value=70),
       st.sampled_from([(4, 4), (4, 2), (8, 1)]),
       st.sampled_from([16, 64]))
@settings(max_examples=12, deadline=None)
def test_blockwise_equals_dense_causal(S, heads, block):
    Hq, Hkv = heads
    q, k, v = _qkv(1, S, Hq, Hkv, 16)
    pos = jnp.arange(S)
    d = dense_attention(q, k, v, pos, pos)
    b = blockwise_attention(q, k, v, pos, pos, block_kv=block)
    np.testing.assert_allclose(np.asarray(d), np.asarray(b), atol=2e-5,
                               rtol=2e-5)


@given(st.integers(min_value=12, max_value=64),
       st.sampled_from([4, 8, 16]))
@settings(max_examples=12, deadline=None)
def test_local_window_equals_masked_dense(S, W):
    q, k, v = _qkv(2, S, 4, 2, 16, seed=1)
    pos = jnp.arange(S)
    d = dense_attention(q, k, v, pos, pos, window=W)
    l = local_attention(q, k, v, pos, pos, window=W)
    np.testing.assert_allclose(np.asarray(d), np.asarray(l), atol=2e-5,
                               rtol=2e-5)


@given(st.integers(min_value=12, max_value=64),
       st.sampled_from([8, 16, 32]))
@settings(max_examples=12, deadline=None)
def test_local_chunk_equals_masked_dense(S, C):
    q, k, v = _qkv(2, S, 4, 2, 16, seed=2)
    pos = jnp.arange(S)
    d = dense_attention(q, k, v, pos, pos, chunk=C)
    l = local_attention(q, k, v, pos, pos, chunk=C)
    np.testing.assert_allclose(np.asarray(d), np.asarray(l), atol=2e-5,
                               rtol=2e-5)


def test_rows_are_convex_combinations_of_values():
    """Attention outputs lie in the convex hull of V rows: with V == const c,
    every output must equal c exactly."""
    B, S, H, D = 2, 32, 4, 16
    q, k, _ = _qkv(B, S, H, H, D, seed=3)
    v = jnp.full((B, S, H, D), 3.25)
    pos = jnp.arange(S)
    out = attention(q, k, v, pos, pos, causal=True)
    np.testing.assert_allclose(np.asarray(out), 3.25, atol=1e-5)


def test_causal_prefix_invariance():
    """Causal attention of a prefix equals the prefix of the full result."""
    B, S, H, D = 1, 48, 4, 16
    q, k, v = _qkv(B, S, H, H, D, seed=4)
    pos = jnp.arange(S)
    full = dense_attention(q, k, v, pos, pos)
    half = dense_attention(q[:, :24], k[:, :24], v[:, :24],
                           pos[:24], pos[:24])
    np.testing.assert_allclose(np.asarray(full[:, :24]), np.asarray(half),
                               atol=2e-5, rtol=2e-5)


def test_gqa_equals_repeated_heads():
    """GQA result == MHA with KV heads explicitly repeated."""
    B, S, Hq, Hkv, D = 2, 24, 8, 2, 16
    q, k, v = _qkv(B, S, Hq, Hkv, D, seed=5)
    pos = jnp.arange(S)
    g = dense_attention(q, k, v, pos, pos)
    kr = jnp.repeat(k, Hq // Hkv, axis=2)
    vr = jnp.repeat(v, Hq // Hkv, axis=2)
    m = dense_attention(q, kr, vr, pos, pos)
    np.testing.assert_allclose(np.asarray(g), np.asarray(m), atol=2e-5,
                               rtol=2e-5)


def test_negative_kpos_slots_are_masked():
    """Cache slots carrying kpos=-1 (never written) contribute nothing."""
    B, S, H, D = 1, 16, 2, 8
    q, k, v = _qkv(B, S, H, H, D, seed=6)
    pos = jnp.arange(S)
    kpos_holes = pos.at[5].set(-1).at[11].set(-1)
    out = dense_attention(q, k, v, pos, kpos_holes)
    # oracle: physically remove those keys
    keep = np.array([i for i in range(S) if i not in (5, 11)])
    ref = dense_attention(q, k[:, keep], v[:, keep], pos,
                          pos[keep])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)


def test_softcap_changes_but_keeps_normalization():
    B, S, H, D = 1, 16, 2, 8
    q, k, v = _qkv(B, S, H, H, D, seed=7)
    pos = jnp.arange(S)
    a = dense_attention(q, k, v, pos, pos, softcap=0.0)
    b = dense_attention(q, k, v, pos, pos, softcap=5.0)
    assert float(jnp.abs(a - b).max()) > 1e-6      # cap actually applied
    vc = jnp.ones_like(v)
    out = dense_attention(q, k, vc, pos, pos, softcap=5.0)
    np.testing.assert_allclose(np.asarray(out), 1.0, atol=1e-5)
