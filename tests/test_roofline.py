"""Roofline machinery: HLO collective parsing, spec fitting, TPU cost model
and scheduling GA."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import SHAPES
from repro.core.ga import GAConfig
from repro.core.tpu_ga import optimize_tpu_schedule
from repro.costmodel.tpu_model import TpuSchedule, estimate
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import fit_spec
from repro.roofline.analysis import (HW, RooflineTerms, collective_bytes,
                                     roofline_from_artifact)

HLO_SAMPLE = """
  %all-reduce.5 = bf16[16,512,128]{2,1,0} all-reduce(%x), replica_groups={}
  %ag = f32[1024,32]{1,0} all-gather(%y), dimensions={0}
  %rs.2 = bf16[64]{0} reduce-scatter(%z), dimensions={0}
  %a2a = (f32[8,16]{1,0}, f32[8,16]{1,0}) all-to-all(%p, %q)
  %cp = u8[100]{0} collective-permute(%w)
  %dot.1 = f32[128,128]{1,0} dot(%a, %b)
"""


def test_collective_bytes_parses_all_kinds():
    out = collective_bytes(HLO_SAMPLE)
    assert out["all-reduce"] == 16 * 512 * 128 * 2
    assert out["all-gather"] == 1024 * 32 * 4
    assert out["reduce-scatter"] == 64 * 2
    assert out["all-to-all"] == 2 * 8 * 16 * 4          # tuple result
    assert out["collective-permute"] == 100
    assert out["count"] == 5


def test_collective_bytes_ignores_compute_ops():
    assert collective_bytes("%dot = f32[4,4]{1,0} dot(%a, %b)")["count"] == 0


def test_roofline_terms_and_dominance():
    art = {"chips": 256,
           "cost": {"flops": 197e12, "bytes accessed": 819e9 * 2},
           "collectives": {"all-reduce": int(50e9 * 0.5), "count": 3}}
    t = roofline_from_artifact(art)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(2.0)
    assert t.collective_s == pytest.approx(0.5)
    assert t.dominant == "memory"
    assert t.step_time_s == pytest.approx(2.0)


def test_fit_spec_drops_nondivisible_axes():
    mesh = make_local_mesh(1, 1)   # axes exist but size 1 -> always divides
    s = fit_spec(P("data", "model"), (7, 8), mesh)
    assert s == P("data", "model")

    class FakeMesh:
        axis_names = ("data", "model")
        class devices:
            shape = (4, 8)
    s = fit_spec(P("data", "model"), (7, 16), FakeMesh)
    assert s == P(None, "model")   # 7 % 4 != 0 dropped; 16 % 8 == 0 kept
    s = fit_spec(P(("data", "model"), None), (32, 5), FakeMesh)
    assert s == P(("data", "model"), None)
    s = fit_spec(P(("data", "model"), None), (16, 5), FakeMesh)
    assert s == P(None, None)      # 16 % 32 != 0


def test_tpu_cost_model_remat_tradeoff():
    cfg = get_config("qwen2-7b")
    shape = SHAPES["train_4k"]
    none = estimate(cfg, shape, TpuSchedule(remat="none"))
    full = estimate(cfg, shape, TpuSchedule(remat="full"))
    assert full.compute_s > none.compute_s          # recompute costs flops
    assert full.hbm_resident_bytes < none.hbm_resident_bytes
    mb = estimate(cfg, shape, TpuSchedule(microbatches=8))
    assert mb.hbm_resident_bytes < none.hbm_resident_bytes


def test_tpu_cost_model_compression_cuts_collectives():
    cfg = get_config("qwen2-7b")
    shape = SHAPES["train_4k"]
    raw = estimate(cfg, shape, TpuSchedule())
    gc = estimate(cfg, shape, TpuSchedule(grad_compression=True))
    assert gc.collective_s < raw.collective_s


def test_tpu_ga_finds_feasible_schedule_for_giant_model():
    cfg = get_config("llama4-maverick-400b-a17b")
    res = optimize_tpu_schedule(cfg, SHAPES["train_4k"],
                                ga=GAConfig.fast(generations=15, seed=0))
    # baseline does not fit 16 GB HBM; the GA must find one that does
    assert res.baseline_cost.hbm_resident_bytes > 16e9
    assert res.best_cost.hbm_resident_bytes <= 16e9
    assert res.best.microbatches > 1 or res.best.remat != "none"


def test_tpu_ga_monotone_history():
    cfg = get_config("dbrx-132b")
    res = optimize_tpu_schedule(cfg, SHAPES["train_4k"],
                                ga=GAConfig.fast(generations=10, seed=1))
    h = res.history
    assert all(b >= a - 1e-12 for a, b in zip(h, h[1:]))
