"""Topological sort: correctness vs networkx, randomized-order validity,
cycle detection (property-based)."""
import random

import networkx as nx
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.toposort import (CycleError, is_topological,
                                 topological_sort_edges)


@st.composite
def random_dags(draw):
    n = draw(st.integers(min_value=1, max_value=24))
    nodes = list(range(n))
    edges = []
    for u in range(n):
        for v in range(u + 1, n):
            if draw(st.booleans()):
                edges.append((u, v))
    return nodes, edges


@given(random_dags(), st.integers(min_value=0, max_value=2**32 - 1))
@settings(max_examples=60, deadline=None)
def test_random_dag_orders_are_topological(dag, seed):
    nodes, edges = dag
    rng = random.Random(seed)
    order = topological_sort_edges(nodes, edges, rng)
    assert sorted(order) == sorted(nodes)
    assert is_topological(order, edges)


@given(random_dags())
@settings(max_examples=30, deadline=None)
def test_agrees_with_networkx_reachability(dag):
    nodes, edges = dag
    order = topological_sort_edges(nodes, edges)
    g = nx.DiGraph()
    g.add_nodes_from(nodes)
    g.add_edges_from(edges)
    pos = {n: i for i, n in enumerate(order)}
    for u, v in edges:
        assert pos[u] < pos[v]
    assert nx.is_directed_acyclic_graph(g)


def test_cycle_raises():
    with pytest.raises(CycleError):
        topological_sort_edges([0, 1, 2], [(0, 1), (1, 2), (2, 0)])


def test_edges_outside_nodeset_ignored():
    order = topological_sort_edges([0, 1], [(0, 1), (1, 5), (5, 0)])
    assert order == [0, 1]


def test_randomization_covers_tie_space():
    # diamond: 0 -> {1,2} -> 3 ; both 1,2 orders must appear across seeds
    seen = set()
    for seed in range(20):
        order = topological_sort_edges(
            [0, 1, 2, 3], [(0, 1), (0, 2), (1, 3), (2, 3)],
            random.Random(seed))
        seen.add(tuple(order))
    assert (0, 1, 2, 3) in seen and (0, 2, 1, 3) in seen
