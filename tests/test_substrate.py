"""Substrate tests: optimizer, data pipeline, checkpointing, fault-tolerant
driver, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.data import DataConfig, SyntheticTokenPipeline
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         compress_decompress_ef, cosine_schedule,
                         ef_state_init)
from repro.runtime import (FaultConfig, FaultInjector, run_with_restarts)
from repro.runtime.fault import SimulatedFailure


# ---- optimizer -------------------------------------------------------------------

def test_adamw_reduces_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=0)
    params = {"w": jnp.array([[3.0, -2.0]])}
    state = adamw_init(params, cfg)
    for _ in range(200):
        grads = jax.tree.map(lambda p: 2 * p, params)   # d/dp p^2
        params, state, m = adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.05
    assert int(state["step"]) == 200


def test_adamw_grad_clip_and_metrics():
    cfg = AdamWConfig(lr=1e-2, grad_clip=1.0)
    params = {"w": jnp.ones((4, 4))}
    state = adamw_init(params, cfg)
    grads = {"w": jnp.full((4, 4), 100.0)}
    new_p, state, m = adamw_update(params, grads, state, cfg)
    assert m["grad_norm"] == pytest.approx(400.0)
    assert bool(jnp.all(jnp.isfinite(new_p["w"])))


def test_adamw_bf16_moments():
    cfg = AdamWConfig(moment_dtype="bfloat16")
    params = {"w": jnp.ones((2, 2), jnp.bfloat16)}
    state = adamw_init(params, cfg)
    assert state["mu"]["w"].dtype == jnp.bfloat16
    new_p, state, _ = adamw_update(params, {"w": jnp.ones((2, 2))}, state, cfg)
    assert new_p["w"].dtype == jnp.bfloat16


def test_cosine_schedule_shape():
    assert float(cosine_schedule(jnp.array(0), warmup=10, total=100)) == 0.0
    assert float(cosine_schedule(jnp.array(10), warmup=10, total=100)) \
        == pytest.approx(1.0)
    end = float(cosine_schedule(jnp.array(100), warmup=10, total=100))
    assert end == pytest.approx(0.1, abs=1e-3)


# ---- gradient compression ------------------------------------------------------------

def test_ef_compression_error_feedback_is_unbiased_over_time():
    g = {"w": jnp.array([0.3, -0.7, 0.001, 2.0])}
    ef = ef_state_init(g)
    acc = jnp.zeros(4)
    for _ in range(50):
        deq, ef = compress_decompress_ef(g, ef)
        acc = acc + deq["w"]
    # mean of decompressed gradients converges to the true gradient
    np.testing.assert_allclose(np.asarray(acc / 50), np.asarray(g["w"]),
                               atol=2e-3)


def test_ef_compression_int8_range():
    g = {"w": jnp.linspace(-5, 5, 64)}
    deq, ef = compress_decompress_ef(g, ef_state_init(g))
    # one-shot error bounded by the quantization step
    step = 5.0 / 127
    assert float(jnp.abs(deq["w"] - g["w"]).max()) <= step + 1e-6


# ---- data pipeline ---------------------------------------------------------------------

def test_pipeline_deterministic_and_resumable():
    cfg = DataConfig(vocab=101, seq_len=32, global_batch=8, seed=7)
    p1 = SyntheticTokenPipeline(cfg)
    p2 = SyntheticTokenPipeline(cfg)
    b1 = p1.global_batch_at(5)
    b2 = p2.global_batch_at(5)          # fresh instance, same step
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (8, 32)
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_pipeline_sharding_partitions_global_batch():
    cfg = DataConfig(vocab=101, seq_len=16, global_batch=8, seed=3)
    p = SyntheticTokenPipeline(cfg)
    full = p.global_batch_at(2)
    parts = [p.shard_batch_at(2, s, 4) for s in range(4)]
    stacked = np.concatenate([x["tokens"] for x in parts])
    np.testing.assert_array_equal(full["tokens"], stacked)


def test_pipeline_steps_differ():
    cfg = DataConfig(vocab=101, seq_len=16, global_batch=2, seed=3)
    p = SyntheticTokenPipeline(cfg)
    assert not np.array_equal(p.global_batch_at(0)["tokens"],
                              p.global_batch_at(1)["tokens"])


# ---- checkpointing ------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "step": jnp.int32(7)}}
    save_checkpoint(str(tmp_path), 3, tree)
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    restored, step = load_checkpoint(str(tmp_path), like)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_atomicity_and_retention(tmp_path):
    tree = {"w": jnp.ones((2,))}
    for s in range(5):
        save_checkpoint(str(tmp_path), s, tree, keep=2)
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert kept == ["step_00000003", "step_00000004"]
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_checkpoint_corruption_detected(tmp_path):
    tree = {"w": jnp.ones((8,))}
    path = save_checkpoint(str(tmp_path), 0, tree)
    # flip a byte in the tensor file
    fname = [f for f in os.listdir(path) if f.startswith("leaf_")][0]
    fp = os.path.join(path, fname)
    data = bytearray(open(fp, "rb").read())
    data[-1] ^= 0xFF
    open(fp, "wb").write(bytes(data))
    with pytest.raises(IOError, match="checksum"):
        load_checkpoint(str(tmp_path), tree)


def test_async_checkpoint_manager(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.full((16,), 3.0)}
    mgr.save_async(1, tree)
    mgr.wait()
    assert mgr.latest_step() == 1
    restored, step = mgr.restore(jax.tree.map(jnp.zeros_like, tree))
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))


# ---- fault-tolerant driver -----------------------------------------------------------------

def test_run_with_restarts_recovers_and_loses_no_steps(tmp_path):
    """Inject 2 failures; verify the run completes, restarts happened, and
    every step executed exactly once after its last checkpoint."""
    executed = []
    store = {}

    def init_state():
        return {"sum": 0, "last": -1}

    def step_fn(state, step):
        executed.append(step)
        return {"sum": state["sum"] + step, "last": step}

    def save_fn(state, step):
        store["ckpt"] = (dict(state), step)

    def restore_fn():
        return (dict(store["ckpt"][0]), store["ckpt"][1]) \
            if "ckpt" in store else None

    inj = FaultInjector(fail_at_steps=[7, 13])
    out = run_with_restarts(total_steps=20, init_state=init_state,
                            step_fn=step_fn, save_fn=save_fn,
                            restore_fn=restore_fn, save_every=5,
                            injector=inj)
    assert out["restarts"] == 2
    assert out["completed_steps"] == 20
    assert out["state"]["sum"] == sum(range(20))   # exactly-once semantics
    assert out["state"]["last"] == 19


def test_run_with_restarts_gives_up_after_budget():
    inj = FaultInjector(fail_at_steps=[1])

    def step_fn(state, step):
        if step == 1:
            raise SimulatedFailure("always")
        return state

    with pytest.raises(SimulatedFailure):
        run_with_restarts(total_steps=5, init_state=dict,
                          step_fn=step_fn, save_fn=lambda s, t: None,
                          restore_fn=lambda: None,
                          fault=FaultConfig(max_restarts=2), injector=None)
