"""Dry-run machinery integration test: run real lower+compile cells at
reduced scale on an 8-device local mesh (subprocess so the device-count flag
stays contained)."""
import json
import os
import subprocess
import sys
import tempfile

import pytest

REPO = os.path.join(os.path.dirname(__file__), "..")


def _run_cell(arch, shape, tmp):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", "local", "--reduced", "--out", tmp],
        env=env, capture_output=True, text=True, timeout=900, cwd=REPO)
    assert res.returncode == 0, f"stdout:{res.stdout}\nstderr:{res.stderr[-2000:]}"
    path = os.path.join(tmp, f"{arch}__{shape}__local.json")
    with open(path) as f:
        return json.load(f)


@pytest.mark.parametrize("arch,shape", [
    ("qwen2-7b", "train_4k"),          # dense train step
    ("dbrx-132b", "decode_32k"),       # MoE decode with KV cache
])
def test_dryrun_cell_compiles_and_reports(arch, shape, tmp_path):
    art = _run_cell(arch, shape, str(tmp_path))
    assert art["status"] == "ok"
    assert art["cost"]["flops"] > 0
    assert art["cost"]["bytes accessed"] > 0
    assert art["collectives"]["count"] >= 0
    assert art["memory"].get("temp_size_bytes") is not None
    # extrapolation metadata present and coherent
    assert art["cost_points"]["reps_full"] >= 2
    assert art["cost"]["flops"] >= art["cost_points"]["a"]["flops"]
