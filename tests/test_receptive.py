"""Receptive-field backtrace: textbook values + footprint monotonicity."""
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.graph import Layer, LayerGraph
from repro.core.receptive import (backtrace_rows, group_footprint_words,
                                  max_tile_rows, receptive_field_hw,
                                  required_input_rows)


def conv_chain(ks, strides=None, hw=32, ch=4):
    strides = strides or [1] * len(ks)
    g = LayerGraph("rf")
    prev = g.add(Layer(name="input", kind="input", m=ch, p=hw, q=hw))
    h = w = hw
    names = []
    for i, (k, s) in enumerate(zip(ks, strides)):
        p = (h + 2 * (k // 2) - k) // s + 1
        q = (w + 2 * (k // 2) - k) // s + 1
        prev = g.add(Layer(name=f"c{i}", kind="conv", c=ch, h=h, w=w, m=ch,
                           p=p, q=q, r=k, s=k, stride=(s, s),
                           padding=(k // 2, k // 2)), [prev])
        names.append(prev)
        h, w = p, q
    return g, names


def test_required_rows_3x3():
    l = Layer(name="c", kind="conv", c=1, h=32, w=32, m=1, p=32, q=32,
              r=3, s=3, padding=(1, 1))
    assert required_input_rows(l, 1) == 3
    assert required_input_rows(l, 4) == 6            # (4-1)*1 + 3


def test_required_rows_stride2():
    l = Layer(name="c", kind="conv", c=1, h=32, w=32, m=1, p=16, q=16,
              r=3, s=3, stride=(2, 2), padding=(1, 1))
    assert required_input_rows(l, 1) == 3
    assert required_input_rows(l, 2) == 5            # (2-1)*2 + 3


def test_two_3x3_convs_give_5x5_rf():
    # classic result: stacking two 3x3 convs -> 5x5 receptive field (Fig. 5)
    g, names = conv_chain([3, 3])
    rf = receptive_field_hw(g, names)
    assert rf == (5, 5)


def test_three_3x3_convs_give_7x7_rf():
    g, names = conv_chain([3, 3, 3])
    assert receptive_field_hw(g, names) == (7, 7)


def test_pointwise_does_not_grow_rf():
    # paper Fig. 3: pointwise receptive field grows differently from 3x3
    g, names = conv_chain([1, 3, 1])
    assert receptive_field_hw(g, names) == (3, 3)


def test_stride_doubles_downstream_growth():
    g, names = conv_chain([3, 3], strides=[2, 1])
    # one output px needs 3 rows of mid; mid 3 rows need (3-1)*2+3 = 7 input
    assert receptive_field_hw(g, names) == (7, 7)


def test_backtrace_rows_clamped_to_height():
    g, names = conv_chain([3, 3], hw=4)
    rows = backtrace_rows(g, names, 100)
    for n in names:
        assert rows[n] <= g.layers[n].p


@given(st.integers(min_value=1, max_value=16))
@settings(max_examples=20, deadline=None)
def test_footprint_monotonic_in_tile(t):
    g, names = conv_chain([3, 3, 3], hw=32)
    f1 = group_footprint_words(g, names, t)
    f2 = group_footprint_words(g, names, t + 1)
    assert f2 >= f1 > 0


def test_max_tile_rows_maximal_and_feasible():
    g, names = conv_chain([3, 3], hw=32, ch=8)
    cap = group_footprint_words(g, names, 5)
    t = max_tile_rows(g, names, cap)
    assert t >= 5
    assert group_footprint_words(g, names, t) <= cap
    if t < 32:
        assert group_footprint_words(g, names, t + 1) > cap


def test_max_tile_rows_zero_when_too_small():
    g, names = conv_chain([3, 3], hw=32, ch=64)
    assert max_tile_rows(g, names, 10) == 0
