"""`repro.obs` units: metric instruments and registry, the JSONL tracer
(schema, ambient span stack, null objects), the trace-file aggregator
(`repro trace`), the collector's window math, and the artifact-summary
renderer.  Search-level integration (bit-identity, observer ordering,
span counts against real runs) lives in tests/test_obs_search.py."""
import io
import json
import math
import os

import pytest

from repro.obs import (NULL_REGISTRY, NULL_TRACER, SCHEMA_VERSION,
                       MetricRegistry, TelemetryCollector, Tracer, clock,
                       trace_path_from_env, validate_event)
from repro.obs.collect import TRACE_ENV
from repro.obs.metrics import Counter, Gauge, Histogram, series_name
from repro.obs.report import render_telemetry
from repro.obs.traceview import read_trace


# ---- instruments ------------------------------------------------------------------

def test_counter_gauge_histogram_basics():
    c = Counter()
    c.inc()
    c.inc(4)
    assert c.snapshot() == 5
    g = Gauge()
    g.set(2)
    g.set(0.25)
    assert g.snapshot() == 0.25
    h = Histogram()
    for v in (1.0, 3.0, 0.5):
        h.observe(v)
    s = h.snapshot()
    assert s["count"] == 3 and s["total"] == 4.5
    assert s["min"] == 0.5 and s["max"] == 3.0
    assert s["mean"] == pytest.approx(1.5)


def test_histogram_buckets_are_power_of_two_magnitudes():
    h = Histogram()
    # frexp exponents: 1.0 -> 1, 2.0..3.99 -> 2, 0.5 -> 0; v <= 0 -> 0
    h.observe(1.0)
    h.observe(2.0)
    h.observe(3.0)
    h.observe(0.0)
    s = h.snapshot()
    assert s["buckets"] == {"0": 1, "1": 1, "2": 2}
    # string keys so the snapshot JSON-serializes with sort_keys
    json.dumps(s, sort_keys=True)


def test_empty_histogram_snapshot_has_no_infinities():
    s = Histogram().snapshot()
    assert s == {"count": 0, "total": 0.0, "min": 0.0, "max": 0.0,
                 "mean": 0.0, "buckets": {}}
    assert math.isfinite(s["min"]) and math.isfinite(s["max"])


def test_registry_get_or_create_and_labels():
    reg = MetricRegistry()
    assert reg.counter("a") is reg.counter("a")
    # distinct label sets are distinct series; label order is canonical
    assert reg.counter("a", x="1") is not reg.counter("a", x="2")
    assert reg.counter("b", x="1", y="2") is reg.counter("b", y="2", x="1")
    assert len(reg) == 4


def test_registry_rejects_type_conflict_on_one_series():
    reg = MetricRegistry()
    reg.counter("n")
    with pytest.raises(TypeError, match="one series, one instrument type"):
        reg.gauge("n")


def test_registry_snapshot_shape_and_series_names():
    reg = MetricRegistry()
    reg.counter("evals", engine="jax").inc(3)
    reg.gauge("rate").set(0.5)
    reg.histogram("lat").observe(2.0)
    snap = reg.snapshot()
    assert set(snap) == {"counters", "gauges", "histograms"}
    assert snap["counters"] == {"evals{engine=jax}": 3}
    assert snap["gauges"] == {"rate": 0.5}
    assert snap["histograms"]["lat"]["count"] == 1
    assert series_name("x", ()) == "x"
    assert series_name("x", (("a", "1"), ("b", "2"))) == "x{a=1,b=2}"


def test_null_registry_is_inert():
    i = NULL_REGISTRY.counter("x", any_label="y")
    i.inc()
    i.set(3.0)
    i.observe(1.0)
    assert len(NULL_REGISTRY) == 0
    assert NULL_REGISTRY.snapshot() == {"counters": {}, "gauges": {},
                                        "histograms": {}}


# ---- tracer -----------------------------------------------------------------------

def events(buf: io.StringIO):
    return [json.loads(line) for line in buf.getvalue().splitlines()]


def test_span_context_manager_nests_and_validates():
    buf = io.StringIO()
    tr = Tracer(stream=buf)
    with tr.span("outer", {"k": 1}):
        with tr.span("inner"):
            tr.point("tick", attrs={"n": 2})
    evs = events(buf)
    assert [e["name"] for e in evs] == ["tick", "inner", "outer"]
    for e in evs:
        assert validate_event(e) == []
    point, inner, outer = evs
    assert point["parent"] == inner["id"]
    assert inner["parent"] == outer["id"]
    assert outer["parent"] is None
    assert outer["attrs"] == {"k": 1}
    assert all(e["pid"] == os.getpid() for e in evs)


def test_retroactive_emit_with_preallocated_id():
    # the SearchSession generation-window pattern: allocate + push an id so
    # children nest under it while open, close it retroactively later
    buf = io.StringIO()
    tr = Tracer(stream=buf)
    gen = tr.alloc_id()
    tr.push(gen)
    tr.emit_span("child", t0=1.0, dur_s=0.5)
    tr.pop()
    tr.emit_span("gen", t0=0.0, dur_s=2.0, span_id=gen, parent=None)
    child, gen_ev = events(buf)
    assert child["parent"] == gen and gen_ev["id"] == gen
    assert validate_event(child) == [] and validate_event(gen_ev) == []


def test_tracer_pop_on_empty_stack_is_none():
    tr = Tracer(stream=io.StringIO())
    assert tr.current() is None and tr.pop() is None


def test_tracer_does_not_close_borrowed_stream():
    buf = io.StringIO()
    Tracer(stream=buf).close()
    assert not buf.closed
    with pytest.raises(ValueError, match="path or a stream"):
        Tracer()


def test_tracer_file_lines_append_and_validate(tmp_path):
    p = tmp_path / "t.jsonl"
    t1 = Tracer(str(p))
    t1.emit_span("a", t0=0.0, dur_s=0.1)
    t1.close()
    t2 = Tracer(str(p))            # append mode: earlier events survive
    t2.point("b")
    t2.close()
    lines = p.read_text().splitlines()
    assert len(lines) == 2
    assert [validate_event(json.loads(ln)) for ln in lines] == [[], []]


def test_validate_event_rejects_schema_drift():
    good = {"v": SCHEMA_VERSION, "pid": 1, "ev": "span", "name": "x",
            "id": 3, "parent": None, "t0": 0.0, "dur_s": 0.1, "attrs": {}}
    assert validate_event(good) == []
    assert validate_event("nope") == ["event is not a JSON object"]
    assert any("v=" in e for e in validate_event({**good, "v": 99}))
    assert any("unknown keys" in e
               for e in validate_event({**good, "rogue": 1}))
    assert any("ev=" in e for e in validate_event({**good, "ev": "blip"}))
    assert validate_event({**good, "dur_s": -1.0})
    assert validate_event({**good, "pid": True})
    assert validate_event({**good, "parent": 0})
    point = {"v": SCHEMA_VERSION, "pid": 1, "ev": "point", "name": "p",
             "parent": None, "ts": 1.0, "attrs": {}}
    assert validate_event(point) == []
    assert any("unknown keys" in e
               for e in validate_event({**point, "dur_s": 0.1}))


def test_null_tracer_is_inert():
    assert NULL_TRACER.enabled is False
    with NULL_TRACER.span("x") as sid:
        assert sid is None
    assert NULL_TRACER.emit_span("x") == 0
    assert NULL_TRACER.alloc_id() == 0
    NULL_TRACER.point("x")
    NULL_TRACER.push(1)
    assert NULL_TRACER.pop() is None and NULL_TRACER.current() is None
    NULL_TRACER.close()


def test_clock_seam_surface():
    assert isinstance(clock.unix_time(), int)
    a = clock.perf_counter()
    assert clock.perf_counter() >= a
    assert clock.now() > 1_600_000_000.0   # wall clock, seconds since epoch


# ---- trace aggregation (repro trace) ----------------------------------------------

def test_read_trace_tree_slowest_and_metrics(tmp_path):
    p = tmp_path / "t.jsonl"
    tr = Tracer(str(p))
    with tr.span("search"):
        for dur in (0.2, 0.4):
            with tr.span("generation"):
                tr.emit_span("batch_eval", t0=0.0, dur_s=dur)
        snap = {"counters": {"eval.states": 10}, "gauges": {},
                "histograms": {"eval.batch_s": Histogram().snapshot()}}
        tr.point("metrics.snapshot", attrs=snap)
    tr.close()
    rep = read_trace(str(p), top=2)
    assert rep.valid and rep.n_events == 6
    assert rep.span_counts == {"search": 1, "generation": 2, "batch_eval": 2}
    paths = {row["path"]: row for row in rep.tree}
    assert paths["search/generation/batch_eval"]["count"] == 2
    assert paths["search/generation/batch_eval"]["max_s"] == 0.4
    assert len(rep.slowest) == 2
    assert rep.slowest[0]["dur_s"] == pytest.approx(0.4)
    assert rep.point_counts == {"metrics.snapshot": 1}
    assert rep.metrics["counters"] == {"eval.states": 10}
    # the JSON the CLI --json mode prints round-trips
    d = json.loads(json.dumps(rep.to_dict()))
    assert d["valid"] and d["span_counts"]["generation"] == 2


def test_read_trace_merges_snapshots_across_processes(tmp_path):
    # forked island workers each emit their own metrics.snapshot point;
    # counters sum, gauges last-wins, histograms combine
    p = tmp_path / "t.jsonl"
    tr = Tracer(str(p))
    h1, h2 = Histogram(), Histogram()
    h1.observe(1.0)
    h2.observe(4.0)
    tr.point("metrics.snapshot", attrs={
        "counters": {"eval.states": 3}, "gauges": {"g": 1.0},
        "histograms": {"h": h1.snapshot()}})
    tr.point("metrics.snapshot", attrs={
        "counters": {"eval.states": 5}, "gauges": {"g": 2.0},
        "histograms": {"h": h2.snapshot()}})
    tr.close()
    rep = read_trace(str(p))
    assert rep.metrics["counters"]["eval.states"] == 8
    assert rep.metrics["gauges"]["g"] == 2.0
    h = rep.metrics["histograms"]["h"]
    assert h["count"] == 2 and h["min"] == 1.0 and h["max"] == 4.0
    assert h["mean"] == pytest.approx(2.5)


def test_read_trace_invalid_lines_fail_validity_but_still_aggregate(tmp_path):
    p = tmp_path / "t.jsonl"
    tr = Tracer(str(p))
    tr.emit_span("ok", t0=0.0, dur_s=0.1)
    tr.close()
    with open(p, "a") as f:
        f.write("not json at all\n")
        f.write(json.dumps({"v": 99, "pid": 1, "ev": "span"}) + "\n")
        f.write("\n")                       # blank lines are skipped
    rep = read_trace(str(p))
    assert not rep.valid and len(rep.errors) == 2
    assert rep.n_events == 1 and rep.span_counts == {"ok": 1}
    assert "INVALID" in rep.describe()


def test_read_trace_orphan_parent_roots_at_own_name(tmp_path):
    # a forked worker's child span can outlive a parent window that is
    # discarded unemitted — it must root at its own name, not crash
    p = tmp_path / "t.jsonl"
    tr = Tracer(str(p))
    tr.emit_span("batch_eval", t0=0.0, dur_s=0.1, parent=12345)
    tr.close()
    rep = read_trace(str(p))
    assert rep.valid
    assert rep.tree[0]["path"] == "batch_eval"


# ---- collector --------------------------------------------------------------------

class FakeEvaluator:
    group_hits = 0
    group_misses = 0


def test_collector_window_math_and_generation_records():
    col = TelemetryCollector()                      # metrics only, no tracer
    ev = FakeEvaluator()
    col.bind_evaluator(ev)
    col.begin_search({"workload": "w"})
    col.record_batch(4, 3, [2.0, 0.0, 1.0, 1.0], "numpy", 0.0, 0.01, 2)
    ev.group_hits, ev.group_misses = 6, 2
    col.on_step(0, best=2.0, evals=3, offspring=4)
    assert len(col.generations) == 1
    rec = col.generations[0]
    assert rec["batch_states"] == 4 and rec["batch_unique"] == 3
    assert rec["rejection_rate"] == pytest.approx(0.25)
    assert rec["mean"] == pytest.approx(1.0)
    assert rec["std"] == pytest.approx(math.sqrt(0.5))
    assert rec["group_hit_rate"] == pytest.approx(6 / 8)
    assert rec["novel_groups"] == 2
    # the window drained: an empty next tick records zeros, not stale sums
    col.on_step(1, best=2.0, evals=3, offspring=4)
    assert col.generations[1]["batch_states"] == 0
    assert col.generations[1]["mean"] == 0.0
    snap = col.registry.snapshot()
    assert snap["counters"]["eval.states"] == 4
    assert snap["counters"]["eval.invalid"] == 1
    assert snap["counters"]["eval.batches_by_engine{engine=numpy}"] == 1
    s = col.summary({"group_hit_rate": 0.75})
    assert s["schema"] == 1 and s["steps"] == 2
    assert s["best"] == [2.0, 2.0]
    assert s["rejection_rate"] == [0.25, 0.0]
    assert s["cache"]["group_hit_rate"] == 0.75
    json.dumps(s, sort_keys=True)                   # artifact-embeddable


def test_collector_span_scaffolding_counts_generations():
    buf = io.StringIO()
    col = TelemetryCollector(tracer=Tracer(stream=buf))
    col.bind_evaluator(FakeEvaluator())
    col.begin_search({"workload": "w", "seed": 0})
    col.record_batch(2, 2, [1.0, 1.5], "scalar", 0.0, 0.01, 1)
    col.on_step(0, best=1.5, evals=2, offspring=2)
    col.record_batch(2, 1, [1.5], "scalar", 0.0, 0.01, 0)
    col.on_step(1, best=1.5, evals=3, offspring=4)
    col.end_search({"unique_groups": 3})
    evs = events(buf)
    assert all(validate_event(e) == [] for e in evs)
    by_name = {}
    for e in evs:
        by_name.setdefault(e["name"], []).append(e)
    # exactly one generation span per tick; the dangling post-final window
    # is discarded unemitted
    assert len(by_name["generation"]) == 2
    search = by_name["search"][0]
    assert search["attrs"]["steps"] == 2
    assert search["attrs"]["cache"] == {"unique_groups": 3}
    assert all(g["parent"] == search["id"] for g in by_name["generation"])
    gen_ids = {g["id"] for g in by_name["generation"]}
    assert all(b["parent"] in gen_ids for b in by_name["batch_eval"])
    # novel-group costing window nests under its batch span
    cost = by_name["costmodel"][0]
    assert cost["parent"] == by_name["batch_eval"][0]["id"]
    assert by_name["metrics.snapshot"][0]["parent"] == search["id"]


def test_collector_from_env(monkeypatch, tmp_path):
    monkeypatch.delenv(TRACE_ENV, raising=False)
    assert trace_path_from_env() is None
    assert TelemetryCollector.from_env() is None
    monkeypatch.setenv(TRACE_ENV, "")               # empty means unset
    assert TelemetryCollector.from_env() is None
    p = tmp_path / "env.jsonl"
    monkeypatch.setenv(TRACE_ENV, str(p))
    col = TelemetryCollector.from_env()
    assert col is not None and col.tracer.enabled
    col.tracer.point("hello")
    col.close()
    assert validate_event(json.loads(p.read_text())) == []


def test_collector_migration_and_certificate_hooks():
    buf = io.StringIO()
    col = TelemetryCollector(tracer=Tracer(stream=buf))
    col.record_migration(2, best=1.2, islands=4, migration=False)
    col.record_migration(3, best=1.3, islands=4, migration=True)
    snap = col.registry.snapshot()
    assert snap["counters"]["island.barriers"] == 2
    assert snap["counters"]["island.migrations"] == 1

    class Cert:
        traffic_words = 100
        schedule_lb_words = 80
        graph_lb_words = 60
        gap_vs_schedule = 0.25
        gap_vs_graph = 0.666667

    col.record_certificate("sha256:ab", Cert(), ok=True)
    snap = col.registry.snapshot()
    assert snap["counters"]["verify.artifacts{ok=true}"] == 1
    evs = events(buf)
    names = [e["name"] for e in evs]
    assert names.count("island.migration") == 1     # barriers are not points
    cert_ev = [e for e in evs if e["name"] == "verify.certificate"][0]
    assert cert_ev["attrs"]["gap_vs_schedule"] == 0.25
    assert all(validate_event(e) == [] for e in evs)


# ---- renderer ---------------------------------------------------------------------

def make_summary(n=6):
    return {
        "schema": 1, "steps": n,
        "best": [1.0 + 0.1 * i for i in range(n)],
        "mean": [0.8 + 0.1 * i for i in range(n)],
        "std": [0.1] * n,
        "rejection_rate": [0.5 / (i + 1) for i in range(n)],
        "group_hit_rate": [i / n for i in range(n)],
        "unique_states": [10 * (i + 1) for i in range(n)],
        "offspring": [12 * (i + 1) for i in range(n)],
        "cache": {"group_hit_rate": 0.9, "unique_groups": 42,
                  "pop_backend": "numpy", "batch_evals_per_sec": 5000.0},
        "metrics": {"counters": {"eval.states": 60, "eval.invalid": 9}},
    }


def test_render_telemetry_curve_cache_and_rejection_lines():
    out = render_telemetry(make_summary())
    assert "6 steps, best 1.0000 -> 1.5000" in out
    assert "60 unique states" in out
    assert "unique_groups 42" in out and "engine numpy" in out
    assert "9 of 60 scored states were unschedulable (15.0%)" in out
    assert out.count("|#") == 6                     # one bar row per step


def test_render_telemetry_downsamples_long_runs_keeping_endpoints():
    out = render_telemetry(make_summary(n=200))
    rows = [ln for ln in out.splitlines() if "|" in ln]
    assert len(rows) == 20
    assert "     0  " in rows[0] and "   199  " in rows[-1]


def test_render_telemetry_empty_summary():
    out = render_telemetry({"schema": 1, "steps": 0, "best": []})
    assert "no per-generation records" in out
