"""`repro.serve`: content-addressed ArtifactStore (round-trip, atomicity,
concurrent writers, schema leniency) and the batch scheduler (in-flight
dedup, store hits with zero new evaluations, worker fan-out, CLI verbs)."""
import json
import multiprocessing
import os

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.costmodel.evaluator import ScheduleCost
from repro.search import ScheduleArtifact, SearchSpec
from repro.serve import (ArtifactStore, BatchScheduler, StoreError,
                         artifact_key, spec_hash)

FAST = {"preset": "fast", "generations": 4}


def make_artifact(workload="vgg16", seed=0, mask=0x15, fitness=1.25,
                  fingerprint="sha256:feed", backend="ga"):
    """A structurally valid artifact without running a search."""
    cost = ScheduleCost(energy_pj=10.0, cycles=5.0, dram_read_words=7,
                        dram_write_words=3, act_write_events=2, macs=100,
                        n_groups=4)
    return ScheduleArtifact(
        spec=SearchSpec(workload=workload, backend=backend, seed=seed,
                        backend_config=dict(FAST)),
        graph_fingerprint=fingerprint, n_edges=21, genome_mask=mask,
        best_fitness=fitness, baseline=cost, best=cost,
        history=[1.0, fitness], evaluations=9, offspring_evaluated=12)


# ---- keys -------------------------------------------------------------------------

def test_spec_hash_canonical_across_json_round_trip():
    spec = SearchSpec(workload="vgg16", backend="island",
                      backend_config={"islands": 4, "migrate_every": 8})
    again = SearchSpec.from_json(spec.to_json())
    assert spec_hash(spec) == spec_hash(again)
    assert artifact_key("sha256:f", spec) == artifact_key("sha256:f", again)


def test_key_changes_with_spec_and_fingerprint():
    spec = SearchSpec(workload="vgg16")
    assert artifact_key("sha256:a", spec) != artifact_key("sha256:b", spec)
    assert artifact_key("sha256:a", spec) != \
        artifact_key("sha256:a", spec.replace(seed=1))


# ---- store round-trip -------------------------------------------------------------

@settings(max_examples=25)
@given(mask=st.integers(min_value=0, max_value=(1 << 21) - 1),
       seed=st.integers(min_value=0, max_value=1 << 16),
       workload=st.sampled_from(["vgg16", "unet", "resnet50"]),
       backend=st.sampled_from(["ga", "island", "random"]))
def test_store_put_get_round_trip(mask, seed, workload, backend):
    # tempfile, not a pytest fixture: the conftest hypothesis shim (and
    # real hypothesis's health checks) don't mix fixtures with @given
    import shutil
    import tempfile
    root = tempfile.mkdtemp(prefix="store-prop-")
    try:
        store = ArtifactStore(root)
        art = make_artifact(workload=workload, seed=seed, mask=mask,
                            backend=backend)
        key = store.put(art)
        got = store.get(art.graph_fingerprint, art.spec)
        assert got is not None
        assert got.to_dict() == art.to_dict()
        assert store.path_for(key).startswith(root)
        assert list(store.keys()) == [key]
    finally:
        shutil.rmtree(root, ignore_errors=True)


def test_store_miss_and_counters(tmp_path):
    store = ArtifactStore(str(tmp_path))
    assert store.get("sha256:none", SearchSpec(workload="vgg16")) is None
    store.put(make_artifact())
    store.get("sha256:feed", make_artifact().spec)
    s = store.stats()
    assert (s["hits"], s["misses"], s["puts"], s["objects"]) == (1, 1, 1, 1)


def test_store_put_is_idempotent(tmp_path):
    store = ArtifactStore(str(tmp_path))
    art = make_artifact()
    assert store.put(art) == store.put(art)
    assert len(store) == 1


def test_store_rejects_corrupt_object(tmp_path):
    store = ArtifactStore(str(tmp_path))
    art = make_artifact()
    key = store.put(art)
    with open(store.path_for(key), "w") as f:
        f.write("{ not json")
    with pytest.raises(StoreError, match="corrupt"):
        store.get(art.graph_fingerprint, art.spec)


def test_store_rejects_key_content_mismatch(tmp_path):
    """An object hand-copied under the wrong key must not be served."""
    store = ArtifactStore(str(tmp_path))
    art = make_artifact()
    key = store.put(art)
    other = make_artifact(seed=99)
    wrong = store.path_for(artifact_key(other.graph_fingerprint, other.spec))
    os.makedirs(os.path.dirname(wrong), exist_ok=True)
    with open(store.path_for(key)) as src, open(wrong, "w") as dst:
        dst.write(src.read())
    with pytest.raises(StoreError, match="does not match its key"):
        store.get(other.graph_fingerprint, other.spec)


def test_store_version_gate(tmp_path):
    ArtifactStore(str(tmp_path))
    (tmp_path / "store.json").write_text(json.dumps({"store_version": 99}))
    with pytest.raises(StoreError, match="layout version"):
        ArtifactStore(str(tmp_path))


def test_store_requires_create_flag_for_new_root(tmp_path):
    with pytest.raises(StoreError, match="no store"):
        ArtifactStore(str(tmp_path / "absent"), create=False)


# ---- schema leniency (pre-PR-3 artifacts) -----------------------------------------

def _pre_pr3_dict():
    """An artifact dict as PR-2-era builds wrote it: no costmodel field,
    no group_breakdowns key."""
    d = make_artifact().to_dict()
    del d["group_breakdowns"]
    del d["spec"]["costmodel"]
    return d


def test_pre_pr3_artifact_loads_with_warning(tmp_path):
    art = ScheduleArtifact.from_dict(_pre_pr3_dict())
    assert art.spec.costmodel == "default"
    assert art.group_breakdowns == []
    assert any("predates per-group cost breakdowns" in w
               for w in art.load_warnings)
    # and straight out of a store object file, too
    store = ArtifactStore(str(tmp_path))
    path = store.path_for(artifact_key(art.graph_fingerprint, art.spec))
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(_pre_pr3_dict(), f)
    got = store.get(art.graph_fingerprint, art.spec)
    assert got is not None and got.load_warnings


def test_malformed_breakdown_rows_drop_not_crash():
    d = make_artifact().to_dict()
    d["group_breakdowns"] = [{"bogus": 1}]
    art = ScheduleArtifact.from_dict(d)
    assert art.group_breakdowns == []
    assert any("malformed group breakdown" in w for w in art.load_warnings)


def test_missing_cost_fields_raise_value_error_not_type_error(tmp_path):
    """baseline/best are load-bearing: a record missing required fields is
    corrupt, but it must surface as the error type callers (and the CLI
    handler) already catch."""
    from repro.__main__ import main
    d = make_artifact().to_dict()
    del d["best"]["energy_pj"]
    with pytest.raises(ValueError, match="malformed ScheduleCost"):
        ScheduleArtifact.from_dict(d)
    path = tmp_path / "corrupt.json"
    path.write_text(json.dumps(d))
    assert main(["report", str(path)]) == 2      # "error: ...", no traceback


def test_truncated_artifact_missing_object_raises_value_error(tmp_path):
    from repro.__main__ import main
    d = make_artifact().to_dict()
    del d["best"]
    with pytest.raises(ValueError, match="missing required field 'best'"):
        ScheduleArtifact.from_dict(d)
    path = tmp_path / "truncated.json"
    path.write_text(json.dumps(d))
    assert main(["report", str(path)]) == 2


def test_unknown_cost_fields_warn_not_crash():
    d = make_artifact().to_dict()
    d["best"]["future_field"] = 1.0
    art = ScheduleArtifact.from_dict(d)
    assert art.best.energy_pj == 10.0
    assert any("unknown ScheduleCost fields" in w for w in art.load_warnings)


def test_cli_report_pre_pr3_artifact_warns_and_succeeds(tmp_path, capsys):
    from repro.__main__ import main
    path = tmp_path / "old.json"
    path.write_text(json.dumps(_pre_pr3_dict()))
    assert main(["report", str(path)]) == 0
    err = capsys.readouterr().err
    assert "warning" in err and "predates" in err


# ---- concurrent writers -----------------------------------------------------------

def _hammer(args):
    root, worker = args
    store = ArtifactStore(root)
    for i in range(12):
        # keys overlap across workers (same seed -> same key), so every
        # object is raced by all four writers
        store.put(make_artifact(mask=i, seed=i, fitness=1.0 + worker))
    return worker


def test_concurrent_writers_never_tear_objects(tmp_path):
    root = str(tmp_path)
    ArtifactStore(root)
    try:
        ctx = multiprocessing.get_context("fork")
    except ValueError:
        pytest.skip("no fork on this platform")
    with ctx.Pool(4) as pool:
        done = pool.map(_hammer, [(root, w) for w in range(4)])
    assert sorted(done) == [0, 1, 2, 3]
    store = ArtifactStore(root)
    keys = list(store.keys())
    assert len(keys) == 12                   # one object per distinct key
    for key in keys:
        art = store.load_key(key)            # parses whole: never torn
        assert art.genome_mask in range(12)
        assert art.best_fitness in (1.0, 2.0, 3.0, 4.0)


# ---- scheduler --------------------------------------------------------------------

def test_scheduler_dedups_and_caches(tmp_path):
    store = ArtifactStore(str(tmp_path))
    sched = BatchScheduler(store, workers=1)
    spec = SearchSpec(workload="vgg16", backend_config=dict(FAST))
    sched.submit(spec)
    sched.submit(SearchSpec.from_dict(spec.to_dict()))   # identical
    sched.submit(spec.replace(seed=1))                   # distinct
    out = sched.run()
    s = out.stats
    assert s["searched"] == 2 and s["cache_hits"] == 1
    assert s["deduped_in_flight"] == 1 and s["failed"] == 0
    assert sched.searches_run == 2
    assert out.jobs[1].key == out.jobs[0].key


def test_scheduler_resubmit_hits_store_with_zero_evaluations(tmp_path,
                                                            monkeypatch):
    store = ArtifactStore(str(tmp_path))
    spec = SearchSpec(workload="vgg16", backend_config=dict(FAST))
    first = BatchScheduler(store, workers=1)
    first.submit(spec)
    assert first.run().stats["searched"] == 1

    # an identical resubmission must be a pure read: no session, no
    # evaluator, zero new evaluations — searching at all is the failure
    import repro.serve.scheduler as sched_mod

    def boom(*a, **k):
        raise AssertionError("cache hit must not build a SearchSession")

    monkeypatch.setattr(sched_mod, "SearchSession", boom)
    again = BatchScheduler(store, workers=1)
    job = again.submit(SearchSpec.from_dict(spec.to_dict()))
    out = again.run()
    assert out.stats == {**out.stats, "searched": 0, "cache_hits": 1}
    assert again.searches_run == 0
    assert job.artifact.genome_mask >= 0


def test_scheduler_worker_pool_matches_inline(tmp_path):
    specs = [SearchSpec(workload="vgg16", backend_config=dict(FAST)),
             SearchSpec(workload="unet", backend_config=dict(FAST))]
    inline_store = ArtifactStore(str(tmp_path / "a"))
    pooled_store = ArtifactStore(str(tmp_path / "b"))
    inline = BatchScheduler(inline_store, workers=1)
    pooled = BatchScheduler(pooled_store, workers=2)
    for s in specs:
        inline.submit(s)
        pooled.submit(s)
    ja, jb = inline.run().jobs, pooled.run().jobs
    for a, b in zip(ja, jb):
        assert a.key == b.key
        assert a.artifact.genome_mask == b.artifact.genome_mask
        assert a.artifact.best_fitness == b.artifact.best_fitness


def test_scheduler_pool_runs_island_backend(tmp_path):
    """Island searches inside daemonic pool workers degrade to threads
    (daemons may not fork children) instead of failing the job."""
    store = ArtifactStore(str(tmp_path))
    sched = BatchScheduler(store, workers=2)
    island = SearchSpec(workload="vgg16", backend="island",
                        backend_config={**FAST, "islands": 2,
                                        "migrate_every": 2})
    sched.submit(island)
    sched.submit(SearchSpec(workload="unet", backend_config=dict(FAST)))
    out = sched.run()
    assert out.stats["failed"] == 0 and out.stats["searched"] == 2
    # pooled island result matches the inline one exactly
    inline = BatchScheduler(ArtifactStore(str(tmp_path / "b")), workers=1)
    job = inline.submit(SearchSpec.from_dict(island.to_dict()))
    inline.run()
    assert job.artifact.genome_mask == out.jobs[0].artifact.genome_mask


def test_scheduler_isolates_failing_jobs(tmp_path):
    store = ArtifactStore(str(tmp_path))
    sched = BatchScheduler(store, workers=1)
    sched.submit(SearchSpec(workload="no_such_net"))
    ok = sched.submit(SearchSpec(workload="vgg16",
                                 backend_config=dict(FAST)))
    out = sched.run()
    assert out.stats["failed"] == 1 and out.stats["searched"] == 1
    assert out.jobs[0].status == "failed" and "no_such_net" in \
        out.jobs[0].error
    assert ok.status == "done"


def test_scheduler_isolates_corrupt_store_objects(tmp_path):
    """One damaged store object fails only its own job; the rest of the
    batch still resolves."""
    store = ArtifactStore(str(tmp_path))
    spec = SearchSpec(workload="vgg16", backend_config=dict(FAST))
    seeder = BatchScheduler(store, workers=1)
    seeder.submit(spec)
    key = seeder.run().jobs[0].key
    with open(store.path_for(key), "w") as f:
        f.write("{ torn")
    sched = BatchScheduler(store, workers=1)
    bad = sched.submit(SearchSpec.from_dict(spec.to_dict()))
    good = sched.submit(SearchSpec(workload="unet",
                                   backend_config=dict(FAST)))
    out = sched.run()
    assert bad.status == "failed" and "corrupt" in bad.error
    assert good.status == "done"
    assert out.stats["failed"] == 1 and out.stats["searched"] == 1


# ---- CLI --------------------------------------------------------------------------

def _write_jobs(path, n_dup=2):
    jobs = [{"workload": "vgg16", "backend_config": FAST}] * n_dup
    jobs.append({"workload": "unet", "backend_config": FAST})
    path.write_text(json.dumps(jobs))


def test_cli_serve_then_submit_round(tmp_path, capsys):
    from repro.__main__ import main
    jobs = tmp_path / "jobs.json"
    store = tmp_path / "store"
    _write_jobs(jobs)
    rc = main(["serve", "--store", str(store), "--requests", str(jobs),
               "--json"])
    assert rc == 0
    payload = json.loads(capsys.readouterr().out)
    assert payload["stats"]["searched"] == 2
    assert payload["stats"]["cache_hits"] == 1

    # full-batch resubmission: all served, nothing searched
    rc = main(["serve", "--store", str(store), "--requests", str(jobs),
               "--json"])
    payload = json.loads(capsys.readouterr().out)
    assert rc == 0 and payload["stats"]["searched"] == 0
    assert payload["stats"]["cache_hits"] == 3

    # submit: identical single request is a store hit
    out = tmp_path / "served.json"
    rc = main(["submit", "--store", str(store), "--workload", "vgg16",
               "--backend-config", json.dumps(FAST), "--out", str(out)])
    assert rc == 0
    assert "served from store" in capsys.readouterr().out
    assert json.loads(out.read_text())["spec"]["workload"] == "vgg16"


def test_cli_serve_reports_failures_in_exit_code(tmp_path, capsys):
    from repro.__main__ import main
    jobs = tmp_path / "jobs.json"
    jobs.write_text(json.dumps([{"workload": "no_such_net"}]))
    assert main(["serve", "--store", str(tmp_path / "s"),
                 "--requests", str(jobs)]) == 1
    assert main(["serve", "--store", str(tmp_path / "s"),
                 "--requests", str(tmp_path / "missing.json")]) == 2


def test_cli_list_shows_backend_knobs(capsys):
    from repro.__main__ import main
    assert main(["list"]) == 0
    out = capsys.readouterr().out
    assert "island" in out
    assert "migrate_every" in out            # knobs surfaced from docstrings
    assert "crossover_rate" in out
