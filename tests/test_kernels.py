"""Per-kernel validation: shape/dtype sweeps, interpret=True vs pure-jnp
oracle (ref.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (flash_attention, fused_rmsnorm, mamba_scan,
                           rglru_scan)
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mamba_scan.ref import mamba_scan_ref
from repro.kernels.rglru_scan.ref import rglru_scan_ref
from repro.kernels.rmsnorm.ref import rmsnorm_ref

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _tol(dtype):
    return TOL[jnp.bfloat16] if dtype == jnp.bfloat16 else TOL[jnp.float32]


# ---- flash attention ------------------------------------------------------------

@pytest.mark.parametrize("B,S,Hq,Hkv,D", [
    (1, 64, 4, 4, 32),      # MHA
    (2, 80, 4, 2, 32),      # GQA, non-multiple S
    (1, 33, 8, 1, 16),      # MQA, ragged S
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_causal(B, S, Hq, Hkv, D, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), dtype)
    out = flash_attention(q, k, v, block_q=32, block_kv=32, interpret=True)
    ref = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@pytest.mark.parametrize("mode", [dict(window=16), dict(chunk=32),
                                  dict(causal=False)])
def test_flash_attention_masks(mode):
    B, S, Hq, Hkv, D = 2, 96, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    kwargs = dict(causal=True)
    kwargs.update(mode)
    out = flash_attention(q, k, v, block_q=32, block_kv=32, interpret=True,
                          **kwargs)
    ref = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), **kwargs
                        ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)


def test_flash_attention_decode_offset():
    B, Skv, Hq, Hkv, D = 2, 64, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, 1, Hq, D))
    k = jax.random.normal(ks[1], (B, Skv, Hkv, D))
    v = jax.random.normal(ks[2], (B, Skv, Hkv, D))
    out = flash_attention(q, k, v, q_offset=Skv - 1, block_q=8, block_kv=32,
                          interpret=True)
    ref = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), q_offset=Skv - 1
                        ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)


# ---- mamba scan ------------------------------------------------------------------

@pytest.mark.parametrize("B,S,Di,N", [(1, 32, 16, 4), (2, 40, 24, 8),
                                      (1, 7, 130, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mamba_scan(B, S, Di, N, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    da = jax.random.uniform(ks[0], (B, S, Di, N), dtype, 0.5, 0.99)
    dbx = (jax.random.normal(ks[1], (B, S, Di, N)) * 0.1).astype(dtype)
    c = jax.random.normal(ks[2], (B, S, N), dtype)
    y = mamba_scan(da, dbx, c, block_d=8, time_chunk=16, interpret=True)
    yr = mamba_scan_ref(da, dbx, c)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


# ---- rg-lru scan -------------------------------------------------------------------

@pytest.mark.parametrize("B,S,W", [(1, 32, 16), (2, 50, 20), (1, 9, 129)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_scan(B, S, W, dtype):
    ks = jax.random.split(jax.random.PRNGKey(4), 2)
    a = jax.random.uniform(ks[0], (B, S, W), dtype, 0.5, 0.99)
    b = jax.random.normal(ks[1], (B, S, W), dtype)
    h = rglru_scan(a, b, block_w=8, time_chunk=16, interpret=True)
    hr = rglru_scan_ref(a, b)
    np.testing.assert_allclose(np.asarray(h, np.float32),
                               np.asarray(hr, np.float32),
                               atol=_tol(dtype) * 5, rtol=_tol(dtype) * 5)


# ---- fused rmsnorm ------------------------------------------------------------------

@pytest.mark.parametrize("N,D", [(16, 64), (37, 128), (5, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("with_residual", [False, True])
def test_fused_rmsnorm(N, D, dtype, with_residual):
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    x = jax.random.normal(ks[0], (N, D), dtype)
    w = (jax.random.normal(ks[1], (D,)) * 0.1 + 1.0).astype(dtype)
    if with_residual:
        r = jax.random.normal(ks[2], (N, D), dtype)
        y, res = fused_rmsnorm(x, w, r, block_rows=16, interpret=True)
        yr, resr = rmsnorm_ref(x, w, r)
        np.testing.assert_allclose(np.asarray(res, np.float32),
                                   np.asarray(resr, np.float32),
                                   atol=_tol(dtype), rtol=_tol(dtype))
    else:
        y = fused_rmsnorm(x, w, block_rows=16, interpret=True)
        yr = rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


def test_kernels_match_model_attention():
    """The Pallas kernel agrees with the model's XLA attention paths."""
    from repro.models.attention import attention
    B, S, Hq, Hkv, D = 2, 64, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    pos = jnp.arange(S)
    xla = attention(q, k, v, pos, pos, causal=True, impl="blockwise",
                    block_kv=32)
    pallas = flash_attention(q, k, v, block_q=32, block_kv=32,
                             interpret=True)
    np.testing.assert_allclose(np.asarray(xla), np.asarray(pallas),
                               atol=2e-5, rtol=2e-5)
