"""Schedule report + TPU-GA sharding-mode genome."""
from repro.configs import get_config
from repro.configs.base import SHAPES
from repro.core import GAConfig, optimize
from repro.core.report import schedule_report
from repro.core.tpu_ga import optimize_tpu_schedule
from repro.costmodel import SIMBA
from repro.workloads import mobilenet_v3_large


def test_schedule_report_renders_all_groups():
    res = optimize(mobilenet_v3_large(), SIMBA,
                   GAConfig.fast(generations=10, seed=0))
    text = schedule_report(res, SIMBA)
    assert "edp x" in text
    # one row per group (+3 header lines)
    assert len(text.splitlines()) == res.best.n_groups + 3
    assert f"groups={res.best.n_groups}" in text


def test_schedule_report_max_rows():
    res = optimize(mobilenet_v3_large(), SIMBA,
                   GAConfig.fast(generations=5, seed=1))
    text = schedule_report(res, SIMBA, max_rows=4)
    assert "more groups" in text


def test_tpu_ga_selects_fsdp_for_dense_tp_for_moe():
    """The GA's extended genome reproduces the manual §Perf-5 hillclimb:
    FSDP for dense models, TP/EP retained for MoE."""
    dense = optimize_tpu_schedule(get_config("stablelm-1.6b"),
                                  SHAPES["train_4k"],
                                  ga=GAConfig.fast(generations=20, seed=0))
    assert dense.best.sharding == "fsdp"
    moe = optimize_tpu_schedule(get_config("dbrx-132b"), SHAPES["train_4k"],
                                ga=GAConfig.fast(generations=20, seed=0))
    assert moe.best.sharding == "tp"
    assert moe.best_cost.hbm_resident_bytes <= 16e9
