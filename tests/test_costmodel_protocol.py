"""CostModel protocol: registry, CostBreakdown, TPU roofline backend,
per-group breakdowns in artifacts/reports, and mapper bounds."""
import json
import math

import pytest

from repro.core.fusion import FusionState
from repro.core.graph import Layer, LayerGraph
from repro.costmodel import (CostBreakdown, CostModel, DefaultCostModel,
                             Evaluator, ScheduleCost, SIMBA,
                             TpuFusionCostModel, spatial_utilization)
from repro.search import (ACCELERATORS, COSTMODELS, RegistryError,
                          SearchSession, SearchSpec, build_accelerator,
                          register_costmodel, search)
from tests.test_fusion import chain


# ---- registry ---------------------------------------------------------------------

def test_builtin_costmodels_registered():
    assert "default" in COSTMODELS and "tpu" in COSTMODELS
    assert COSTMODELS.get("default") is DefaultCostModel
    with pytest.raises(RegistryError, match="unknown costmodel"):
        COSTMODELS.get("accelergy")


def test_register_custom_costmodel_runs_end_to_end():
    name = "test_unit_energy"
    if name not in COSTMODELS:
        @register_costmodel(name)
        class UnitEnergyModel(DefaultCostModel):
            """Energy = DRAM words only: a pure traffic objective."""
            name_ = name

            def cost_group(self, key):
                bd = super().cost_group(key)
                if bd is None:
                    return None
                traffic = float(bd.dram_read_words + bd.dram_write_words)
                return CostBreakdown(
                    energy_pj=traffic,
                    compute_cycles=bd.compute_cycles,
                    dram_cycles=bd.dram_cycles,
                    dram_read_words=bd.dram_read_words,
                    dram_write_words=bd.dram_write_words,
                    act_write_events=bd.act_write_events,
                    macs=bd.macs, members=bd.members,
                    energy_terms={"dram_words": traffic})
    art = search("mobilenet_v3", "simba", costmodel=name, backend="ga",
                 backend_config={"preset": "fast", "generations": 3}, seed=0)
    assert art.spec.costmodel == name
    # energy now *is* dram traffic, word for word
    assert art.best.energy_pj == pytest.approx(
        art.best.dram_read_words + art.best.dram_write_words)


def test_spec_rejects_unknown_costmodel_at_session_creation():
    with pytest.raises(RegistryError, match="unknown costmodel"):
        SearchSession(SearchSpec(workload="mobilenet_v3",
                                 costmodel="timeloop9000"))


# ---- CostBreakdown ----------------------------------------------------------------

def test_breakdown_totals_and_round_trip():
    bd = CostBreakdown(energy_pj=10.0, compute_cycles=5.0, dram_cycles=7.0,
                       dram_read_words=100, dram_write_words=50,
                       act_write_events=2, macs=1000,
                       members=("a", "b"), tile_rows=4, weight_passes=2,
                       utilization=0.5, energy_terms={"mac": 4.0, "dram": 6.0})
    assert bd.cycles == 7.0                      # max(compute, dram)
    assert bd.edp == 70.0
    assert bd.totals() == (10.0, 7.0, 100, 50, 2, 1000)
    again = CostBreakdown.from_dict(json.loads(json.dumps(bd.to_dict())))
    assert again == bd


def test_default_model_breakdowns_sum_to_schedule_cost():
    g = chain(5)
    ev = Evaluator(g, SIMBA)
    state = FusionState.fully_fused(g)
    cost = ev.evaluate(state)
    bds = ev.breakdowns(state)
    assert cost is not None and bds is not None
    assert len(bds) == cost.n_groups
    assert sum(b.energy_pj for b in bds) == pytest.approx(cost.energy_pj,
                                                          rel=1e-12)
    assert sum(b.cycles for b in bds) == pytest.approx(cost.cycles,
                                                       rel=1e-12)
    assert sum(b.macs for b in bds) == cost.macs
    for b in bds:
        # declarative terms decompose the total exactly
        assert sum(b.energy_terms.values()) == pytest.approx(b.energy_pj,
                                                             rel=1e-12)
        assert set(b.energy_terms) == {"mac", "rf", "act_buf", "weight_buf",
                                       "noc", "dram"}
        assert 0.0 < b.utilization <= 1.0


def test_breakdowns_none_for_unschedulable_state():
    from tests.test_fusion import skip_graph
    g = skip_graph()
    ev = Evaluator(g, SIMBA)
    s = FusionState(g, frozenset({("a", "add")}))
    assert ev.breakdowns(s) is None


# ---- TPU roofline backend ---------------------------------------------------------

def test_tpu_model_fusion_saves_hbm_traffic():
    g = chain(4)
    ev = Evaluator(g, SIMBA, costmodel=TpuFusionCostModel)
    base = ev.layerwise()
    fused = ev.evaluate(FusionState.fully_fused(g))
    assert fused is not None
    assert fused.energy_pj < base.energy_pj
    total = lambda c: c.dram_read_words + c.dram_write_words
    assert total(fused) < total(base)
    assert fused.macs == base.macs
    # TPU clock, not the edge machine's 200 MHz
    assert base.clock_hz == pytest.approx(940e6)


def test_tpu_model_vmem_capacity_invalidates_giant_tiles():
    g = LayerGraph("huge")
    i = g.add(Layer(name="input", kind="input", m=2048, p=1024, q=1024))
    a = g.add(Layer(name="a", kind="conv", c=2048, h=1024, w=1024, m=2048,
                    p=1024, q=1024, r=3, s=3, padding=(1, 1)), [i])
    g.add(Layer(name="b", kind="conv", c=2048, h=1024, w=1024, m=2048,
                p=1024, q=1024, r=3, s=3, padding=(1, 1)), [a])
    ev = Evaluator(g, SIMBA, costmodel=TpuFusionCostModel)
    assert ev.evaluate(FusionState.fully_fused(g)) is None
    assert ev.fitness(FusionState.fully_fused(g)) == 0.0


def test_tpu_model_reference_and_bitmask_paths_agree():
    from repro.core.fusion_ref import ReferenceFusionState
    g = chain(5)
    ev_new = Evaluator(g, SIMBA, costmodel=TpuFusionCostModel)
    ev_ref = Evaluator(g, SIMBA, costmodel=TpuFusionCostModel)
    for fused in (frozenset(), frozenset({("c0", "c1")}),
                  frozenset(g.edges)):
        new = ev_new.evaluate(FusionState(g, fused))
        ref = ev_ref.evaluate(ReferenceFusionState(g, fused))
        assert new == ref


def test_cli_costmodel_tpu_end_to_end(tmp_path):
    from repro.__main__ import main
    out = tmp_path / "tpu.json"
    rc = main(["search", "--workload", "mobilenet_v3", "--accelerator",
               "flexnn", "--costmodel", "tpu", "--backend", "ga",
               "--preset", "fast", "--generations", "3", "--out", str(out)])
    assert rc == 0
    data = json.loads(out.read_text())
    assert data["spec"]["costmodel"] == "tpu"
    assert data["group_breakdowns"], "artifact must store breakdowns"
    assert main(["report", str(out), "--breakdown"]) == 0
    # unknown costmodel is a clean CLI error, not a traceback
    assert main(["search", "--workload", "mobilenet_v3", "--costmodel",
                 "nope", "--out", str(out)]) == 2


# ---- artifact / report ------------------------------------------------------------

def test_artifact_round_trips_group_breakdowns(tmp_path):
    art = search("mobilenet_v3", "simba", backend="ga", seed=0,
                 backend_config={"preset": "fast", "generations": 3})
    assert len(art.group_breakdowns) == art.best.n_groups
    path = tmp_path / "a.json"
    art.save(str(path))
    from repro.search import ScheduleArtifact
    loaded = ScheduleArtifact.load(str(path))
    assert loaded.group_breakdowns == art.group_breakdowns
    assert sum(b.energy_pj for b in loaded.group_breakdowns) == \
        pytest.approx(art.best.energy_pj, rel=1e-12)


def test_breakdown_report_renders():
    from repro.core.report import breakdown_report
    art = search("mobilenet_v3", "simba", backend="ga", seed=0,
                 backend_config={"preset": "fast", "generations": 3})
    text = breakdown_report(art.group_breakdowns, max_rows=5)
    assert "energy%" in text and "more groups" in text
    full = breakdown_report(art.group_breakdowns, max_rows=0)
    assert len(full.splitlines()) == len(art.group_breakdowns) + 1
    assert breakdown_report([]).startswith("(artifact stores no")


# ---- mapper bounds (satellite) ----------------------------------------------------

def test_spatial_utilization_bounded_across_zoo_and_machines():
    """u in (0, 1] for every layer of every zoo workload on every
    registered accelerator."""
    from repro.workloads import WORKLOADS as ZOO
    for wname, builder in ZOO.items():
        g = builder()
        for aname in ACCELERATORS:
            acc = build_accelerator(aname)
            for layer in g.layers.values():
                u = spatial_utilization(layer, acc)
                assert 0.0 < u <= 1.0, (wname, aname, layer.name, u)


def test_schedule_cost_metric_rejects_unknown_objective():
    g = chain(3)
    cost = Evaluator(g, SIMBA).layerwise()
    with pytest.raises(ValueError) as e:
        cost.metric("latency_per_dollar")
    msg = str(e.value)
    assert "latency_per_dollar" in msg
    assert "edp" in msg and "register_objective" in msg


def test_costmodel_protocol_is_abstract():
    g = chain(3)
    cm = CostModel(g, SIMBA)
    with pytest.raises(NotImplementedError):
        cm.cost_group(1)
    with pytest.raises(NotImplementedError):
        cm.cost_layer(g.layers["c0"])
    assert cm.member_names(frozenset({"c1", "c0"})) == ["c0", "c1"]
    assert cm.member_names(0b11) == ["input", "c0"]
