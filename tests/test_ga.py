"""GA engine: finds brute-force optimum on small graphs, improves real
workloads, never returns invalid states."""
import itertools
import random

import pytest

from repro.core.fusion import FusionState
from repro.core.ga import GAConfig, run_ga
from repro.core.graph import Layer, LayerGraph
from repro.core.schedule import optimize
from repro.costmodel import SIMBA, Evaluator
from repro.workloads import mobilenet_v3_large
from tests.test_fusion import chain, skip_graph


def brute_force_best(g, ev, objective="edp"):
    best = None
    edges = g.edges
    for bits in itertools.product([0, 1], repeat=len(edges)):
        fused = frozenset(e for e, b in zip(edges, bits) if b)
        s = FusionState(g, fused)
        f = ev.fitness(s, objective)
        if best is None or f > best[0]:
            best = (f, s)
    return best


def test_ga_matches_brute_force_on_chain():
    g = chain(5)        # 5 edges -> 32 states
    ev = Evaluator(g, SIMBA)
    bf_f, _ = brute_force_best(g, ev)
    res = run_ga(g, ev, GAConfig.fast(generations=30, seed=0))
    assert res.best_fitness == pytest.approx(bf_f, rel=1e-9)


def test_ga_matches_brute_force_on_skip_graph():
    g = skip_graph()    # includes unschedulable corners
    ev = Evaluator(g, SIMBA)
    bf_f, _ = brute_force_best(g, ev)
    res = run_ga(g, ev, GAConfig.fast(generations=30, seed=1))
    assert res.best_fitness == pytest.approx(bf_f, rel=1e-9)


def test_ga_monotone_history():
    g = chain(6)
    ev = Evaluator(g, SIMBA)
    res = run_ga(g, ev, GAConfig.fast(generations=20, seed=2))
    assert all(b >= a - 1e-12 for a, b in zip(res.history, res.history[1:]))


def test_ga_improves_mobilenet_on_simba():
    res = optimize(mobilenet_v3_large(), SIMBA,
                   GAConfig.fast(generations=25, seed=0))
    assert res.edp_improvement > 1.2
    assert res.energy_improvement > 1.2
    assert res.best.act_write_events < res.baseline.act_write_events
    # returned best state is valid & schedulable
    assert res.best_state.is_schedulable()


def test_ga_never_selects_invalid_best():
    g = skip_graph()
    ev = Evaluator(g, SIMBA)
    res = run_ga(g, ev, GAConfig.fast(generations=10, seed=3))
    assert ev.evaluate(res.best_state) is not None


def test_fitness_of_layerwise_never_below_one_at_best():
    g = chain(4)
    ev = Evaluator(g, SIMBA)
    res = run_ga(g, ev, GAConfig.fast(generations=10, seed=4))
    assert res.best_fitness >= 1.0   # layerwise is in the initial population
