"""Telemetry against real searches: fixed-seed runs with telemetry on are
bit-identical to telemetry off (winner mask, fitness history, unique-state
counts, and the raw RNG draw sequence), observer hooks tick in order
(telemetry record first, so progress callbacks already see it), budget and
patience stop at the same generation either way, traced runs emit
schema-valid JSONL whose generation-span count equals the session's
generation count, and artifacts/CLI round-trip the embedded summary."""
import json
import os
import random
import subprocess
import sys

import pytest

from repro.obs import validate_event
from repro.obs.report import render_telemetry
from repro.obs.traceview import read_trace
from repro.search import ScheduleArtifact, SearchSession, SearchSpec, search
from repro.serve import ArtifactStore, BatchScheduler

FAST = {"preset": "fast", "generations": 6}


def signature(art):
    """Everything about a search trajectory that must not move."""
    return (art.genome_mask, art.best_fitness, art.history,
            art.evaluations, art.offspring_evaluated,
            art.best.energy_pj, art.best.cycles)


# ---- bit-identity -----------------------------------------------------------------

def test_fixed_seed_search_bit_identical_with_telemetry(tmp_path):
    base = dict(workload="mobilenet_v3", accelerator="simba", backend="ga",
                seed=0, backend_config=dict(FAST))
    off = search(**base)
    on = search(**base, telemetry=True)
    traced = SearchSession(SearchSpec(**base, telemetry=True),
                          trace_path=str(tmp_path / "t.jsonl")).run()
    assert signature(on) == signature(off)
    assert signature(traced) == signature(off)
    # telemetry on populates the artifact; off leaves it absent
    assert off.telemetry is None
    assert on.telemetry is not None
    assert on.telemetry["steps"] == len(on.history)


def test_env_trace_activates_without_touching_the_spec(tmp_path, monkeypatch):
    spec = SearchSpec(workload="mobilenet_v3", accelerator="simba",
                      backend="ga", seed=0, backend_config=dict(FAST))
    monkeypatch.delenv("REPRO_TRACE", raising=False)
    off = SearchSession(spec).run()
    p = tmp_path / "env.jsonl"
    monkeypatch.setenv("REPRO_TRACE", str(p))
    traced = SearchSession(spec).run()
    assert signature(traced) == signature(off)
    assert read_trace(str(p)).valid
    # the default-off spec serializes without the flag: store keys and
    # canonical spec JSON are byte-identical to pre-telemetry builds
    assert "telemetry" not in spec.to_dict()
    assert traced.spec.to_json() == off.spec.to_json()


class RecordingRandom(random.Random):
    """Records every underlying draw (`random()` and `getrandbits()` feed
    all derived methods: randrange, shuffle, sample, ...)."""

    draws = None                           # class-level sink, swapped per run

    def random(self):
        v = super().random()
        RecordingRandom.draws.append(v)
        return v

    def getrandbits(self, k):
        v = super().getrandbits(k)
        RecordingRandom.draws.append((k, v))
        return v


def test_rng_draw_sequence_identical_with_telemetry(monkeypatch, tmp_path):
    monkeypatch.setattr(random, "Random", RecordingRandom)
    base = dict(workload="mobilenet_v3", accelerator="simba", backend="ga",
                seed=0, backend_config={"preset": "fast", "generations": 3})

    def run_and_record(**kw):
        RecordingRandom.draws = []
        art = search(**base, **kw)
        return art, RecordingRandom.draws

    art_off, draws_off = run_and_record()
    art_on, draws_on = run_and_record(telemetry=True)
    assert draws_off, "the GA consumed no recorded randomness?"
    assert draws_on == draws_off           # recording consumes no RNG
    assert signature(art_on) == signature(art_off)


# ---- observer ordering + stopping policy ------------------------------------------

def test_progress_callback_already_sees_the_generation_record():
    spec = SearchSpec(workload="mobilenet_v3", accelerator="simba",
                      backend="ga", seed=0, backend_config=dict(FAST),
                      telemetry=True)
    session = SearchSession(spec)
    ticks = []

    def progress(p):
        recs = session.telemetry.generations
        ticks.append((p.step, len(recs), recs[-1]["step"],
                      recs[-1]["best"]))

    art = session.run(progress=progress)
    assert len(ticks) == len(art.history)
    for i, (step, n_recs, last_step, last_best) in enumerate(ticks):
        # collector.on_step ran BEFORE this progress tick: step i's record
        # is already the newest one, carrying this tick's best
        assert n_recs == i + 1
        assert last_step == step
        assert last_best == art.history[i]
    # the per-tick unique-state counts surface verbatim in the summary
    assert art.telemetry["unique_states"][-1] == art.evaluations


@pytest.mark.parametrize("stopper", [{"budget": 60}, {"patience": 2}])
def test_budget_and_patience_stop_identically_on_and_off(stopper):
    base = dict(workload="mobilenet_v3", accelerator="simba", backend="ga",
                seed=0,
                backend_config={"preset": "fast", "generations": 200},
                **stopper)
    off = search(**base)
    on = search(**base, telemetry=True)
    assert len(off.history) < 200          # the stopper actually cut the run
    assert signature(on) == signature(off)
    assert on.telemetry["steps"] == len(off.history)


# ---- traced runs ------------------------------------------------------------------

def test_traced_run_emits_schema_valid_spans_matching_history(tmp_path):
    p = tmp_path / "run.jsonl"
    spec = SearchSpec(workload="mobilenet_v3", accelerator="simba",
                      backend="ga", seed=0, backend_config=dict(FAST))
    art = SearchSession(spec, trace_path=str(p)).run()
    with open(p) as f:
        evs = [json.loads(line) for line in f]
    assert evs and all(validate_event(e) == [] for e in evs)
    rep = read_trace(str(p))
    assert rep.valid
    assert rep.span_counts["search"] == 1
    assert rep.span_counts["generation"] == len(art.history)
    assert rep.span_counts["batch_eval"] >= len(art.history)
    assert rep.metrics["counters"]["eval.unique"] == art.evaluations
    # every per-generation array in the embedded summary is |history| long
    t = art.telemetry
    assert t is not None and t["steps"] == len(art.history)
    for key in ("best", "mean", "std", "rejection_rate", "group_hit_rate",
                "unique_states", "offspring"):
        assert len(t[key]) == len(art.history), key
    assert t["best"] == [round(b, 6) for b in art.history]
    assert t["cache"]["unique_groups"] > 0


def test_artifact_round_trips_telemetry_and_report_renders(tmp_path):
    art = search("mobilenet_v3", "simba", backend="ga", seed=0,
                 backend_config=dict(FAST), telemetry=True)
    again = ScheduleArtifact.from_json(art.to_json())
    assert again.telemetry == art.telemetry
    # the report renders from the embedded summary alone — no trace file
    out = render_telemetry(again.telemetry)
    assert f"{len(art.history)} steps" in out
    assert f"{art.evaluations} unique states" in out
    assert "unique_groups" in out


def test_island_thread_mode_counts_barriers_and_migrations(tmp_path):
    p = tmp_path / "island.jsonl"
    spec = SearchSpec(
        workload="mobilenet_v3", accelerator="simba", backend="island",
        seed=0, telemetry=True,
        backend_config={"preset": "fast", "generations": 7, "islands": 2,
                        "migrate_every": 3, "workers": "thread"})
    art = SearchSession(spec, trace_path=str(p)).run()
    counters = art.telemetry["metrics"]["counters"]
    # 7 generations / migrate_every=3 -> barriers after gens 3 and 6 (the
    # final generation never barriers), both migrating
    assert counters["island.barriers"] == 2
    assert counters["island.migrations"] == 2
    rep = read_trace(str(p))
    assert rep.valid
    assert rep.point_counts.get("island.migration") == 2


# ---- serve + CLI ------------------------------------------------------------------

def test_serve_scheduler_records_jobs_dedup_and_store_hits(tmp_path):
    from repro.obs import TelemetryCollector, Tracer
    p = tmp_path / "serve.jsonl"
    store = ArtifactStore(str(tmp_path / "store"))
    spec = SearchSpec(workload="vgg16", backend="ga",
                      backend_config={"preset": "fast", "generations": 4})
    col = TelemetryCollector(tracer=Tracer(str(p)))
    sched = BatchScheduler(store, workers=1, obs=col)
    sched.submit(spec)
    sched.submit(SearchSpec.from_dict(spec.to_dict()))   # in-flight dup
    out = sched.run()
    assert out.stats["searched"] == 1 and out.stats["cache_hits"] == 1
    col2 = TelemetryCollector(tracer=Tracer(str(p)))
    again = BatchScheduler(store, workers=1, obs=col2)
    again.submit(SearchSpec.from_dict(spec.to_dict()))   # pure store read
    again.run()
    col.close()
    col2.close()
    c1 = col.registry.snapshot()["counters"]
    assert c1["serve.jobs{outcome=searched}"] == 1
    assert c1["serve.jobs{outcome=cache_hit}"] == 1
    assert c1["serve.deduped_in_flight"] == 1
    assert c1["serve.store_misses"] == 1 and c1["serve.store_hits"] == 0
    c2 = col2.registry.snapshot()["counters"]
    assert c2["serve.store_hits"] == 1
    rep = read_trace(str(p))
    assert rep.valid
    assert rep.point_counts["serve.job"] == 3
    assert rep.span_counts["serve.batch"] == 2


def test_cli_trace_report_round_trip(tmp_path):
    art = tmp_path / "a.json"
    trace = tmp_path / "t.jsonl"

    env = {**os.environ, "PYTHONPATH": "src"}
    env.pop("REPRO_TRACE", None)

    def repro(*argv):
        return subprocess.run(
            [sys.executable, "-m", "repro", *argv], cwd="/root/repo",
            capture_output=True, text=True, env=env)

    r = repro("search", "--workload", "mobilenet_v3", "--accelerator",
              "simba", "--backend", "ga", "--preset", "fast",
              "--generations", "2", "--seed", "0", "--out", str(art),
              "--trace", str(trace))
    assert r.returncode == 0, r.stderr
    r = repro("trace", str(trace), "--json")
    assert r.returncode == 0, r.stderr
    agg = json.loads(r.stdout)
    saved = json.loads(art.read_text())
    assert agg["valid"]
    assert agg["span_counts"]["generation"] == len(saved["history"])
    assert len(saved["telemetry"]["best"]) == len(saved["history"])
    r = repro("report", str(art), "--telemetry")
    assert r.returncode == 0, r.stderr
    assert "telemetry" in r.stdout and "convergence" in r.stdout
    # --telemetry on an untraced artifact is a loud error, not silence
    plain = tmp_path / "plain.json"
    r = repro("search", "--workload", "mobilenet_v3", "--backend", "ga",
              "--preset", "fast", "--generations", "2", "--seed", "0",
              "--out", str(plain))
    assert r.returncode == 0, r.stderr
    r = repro("report", str(plain), "--telemetry")
    assert r.returncode == 2
    assert "carries no telemetry summary" in r.stderr
