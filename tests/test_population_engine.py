"""Array-native population engine vs the scalar reference paths.

``repro.core.population.PopulationEvaluator`` must agree *bit-for-bit* with
the incremental engine on every surface it replaces:

* batched union-find group labels vs ``FusionState.group_masks()``,
* batched schedulability vs ``FusionState.is_schedulable()`` /
  ``ReferenceFusionState``,
* batched fitness vs the canonical scalar sum in ``Evaluator._fitness_fast``
  (same float operations in the same order — equality, not approx),

on random graphs and random populations (duplicates included), plus the
rare paths: the exact multi-group condensation-cycle residue
(:meth:`_sched_exact`), wide groups (span > 52 nodes), and the pure-python
group-table path for graphs too wide for int64 keys.  Finally, a fixed-seed
GA run must produce the identical best genome and fitness trajectory with
the engine on and off.
"""
import random

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

np = pytest.importorskip("numpy")

from repro.core.fusion import FusionState
from repro.core.fusion_ref import ReferenceFusionState
from repro.core.graph import Layer, LayerGraph
from repro.core.population import MIN_BATCH
from repro.costmodel import SIMBA, Evaluator
from repro.workloads import mobilenet_v3_large

OBJECTIVES = ("edp", "energy", "cycles", "dram")


def _conv(name, c, hw, m, k=3):
    return Layer(name=name, kind="conv", c=c, h=hw, w=hw, m=m, p=hw, q=hw,
                 r=k, s=k, padding=(k // 2, k // 2))


def _expected_labels(state: FusionState, n: int):
    want = list(range(n))            # default: every node its own group
    for gm in state.group_masks():
        mn = (gm & -gm).bit_length() - 1
        mm = gm
        while mm:
            b = mm & -mm
            want[b.bit_length() - 1] = mn
            mm ^= b
    return want


def _check_population(graph, masks):
    """Engine vs scalar reference on one batch (labels, sched, fitness)."""
    cg = graph.compiled()
    states = [FusionState.from_mask(graph, mk) for mk in masks]
    ev = Evaluator(graph, SIMBA)
    pe = ev.population(backend="numpy")
    lab = pe.group_labels(masks)
    sch = pe.schedulable_masks(masks)
    scalar = Evaluator(graph, SIMBA)     # fresh: no shared cache effects
    fits = {obj: pe.fitness_masks(masks, obj) for obj in OBJECTIVES}
    for i, s in enumerate(states):
        assert lab[i].tolist() == _expected_labels(s, cg.n)
        assert bool(sch[i]) == s.is_schedulable()
        for obj in OBJECTIVES:
            # bit-identical to the canonical scalar sum; fitness() may
            # re-associate the same floats (~1 ulp), so only approx there
            assert fits[obj][i] == scalar._fitness_fast(s, obj)
            assert fits[obj][i] == pytest.approx(scalar.fitness(s, obj),
                                                 rel=1e-9)


@st.composite
def random_dag_population(draw):
    """A random layered conv DAG (chains + joins) and a random population
    with duplicate genomes."""
    n = draw(st.integers(min_value=4, max_value=9))
    hw, ch = 8, 4
    g = LayerGraph("rand")
    names = [g.add(Layer(name="in", kind="input", m=ch, p=hw, q=hw))]
    for i in range(n):
        k = draw(st.sampled_from([1, 3]))
        # parents: previous node, plus possibly one earlier (join -> add)
        prev = names[-1]
        extra = draw(st.integers(min_value=0, max_value=len(names) - 1))
        parents = [prev]
        if names[extra] != prev and draw(st.booleans()):
            parents.append(names[extra])
        cname = g.add(_conv(f"c{i}", ch, hw, ch, k), [prev])
        if len(parents) > 1:
            cname = g.add(Layer(name=f"a{i}", kind="add", c=ch, h=hw, w=hw,
                                m=ch, p=hw, q=hw), [cname, names[extra]])
        names.append(cname)
    m = g.compiled().m
    seed = draw(st.integers(min_value=0, max_value=2**31))
    rng = random.Random(seed)
    pop = [rng.getrandbits(m) for _ in range(24)]
    pop += pop[:8]                       # duplicates inside one batch
    return g, pop


@given(random_dag_population())
@settings(max_examples=25, deadline=None)
def test_engine_matches_scalar_on_random_graphs(gp):
    graph, masks = gp
    _check_population(graph, masks)


def test_engine_matches_scalar_on_mobilenet():
    graph = mobilenet_v3_large()
    m = graph.compiled().m
    rng = random.Random(11)
    masks = [rng.getrandbits(m) for _ in range(60)]
    masks += masks[:10]
    _check_population(graph, masks)


def test_reference_engine_agreement_small_graph():
    g = LayerGraph("chain")
    prev = g.add(Layer(name="in", kind="input", m=4, p=8, q=8))
    for i in range(5):
        prev = g.add(_conv(f"c{i}", 4, 8, 4), [prev])
    edges = g.edges
    m = g.compiled().m
    ev = Evaluator(g, SIMBA)
    pe = ev.population(backend="numpy")
    masks = list(range(1 << m))
    sch = pe.schedulable_masks(masks)
    for i, mk in enumerate(masks):
        fused = frozenset(e for j, e in enumerate(edges) if (mk >> j) & 1)
        ref = ReferenceFusionState(g, fused)
        assert bool(sch[i]) == ref.is_schedulable()


# ---- rare paths ---------------------------------------------------------------------
def _residue_graph():
    """Two fused groups, each individually cycle-free (no ``self_bad``),
    whose condensation still cycles: A={1,4} (fused 1->4), B={2,3,5}
    (fused 2->5, 3->5), unfused edges 1->3 (A->B) and 2->4 (B->A)."""
    g = LayerGraph("residue")
    l0 = g.add(Layer(name="n0", kind="input", m=4, p=8, q=8))
    l1 = g.add(_conv("n1", 4, 8, 4), [l0])
    l2 = g.add(_conv("n2", 4, 8, 4), [l0])
    l3 = g.add(_conv("n3", 4, 8, 4), [l1])
    g.add(Layer(name="n4", kind="add", c=4, h=8, w=8, m=4, p=8, q=8),
          [l1, l2])
    g.add(Layer(name="n5", kind="add", c=4, h=8, w=8, m=4, p=8, q=8),
          [l2, l3])
    return g


def test_residue_exact_cycle_check():
    g = _residue_graph()
    cg = g.compiled()
    eid = cg.edge_id
    fuse = lambda *edges: sum(1 << eid[e] for e in edges)
    cyc = fuse(("n1", "n4"), ("n2", "n5"), ("n3", "n5"))   # A + B: cycle
    ok = fuse(("n1", "n4"))                                # A alone: fine
    ev = Evaluator(g, SIMBA)
    pe = ev.population(backend="numpy")
    masks = [cyc, ok, 0, cyc]
    sch = pe.schedulable_masks(masks)
    states = [FusionState.from_mask(g, mk) for mk in masks]
    assert [bool(b) for b in sch] == [s.is_schedulable() for s in states]
    assert not sch[0] and sch[1] and sch[2]
    # the cyclic genome must have been caught by the exact residue check,
    # not the per-group flags (its groups are individually cycle-free)
    assert pe.stats()["residue_checks"] > 0
    for obj in OBJECTIVES:
        fits = pe.fitness_masks(masks, obj)
        scalar = Evaluator(g, SIMBA)
        for i, s in enumerate(states):
            assert fits[i] == scalar._fitness_fast(s, obj)


def test_wide_group_span_over_52():
    """A fully fused 60-conv chain has group span > 52 — the int64 key fast
    path must hand these to the exact python path."""
    g = LayerGraph("long")
    prev = g.add(Layer(name="in", kind="input", m=4, p=64, q=64))
    for i in range(60):
        prev = g.add(_conv(f"c{i}", 4, 64, 4, k=1), [prev])
    m = g.compiled().m
    rng = random.Random(3)
    masks = [(1 << m) - 1, 0, rng.getrandbits(m), (1 << m) - 1]
    _check_population(g, masks)


def test_python_rows_path_very_wide_graph():
    """Graphs beyond 1024 nodes cannot pack labels into int64 keys; the
    per-slot python table path must still agree with the scalar engine."""
    g = LayerGraph("huge")
    prev = g.add(Layer(name="in", kind="input", m=2, p=4, q=4))
    for i in range(1040):
        prev = g.add(_conv(f"c{i}", 2, 4, 2, k=1), [prev])
    m = g.compiled().m
    rng = random.Random(5)
    masks = [rng.getrandbits(m) for _ in range(3)]
    ev = Evaluator(g, SIMBA)
    pe = ev.population(backend="numpy")
    lab = pe.group_labels(masks)
    sch = pe.schedulable_masks(masks)
    for i, mk in enumerate(masks):
        s = FusionState.from_mask(g, mk)
        assert lab[i].tolist() == _expected_labels(s, g.compiled().n)
        assert bool(sch[i]) == s.is_schedulable()


# ---- engine selection + fixed-seed identity ----------------------------------------
def _ga_run(monkeypatch, mode, generations=10):
    from repro.search import SearchSession, SearchSpec
    monkeypatch.setenv("REPRO_POP_ENGINE", mode)
    spec = SearchSpec(workload="mobilenet_v3", accelerator="simba",
                      backend="ga", backend_config={"generations": generations},
                      seed=0)
    s = SearchSession(spec)
    s.run()
    return s


def test_fixed_seed_bit_identity_engine_on_vs_off(monkeypatch):
    off = _ga_run(monkeypatch, "off")
    on = _ga_run(monkeypatch, "numpy")
    assert off.evaluator.cache_stats()["pop_backend"] == "off"
    assert on.evaluator.cache_stats()["pop_backend"] == "numpy"
    assert on.result.best_state.mask == off.result.best_state.mask
    assert on.result.best_fitness == off.result.best_fitness
    assert on.result.history == off.result.history
    # pin the absolute values so a drift in BOTH engines is also caught
    assert hex(on.result.best_state.mask) == "0x10080410000c0004005c4a"
    assert on.result.best_fitness == 1.2808320767908055


def test_small_batches_use_scalar_path():
    graph = mobilenet_v3_large()
    ev = Evaluator(graph, SIMBA)
    states = [FusionState.from_mask(graph, 1 << i)
              for i in range(MIN_BATCH - 1)]
    fits = ev.fitness_batch(states, "edp")
    assert ev.cache_stats()["pop_batches"] == 0      # engine never engaged
    scalar = Evaluator(graph, SIMBA)
    assert fits == [scalar._fitness_fast(s, "edp") for s in states]


def test_engine_mode_off_env(monkeypatch):
    monkeypatch.setenv("REPRO_POP_ENGINE", "off")
    graph = mobilenet_v3_large()
    ev = Evaluator(graph, SIMBA)
    assert ev.cache_stats()["pop_backend"] == "off"
    monkeypatch.setenv("REPRO_POP_ENGINE", "bogus")
    from repro.core.population import engine_mode
    with pytest.raises(ValueError):
        engine_mode()


def test_jax_backend_labels_bit_identical():
    pytest.importorskip("jax")
    graph = mobilenet_v3_large()
    m = graph.compiled().m
    rng = random.Random(9)
    masks = [rng.getrandbits(m) for _ in range(40)]
    ev_np = Evaluator(graph, SIMBA)
    pe_np = ev_np.population(backend="numpy")
    ev_jx = Evaluator(graph, SIMBA)
    pe_jx = ev_jx.population(backend="jax")
    if pe_jx.backend != "jax":
        pytest.skip("jax backend unavailable at runtime")
    assert np.array_equal(pe_jx.group_labels(masks), pe_np.group_labels(masks))
    for obj in OBJECTIVES:
        a = pe_jx.fitness_masks(masks, obj)
        b = pe_np.fitness_masks(masks, obj)
        assert np.array_equal(a, b)
