"""Subprocess helper: elastic re-mesh — train on a (2,4) mesh, checkpoint,
restore the run onto a (4,2) mesh (different sharding layout), finish, and
match an uninterrupted single-device run."""
import os
import sys
import tempfile

assert "--xla_force_host_platform_device_count=8" in \
    os.environ.get("XLA_FLAGS", ""), "run via the pytest wrapper"

import dataclasses

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

from repro.configs import get_reduced
from repro.launch.mesh import make_local_mesh
from repro.launch.train import TrainRunConfig, train_loop
from repro.runtime import FaultInjector

cfg = dataclasses.replace(get_reduced("stablelm-1.6b"), param_dtype="float32",
                          compute_dtype="float32")

with tempfile.TemporaryDirectory() as tmp:
    base = dict(cfg=cfg, steps=12, global_batch=8, seq_len=32, lr=1e-3,
                save_every=6, log_every=1)

    # phase 1: train to a mid-run checkpoint on mesh (2,4); crash at step 8
    run1 = TrainRunConfig(ckpt_dir=os.path.join(tmp, "ck"), **base)
    inj = FaultInjector(fail_at_steps=[8])
    try:
        train_loop(run1, mesh=make_local_mesh(2, 4), injector=inj,
                   log=lambda *a: None,
                   fault=__import__("repro.runtime",
                                    fromlist=["FaultConfig"]).FaultConfig(
                       max_restarts=0))
    except Exception:
        pass      # crashed as planned with no restart budget

    # phase 2: a NEW job on a DIFFERENT mesh shape resumes from the ckpt
    run2 = TrainRunConfig(ckpt_dir=os.path.join(tmp, "ck"), **base)
    out2 = train_loop(run2, mesh=make_local_mesh(4, 2), log=lambda *a: None)

    # oracle: uninterrupted single-device run
    run3 = TrainRunConfig(ckpt_dir=None, **base)
    out3 = train_loop(run3, mesh=make_local_mesh(1, 1), log=lambda *a: None)

    l2 = np.array(out2["history"]["loss"])
    l3 = np.array(out3["history"]["loss"])
    print("resumed(4,2):", l2[-3:])
    print("oracle(1,1) :", l3[-3:])
    np.testing.assert_allclose(l2[-3:], l3[-3:], rtol=3e-4, atol=3e-4)
    import jax
    pa = jax.tree.leaves(out2["state"]["params"])
    pb = jax.tree.leaves(out3["state"]["params"])
    for a, b in zip(pa, pb):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)
    print("ELASTIC_REMESH_OK")
