"""Subprocess helper: verify DP+TP sharded training matches single-device.

Run with XLA_FLAGS=--xla_force_host_platform_device_count=8 (the test sets
it); trains the same tiny model on a (data=2, model=4) mesh and on (1, 1),
then asserts the loss trajectories agree.
"""
import os
import sys

assert "--xla_force_host_platform_device_count=8" in \
    os.environ.get("XLA_FLAGS", ""), "run via the pytest wrapper"

import dataclasses

import jax
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

from repro.configs import get_reduced
from repro.launch.mesh import make_local_mesh
from repro.launch.train import TrainRunConfig, train_loop

cfg = dataclasses.replace(get_reduced("qwen2-7b"), param_dtype="float32",
                          compute_dtype="float32")
run = TrainRunConfig(cfg=cfg, steps=8, global_batch=8, seq_len=32,
                     lr=1e-3, log_every=1)

out_sharded = train_loop(run, mesh=make_local_mesh(2, 4), log=lambda *a: None)
out_single = train_loop(run, mesh=make_local_mesh(1, 1), log=lambda *a: None)

ls = np.array(out_sharded["history"]["loss"])
l1 = np.array(out_single["history"]["loss"])
print("sharded:", ls)
print("single :", l1)
np.testing.assert_allclose(ls, l1, rtol=2e-4, atol=2e-4)
print("SHARDED_MATCHES_SINGLE")
