"""`repro.serve.daemon`: persistent queue (priorities, journal replay,
dedup), daemon lifecycle over a real socket (submit/poll/cancel,
restart-replays-journal, zero-eval store hits), warm-start pins, store GC.
"""
import json
import os
import time
import urllib.error
import urllib.request

import pytest

from repro.search import ScheduleArtifact, SearchSession, SearchSpec
from repro.serve import (ArtifactStore, ScheduleDaemon, artifact_key,
                         collect_garbage, find_warm_start)
from repro.serve.queue import JobQueue
from repro.serve.warmstart import adapt_mask, workload_family

FAST = {"preset": "fast", "generations": 4}


def fast_spec(workload="vgg16", seed=0, generations=4, **kw):
    return SearchSpec(workload=workload, seed=seed,
                      backend_config={"preset": "fast",
                                      "generations": generations}, **kw)


# ---- JobQueue ---------------------------------------------------------------------

def spec_dict(seed=0, workload="vgg16"):
    return fast_spec(workload=workload, seed=seed).to_dict()


def test_queue_priority_order(tmp_path):
    q = JobQueue(str(tmp_path))
    a = q.submit(spec_dict(seed=0), priority=0, key="ka")
    b = q.submit(spec_dict(seed=1), priority=5, key="kb")
    c = q.submit(spec_dict(seed=2), priority=1, key="kc")
    order = [q.next_job().id for _ in range(3)]
    assert order == [b.id, c.id, a.id]
    q.close()


def test_queue_ties_run_in_submission_order(tmp_path):
    q = JobQueue(str(tmp_path))
    ids = [q.submit(spec_dict(seed=i), key=f"k{i}").id for i in range(4)]
    assert [q.next_job().id for _ in range(4)] == ids
    q.close()


def test_queue_journal_replay_requeues_running_and_queued(tmp_path):
    q = JobQueue(str(tmp_path))
    a = q.submit(spec_dict(seed=0), priority=2, key="ka")
    b = q.submit(spec_dict(seed=1), priority=0, key="kb")
    started = q.next_job()
    assert started.id == a.id            # higher priority first
    q.close()                            # "crash": a was running, b queued

    q2 = JobQueue(str(tmp_path))
    assert q2.replay.jobs == 2
    assert q2.replay.requeued == 2       # running job re-runs from scratch
    assert {j.state for j in q2.list_jobs()} == {"queued"}
    # ids continue past the replayed ones
    c = q2.submit(spec_dict(seed=2), key="kc")
    assert c.id == b.id + 1
    q2.close()


def test_queue_replay_keeps_terminal_states(tmp_path):
    q = JobQueue(str(tmp_path))
    a = q.submit(spec_dict(seed=0), key="ka")
    assert q.next_job().id == a.id
    q.resolve_done(a.id, "searched", "ka")
    b = q.submit(spec_dict(seed=1), key="kb")
    assert q.cancel(b.id) == "cancelled"
    q.close()

    q2 = JobQueue(str(tmp_path))
    assert q2.get(a.id).state == "done"
    assert q2.get(a.id).outcome == "searched"
    assert q2.get(b.id).state == "cancelled"
    assert q2.replay.requeued == 0
    q2.close()


def test_queue_dedup_attaches_and_resolves_with_primary(tmp_path):
    q = JobQueue(str(tmp_path))
    a = q.submit(spec_dict(seed=0), key="same")
    b = q.submit(spec_dict(seed=0), key="same")
    assert b.attached_to == a.id
    assert q.next_job().id == a.id
    assert q.next_job(timeout=0.05) is None   # b never enters the heap
    q.resolve_done(a.id, "searched", "same")
    assert q.get(b.id).state == "done"
    assert q.get(b.id).outcome == "cache_hit"
    q.close()


def test_queue_dedup_failure_propagates(tmp_path):
    q = JobQueue(str(tmp_path))
    a = q.submit(spec_dict(seed=0), key="same")
    b = q.submit(spec_dict(seed=0), key="same")
    q.next_job()
    q.resolve_failed(a.id, "boom")
    assert q.get(b.id).state == "failed"
    assert q.get(b.id).error == "boom"
    q.close()


def test_queue_cancelled_primary_requeues_attached(tmp_path):
    q = JobQueue(str(tmp_path))
    a = q.submit(spec_dict(seed=0), key="same")
    b = q.submit(spec_dict(seed=0), key="same")
    assert q.next_job().id == a.id
    q.resolve_cancelled(a.id)
    nxt = q.next_job(timeout=1.0)
    assert nxt is not None and nxt.id == b.id  # request still stands
    q.close()


def test_queue_tolerates_torn_trailing_line(tmp_path):
    q = JobQueue(str(tmp_path))
    q.submit(spec_dict(seed=0), key="ka")
    q.close()
    with open(tmp_path / "queue.jsonl", "a") as f:
        f.write('{"v":1,"event":"sub')      # torn mid-crash write
    q2 = JobQueue(str(tmp_path))
    assert q2.replay.jobs == 1
    assert len(q2.replay.warnings) == 1
    q2.close()


def test_queue_live_keys_cover_non_terminal_jobs(tmp_path):
    q = JobQueue(str(tmp_path))
    a = q.submit(spec_dict(seed=0), key="ka")
    q.submit(spec_dict(seed=1), key="kb")
    q.next_job()
    q.resolve_done(a.id, "searched", "ka")
    assert q.live_keys() == {"kb"}
    q.close()


# ---- daemon over a real socket ----------------------------------------------------

def _get(base, path):
    with urllib.request.urlopen(base + path, timeout=30) as r:
        return json.load(r)


def _post(base, path, payload):
    req = urllib.request.Request(base + path,
                                 data=json.dumps(payload).encode())
    with urllib.request.urlopen(req, timeout=30) as r:
        return json.load(r)


def _delete(base, path):
    req = urllib.request.Request(base + path, method="DELETE")
    with urllib.request.urlopen(req, timeout=30) as r:
        return json.load(r)


def _wait(base, jid, timeout=120.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        j = _get(base, f"/jobs/{jid}")
        if j["state"] in ("done", "failed", "cancelled"):
            return j
        time.sleep(0.05)
    raise AssertionError(f"job {jid} did not resolve: {j}")


@pytest.fixture()
def daemon(tmp_path):
    svc = ScheduleDaemon(str(tmp_path / "store"), workers=1)
    svc.start()
    try:
        yield svc, f"http://127.0.0.1:{svc.port}"
    finally:
        svc.stop()


def test_daemon_submit_poll_artifact_metrics(daemon):
    svc, base = daemon
    assert _get(base, "/healthz") == {"ok": True}
    job = _post(base, "/jobs", {"spec": fast_spec().to_dict()})
    assert job["state"] in ("queued", "running", "done")
    done = _wait(base, job["id"])
    assert done["outcome"] == "searched"
    assert done["key"]
    # live per-generation convergence records were served
    assert len(done["progress"]) == 4
    assert done["progress"][0]["step"] == 0
    assert done["summary"]["edp_x"] > 0
    art = _get(base, f"/artifacts/{done['key']}")
    assert art["genome_mask"] is not None
    m = _get(base, "/metrics")
    assert m["jobs"]["done"] == 1
    assert m["daemon"]["searches_run"] == 1
    assert m["metrics"]["counters"]["daemon.jobs{outcome=searched}"] == 1
    assert m["metrics"]["counters"]["eval.states"] > 0


def test_daemon_store_hit_serves_with_zero_new_evaluations(daemon):
    svc, base = daemon
    first = _wait(base, _post(base, "/jobs",
                              {"spec": fast_spec().to_dict()})["id"])
    evals_before = _get(base, "/metrics")["metrics"]["counters"]["eval.states"]
    dup = _post(base, "/jobs", {"spec": fast_spec().to_dict()})
    # resolved AT submission: no queueing, no search, no evaluator
    assert dup["state"] == "done"
    assert dup["outcome"] == "cache_hit"
    assert dup["key"] == first["key"]
    m = _get(base, "/metrics")
    assert m["metrics"]["counters"]["eval.states"] == evals_before
    assert svc.searches_run == 1
    assert svc.store_hits == 1


def test_daemon_404s(daemon):
    svc, base = daemon
    for path in ("/jobs/999", "/artifacts/" + "0" * 64, "/nope"):
        with pytest.raises(urllib.error.HTTPError) as ei:
            _get(base, path)
        assert ei.value.code == 404


def test_daemon_bad_spec_is_400(daemon):
    svc, base = daemon
    for payload in ({}, {"spec": {"workload": "no_such_net"}},
                    {"spec": {"workload": "vgg16", "bogus_field": 1}}):
        with pytest.raises(urllib.error.HTTPError) as ei:
            _post(base, "/jobs", payload)
        assert ei.value.code == 400


def test_daemon_cancel_running_job_cooperatively(daemon):
    svc, base = daemon
    # enough generations that the cancel lands mid-search
    job = _post(base, "/jobs", {"spec": fast_spec(
        workload="unet", generations=100000).to_dict()})
    deadline = time.monotonic() + 60
    while _get(base, f"/jobs/{job['id']}")["state"] != "running":
        assert time.monotonic() < deadline, "job never started"
        time.sleep(0.02)
    out = _delete(base, f"/jobs/{job['id']}")
    assert out["state"] in ("cancelling", "cancelled")
    final = _wait(base, job["id"])
    assert final["state"] == "cancelled"
    # a repeat DELETE reports the job as already resolved (409)
    with pytest.raises(urllib.error.HTTPError) as ei:
        _delete(base, f"/jobs/{job['id']}")
    assert ei.value.code == 409


def test_daemon_cancel_queued_job(tmp_path):
    svc = ScheduleDaemon(str(tmp_path / "store"), workers=0)
    svc.start()
    base = f"http://127.0.0.1:{svc.port}"
    try:
        job = _post(base, "/jobs", {"spec": fast_spec().to_dict()})
        assert job["state"] == "queued"
        assert _delete(base, f"/jobs/{job['id']}")["state"] == "cancelled"
        assert _get(base, f"/jobs/{job['id']}")["state"] == "cancelled"
    finally:
        svc.stop()


def test_daemon_restart_replays_journal(tmp_path):
    store_dir = str(tmp_path / "store")
    svc = ScheduleDaemon(store_dir, workers=0)   # nothing drains
    svc.start()
    base = f"http://127.0.0.1:{svc.port}"
    j0 = _post(base, "/jobs", {"spec": fast_spec(seed=0).to_dict(),
                               "priority": 1})
    j1 = _post(base, "/jobs", {"spec": fast_spec(seed=1).to_dict(),
                               "priority": 5})
    svc.stop()                                   # jobs still queued

    svc2 = ScheduleDaemon(store_dir, workers=1)
    assert svc2.queue.replay.requeued == 2
    svc2.start()
    base2 = f"http://127.0.0.1:{svc2.port}"
    try:
        done1 = _wait(base2, j1["id"])
        done0 = _wait(base2, j0["id"])
        assert done0["outcome"] == "searched"
        assert done1["outcome"] == "searched"
        assert svc2.searches_run == 2
    finally:
        svc2.stop()


def test_daemon_inflight_dedup_one_search_serves_both(tmp_path):
    store_dir = str(tmp_path / "store")
    svc = ScheduleDaemon(store_dir, workers=0)   # hold both in the queue
    svc.start()
    base = f"http://127.0.0.1:{svc.port}"
    ja = _post(base, "/jobs", {"spec": fast_spec().to_dict()})
    jb = _post(base, "/jobs", {"spec": fast_spec().to_dict()})
    assert not ja["deduped"]
    assert jb["deduped"]                          # attached in-flight
    svc.stop()

    svc2 = ScheduleDaemon(store_dir, workers=1)
    svc2.start()
    base2 = f"http://127.0.0.1:{svc2.port}"
    try:
        da = _wait(base2, ja["id"])
        db = _wait(base2, jb["id"])
        assert da["key"] == db["key"]
        assert svc2.searches_run == 1             # exactly one search
        assert {da["outcome"], db["outcome"]} == {"searched", "cache_hit"}
    finally:
        svc2.stop()


# ---- warm-start pins --------------------------------------------------------------

def test_daemon_default_results_bit_identical_to_direct_session(tmp_path):
    spec = fast_spec()
    direct = SearchSession(spec).run()

    svc = ScheduleDaemon(str(tmp_path / "store"), workers=1)
    svc.start()
    base = f"http://127.0.0.1:{svc.port}"
    try:
        done = _wait(base, _post(base, "/jobs",
                                 {"spec": spec.to_dict()})["id"])
        via_daemon = svc.store.load_key(done["key"])
    finally:
        svc.stop()
    # same fixed-seed trajectory, same store key, byte-identical payload
    # minus wall-clock provenance (wall_s, created_unix, and the timing
    # rates inside backend_stats are the only fields a clock feeds)
    assert done["key"] == artifact_key(direct.graph_fingerprint, spec)
    a, b = direct.to_dict(), via_daemon.to_dict()
    for d in (a, b):
        d.pop("wall_s"), d.pop("created_unix")
        for k in ("batch_time_s", "batch_evals_per_sec"):
            d["backend_stats"].pop(k, None)
    assert a == b


def test_warm_start_seeds_first_generation_at_or_above_cold(tmp_path):
    donor_spec = fast_spec(seed=0, generations=12)
    cold_spec = fast_spec(seed=7)
    cold = SearchSession(cold_spec).run()

    svc = ScheduleDaemon(str(tmp_path / "store"), workers=1)
    svc.start()
    base = f"http://127.0.0.1:{svc.port}"
    try:
        donor = _wait(base, _post(base, "/jobs",
                                  {"spec": donor_spec.to_dict()})["id"])
        warm_job = _wait(base, _post(
            base, "/jobs",
            {"spec": cold_spec.to_dict(), "warm_start": True})["id"])
        warm = svc.store.load_key(warm_job["key"])
        donor_art = svc.store.load_key(donor["key"])
    finally:
        svc.stop()
    assert warm_job["outcome"] == "searched"
    # the donor's converged winner joins the initial pool, so the warm
    # run's first generation can never be worse than it — and must be at
    # least as good as the cold run's first generation
    assert warm.history[0] >= donor_art.best_fitness - 1e-9
    assert warm.history[0] >= cold.history[0] - 1e-9
    # warm-starting never changes the request's identity
    assert warm_job["key"] == artifact_key(cold.graph_fingerprint, cold_spec)


def test_warm_start_ranking_prefers_same_fingerprint(tmp_path):
    store = ArtifactStore(str(tmp_path))
    exact = SearchSession(fast_spec(seed=0)).run()
    other = SearchSession(fast_spec(workload="unet", seed=0)).run()
    store.put(exact)
    store.put(other)
    seed = find_warm_start(store, exact.graph_fingerprint, fast_spec(seed=3))
    assert seed is not None and seed.exact
    assert seed.mask == exact.genome_mask
    # family match: same workload name, different params -> inexact donor
    fam = find_warm_start(store, "sha256:elsewhere",
                          fast_spec(workload="vgg16@hw=160", seed=0))
    assert fam is not None and not fam.exact
    assert workload_family("vgg16@hw=160") == "vgg16"
    # no donor at all for an unknown family
    assert find_warm_start(store, "sha256:x",
                           fast_spec(workload="resnet50")) is None


def test_adapt_mask_clips_to_edge_range():
    assert adapt_mask(0b1011, 2) == 0b11
    assert adapt_mask(0b1011, 8) == 0b1011
    assert adapt_mask(0b1011, 0) == 0


def test_seed_genomes_default_empty_keeps_ga_identical():
    # belt and braces on top of the byte-identity test above: the seeding
    # hook's empty default must leave run_ga_problem's draws untouched
    from repro.core.ga import GAConfig, run_ga_problem
    from repro.core.problem import FusionProblem, SearchProblem
    from repro.search.registry import build_accelerator, build_workload
    from repro.costmodel.evaluator import Evaluator

    assert SearchProblem.seed_genomes == ()
    graph = build_workload("vgg16")
    cfg = GAConfig.fast(generations=3)
    r1 = run_ga_problem(FusionProblem(
        graph, Evaluator(graph, build_accelerator("simba"))), cfg)
    p2 = FusionProblem(graph, Evaluator(graph, build_accelerator("simba")))
    p2.seed_genomes = ()                 # explicit empty == absent
    r2 = run_ga_problem(p2, cfg)
    assert r1.history == r2.history
    assert r1.best_state.mask == r2.best_state.mask
    assert r1.evaluations == r2.evaluations


# ---- store GC ---------------------------------------------------------------------

def _store_with_artifacts(root, n=4):
    store = ArtifactStore(str(root))
    keys = []
    for seed in range(n):
        art = SearchSession(fast_spec(seed=seed, generations=1)).run()
        keys.append(store.put(art))
    return store, keys


def test_gc_evicts_least_recently_used_first(tmp_path):
    store, keys = _store_with_artifacts(tmp_path, n=4)
    now = time.time()
    for i, key in enumerate(keys):       # keys[0] oldest access
        os.utime(store.path_for(key), (now - 1000 + i, now - 1000 + i))
    res = collect_garbage(store, max_objects=2, live=frozenset())
    assert res.evicted == keys[:2]
    assert sorted(store.keys()) == sorted(keys[2:])


def test_gc_never_evicts_live_keys(tmp_path):
    store, keys = _store_with_artifacts(tmp_path, n=3)
    now = time.time()
    for i, key in enumerate(keys):
        os.utime(store.path_for(key), (now - 1000 + i, now - 1000 + i))
    res = collect_garbage(store, max_objects=1, live={keys[0]})
    assert keys[0] not in res.evicted
    assert keys[0] in res.kept_live
    assert os.path.isfile(store.path_for(keys[0]))


def test_gc_respects_max_bytes(tmp_path):
    store, keys = _store_with_artifacts(tmp_path, n=3)
    sizes = {k: os.path.getsize(store.path_for(k)) for k in keys}
    budget = sizes[keys[1]] + sizes[keys[2]]
    res = collect_garbage(store, max_bytes=budget, live=frozenset())
    remaining = sum(os.path.getsize(store.path_for(k))
                    for k in store.keys())
    assert remaining <= budget
    assert res.evicted_bytes > 0


def test_gc_reports_corrupt_objects_without_deleting(tmp_path):
    store, keys = _store_with_artifacts(tmp_path, n=2)
    bad = store.path_for(keys[0])
    with open(bad, "w") as f:
        f.write("{not json")
    res = collect_garbage(store, max_objects=0, live=frozenset())
    assert keys[0] in res.corrupt
    assert os.path.isfile(bad)           # reported, not deleted
    assert keys[1] in res.evicted        # the healthy object still evicts


def test_gc_dry_run_deletes_nothing(tmp_path):
    store, keys = _store_with_artifacts(tmp_path, n=2)
    res = collect_garbage(store, max_objects=0, live=frozenset(),
                          dry_run=True)
    assert len(res.evicted) == 2
    assert sorted(store.keys()) == sorted(keys)


def test_gc_pins_keys_from_queue_journal(tmp_path):
    store, keys = _store_with_artifacts(tmp_path, n=2)
    q = JobQueue(str(tmp_path))          # journal in the store dir
    q.submit(spec_dict(seed=0), key=keys[0])
    q.close()
    res = collect_garbage(store, max_objects=0)
    assert keys[0] in res.kept_live
    assert keys[1] in res.evicted


def test_store_hit_refreshes_lru_clock(tmp_path):
    store, keys = _store_with_artifacts(tmp_path, n=1)
    art = store.load_key(keys[0])
    path = store.path_for(keys[0])
    os.utime(path, (1000.0, 1000.0))
    store.get(art.graph_fingerprint, art.spec)
    assert os.path.getmtime(path) > 1000.0
