"""Per-architecture smoke tests: REDUCED config of the same family, one
forward + one train step on CPU, asserting output shapes and no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.configs.base import SHAPES, ShapeConfig
from repro.launch.steps import make_train_step
from repro.models import transformer as T


def _smoke_batch(cfg, B=2, S=16, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    toks = jax.random.randint(ks[0], (B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.img_tokens:
        batch["img_embeds"] = jax.random.normal(
            ks[1], (B, cfg.img_tokens, cfg.d_model), jnp.float32)
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(
            ks[2], (B, cfg.enc_seq, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finiteness(arch):
    cfg = dataclasses.replace(get_reduced(arch), param_dtype="float32")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = _smoke_batch(cfg, B, S)
    logits, aux = T.forward(params, cfg, batch)
    exp_seq = S + cfg.img_tokens
    assert logits.shape == (B, exp_seq, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"
    assert bool(jnp.isfinite(aux)), f"{arch}: non-finite aux loss"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_runs_and_is_finite(arch):
    cfg = dataclasses.replace(get_reduced(arch), param_dtype="float32")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    train_step, opt_init = make_train_step(cfg)
    opt_state = opt_init(params)
    batch = _smoke_batch(cfg)
    new_p, new_opt, metrics = jax.jit(train_step)(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert metrics["grad_norm"] > 0
    # params actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                         params, new_p)
    assert max(jax.tree.leaves(moved)) > 0
    assert int(new_opt["adam"]["step"]) == 1


@pytest.mark.parametrize("arch", ["falcon-mamba-7b", "recurrentgemma-2b",
                                  "qwen2-7b", "whisper-small",
                                  "llama4-maverick-400b-a17b"])
def test_decode_matches_forward(arch):
    """Prefill + decode_step reproduce the full-forward logits."""
    cfg = dataclasses.replace(get_reduced(arch), param_dtype="float32",
                              capacity_factor=16.0)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    B, S, steps = 2, 12, 2
    batch = _smoke_batch(cfg, B, S)
    logits, _ = T.forward(params, cfg, batch)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :S - steps]
    _, caches, enc_kv = T.prefill(params, cfg, pre,
                                  max_len=S + cfg.img_tokens + 4,
                                  cache_dtype=jnp.float32)
    for i in range(steps):
        p = S - steps + i
        lg, caches = T.decode_step(
            params, cfg, batch["tokens"][:, p:p + 1],
            jnp.int32(cfg.img_tokens + p), caches, enc_kv=enc_kv)
        ref = logits[:, cfg.img_tokens + p]
        np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(ref),
                                   atol=3e-4, rtol=3e-3)


def test_full_configs_match_assignment():
    """The FULL configs encode the assigned architecture table exactly."""
    rows = {
        "falcon-mamba-7b": (64, 4096, None, None, 0, 65024),
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
        "qwen2-7b": (28, 3584, 28, 4, 18944, 152064),
        "stablelm-1.6b": (24, 2048, 32, 32, 5632, 100352),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "phi-3-vision-4.2b": (32, 3072, 32, 32, 8192, 32064),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
    }
    for arch, (L, d, H, kv, ff, vocab) in rows.items():
        cfg = get_config(arch)
        assert cfg.n_layers == L, arch
        assert cfg.d_model == d, arch
        if H is not None:
            assert cfg.n_heads == H, arch
            assert cfg.n_kv_heads == kv, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab == vocab, arch
    assert get_config("falcon-mamba-7b").ssm_state == 16
    assert get_config("dbrx-132b").n_experts == 16
    assert get_config("dbrx-132b").top_k == 4
    l4 = get_config("llama4-maverick-400b-a17b")
    assert l4.n_experts == 128 and l4.top_k == 1
    rg = get_config("recurrentgemma-2b")
    assert rg.block_pattern == ("rglru", "rglru", "attn_local")


def test_param_count_scales():
    """Sanity: approximate parameter counts near the advertised sizes."""
    expect = {"qwen2-7b": (6e9, 9e9), "stablelm-1.6b": (1.3e9, 2e9),
              "dbrx-132b": (110e9, 145e9),
              "llama4-maverick-400b-a17b": (330e9, 450e9),
              "recurrentgemma-2b": (2e9, 3.3e9),
              "falcon-mamba-7b": (6e9, 8.5e9)}
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).n_params
        assert lo < n < hi, f"{arch}: {n:.3e} not in ({lo:.0e}, {hi:.0e})"
