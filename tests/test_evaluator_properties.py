"""Property-based tests (hypothesis): cost-model invariants over random
graphs and fusion states."""
import random

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.fusion import FusionState
from repro.core.ga import GAConfig, run_ga
from repro.core.graph import Layer, LayerGraph
from repro.costmodel import SIMBA, Evaluator


@st.composite
def random_conv_graphs(draw):
    """Chains of 3-7 convs with random dims and occasional residual adds."""
    n = draw(st.integers(min_value=3, max_value=7))
    hw = draw(st.sampled_from([8, 16, 32]))
    ch = draw(st.sampled_from([4, 8, 16]))
    g = LayerGraph("rand")
    prev = g.add(Layer(name="input", kind="input", m=ch, p=hw, q=hw))
    anchors = [prev]
    c, h, w = ch, hw, hw
    for i in range(n):
        k = draw(st.sampled_from([1, 3]))
        m = draw(st.sampled_from([4, 8, 16]))
        prev = g.add(Layer(name=f"c{i}", kind="conv", c=c, h=h, w=w, m=m,
                           p=h, q=w, r=k, s=k, padding=(k // 2, k // 2)),
                     [prev])
        c = m
        if draw(st.booleans()) and len(anchors) > 1:
            a = anchors[-1]
            if g.layers[a].m == m and g.layers[a].p == h:
                prev = g.add(Layer(name=f"add{i}", kind="add", c=m, h=h,
                                   w=w, m=m, p=h, q=w), [a, prev])
        anchors.append(prev)
    return g


@st.composite
def graph_and_state(draw):
    g = draw(random_conv_graphs())
    edges = g.edges
    fused = frozenset(e for e in edges if draw(st.booleans()))
    return g, FusionState(g, fused)


@given(graph_and_state())
@settings(max_examples=40, deadline=None)
def test_macs_conserved_and_costs_positive(gs):
    g, state = gs
    ev = Evaluator(g, SIMBA)
    base = ev.layerwise()
    cost = ev.evaluate(state)
    if cost is None:              # invalid states are allowed to be skipped
        assert not state.is_schedulable() or True
        return
    assert cost.macs == base.macs                # schedule-invariant work
    assert cost.energy_pj > 0
    assert cost.cycles > 0
    # DRAM writes only ever shrink under fusion (outputs subset layerwise's)
    assert cost.dram_write_words <= base.dram_write_words
    assert cost.act_write_events <= base.act_write_events


@given(graph_and_state())
@settings(max_examples=30, deadline=None)
def test_fitness_nonnegative_and_layerwise_unity(gs):
    g, state = gs
    ev = Evaluator(g, SIMBA)
    assert ev.fitness(FusionState.layerwise(g)) == 1.0
    assert ev.fitness(state) >= 0.0


@pytest.mark.parametrize("seed", [0, 7, 42, 99])
def test_crossover_children_are_valid_genomes(seed):
    from tests.test_fusion import skip_graph
    g = skip_graph()
    ev = Evaluator(g, SIMBA)
    res = run_ga(g, ev, GAConfig.fast(generations=8, seed=seed,
                                      crossover_rate=0.5))
    assert res.best_state.fused <= set(g.edges)
    assert ev.evaluate(res.best_state) is not None


def test_ga_deterministic_given_seed():
    from repro.workloads import mobilenet_v3_large
    g = mobilenet_v3_large()
    ev1, ev2 = Evaluator(g, SIMBA), Evaluator(g, SIMBA)
    r1 = run_ga(g, ev1, GAConfig.fast(generations=10, seed=42))
    r2 = run_ga(g, ev2, GAConfig.fast(generations=10, seed=42))
    assert r1.best_fitness == r2.best_fitness
    assert r1.best_state.fused == r2.best_state.fused
    assert r1.history == r2.history
