"""Island-model GA backend: ga-equivalence at islands=1, fixed-seed
superiority at islands=4, migration determinism, seed derivation, and the
migration plumbing itself."""
import pytest

from repro.core.ga import GAConfig, run_ga_problem
from repro.core.problem import FusionProblem
from repro.costmodel import SIMBA
from repro.costmodel.evaluator import Evaluator
from repro.search import BackendError, search
from repro.search.island import (IslandBackend, island_seed, inject_migrants,
                                 _sync_gens)
from repro.workloads import vgg16

FAST = {"preset": "fast", "generations": 12}


def _search(backend, seed=3, **extra):
    return search("vgg16", "simba", backend=backend, seed=seed,
                  backend_config={**FAST, **extra})


# ---- ga equivalence ---------------------------------------------------------------

def test_islands_one_bit_identical_to_ga():
    """At islands=1 the backend IS the ga backend: genome, fitness,
    history, and the winning ScheduleCost agree bit-for-bit at fixed
    seed."""
    a = _search("ga")
    b = _search("island", islands=1)
    assert b.genome_mask == a.genome_mask
    assert b.best_fitness == a.best_fitness
    assert b.history == a.history
    assert b.best == a.best                  # frozen dataclass: field-exact
    assert b.baseline == a.baseline
    assert b.evaluations == a.evaluations
    assert b.offspring_evaluated == a.offspring_evaluated


def test_islands_four_fixed_seed_fitness_at_least_ga():
    a = _search("ga")
    b = _search("island", islands=4, migrate_every=4)
    assert b.best_fitness >= a.best_fitness
    # 4 islands really did ~4x the search work
    assert b.offspring_evaluated > 3 * a.offspring_evaluated


# ---- determinism ------------------------------------------------------------------

def test_migration_determinism_across_runs():
    a = _search("island", islands=3, migrate_every=3)
    b = _search("island", islands=3, migrate_every=3)
    assert a.genome_mask == b.genome_mask
    assert a.best_fitness == b.best_fitness
    assert a.history == b.history


def test_thread_workers_match_process_workers():
    a = _search("island", islands=3, migrate_every=3)
    b = _search("island", islands=3, migrate_every=3, workers="thread")
    assert a.genome_mask == b.genome_mask
    assert a.history == b.history


def test_island_seed_derivation():
    assert island_seed(7, 0) == 7            # island 0 reproduces ga's stream
    seeds = [island_seed(7, i) for i in range(8)]
    assert len(set(seeds)) == 8
    assert seeds == [island_seed(7, i) for i in range(8)]  # stable
    assert island_seed(8, 3) != island_seed(7, 3)


# ---- config / session integration -------------------------------------------------

def test_island_config_validation():
    with pytest.raises(BackendError):
        _search("island", islands=0)
    with pytest.raises(BackendError):
        _search("island", migrate_every=0)
    with pytest.raises(BackendError):
        _search("island", workers="gpu")
    with pytest.raises(BackendError):
        _search("island", islands=2, nonsense=1)


def test_island_rejects_seed_carrying_ga_config():
    """A ga_config seed would win over island_seed derivation and collapse
    every island onto one stream (N identical searches)."""
    with pytest.raises(BackendError, match="per-island seeds"):
        search("vgg16", "simba", backend="island",
               backend_config={"islands": 2,
                               "ga_config": {"generations": 4, "seed": 5}})
    # seedless ga_config dicts are fine: each island gets its derived seed
    art = search("vgg16", "simba", backend="island", seed=3,
                 backend_config={"islands": 2, "migrate_every": 2,
                                 "ga_config": {"generations": 4,
                                               "population": 20,
                                               "top_n": 4,
                                               "mutations_per_gen": 20,
                                               "random_survivors": 3}})
    assert art.best_fitness >= 1.0


def test_failed_island_releases_the_healthy_ones():
    """One dead island must not leave its peers blocked at the sync
    barrier until the recv timeout: the parent broadcasts stop."""
    import queue

    from repro.search.island import _Chan

    dead_inbox = queue.Queue()
    dead_inbox.put(("error", "boom"))
    dead = _Chan(inbox=dead_inbox, outbox=queue.Queue())
    healthy_out = queue.Queue()
    healthy = _Chan(inbox=queue.Queue(), outbox=healthy_out)
    with pytest.raises(BackendError, match="island 0 failed: boom"):
        IslandBackend._drive(problem=None, chans=[dead, healthy],
                             sync_gens=[1], migrate_every=2, observer=None)
    assert healthy_out.get_nowait() == ("stop", [])


def test_chan_turns_dead_peer_into_timeout_error():
    """A hard-killed worker (closed pipe) must surface through recv as the
    worker-is-gone error, not a raw EOFError."""
    import multiprocessing

    from repro.search.island import _Chan

    try:
        ctx = multiprocessing.get_context("fork")
    except ValueError:
        pytest.skip("no fork on this platform")
    parent, child = ctx.Pipe(duplex=True)
    child.close()                            # the "worker" died
    with pytest.raises(TimeoutError, match="died"):
        _Chan(conn=parent).recv(timeout=5)


def test_erroring_problem_surfaces_promptly_as_backend_error():
    import time

    class Exploding(FusionProblem):
        def fitness_batch(self, genomes):
            raise RuntimeError("cost service down")

    g = vgg16()
    problem = Exploding(g, Evaluator(g, SIMBA))
    t0 = time.monotonic()
    with pytest.raises(BackendError, match="island .* failed"):
        IslandBackend().run(problem, seed=0, islands=2, migrate_every=2,
                            preset="fast", generations=6)
    assert time.monotonic() - t0 < 60


def test_island_budget_stops_at_sync():
    full = _search("island", islands=2, migrate_every=2)
    capped = search("vgg16", "simba", backend="island", seed=3, budget=1,
                    backend_config={**FAST, "islands": 2, "migrate_every": 2})
    assert len(capped.history) < len(full.history)


def test_island_budget_enforced_even_without_migrations():
    """migrate_every larger than the run must not disable the budget:
    observation-only syncs still let the session stop the islands."""
    capped = search("vgg16", "simba", backend="island", seed=3, budget=1,
                    backend_config={"preset": "fast", "generations": 25,
                                    "islands": 2, "migrate_every": 1000})
    assert len(capped.history) <= 10         # stopped at the first obs sync


# ---- migration plumbing -----------------------------------------------------------

def test_sync_gens_skip_last_generation():
    assert _sync_gens(10, 3) == [2, 5, 8]
    assert _sync_gens(9, 3) == [2, 5]        # g=8 is the last gen: dropped
    assert _sync_gens(10, 20) == []          # run shorter than any cadence
    # large migrate_every still observes every OBSERVE_EVERY_MAX gens
    # (g=19,39 are migrations; 9/29 observation-only; 39 dropped as last)
    assert _sync_gens(40, 20) == [9, 19, 29]


def test_inject_migrants_replaces_worst_keeps_best():
    g = vgg16()
    problem = FusionProblem(g, Evaluator(g, SIMBA))
    res = run_ga_problem(problem, GAConfig.fast(generations=2, seed=0))
    pool = [(problem.fitness(res.best_state), res.best_state),
            (0.5, problem.initial())]
    better = run_ga_problem(problem, GAConfig.fast(generations=4, seed=9))
    enc = problem.encode_genome(better.best_state)
    out = inject_migrants(problem, pool, [(better.best_fitness, enc)])
    assert len(out) == 2
    keys = {problem.key(s) for _, s in out}
    assert problem.key(res.best_state) in keys          # best survives
    assert problem.key(better.best_state) in keys       # migrant landed
    # duplicate immigrants are dropped, pool unchanged
    again = inject_migrants(problem, out, [(better.best_fitness, enc)])
    assert {problem.key(s) for _, s in again} == keys


def test_sync_gens_1_means_migrate_every_generation():
    assert _sync_gens(4, 1) == [0, 1, 2]


def test_encode_decode_genome_round_trip():
    g = vgg16()
    problem = FusionProblem(g, Evaluator(g, SIMBA))
    state = problem.initial().mutate(__import__("random").Random(0))
    enc = problem.encode_genome(state)
    assert isinstance(enc, int)
    back = problem.decode_genome(enc)
    assert back.mask == state.mask and back.graph is g
