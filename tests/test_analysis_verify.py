"""repro.analysis.verify as an adversary: clean artifacts from every
backend verify, every corruption is rejected with a specific diagnostic,
and the lower-bound certificate's gap is >= 0 across the zoo."""
import dataclasses
import json

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.analysis import verify_artifact, verify_store
from repro.analysis.bounds import graph_bound, group_bound, onchip_words_for
from repro.analysis.verify import _GraphView
from repro.core.fusion import FusionState
from repro.core.graph import Layer, LayerGraph
from repro.search import (ScheduleArtifact, SearchSession, SearchSpec,
                          build_accelerator, search)
from repro.search.artifact import graph_fingerprint
from repro.serve import ArtifactStore


def chain(n=4, name="chain"):
    g = LayerGraph(name)
    prev = g.add(Layer(name="input", kind="input", m=8, p=16, q=16))
    for i in range(n):
        prev = g.add(Layer(name=f"c{i}", kind="conv", c=8, h=16, w=16,
                           m=8, p=16, q=16, r=3, s=3, padding=(1, 1)),
                     [prev])
    return g


def residual(name="residual"):
    g = LayerGraph(name)
    i = g.add(Layer(name="input", kind="input", m=8, p=16, q=16))
    a = g.add(Layer(name="a", kind="conv", c=8, h=16, w=16, m=8, p=16,
                    q=16, r=3, s=3, padding=(1, 1)), [i])
    b = g.add(Layer(name="b", kind="conv", c=8, h=16, w=16, m=8, p=16,
                    q=16, r=3, s=3, padding=(1, 1)), [a])
    g.add(Layer(name="add", kind="add", c=8, h=16, w=16, m=8, p=16, q=16),
          [a, b])
    return g


def diamond():
    """a -> {b, c} -> d: fusing (a,b)+(b,d) leaves c outside the group,
    creating a condensation cycle group <-> c."""
    g = LayerGraph("diamond")
    a = g.add(Layer(name="a", kind="conv", c=4, h=8, w=8, m=4, p=8, q=8,
                    r=1, s=1))
    b = g.add(Layer(name="b", kind="conv", c=4, h=8, w=8, m=4, p=8, q=8,
                    r=1, s=1), [a])
    c = g.add(Layer(name="c", kind="conv", c=4, h=8, w=8, m=4, p=8, q=8,
                    r=1, s=1), [a])
    g.add(Layer(name="d", kind="add", c=4, h=8, w=8, m=4, p=8, q=8),
          [b, c])
    return g


def run_search(graph, backend="ga", **cfg):
    session = SearchSession.from_objects(
        graph, build_accelerator("simba"), backend=backend,
        backend_config=cfg, budget=200)
    return session.run()


# ---- independence ----------------------------------------------------------------


def test_legality_path_imports_neither_fusion_nor_evaluator():
    """The acceptance rule: the verifier's derivations must not lean on the
    engine modules whose output they check."""
    import repro.analysis.bounds as bounds
    import repro.analysis.verify as verify
    for mod in (verify, bounds):
        with open(mod.__file__) as f:
            src = f.read()
        imports = [ln for ln in src.splitlines()
                   if ln.lstrip().startswith(("import ", "from "))]
        for ln in imports:
            assert "core.fusion" not in ln, f"{mod.__name__}: {ln}"
            assert "core import fusion" not in ln, f"{mod.__name__}: {ln}"
            assert "costmodel.evaluator" not in ln, f"{mod.__name__}: {ln}"
            assert "costmodel import evaluator" not in ln, \
                f"{mod.__name__}: {ln}"


# ---- engine agreement ------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(mask=st.integers(min_value=0, max_value=(1 << 6) - 1),
       which=st.sampled_from(["chain", "residual", "diamond"]))
def test_view_agrees_with_engine_on_random_genomes(mask, which):
    graph = {"chain": chain, "residual": residual, "diamond": diamond}[
        which]()
    m = graph.compiled().m
    mask &= (1 << m) - 1
    view = _GraphView(graph)
    state = FusionState.from_mask(graph, mask)
    assert view.m == m
    derived = [{view.names[i] for i in g} for g in view.groups_of(mask)]
    engine = [set(g) for g in state.groups()]
    assert sorted(map(sorted, derived)) == sorted(map(sorted, engine))
    assert view.condensation_acyclic(view.groups_of(mask)) \
        == state.is_schedulable()


def test_footprint_matches_receptive_module():
    from repro.core.receptive import group_footprint_words
    graph = chain(5)
    view = _GraphView(graph)
    members = [view.id_of[n] for n in ("c0", "c1", "c2")]
    names = ["c0", "c1", "c2"]
    for t in (1, 2, 7):
        assert view.footprint_words(members, t) \
            == group_footprint_words(graph, names, t)


# ---- clean artifacts from every backend ------------------------------------------


@pytest.mark.parametrize("backend,cfg", [
    ("ga", {"preset": "fast", "generations": 6}),
    ("island", {"islands": 2}),
    ("exhaustive", {}),
])
def test_every_backend_artifact_verifies_clean(backend, cfg):
    artifact = run_search(residual(f"res_{backend}"), backend, **cfg)
    report = verify_artifact(artifact)
    assert report.ok, report.describe()
    cert = report.certificate
    assert cert is not None
    assert cert.gap_vs_schedule >= 0
    assert cert.gap_vs_graph >= 0
    assert cert.schedule_lb_words >= cert.graph_lb_words


@pytest.mark.parametrize("workload,accel,costmodel", [
    ("mobilenet_v3", "simba", "default"),
    ("mobilenet_v3", "eyeriss", "default"),
    ("vgg16", "simba@act-32", "default"),
    ("unet", "simba", "tpu"),
])
def test_zoo_gap_nonnegative(workload, accel, costmodel):
    artifact = search(workload, accel, costmodel=costmodel, budget=150,
                      backend_config={"preset": "fast"})
    report = verify_artifact(artifact)
    assert report.ok, report.describe()
    assert report.certificate is not None
    assert report.certificate.gap_vs_schedule >= 0
    assert report.certificate.gap_vs_graph >= 0


# ---- adversary: genome corruption ------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(bit=st.integers(min_value=0, max_value=1 << 30))
def test_flipping_any_genome_bit_is_rejected(bit):
    artifact = _CLEAN["artifact"]
    flipped = dataclasses.replace(
        artifact, genome_mask=artifact.genome_mask ^
        (1 << (bit % artifact.n_edges)))
    report = verify_artifact(flipped)
    assert not report.ok
    # the stored fused-edge list can never match a flipped genome: every
    # deduped bit is a distinct edge
    assert not report.check("fused-edges").ok, report.describe()


def test_out_of_range_genome_is_rejected():
    artifact = _CLEAN["artifact"]
    report = verify_artifact(dataclasses.replace(
        artifact, genome_mask=1 << artifact.n_edges))
    assert not report.ok
    assert not report.check("edges").ok


# ---- adversary: IR corruption ----------------------------------------------------


def _mutate_ir(artifact, **node_updates):
    ir = dict(artifact.graph_ir)
    ir["nodes"] = [dict(n) for n in ir["nodes"]]
    ir["nodes"][1].update(node_updates)
    return dataclasses.replace(artifact, graph_ir=ir)


def test_corrupting_embedded_ir_geometry_is_rejected():
    report = verify_artifact(_mutate_ir(_CLEAN["artifact"], m=999))
    assert not report.ok
    fail = report.check("fingerprint")
    assert not fail.ok
    assert "hashes to" in fail.detail          # specific diagnostic


def test_unparseable_embedded_ir_is_rejected():
    report = verify_artifact(_mutate_ir(_CLEAN["artifact"], kind="warp"))
    assert not report.ok
    assert not report.check("graph-source").ok


def test_stripped_ir_on_ir_workload_is_rejected():
    report = verify_artifact(dataclasses.replace(
        _CLEAN["artifact"], graph_ir=None))
    assert not report.ok
    assert "embedded" in report.check("graph-source").detail


def test_legacy_fingerprint_format_gets_distinct_diagnostic():
    art = _CLEAN["artifact"]
    legacy = dataclasses.replace(
        art, graph_fingerprint="sha256:" + "0" * 64,
        spec=art.spec.replace(workload="ir:sha256:" + "0" * 64))
    report = verify_artifact(legacy)
    fail = report.check("fingerprint")
    assert not fail.ok
    assert "'sha256'" in fail.detail and "regenerate" in fail.detail


# ---- adversary: cost corruption --------------------------------------------------


def test_inflated_cost_is_rejected_via_breakdowns():
    artifact = _CLEAN["artifact"]
    inflated = dataclasses.replace(
        artifact, best=dataclasses.replace(
            artifact.best,
            dram_read_words=artifact.best.dram_read_words * 3))
    report = verify_artifact(inflated)
    assert not report.ok
    assert not report.check("cost-consistency").ok


def test_deflated_cost_is_rejected_via_lower_bound():
    artifact = _CLEAN["artifact"]
    deflated = dataclasses.replace(
        artifact, group_breakdowns=[],
        best=dataclasses.replace(artifact.best, dram_read_words=1,
                                 dram_write_words=0))
    report = verify_artifact(deflated)
    assert not report.ok
    fail = report.check("bounds")
    assert not fail.ok and "BELOW" in fail.detail


def test_wrong_group_count_is_rejected():
    artifact = _CLEAN["artifact"]
    report = verify_artifact(dataclasses.replace(
        artifact, best=dataclasses.replace(
            artifact.best, n_groups=artifact.best.n_groups + 1)))
    assert not report.check("groups").ok


# ---- adversary: unschedulable genome ---------------------------------------------


def test_unschedulable_condensation_is_rejected_by_own_kahn():
    graph = diamond()
    cg = graph.compiled()
    fused = {("a", "b"), ("b", "d")}
    mask = sum(1 << i for i, e in enumerate(cg.edge_pairs) if e in fused)
    base = _CLEAN["artifact"]
    forged = dataclasses.replace(
        base,
        spec=base.spec.replace(workload=f"ir:{graph_fingerprint(graph)}"),
        graph_fingerprint=graph_fingerprint(graph),
        graph_ir=graph.to_ir().to_dict(),
        n_edges=cg.m, genome_mask=mask,
        fused_edges=sorted([u, v] for u, v in fused),
        group_breakdowns=[])
    report = verify_artifact(forged)
    assert not report.ok
    fail = report.check("schedulable")
    assert not fail.ok and "cycle" in fail.detail


# ---- store-level verification ----------------------------------------------------


def test_verify_store_checks_content_addresses(tmp_path):
    store = ArtifactStore(str(tmp_path / "st"))
    artifact = run_search(chain(3, "store_chain"))
    key = store.put(artifact)
    results = dict(verify_store(str(tmp_path / "st")))
    assert results[key].ok, results[key].describe()

    # hand-edit the object under its old key: the content address moves
    path = store.path_for(key)
    with open(path) as f:
        d = json.load(f)
    d["spec"]["seed"] = 999
    with open(path, "w") as f:
        json.dump(d, f)
    results = dict(verify_store(str(tmp_path / "st")))
    assert not results[key].ok
    assert not results[key].check("store-key").ok


def test_verify_store_reports_unreadable_objects(tmp_path):
    store = ArtifactStore(str(tmp_path / "st"))
    key = store.put(run_search(chain(3, "store_chain2")))
    with open(store.path_for(key), "w") as f:
        f.write("{ not json")
    (key2, report), = verify_store(str(tmp_path / "st"))
    assert key2 == key and not report.ok
    assert report.checks[0].name == "store-object"


# ---- bounds unit behavior --------------------------------------------------------


def test_group_floor_counts_boundary_tensors_once():
    g = chain(2)
    S = 10 ** 6
    lone = group_bound(g, ["c0"], S)
    c0 = g.layers["c0"]
    assert lone.floor_words == c0.weight_size + c0.input_size \
        + c0.output_size
    fused = group_bound(g, ["c0", "c1"], S)
    c1 = g.layers["c1"]
    # interior c0->c1 tensor is free; weights + group input + group output
    assert fused.floor_words == c0.weight_size + c1.weight_size \
        + c0.input_size + c1.output_size


def test_graph_bound_excludes_free_graph_inputs():
    g = chain(2)
    S = 10 ** 6
    b = graph_bound(g, S)
    # weights once + sink output once; the input placeholder costs nothing
    assert b.floor_words == g.total_weights + g.layers["c1"].output_size


def test_onchip_words_known_models_only():
    assert onchip_words_for("default", "simba") > 0
    assert onchip_words_for("tpu", "simba") == (16 * 1024 * 1024 // 2) // 2
    assert onchip_words_for("mystery", "simba") is None


# ---- CLI surface -----------------------------------------------------------------


def test_cli_report_prints_certificate_gap(tmp_path, capsys):
    from repro.__main__ import main
    artifact = search("mobilenet_v3", "simba", budget=150,
                      backend_config={"preset": "fast"})
    p = str(tmp_path / "a.json")
    artifact.save(p)
    assert main(["report", p]) == 0
    out = capsys.readouterr().out
    assert "certificate  : DRAM traffic" in out
    assert "gap" in out
    assert "verification : all checks passed" in out


def test_cli_verify_exit_codes(tmp_path, capsys):
    from repro.__main__ import main
    artifact = _CLEAN["artifact"]
    good = str(tmp_path / "good.json")
    artifact.save(good)
    assert main(["verify", good]) == 0
    bad = str(tmp_path / "bad.json")
    dataclasses.replace(artifact,
                        genome_mask=artifact.genome_mask ^ 1).save(bad)
    assert main(["verify", bad]) == 1
    out = capsys.readouterr().out
    assert "FAILED" in out and "fused-edges" in out
    assert main(["verify"]) == 2


def test_cli_list_store_surfaces_load_warnings(tmp_path, capsys):
    from repro.__main__ import main
    store = ArtifactStore(str(tmp_path / "st"))
    key = store.put(run_search(chain(3, "store_chain3")))
    # strip the breakdowns key: loads with a legacy-writer warning
    path = store.path_for(key)
    with open(path) as f:
        d = json.load(f)
    del d["group_breakdowns"]
    with open(path, "w") as f:
        json.dump(d, f)
    assert main(["list", "--store", str(tmp_path / "st")]) == 0
    out = capsys.readouterr().out
    assert key[:12] in out
    assert "warning:" in out and "predates" in out
    assert "1 with load warnings" in out


# one clean embedded-IR artifact shared by the adversary tests (session
# scope would hide it from the hypothesis shim; module-level dict keeps
# the one search cheap and explicit)
_CLEAN = {}


def _make_clean():
    artifact = run_search(residual("clean_res"), "ga",
                          preset="fast", generations=6)
    assert artifact.graph_ir is not None
    assert verify_artifact(artifact).ok
    return artifact


_CLEAN["artifact"] = _make_clean()
