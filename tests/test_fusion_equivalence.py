"""Incremental engine vs retained reference: bit-for-bit agreement.

The GA hot path (``repro.core.fusion.FusionState`` + the mask-keyed
``Evaluator`` fast path) must agree exactly with the original dict/frozenset
implementation (``repro.core.fusion_ref.ReferenceFusionState``) on

* ``groups()`` — same partition, same (first-seen) order,
* ``is_schedulable()``,
* ``evaluate()`` — identical :class:`ScheduleCost` including float fields,

for randomly sampled fusion states on real paper workloads, and for states
reached through long ``mutate`` chains (which exercise every incremental
path: component merge, component split, same-partition flips, and the
incremental condensation-cycle tests).  Also pins fixed-seed ``run_ga``
determinism.
"""
import random

import pytest

from repro.core.fusion import FusionState
from repro.core.fusion_ref import ReferenceFusionState
from repro.core.ga import GAConfig, run_ga
from repro.core.graph import Layer, LayerGraph
from repro.costmodel import EYERISS, SIMBA, Evaluator
from repro.workloads import mobilenet_v3_large, resnet50

WORKLOADS = {
    "mobilenet_v3": (mobilenet_v3_large, SIMBA),
    "resnet50": (resnet50, EYERISS),
}


def _random_states(graph, rng, count):
    """``count`` random genomes with mixed fused densities."""
    edges = graph.edges
    out = []
    for _ in range(count):
        p = rng.random()
        out.append(frozenset(e for e in edges if rng.random() < p))
    return out


@pytest.mark.parametrize("name", sorted(WORKLOADS))
def test_random_states_agree_with_reference(name):
    """100 random states per workload (200 total across the suite)."""
    build, acc = WORKLOADS[name]
    g = build()
    ev_new = Evaluator(g, acc)
    ev_ref = Evaluator(g, acc)
    rng = random.Random(0xFACE)
    for fused in _random_states(g, rng, 100):
        s = FusionState(g, fused)
        r = ReferenceFusionState(g, fused)
        assert s.fused == r.fused
        assert s.groups() == r.groups()
        assert s.is_schedulable() == r.is_schedulable()
        assert ev_new.evaluate(s) == ev_ref.evaluate(r)


@pytest.mark.parametrize("name", sorted(WORKLOADS))
def test_mutation_chains_agree_with_reference(name):
    """Long mutate chains hit the incremental merge/split/cycle-test paths."""
    build, acc = WORKLOADS[name]
    g = build()
    ev_new = Evaluator(g, acc)
    ev_ref = Evaluator(g, acc)
    rng = random.Random(7)
    s = FusionState.layerwise(g)
    for i in range(400):
        # materialize structure first so the next mutate takes the
        # incremental path rather than recomputing from scratch
        s.group_masks()
        s.is_schedulable()
        s = s.mutate(rng)
        r = ReferenceFusionState(g, s.fused)
        assert s.groups() == r.groups(), f"step {i}"
        assert s.is_schedulable() == r.is_schedulable(), f"step {i}"
        assert sorted(s.multi_masks()) == \
            sorted(m for m in s.group_masks() if m & (m - 1)), f"step {i}"
        if i % 10 == 0:
            assert ev_new.evaluate(s) == ev_ref.evaluate(r), f"step {i}"


def test_group_identity_helpers_agree():
    g = mobilenet_v3_large()
    rng = random.Random(3)
    for fused in _random_states(g, rng, 10):
        s = FusionState(g, fused)
        r = ReferenceFusionState(g, fused)
        assert s.group_edges() == r.group_edges()
        assert s.offchip_tensors() == r.offchip_tensors()
        for n in g.names:
            assert s.group_of(n) == r.group_of(n)
            assert s.tensor_offchip(n) == r.tensor_offchip(n)
        if s.is_schedulable():
            assert s.group_schedule(random.Random(11)) == \
                r.group_schedule(random.Random(11))


def test_batch_fitness_matches_exact_fitness():
    """The batched baseline-plus-corrections path may re-associate float sums
    but must agree with the exact per-state path to ~1 ulp."""
    g = resnet50()
    ev = Evaluator(g, SIMBA)
    rng = random.Random(21)
    states = [FusionState(g, f) for f in _random_states(g, rng, 40)]
    batched = ev.fitness_batch(states)
    for s, fb in zip(states, batched):
        fx = ev.fitness(s)
        assert fb == pytest.approx(fx, rel=1e-9, abs=1e-12)


def _diamondish_graph():
    """Re-converging DAG where condensation paths *descend* in node ids by
    entering a multi-member group at a high-id member and leaving from a
    low-id one — the shape that broke id-pruned reachability."""
    g = LayerGraph("diamondish")
    conv = dict(kind="conv", c=4, h=8, w=8, m=4, p=8, q=8, r=3, s=3,
                padding=(1, 1))
    g.add(Layer(name="n0", kind="input", m=4, p=8, q=8))
    g.add(Layer(name="n1", **conv), ["n0"])
    g.add(Layer(name="n2", **conv), ["n0"])
    g.add(Layer(name="n3", kind="add", c=4, h=8, w=8, m=4, p=8, q=8),
          ["n1", "n2"])
    g.add(Layer(name="n6", **conv), ["n0"])
    g.add(Layer(name="n8", **conv), ["n6"])
    g.add(Layer(name="n11", kind="add", c=4, h=8, w=8, m=4, p=8, q=8),
          ["n8", "n2"])
    return g


def test_incremental_cycle_test_sees_descending_paths():
    """Regression: combine() on a schedulable parent whose new cycle runs
    through a group entered at a high node id and left at a low one must be
    detected (id-based BFS pruning was unsound here)."""
    g = _diamondish_graph()
    parent_fused = frozenset({("n0", "n1"), ("n0", "n6"), ("n2", "n11")})
    parent = FusionState(g, parent_fused)
    parent.group_masks()
    assert parent.is_schedulable()
    child = parent.combine(("n1", "n3"))
    ref = ReferenceFusionState(g, child.fused)
    assert child.is_schedulable() == ref.is_schedulable() == False  # noqa: E712


def _random_dag(rng, n_nodes):
    g = LayerGraph(f"rand{n_nodes}")
    conv = dict(kind="conv", c=4, h=8, w=8, m=4, p=8, q=8, r=3, s=3,
                padding=(1, 1))
    g.add(Layer(name="n0", kind="input", m=4, p=8, q=8))
    for i in range(1, n_nodes):
        k = rng.randint(1, min(3, i))
        preds = rng.sample([f"n{j}" for j in range(i)], k)
        if k == 1:
            g.add(Layer(name=f"n{i}", **conv), preds)
        else:
            g.add(Layer(name=f"n{i}", kind="add", c=4, h=8, w=8,
                        m=4, p=8, q=8), preds)
    return g


def test_mutation_chains_agree_on_random_dags():
    """Randomized topologies: 60 random re-converging DAGs x 60-step mutate
    chains, incremental groups/schedulability vs the reference each step."""
    rng = random.Random(0xDA6)
    for trial in range(60):
        g = _random_dag(rng, rng.randint(5, 14))
        s = FusionState.layerwise(g)
        for step in range(60):
            s.group_masks()
            s.is_schedulable()
            s = s.mutate(rng)
            r = ReferenceFusionState(g, s.fused)
            assert s.groups() == r.groups(), (trial, step)
            assert s.is_schedulable() == r.is_schedulable(), (trial, step)


def test_parallel_edges_share_one_genome_bit():
    """A layer consuming the same producer twice (x + x) yields parallel
    edges; the bitmask genome must collapse them like the reference
    frozenset does, or one logical genome gets several unequal masks."""
    g = LayerGraph("selfadd")
    g.add(Layer(name="a", kind="input", m=4, p=8, q=8))
    g.add(Layer(name="dbl", kind="add", c=4, h=8, w=8, m=4, p=8, q=8),
          ["a", "a"])
    s1 = FusionState(g, frozenset({("a", "dbl")}))
    s2 = FusionState.fully_fused(g)
    assert s1 == s2 and hash(s1) == hash(s2) and s1.key() == s2.key()
    r = ReferenceFusionState.fully_fused(g)
    assert s1.groups() == r.groups()
    assert s1.is_schedulable() == r.is_schedulable()


def test_run_ga_deterministic_at_fixed_seed():
    g = mobilenet_v3_large()
    cfg = GAConfig.fast(generations=12, seed=5)
    r1 = run_ga(g, Evaluator(g, SIMBA), cfg)
    r2 = run_ga(g, Evaluator(g, SIMBA), cfg)
    assert r1.history == r2.history
    assert r1.best_fitness == r2.best_fitness
    assert r1.best_state.mask == r2.best_state.mask
    assert r1.offspring_evaluated == r2.offspring_evaluated
    assert r1.evaluations == r2.evaluations
