"""Daemon load benchmark: 1k+ mixed-spec jobs over the real HTTP socket.

Starts a :class:`repro.serve.daemon.ScheduleDaemon` on a loopback port,
warms the store by searching each unique spec once, then submits 1k+
jobs drawn round-robin from the spec mix and polls them all to terminal
state.  Emits the service's headline numbers: sustained jobs/sec over the
whole run, p50/p99 POST /jobs latency (the client-visible cost of a
submission — store hits resolve inside the POST), and the store hit rate.

The spec mix is deliberately cache-heavy (every spec repeats many times):
the daemon's design point is that repeat traffic is a read, so the
benchmark measures the serving path, not GA throughput — that is
``ga_convergence``/``island_scaling``'s job.

Save a run as ``BENCH_serve.json`` (``--json``) to serve as the serving
baseline; CI compares ``serve_load:jobs_per_sec`` warn-only (machine-local
HTTP latency is noisy across runners).
"""
from __future__ import annotations

import json
import time
import urllib.request

from repro.search import SearchSpec
from repro.serve.daemon import ScheduleDaemon

from benchmarks.common import emit, record

#: unique specs in the mix: 4 registry workloads x 2 seeds
WORKLOADS = ("mobilenet_v3", "resnet50", "unet", "vgg16")
SEEDS = (0, 1)


def _spec_mix(generations: int):
    return [SearchSpec(workload=w, seed=s,
                       backend_config={"preset": "fast",
                                       "generations": generations}).to_dict()
            for w in WORKLOADS for s in SEEDS]


def _post_job(base: str, spec_dict: dict) -> dict:
    req = urllib.request.Request(
        base + "/jobs", data=json.dumps({"spec": spec_dict}).encode())
    with urllib.request.urlopen(req, timeout=60) as r:
        return json.load(r)


def _get(base: str, path: str) -> dict:
    with urllib.request.urlopen(base + path, timeout=60) as r:
        return json.load(r)


def _percentile(sorted_vals, q: float) -> float:
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, int(round(q * (len(sorted_vals) - 1))))
    return sorted_vals[idx]


def run(full: bool = False):
    n_jobs = 4096 if full else 1024
    generations = 8 if full else 4
    mix = _spec_mix(generations)

    import tempfile
    with tempfile.TemporaryDirectory(prefix="repro-serve-bench-") as tmp:
        svc = ScheduleDaemon(tmp, workers=2)
        svc.start()
        base = f"http://127.0.0.1:{svc.port}"
        try:
            t0 = time.perf_counter()
            # warm phase: one genuine search per unique spec
            warm_ids = [_post_job(base, sd)["id"] for sd in mix]
            _drain(base, warm_ids)
            warm_s = time.perf_counter() - t0

            lat = []
            ids = []
            t1 = time.perf_counter()
            for i in range(n_jobs):
                s0 = time.perf_counter()
                ids.append(_post_job(base, mix[i % len(mix)])["id"])
                lat.append(time.perf_counter() - s0)
            _drain(base, ids)
            serve_s = time.perf_counter() - t1

            m = _get(base, "/metrics")
        finally:
            svc.stop()

    lat.sort()
    p50_ms = _percentile(lat, 0.50) * 1e3
    p99_ms = _percentile(lat, 0.99) * 1e3
    jobs_per_sec = n_jobs / serve_s if serve_s > 0 else 0.0
    total = n_jobs + len(mix)
    hit_rate = svc.store_hits / total if total else 0.0

    emit("serve_load", serve_s * 1e6 / n_jobs,
         f"jobs_per_sec={jobs_per_sec:.0f};p50_ms={p50_ms:.2f};"
         f"p99_ms={p99_ms:.2f};hit_rate={hit_rate:.3f}")
    record("serve_load",
           jobs=n_jobs, unique_specs=len(mix), generations=generations,
           workers=2,
           jobs_per_sec=round(jobs_per_sec, 1),
           p50_ms=round(p50_ms, 3), p99_ms=round(p99_ms, 3),
           hit_rate=round(hit_rate, 4),
           searches=svc.searches_run, store_hits=svc.store_hits,
           warm_s=round(warm_s, 3), serve_s=round(serve_s, 3),
           done=m["jobs"]["done"], failed=m["jobs"]["failed"])


def _drain(base: str, ids, timeout: float = 600.0) -> None:
    """Poll until every job id is terminal (done/failed/cancelled)."""
    deadline = time.monotonic() + timeout
    pending = list(ids)
    while pending:
        if time.monotonic() > deadline:
            raise RuntimeError(f"{len(pending)} job(s) never resolved")
        j = _get(base, f"/jobs/{pending[-1]}")
        if j["state"] in ("done", "failed", "cancelled"):
            pending.pop()
        else:
            time.sleep(0.02)


if __name__ == "__main__":
    run()
