"""Paper Fig. 7: energy per MAC vs receptive-field (tile) size, early
ResNet-50 layer (56x56 feature map) on the SIMBA-like architecture.

Reproduces the effect the GA exploits: computing a t x t output tile per
DRAM pass (single-tile mode, as in prior work [14]) re-fetches the halo
every pass; larger receptive fields amortize the per-access energy across
more MACs, so pJ/MAC falls with tile size.
"""
from __future__ import annotations

from repro.costmodel import DEFAULT_ENERGY, SIMBA
from repro.core.graph import Layer

from benchmarks.common import emit, time_call


def pj_per_mac_at_tile(t: int, *, c=64, m=64, hw=56, k=3) -> float:
    """Energy/MAC when producing t x t output tiles, inputs re-fetched from
    DRAM per tile (halo overlap not cached across tiles)."""
    em, acc = DEFAULT_ENERGY, SIMBA
    halo = t + k - 1
    in_words = c * halo * halo
    w_words = m * c * k * k                     # weights resident (fit check)
    macs = m * t * t * c * k * k
    n_tiles = (hw // t) ** 2
    # per-tile: inputs from DRAM, weights amortized across the whole layer
    e_dram = in_words * em.e_dram + (w_words * em.e_dram / n_tiles)
    e_sram = (macs / 64 + in_words + m * t * t) * em.e_sram(acc.act_buf_kib)
    e_mac = macs * (em.e_mac + 3 * em.e_rf)
    return (e_dram + e_sram + e_mac) / macs


def run(full: bool = False):
    tiles = [1, 2, 4, 7, 8, 14, 28, 56]
    prev = None
    for t in tiles:
        us, pj = time_call(pj_per_mac_at_tile, t)
        emit(f"fig7_rf_tile_{t}", us, f"pJ/MAC={pj:.3f}")
        prev = pj
    # the paper's qualitative claim: energy/MAC falls monotonically with RF
    vals = [pj_per_mac_at_tile(t) for t in tiles]
    mono = all(b <= a * 1.001 for a, b in zip(vals, vals[1:]))
    emit("fig7_monotonic_decrease", 0.0,
         f"monotonic={mono};range={vals[0]:.2f}->{vals[-1]:.2f}pJ/MAC;"
         f"ratio={vals[0]/vals[-1]:.2f}x")


if __name__ == "__main__":
    run()
