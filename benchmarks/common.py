"""Shared benchmark helpers: timing + CSV row emission."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

ROWS: List[Tuple[str, float, str]] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def time_call(fn: Callable, *args, repeats: int = 3, **kw) -> Tuple[float, object]:
    fn(*args, **kw)                      # warmup / compile
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeats
    return dt * 1e6, out
