"""Shared benchmark helpers: timing, CSV row emission, and machine-readable
JSON records (``benchmarks/run.py --json``).

Numeric record fields are mirrored into a :class:`repro.obs.MetricRegistry`
as ``<record>.<field>`` gauges, and the registry snapshot rides along in
the JSON payload (``metrics`` key) — the same rollup shape ``repro trace``
aggregates, so bench output and trace output diff with the same tooling.
"""
from __future__ import annotations

import json
import os
import platform
import sys
import time
from typing import Callable, Dict, List, Tuple

from repro.obs import MetricRegistry

ROWS: List[Tuple[str, float, str]] = []
RECORDS: List[Dict] = []        # structured metrics for the JSON report
REGISTRY = MetricRegistry()     # gauge mirror of every numeric record field


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def record(name: str, **fields):
    """Emit a structured metric record (kept alongside the CSV rows so perf
    trajectories can be diffed against ``BENCH_*.json`` baselines)."""
    RECORDS.append({"name": name, **fields})
    for k, v in fields.items():
        # bools are ints in Python; keep flags out of the numeric gauges
        if isinstance(v, (int, float)) and not isinstance(v, bool):
            REGISTRY.gauge(f"{name}.{k}").set(float(v))


def dump_json(path: str) -> None:
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    payload = {
        "meta": {
            "python": sys.version.split()[0],
            "platform": platform.platform(),
            "unix_time": int(time.time()),
        },
        "rows": [{"name": n, "us_per_call": us, "derived": d}
                 for n, us, d in ROWS],
        "records": RECORDS,
        "metrics": REGISTRY.snapshot(),
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {len(ROWS)} rows / {len(RECORDS)} records to {path}")


def time_call(fn: Callable, *args, repeats: int = 3, **kw) -> Tuple[float, object]:
    fn(*args, **kw)                      # warmup / compile
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn(*args, **kw)
    dt = (time.perf_counter() - t0) / repeats
    return dt * 1e6, out
