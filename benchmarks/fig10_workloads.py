"""Paper Fig. 10 + §VI: 3 CNNs x 3 accelerators, energy + EDP improvement
and geometric means.  Claims checked: MobileNet-v3 on SIMBA ~1.8x energy /
1.9x EDP; SIMBA-family geomean EDP ~1.4x; Eyeriss ~1.12x EDP (paper quotes
1.12-1.15x)."""
from __future__ import annotations

import math

from repro.core import GAConfig, optimize
from repro.costmodel import EYERISS, SIMBA, SIMBA2X2
from repro.workloads import mobilenet_v3_large, resnet50, unet

from benchmarks.common import emit, time_call


def run(full: bool = False):
    ga_gens = 500 if full else 150
    nets = [("mobilenet_v3", mobilenet_v3_large), ("unet", unet),
            ("resnet50", resnet50)]
    archs = [SIMBA, SIMBA2X2, EYERISS]
    results = {}
    for nname, build in nets:
        g = build()
        for acc in archs:
            ga = GAConfig(generations=ga_gens, seed=0)
            us, res = time_call(lambda: optimize(g, acc, ga), repeats=1)
            s = res.summary()
            results[(nname, acc.name)] = s
            emit(f"fig10_{nname}_{acc.name}", us,
                 f"energy_x={s['energy_x']};edp_x={s['edp_x']}")
    for acc in archs:
        geo_e = math.prod(results[(n, acc.name)]["energy_x"]
                          for n, _ in nets) ** (1 / len(nets))
        geo_d = math.prod(results[(n, acc.name)]["edp_x"]
                          for n, _ in nets) ** (1 / len(nets))
        paper = {"simba": "1.4", "simba2x2": "1.4", "eyeriss": "1.12"}
        emit(f"fig10_geomean_{acc.name}", 0.0,
             f"energy_x={geo_e:.3f};edp_x={geo_d:.3f};"
             f"paper_edp={paper[acc.name]}")


if __name__ == "__main__":
    run()
