"""Paper Fig. 10 + §VI: 3 CNNs x 3 accelerators, energy + EDP improvement
and geometric means, searched through the ``repro.search`` facade.  Claims
checked: MobileNet-v3 on SIMBA ~1.8x energy / 1.9x EDP; SIMBA-family
geomean EDP ~1.4x; Eyeriss ~1.12x EDP (paper quotes 1.12-1.15x)."""
from __future__ import annotations

import math

from repro.search import search

from benchmarks.common import emit

NETS = ("mobilenet_v3", "unet", "resnet50")
ARCHS = ("simba", "simba2x2", "eyeriss")


def run(full: bool = False):
    ga_gens = 500 if full else 150
    results = {}
    for net in NETS:
        for arch in ARCHS:
            artifact = search(net, arch, backend="ga", seed=0,
                              backend_config={"generations": ga_gens})
            s = artifact.summary()
            results[(net, arch)] = s
            emit(f"fig10_{net}_{arch}", artifact.wall_s * 1e6,
                 f"energy_x={s['energy_x']};edp_x={s['edp_x']}")
    for arch in ARCHS:
        geo_e = math.prod(results[(n, arch)]["energy_x"]
                          for n in NETS) ** (1 / len(NETS))
        geo_d = math.prod(results[(n, arch)]["edp_x"]
                          for n in NETS) ** (1 / len(NETS))
        paper = {"simba": "1.4", "simba2x2": "1.4", "eyeriss": "1.12"}
        emit(f"fig10_geomean_{arch}", 0.0,
             f"energy_x={geo_e:.3f};edp_x={geo_d:.3f};"
             f"paper_edp={paper[arch]}")


if __name__ == "__main__":
    run()
