"""Island-model GA scaling: aggregate search throughput at 1/2/4 islands.

Runs the ``island`` backend on MobileNet-v3 / SIMBA at a fixed seed and
emits ``evals_per_sec`` (total offspring evaluated across all islands per
second of wall time) per island count.  At ``islands=1`` the run is the
``ga`` backend itself, so the x1 row doubles as a cross-check against
``BENCH_ga.json``'s throughput; the x2/x4 rows show how much extra search
the same wall-clock buys on spare cores (expect ~linear up to the
machine's core count, then oversubscription flattens it).

Save a run as ``BENCH_island.json`` (``--json``) to serve as the scaling
baseline alongside ``BENCH_ga.json``.
"""
from __future__ import annotations

import os

from repro.search import SearchSession, SearchSpec

from benchmarks.common import emit, record


def run(full: bool = False):
    generations = 200 if full else 60
    for islands in (1, 2, 4):
        spec = SearchSpec(
            workload="mobilenet_v3", accelerator="simba", backend="island",
            backend_config={"generations": generations, "islands": islands,
                            "migrate_every": 20}, seed=0)
        session = SearchSession(spec)
        artifact = session.run()
        res = session.result
        wall_s = artifact.wall_s
        eps = res.offspring_evaluated / wall_s if wall_s > 0 else 0.0
        emit(f"island_scaling_x{islands}", wall_s * 1e6,
             f"evals_per_sec={eps:.0f};"
             f"offspring={res.offspring_evaluated};"
             f"best={res.best_fitness:.4f}")
        record("island_scaling",
               islands=islands, generations=generations, seed=spec.seed,
               workload=spec.workload, accelerator=spec.accelerator,
               cpu_count=os.cpu_count(),
               wall_s=round(wall_s, 4),
               evals_per_sec=round(eps, 1),
               offspring_evaluated=res.offspring_evaluated,
               best_fitness=res.best_fitness)


if __name__ == "__main__":
    run()
