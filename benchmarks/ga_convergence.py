"""Alg. 1 behaviour: GA fitness convergence trace (MobileNet-v3 / SIMBA),
search-engine throughput, and evaluation-cache effectiveness.

Emits the headline GA perf metric, ``evals_per_sec`` — offspring evaluated
per second of wall time over the whole ``run_ga`` call (100 gens, seed 0;
``--full`` restores the paper's 500 gens).  See ``benchmarks/README.md`` for
how to compare runs against a saved ``BENCH_*.json`` baseline.
"""
from __future__ import annotations

import time

from repro.core import GAConfig, run_ga
from repro.costmodel import SIMBA, Evaluator
from repro.workloads import mobilenet_v3_large

from benchmarks.common import emit, record


def run(full: bool = False):
    g = mobilenet_v3_large()
    ev = Evaluator(g, SIMBA)
    ga = GAConfig(generations=500 if full else 100, seed=0)
    t0 = time.perf_counter()
    res = run_ga(g, ev, ga)
    wall_s = time.perf_counter() - t0

    h = res.history
    marks = {0: h[0], len(h) // 4: h[len(h) // 4], len(h) // 2: h[len(h) // 2],
             len(h) - 1: h[-1]}
    trace = ";".join(f"g{k}={v:.3f}" for k, v in sorted(marks.items()))
    emit("ga_convergence_fitness", wall_s * 1e6, trace)

    stats = ev.cache_stats()
    evals_per_sec = res.offspring_evaluated / wall_s if wall_s > 0 else 0.0
    emit("ga_throughput", wall_s * 1e6,
         f"evals_per_sec={evals_per_sec:.0f};"
         f"offspring={res.offspring_evaluated};"
         f"unique_states={res.evaluations}")
    emit("ga_evaluations", 0.0,
         f"unique_states={res.evaluations};"
         f"group_cache={len(ev._group_cache)};"
         f"group_hit_rate={stats['group_hit_rate']:.4f};"
         f"delta_hit_rate={stats['delta_hit_rate']:.4f}")
    record("ga_convergence",
           workload=g.name, accelerator="simba",
           generations=ga.generations, seed=ga.seed,
           wall_s=round(wall_s, 4),
           evals_per_sec=round(evals_per_sec, 1),
           offspring_evaluated=res.offspring_evaluated,
           unique_states=res.evaluations,
           best_fitness=res.best_fitness,
           group_cache_entries=stats["unique_groups"],
           group_hit_rate=round(stats["group_hit_rate"], 6),
           delta_hit_rate=round(stats["delta_hit_rate"], 6))


if __name__ == "__main__":
    run()
