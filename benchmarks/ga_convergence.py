"""Alg. 1 behaviour: GA fitness convergence trace (MobileNet-v3 / SIMBA)
and evaluation-cache effectiveness."""
from __future__ import annotations

from repro.core import GAConfig, run_ga
from repro.costmodel import SIMBA, Evaluator
from repro.workloads import mobilenet_v3_large

from benchmarks.common import emit, time_call


def run(full: bool = False):
    g = mobilenet_v3_large()
    ev = Evaluator(g, SIMBA)
    ga = GAConfig(generations=500 if full else 100, seed=0)
    us, res = time_call(lambda: run_ga(g, ev, ga), repeats=1)
    h = res.history
    marks = {0: h[0], len(h) // 4: h[len(h) // 4], len(h) // 2: h[len(h) // 2],
             len(h) - 1: h[-1]}
    trace = ";".join(f"g{k}={v:.3f}" for k, v in sorted(marks.items()))
    emit("ga_convergence_fitness", us, trace)
    emit("ga_evaluations", 0.0,
         f"unique_states={res.evaluations};"
         f"group_cache={len(ev._group_cache)}")


if __name__ == "__main__":
    run()
