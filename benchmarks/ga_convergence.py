"""Alg. 1 behaviour: GA fitness convergence trace (MobileNet-v3 / SIMBA),
search-engine throughput, and evaluation-cache effectiveness — run through
the ``repro.search`` facade.

Emits the headline GA perf metric, ``evals_per_sec`` — offspring evaluated
per second of backend wall time over the whole search (100 gens, seed 0;
``--full`` restores the paper's 500 gens).  See ``benchmarks/README.md`` for
how to compare runs against a saved ``BENCH_*.json`` baseline.
"""
from __future__ import annotations

from repro.search import SearchSession, SearchSpec

from benchmarks.common import emit, record


def run(full: bool = False):
    spec = SearchSpec(
        workload="mobilenet_v3", accelerator="simba", backend="ga",
        backend_config={"generations": 500 if full else 100}, seed=0)
    session = SearchSession(spec)
    artifact = session.run()
    res = session.result
    wall_s = artifact.wall_s

    h = res.history
    marks = {0: h[0], len(h) // 4: h[len(h) // 4], len(h) // 2: h[len(h) // 2],
             len(h) - 1: h[-1]}
    trace = ";".join(f"g{k}={v:.3f}" for k, v in sorted(marks.items()))
    emit("ga_convergence_fitness", wall_s * 1e6, trace)

    ev = session.evaluator
    stats = ev.cache_stats()
    evals_per_sec = res.offspring_evaluated / wall_s if wall_s > 0 else 0.0
    emit("ga_throughput", wall_s * 1e6,
         f"evals_per_sec={evals_per_sec:.0f};"
         f"offspring={res.offspring_evaluated};"
         f"unique_states={res.evaluations}")
    emit("ga_evaluations", 0.0,
         f"unique_states={res.evaluations};"
         f"group_cache={stats['unique_groups']};"
         f"group_hit_rate={stats['group_hit_rate']:.4f};"
         f"batch_evals_per_sec={stats['batch_evals_per_sec']:.0f};"
         f"pop_backend={stats['pop_backend']}")
    record("ga_convergence",
           workload=spec.workload, accelerator=spec.accelerator,
           generations=spec.backend_config["generations"], seed=spec.seed,
           wall_s=round(wall_s, 4),
           evals_per_sec=round(evals_per_sec, 1),
           offspring_evaluated=res.offspring_evaluated,
           unique_states=res.evaluations,
           best_fitness=res.best_fitness,
           group_cache_entries=stats["unique_groups"],
           group_hit_rate=round(stats["group_hit_rate"], 6),
           batch_evals_per_sec=round(stats["batch_evals_per_sec"], 1),
           pop_backend=stats["pop_backend"])


if __name__ == "__main__":
    run()
