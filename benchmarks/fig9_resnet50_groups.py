"""Paper Fig. 9 + §IV: ResNet-50 on SIMBA-2x2 — the GA's automated fused
schedule, searched through the ``repro.search`` facade.  Claims checked:
overall EDP improvement (paper: 1.2x), larger gains in early layers (paper:
up to 2.7x), DRAM activation-write events drop (paper: 50 -> 15)."""
from __future__ import annotations

from repro.core.fusion import FusionState
from repro.search import SearchSession, SearchSpec

from benchmarks.common import emit


def run(full: bool = False):
    spec = SearchSpec(
        workload="resnet50", accelerator="simba2x2", backend="ga",
        backend_config={"generations": 500 if full else 120}, seed=0)
    session = SearchSession(spec)
    artifact = session.run()
    s = artifact.summary()
    emit("fig9_resnet50_simba2x2_edp", artifact.wall_s * 1e6,
         f"edp_x={s['edp_x']};paper=1.2")
    emit("fig9_resnet50_simba2x2_energy", 0.0, f"energy_x={s['energy_x']}")
    emit("fig9_dram_act_writes", 0.0,
         f"base={s['act_dram_writes_base']};best={s['act_dram_writes_best']};"
         f"paper=50->15")
    emit("fig9_n_fused_groups", 0.0, f"groups={s['groups']}")

    # per-region improvement: early (stage 1-2) vs late layers, approximated
    # by splitting the schedule's groups by position (reuses the session's
    # memoized evaluator — no re-costing)
    g = session.graph
    ev = session.evaluator
    best = session.result.best_state
    names = [n for n in g.names]
    early = set(names[:len(names) // 3])
    e_base_early = e_best_early = e_base_late = e_best_late = 0.0
    lw = FusionState.layerwise(g)
    for state, accum in ((lw, "base"), (best, "best")):
        for group in state.groups():
            cost = ev._group_cost(frozenset(group))
            if cost is None:
                continue
            energy_pj, cyc = cost[0], cost[1]
            tgt_early = all(m in early for m in group)
            edp = energy_pj * max(cyc, 1)
            if accum == "base":
                if tgt_early:
                    e_base_early += edp
                else:
                    e_base_late += edp
            else:
                if tgt_early:
                    e_best_early += edp
                else:
                    e_best_late += edp
    emit("fig9_early_vs_late", 0.0,
         f"early_x={e_base_early / max(e_best_early, 1):.2f};"
         f"late_x={e_base_late / max(e_best_late, 1):.2f};"
         f"paper_early_up_to=2.7")


if __name__ == "__main__":
    run()
