"""Paper Fig. 11 + §IV-A: Eyeriss activation/weight buffer repartitioning at
iso-capacity, ResNet-50, searched through the ``repro.search`` facade's
``eyeriss@act<delta>`` accelerator specs.  Trades 16 KiB steps of weight
buffer for activation buffer and re-runs the GA at each point.  Claim
checked: repartitioning improves EDP ~1.2x (paper: 1.2-1.25x over
baseline)."""
from __future__ import annotations

from repro.search import build_accelerator, search

from benchmarks.common import emit


def run(full: bool = False):
    ga_gens = 500 if full else 100
    base = None
    best = (None, 0.0)
    for delta in (-64, -32, 0, 32, 64, 96, 128):
        accel = f"eyeriss@act{delta:+d}"
        acc = build_accelerator(accel)
        artifact = search("resnet50", accel, backend="ga", seed=0,
                          backend_config={"generations": ga_gens})
        edp = artifact.best.edp
        energy = artifact.best.energy_pj
        cycles = artifact.best.cycles
        if delta == 0:
            base = (edp, energy, cycles)
        emit(f"fig11_act{acc.act_buf_kib}k_w{acc.weight_buf_kib}k",
             artifact.wall_s * 1e6,
             f"edp={edp:.3e};energy_pj={energy:.3e};cycles={cycles:.3e}")
        if best[0] is None or edp < best[1]:
            best = (accel, edp)
    assert base is not None
    emit("fig11_best_repartition", 0.0,
         f"arch={best[0]};edp_x_vs_base={base[0] / best[1]:.3f};"
         f"paper=1.2")

    # beyond-paper extra: the same sweep on the activation-heavy workload
    # (MobileNet-v3), where act-buffer capacity binds fusion depth hardest
    base_m = None
    best_m = None
    for delta in (-64, 0, 64, 128):
        accel = f"eyeriss@act{delta:+d}"
        artifact = search("mobilenet_v3", accel, backend="ga", seed=0,
                          backend_config={"generations": ga_gens})
        if delta == 0:
            base_m = artifact.best.edp
        if best_m is None or artifact.best.edp < best_m:
            best_m = artifact.best.edp
        emit(f"fig11x_mobilenet_act{build_accelerator(accel).act_buf_kib}k",
             0.0, f"edp={artifact.best.edp:.3e}")
    emit("fig11x_mobilenet_best", 0.0,
         f"edp_x_vs_base={base_m / best_m:.3f}")


if __name__ == "__main__":
    run()
