"""Paper Fig. 11 + §IV-A: Eyeriss activation/weight buffer repartitioning at
iso-capacity, ResNet-50.  Trades 16 KiB steps of weight buffer for
activation buffer and re-runs the GA at each point.  Claim checked:
repartitioning improves EDP ~1.2x (paper: 1.2-1.25x over baseline)."""
from __future__ import annotations

from repro.core import GAConfig, optimize
from repro.costmodel import EYERISS
from repro.workloads import resnet50

from benchmarks.common import emit, time_call


def run(full: bool = False):
    ga_gens = 500 if full else 100
    g = resnet50()
    base = None
    best = (None, 0.0)
    for delta in (-64, -32, 0, 32, 64, 96, 128):
        acc = EYERISS.repartition(delta)
        ga = GAConfig(generations=ga_gens, seed=0)
        us, res = time_call(lambda: optimize(g, acc, ga), repeats=1)
        edp = res.best.edp
        energy = res.best.energy_pj
        cycles = res.best.cycles
        if delta == 0:
            base = (edp, energy, cycles)
        emit(f"fig11_act{acc.act_buf_kib}k_w{acc.weight_buf_kib}k", us,
             f"edp={edp:.3e};energy_pj={energy:.3e};cycles={cycles:.3e}")
        if best[0] is None or edp < best[1]:
            best = (acc, edp)
    assert base is not None
    emit("fig11_best_repartition", 0.0,
         f"arch={best[0].name};edp_x_vs_base={base[0] / best[1]:.3f};"
         f"paper=1.2")

    # beyond-paper extra: the same sweep on the activation-heavy workload
    # (MobileNet-v3), where act-buffer capacity binds fusion depth hardest
    from repro.workloads import mobilenet_v3_large
    gm = mobilenet_v3_large()
    base_m = None
    best_m = None
    for delta in (-64, 0, 64, 128):
        acc = EYERISS.repartition(delta)
        r = optimize(gm, acc, GAConfig(generations=ga_gens, seed=0))
        if delta == 0:
            base_m = r.best.edp
        if best_m is None or r.best.edp < best_m:
            best_m = r.best.edp
        emit(f"fig11x_mobilenet_act{acc.act_buf_kib}k", 0.0,
             f"edp={r.best.edp:.3e}")
    emit("fig11x_mobilenet_best", 0.0,
         f"edp_x_vs_base={base_m / best_m:.3f}")


if __name__ == "__main__":
    run()
