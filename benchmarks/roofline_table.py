"""§Roofline: build the 40-cell roofline table from the dry-run artifacts.

Reads ``artifacts/dryrun/*.json``, derives the three terms per (arch x
shape) on the single-pod mesh, identifies the dominant bottleneck, computes
MODEL_FLOPS / HLO_FLOPs, and writes ``artifacts/roofline.csv`` (consumed by
EXPERIMENTS.md)."""
from __future__ import annotations

import glob
import json
import os

from repro.roofline.analysis import HW, roofline_from_artifact

from benchmarks.common import emit

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                       "dryrun")
OUT_CSV = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                       "roofline.csv")


def model_flops(art) -> float:
    """6*N*D (train) / 2*N*D (serving) with N = active params.

    Serving shapes exclude the embedding/unembedding parameters: the decode/
    prefill steps compute logits for one position only, so the vocab matmul
    contributes ~nothing per token (prefill) or a constant (decode)."""
    from repro.configs import get_config
    n = art["n_active_params"]
    toks = art["tokens"]
    if art["shape"].startswith("train"):
        return 6.0 * n * toks
    cfg = get_config(art["arch"])
    emb = cfg.vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    return 2.0 * max(n - emb, 1) * toks


def analytic_memory_s(art) -> float:
    """Analytic per-chip HBM seconds (the HLO-bytes term is an unfused
    upper bound — see DESIGN.md §6.5).  Train: the tpu_model estimate at the
    artifact's remat/microbatch setting.  Serving: params read once per step
    + KV/state-cache traffic."""
    from repro.configs import SHAPES, get_config
    from repro.costmodel.tpu_model import TpuSchedule, estimate
    from repro.roofline.analysis import HW
    cfg = get_config(art["arch"])
    shape = SHAPES[art["shape"]]
    chips = art["chips"]
    hw = HW()
    if shape.kind == "train":
        sched = TpuSchedule(remat=art.get("remat", "none"),
                            microbatches=art.get("microbatches", 1))
        return estimate(cfg, shape, sched, chips=chips,
                        data_par=16, model_par=16, hw=hw).memory_s
    params_b = 2 * cfg.n_params / chips
    if shape.kind == "decode":
        hd, kv = cfg.resolved_head_dim, cfg.n_kv_heads
        attn_layers = sum(1 for k in cfg.layer_kinds() if k.startswith("attn"))
        cache_b = (attn_layers * 2 * shape.global_batch * shape.seq_len
                   * kv * hd * 2) / chips
        return (params_b + cache_b) / hw.hbm_bw
    # prefill: params + ~14 activation tensors of d_model per token per layer
    toks = shape.global_batch * shape.seq_len / chips * 16  # model axis shares
    act_b = 14 * cfg.d_model * 2 * toks * cfg.n_layers / 16
    return (params_b + act_b) / hw.hbm_bw


def suggestion(dom, art) -> str:
    if dom == "compute":
        return ("raise MXU utilization: larger per-chip batch or fewer "
                "remat recomputes")
    if dom == "memory":
        return ("cut HBM traffic: fuse/remat fewer saves, larger microbatch "
                "reuse, bf16 collectives")
    return ("cut collective bytes: wider TP blocks per all-reduce, "
            "grad compression, overlap with compute")


def run(full: bool = False):
    rows = []
    for p in sorted(glob.glob(os.path.join(ART_DIR, "*.json"))):
        # skip perf-iteration variants (4th "__"-separated component = tag)
        if len(os.path.basename(p)[:-5].split("__")) != 3:
            continue
        art = json.load(open(p))
        if art.get("mesh") != "single":
            continue
        cell = f"{art['arch']}__{art['shape']}"
        if art["status"] == "skipped":
            rows.append({"cell": cell, "status": "skipped",
                         "reason": art.get("reason", "")})
            continue
        if art["status"] != "ok":
            rows.append({"cell": cell, "status": "failed"})
            continue
        t = roofline_from_artifact(art)
        mf = model_flops(art)
        hlo_global = t.flops * art["chips"]
        mem_an = analytic_memory_s(art)
        terms = {"compute": t.compute_s, "memory": mem_an,
                 "collective": t.collective_s}
        dominant = max(terms, key=terms.get)
        bound = max(terms.values())
        rows.append({
            "cell": cell, "status": "ok",
            "compute_s": f"{t.compute_s:.4e}",
            "memory_s_hlo_ub": f"{t.memory_s:.4e}",
            "memory_s_analytic": f"{mem_an:.4e}",
            "collective_s": f"{t.collective_s:.4e}",
            "dominant": dominant,
            "model_flops": f"{mf:.4e}",
            "hlo_flops_global": f"{hlo_global:.4e}",
            "useful_ratio": f"{mf / hlo_global:.3f}" if hlo_global else "0",
            "step_bound_s": f"{bound:.4e}",
            "roofline_fraction": f"{terms['compute'] / bound:.3f}"
            if bound else "0",
            "next_action": suggestion(dominant, art),
        })
        emit(f"roofline_{cell}", 0.0,
             f"dom={dominant};cmp={t.compute_s:.2e}s;"
             f"mem_an={mem_an:.2e}s;mem_ub={t.memory_s:.2e}s;"
             f"coll={t.collective_s:.2e}s;"
             f"useful={rows[-1]['useful_ratio']};"
             f"roofline_frac={rows[-1]['roofline_fraction']}")
    keys = ["cell", "status", "compute_s", "memory_s_hlo_ub",
            "memory_s_analytic", "collective_s", "dominant", "model_flops",
            "hlo_flops_global", "useful_ratio", "step_bound_s",
            "roofline_fraction", "next_action", "reason"]
    os.makedirs(os.path.dirname(OUT_CSV), exist_ok=True)
    with open(OUT_CSV, "w") as f:
        f.write(",".join(keys) + "\n")
        for r in rows:
            f.write(",".join(str(r.get(k, "")).replace(",", ";")
                             for k in keys) + "\n")
    emit("roofline_table_rows", 0.0, f"rows={len(rows)};csv={OUT_CSV}")


if __name__ == "__main__":
    run()
