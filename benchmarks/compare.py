"""Compare a fresh ``benchmarks/run.py --json`` report against a committed
baseline and fail on perf regressions — the teeth of the CI perf canary.

    python -m benchmarks.compare BENCH_ga.json /tmp/bench_now.json
    python -m benchmarks.compare base.json now.json \
        --metric ga_convergence:evals_per_sec --max-regression 0.30

A comparison targets one ``record_name:field`` metric (default:
``ga_convergence:evals_per_sec``, the GA engine's headline throughput).
The run fails (exit 1) when::

    now < baseline * (1 - max_regression)

Higher-is-better is assumed; pass ``--lower-is-better`` for time-like
metrics.  ``--max-regression`` defaults to 0.30 — wide enough to absorb
normal machine-to-machine and run-to-run noise while still catching the
step-function slowdowns an accidental O(n^2) or a dropped cache causes.
Override per-environment with ``BENCH_MAX_REGRESSION``.

Secondary warn-only metrics (default: ``ga_convergence:group_hit_rate``,
the GA's cache effectiveness) are compared with the same window but never
fail the run — they print, and regressions go to stderr as warnings.
Repeat ``--warn-metric`` to adjust the set; ``--warn-metric none``
disables it.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Optional


def _load_metric(path: str, record_name: str, field: str) -> float:
    with open(path) as f:
        report = json.load(f)
    for rec in report.get("records", []):
        if rec.get("name") == record_name:
            if field not in rec:
                raise KeyError(
                    f"{path}: record {record_name!r} has no field "
                    f"{field!r}; fields: {sorted(rec)}")
            return float(rec[field])
    names = sorted({r.get("name") for r in report.get("records", [])})
    raise KeyError(f"{path}: no record named {record_name!r}; "
                   f"records present: {names or '(none)'}")


def compare(baseline_path: str, current_path: str, *,
            metric: str = "ga_convergence:evals_per_sec",
            max_regression: float = 0.30,
            lower_is_better: bool = False) -> dict:
    """Return a comparison dict; ``ok`` is False on a regression beyond
    ``max_regression`` (fractional)."""
    record_name, _, field = metric.partition(":")
    if not field:
        raise ValueError(
            f"metric must be 'record_name:field', got {metric!r}")
    base = _load_metric(baseline_path, record_name, field)
    now = _load_metric(current_path, record_name, field)
    if base <= 0:
        raise ValueError(f"baseline {metric} is {base}; cannot compare")
    change = (now - base) / base
    regression = -change if not lower_is_better else change
    return {
        "metric": metric,
        "baseline": base,
        "current": now,
        "change_frac": change,
        "max_regression": max_regression,
        "ok": regression <= max_regression,
    }


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(
        description="fail if a benchmark metric regressed vs a baseline")
    ap.add_argument("baseline", help="committed BENCH_*.json baseline")
    ap.add_argument("current", help="fresh benchmarks/run.py --json output")
    ap.add_argument("--metric", default="ga_convergence:evals_per_sec",
                    help="record_name:field to compare (default: "
                         "ga_convergence:evals_per_sec)")
    ap.add_argument("--max-regression",
                    type=float,
                    default=float(os.environ.get("BENCH_MAX_REGRESSION",
                                                 0.30)),
                    help="allowed fractional drop before failing "
                         "(default 0.30, env BENCH_MAX_REGRESSION)")
    ap.add_argument("--lower-is-better", action="store_true",
                    help="treat increases as regressions (time-like "
                         "metrics)")
    ap.add_argument("--warn-metric", action="append", default=None,
                    metavar="RECORD:FIELD",
                    help="additional record_name:field metrics compared "
                         "warn-only — a regression prints a warning but "
                         "never fails the run (default: "
                         "ga_convergence:group_hit_rate; pass 'none' to "
                         "disable)")
    args = ap.parse_args(argv)
    warn_metrics = args.warn_metric \
        if args.warn_metric is not None else ["ga_convergence:group_hit_rate"]
    warn_metrics = [m for m in warn_metrics if m.lower() != "none"]

    try:
        res = compare(args.baseline, args.current, metric=args.metric,
                      max_regression=args.max_regression,
                      lower_is_better=args.lower_is_better)
    except (OSError, KeyError, ValueError, json.JSONDecodeError) as e:
        print(f"compare error: {e}", file=sys.stderr)
        return 2

    direction = "+" if res["change_frac"] >= 0 else ""
    print(f"{res['metric']}: baseline={res['baseline']:.1f} "
          f"current={res['current']:.1f} "
          f"({direction}{res['change_frac'] * 100:.1f}%, "
          f"allowed regression {res['max_regression'] * 100:.0f}%)")
    # secondary metrics: same window, zero teeth — absent/zero baselines
    # (older BENCH_*.json without the field) degrade to a note, and a
    # regression warns without touching the exit code
    for wm in warn_metrics:
        try:
            wres = compare(args.baseline, args.current, metric=wm,
                           max_regression=args.max_regression,
                           lower_is_better=args.lower_is_better)
        except (OSError, KeyError, ValueError, json.JSONDecodeError) as e:
            print(f"{wm}: unavailable ({e}) (warn-only)")
            continue
        wdir = "+" if wres["change_frac"] >= 0 else ""
        print(f"{wm}: baseline={wres['baseline']:.4f} "
              f"current={wres['current']:.4f} "
              f"({wdir}{wres['change_frac'] * 100:.1f}%) (warn-only)")
        if not wres["ok"]:
            print(f"warning: {wm} regressed beyond the window — not "
                  f"failing the run (warn-only metric), but worth a look",
                  file=sys.stderr)
    if not res["ok"]:
        print("PERF REGRESSION: metric fell beyond the allowed window "
              "(rerun to rule out noise; if the slowdown is real, fix it "
              "or re-baseline BENCH_ga.json in the same PR with a "
              "justification)", file=sys.stderr)
        return 1
    print("perf canary OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
