"""Beyond-paper: the TPU scheduling GA on the three hillclimb cells —
predicted step-time / EDP / HBM residency, baseline vs GA-selected schedule
(validated against compiled artifacts in EXPERIMENTS.md §Perf).  Runs the
TPU genome through the shared ``repro.search`` backend protocol."""
from __future__ import annotations

from repro.configs import get_config
from repro.configs.base import SHAPES
from repro.core.ga import GAConfig
from repro.search.tpu import search_tpu_schedule

from benchmarks.common import emit, time_call

CELLS = [
    ("dbrx-132b", "train_4k"),
    ("llama4-maverick-400b-a17b", "train_4k"),
    ("qwen2-7b", "train_4k"),
]


def run(full: bool = False):
    for arch, shape_name in CELLS:
        cfg = get_config(arch)
        shape = SHAPES[shape_name]
        ga = GAConfig.fast(generations=40 if full else 20)
        us, res = time_call(
            lambda: search_tpu_schedule(cfg, shape, ga=ga), repeats=1)
        b, o = res.baseline_cost, res.best_cost
        fits = "fits" if b.hbm_resident_bytes <= 16e9 else "OOM"
        emit(f"tpu_ga_{arch}_{shape_name}", us,
             f"baseline={fits}@{b.hbm_resident_bytes / 1e9:.1f}GB;"
             f"best=remat:{res.best.remat}/mb:{res.best.microbatches}/"
             f"gc:{res.best.grad_compression};"
             f"best_step={o.step_s * 1e3:.0f}ms;dom={o.dominant};"
             f"best_resident={o.hbm_resident_bytes / 1e9:.1f}GB")


if __name__ == "__main__":
    run()
