"""Kernel micro-benchmarks.

CPU wall-times (XLA-compiled reference paths; Pallas interpret mode is a
correctness vehicle, not a perf path) — the TPU-relevant numbers are the
analytic VMEM working sets per BlockSpec, emitted as `derived`."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mamba_scan.ref import mamba_scan_ref
from repro.kernels.rglru_scan.ref import rglru_scan_ref
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.models.attention import attention

from benchmarks.common import emit, time_call


def _vmem_kb(*tiles):
    return sum(4 * t for t in tiles) / 1024.0


def run(full: bool = False):
    key = jax.random.PRNGKey(0)
    B, S, Hq, Hkv, D = 1, 1024, 8, 2, 128
    q = jax.random.normal(key, (B, S, Hq, D), jnp.float32)
    k = jax.random.normal(key, (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(key, (B, S, Hkv, D), jnp.float32)
    pos = jnp.arange(S)

    f_block = jax.jit(lambda q, k, v: attention(
        q, k, v, pos, pos, impl="blockwise", block_kv=256))
    us, _ = time_call(lambda: jax.block_until_ready(f_block(q, k, v)))
    # flash kernel VMEM: q-tile + k-tile + v-tile + acc + (m, l)
    vm = _vmem_kb(128 * D, 128 * D, 128 * D, 128 * D, 128, 128)
    emit("kernel_flash_attn_1k_xla_blockwise", us,
         f"vmem_tile_kb={vm:.0f};block=(128,128)")

    f_dense = jax.jit(lambda q, k, v: attention(q, k, v, pos, pos,
                                                impl="dense"))
    us, _ = time_call(lambda: jax.block_until_ready(f_dense(q, k, v)))
    emit("kernel_attn_1k_xla_dense", us, "baseline")

    B, S, Di, N = 1, 512, 256, 16
    da = jax.random.uniform(key, (B, S, Di, N), minval=0.5, maxval=0.99)
    dbx = jax.random.normal(key, (B, S, Di, N)) * 0.1
    c = jax.random.normal(key, (B, S, N))
    f_m = jax.jit(mamba_scan_ref)
    us, _ = time_call(lambda: jax.block_until_ready(f_m(da, dbx, c)))
    vm = _vmem_kb(128 * 128 * N * 2, 128 * N, 128 * 128)
    emit("kernel_mamba_scan_512", us, f"vmem_tile_kb={vm:.0f};block=(128,128)")

    a = jax.random.uniform(key, (1, 2048, 2560), minval=0.5, maxval=0.999)
    b = jax.random.normal(key, (1, 2048, 2560))
    f_r = jax.jit(rglru_scan_ref)
    us, _ = time_call(lambda: jax.block_until_ready(f_r(a, b)))
    emit("kernel_rglru_scan_2k", us,
         f"vmem_tile_kb={_vmem_kb(256 * 128 * 2, 128):.0f};block=(128,256)")

    x = jax.random.normal(key, (4096, 4096))
    w = jnp.ones((4096,))
    f_n = jax.jit(rmsnorm_ref)
    us, _ = time_call(lambda: jax.block_until_ready(f_n(x, w)))
    emit("kernel_rmsnorm_4kx4k", us,
         f"vmem_tile_kb={_vmem_kb(256 * 4096):.0f};block_rows=256")


if __name__ == "__main__":
    run()
