"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``--full`` restores the
paper's GA settings (P=100, N=10, G=500); the default uses fewer
generations for CPU wall-time (EXPERIMENTS.md records which setting
produced each number).  ``--json PATH`` additionally writes all rows plus
the structured metric records (GA throughput, cache hit rates, ...) as a
machine-readable report; save one as ``BENCH_<label>.json`` to serve as the
perf-regression baseline (see benchmarks/README.md).
"""
import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper GA settings (slower)")
    ap.add_argument("--only", default="",
                    help="comma-separated benchmark names to run")
    ap.add_argument("--json", default="",
                    help="write rows + structured records to this path")
    args = ap.parse_args()

    from benchmarks import (fig7_receptive_field, fig9_resnet50_groups,
                            fig10_workloads, fig11_repartition,
                            ga_convergence, island_scaling, kernel_bench,
                            roofline_table, serve_load, tpu_schedule_bench)
    suites = {
        "fig7": fig7_receptive_field,
        "fig9": fig9_resnet50_groups,
        "fig10": fig10_workloads,
        "fig11": fig11_repartition,
        "ga": ga_convergence,
        "island": island_scaling,
        "kernels": kernel_bench,
        "roofline": roofline_table,
        "serve": serve_load,
        "tpu_ga": tpu_schedule_bench,
    }
    selected = [s.strip() for s in args.only.split(",") if s.strip()] \
        or list(suites)
    unknown = [s for s in selected if s not in suites]
    if unknown:
        ap.error(f"unknown --only name(s) {', '.join(sorted(unknown))}; "
                 f"valid: {', '.join(suites)}")
    print("name,us_per_call,derived")
    failures = 0
    for name in selected:
        try:
            suites[name].run(full=args.full)
        except Exception:
            failures += 1
            print(f"{name}_FAILED,0,{traceback.format_exc(limit=1)!r}")
    if args.json:
        from benchmarks.common import dump_json
        dump_json(args.json)
    sys.exit(1 if failures else 0)


if __name__ == '__main__':
    main()
