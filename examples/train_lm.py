"""End-to-end training driver: train a ~100M-param LM for a few hundred
steps on the synthetic pipeline, with checkpointing and (optionally) an
injected failure to demonstrate restart-exactly-once.

    pip install -e .   (or: export PYTHONPATH=src)
    python examples/train_lm.py --size 25m --steps 300
    python examples/train_lm.py --size 100m --steps 200
    python examples/train_lm.py --inject-failure 60
"""
import argparse
import time

from repro.configs.base import ModelConfig
from repro.launch.train import TrainRunConfig, train_loop
from repro.runtime import FaultInjector

SIZES = {
    # ~25M params: fast on 1 CPU core
    "25m": ModelConfig(name="lm-25m", family="dense", n_layers=6,
                       d_model=384, n_heads=6, n_kv_heads=2, d_ff=1536,
                       vocab=16_384, param_dtype="float32",
                       compute_dtype="float32"),
    # ~100M params (the assignment's end-to-end scale)
    "100m": ModelConfig(name="lm-100m", family="dense", n_layers=10,
                        d_model=640, n_heads=10, n_kv_heads=2, d_ff=2560,
                        vocab=50_304, param_dtype="float32",
                        compute_dtype="float32"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--size", choices=list(SIZES), default="25m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--inject-failure", type=int, default=0,
                    help="simulate a crash at this step (restart demo)")
    args = ap.parse_args()

    cfg = SIZES[args.size]
    print(f"model: {cfg.name}, ~{cfg.n_params / 1e6:.0f}M params")
    run = TrainRunConfig(cfg=cfg, steps=args.steps, global_batch=args.batch,
                         seq_len=args.seq, lr=args.lr,
                         ckpt_dir=args.ckpt_dir, save_every=50,
                         log_every=10)
    injector = FaultInjector([args.inject_failure]) \
        if args.inject_failure else None
    t0 = time.time()
    out = train_loop(run, injector=injector)
    dt = time.time() - t0
    h = out["history"]
    print(f"\ndone: {out['completed_steps']} steps in {dt:.0f}s "
          f"({out['restarts']} restarts)")
    print(f"loss: {h['loss'][0]:.3f} -> {h['loss'][-1]:.3f}")
    wd = out["watchdog"]
    print(f"watchdog: mean step {sum(wd.durations) / len(wd.durations):.2f}s,"
          f" {len(wd.violations)} deadline violations")


if __name__ == "__main__":
    main()
