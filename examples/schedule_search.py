"""Beyond-paper example: the paper's search re-targeted at TPU training
schedules (remat policy x microbatching x gradient compression x sharding),
costed with the analytical v5e roofline model — then the chosen schedule is
what `repro.launch.dryrun --remat ... --microbatches ...` validates by
compiling.

The TPU genome runs through the same `repro.search` backend protocol as the
paper's fusion states, so any registered backend applies; the space is only
60 schedules, so `--backend exhaustive` gives the ground-truth optimum to
compare the GA against.

    pip install -e .   (or: export PYTHONPATH=src)
    python examples/schedule_search.py --arch dbrx-132b [--backend ga]
"""
import argparse

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import SHAPES
from repro.core.ga import GAConfig
from repro.search import BACKENDS
from repro.search.tpu import search_tpu_schedule


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dbrx-132b", choices=ARCH_IDS)
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--backend", default="ga", choices=BACKENDS.names())
    ap.add_argument("--generations", type=int, default=30)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    res = search_tpu_schedule(
        cfg, SHAPES[args.shape], backend=args.backend,
        ga=GAConfig.fast(generations=args.generations))
    b, o = res.baseline_cost, res.best_cost
    print(f"arch: {args.arch}  shape: {args.shape}  "
          f"({cfg.n_params / 1e9:.0f}B params)  backend: {args.backend}")
    print(f"\nbaseline (paper-faithful: no remat, no microbatching):")
    fits = "fits HBM" if b.hbm_resident_bytes <= 16e9 else \
        "DOES NOT FIT 16 GB HBM"
    print(f"  step {b.step_s * 1e3:7.1f} ms  dominant={b.dominant}  "
          f"resident {b.hbm_resident_bytes / 1e9:.1f} GB/chip  [{fits}]")
    print(f"\nselected schedule: remat={res.best.remat}, "
          f"microbatches={res.best.microbatches}, "
          f"grad_compression={res.best.grad_compression}")
    print(f"  step {o.step_s * 1e3:7.1f} ms  dominant={o.dominant}  "
          f"resident {o.hbm_resident_bytes / 1e9:.1f} GB/chip")
    print(f"  terms: compute {o.compute_s * 1e3:.1f} ms | memory "
          f"{o.memory_s * 1e3:.1f} ms | collective "
          f"{o.collective_s * 1e3:.1f} ms")
    print(f"\nvalidate on the production mesh with:\n"
          f"  PYTHONPATH=src python -m repro.launch.dryrun --arch {args.arch}"
          f" --shape {args.shape} --mesh both --remat {res.best.remat}"
          f" --microbatches {res.best.microbatches}")


if __name__ == "__main__":
    main()
