"""Beyond-paper example: one fixed search, every machine x cost backend.

The paper's headline table holds the GA fixed and swaps the hardware
(Fig. 10); with `repro.hw` + the `CostModel` protocol that sweep is a
nested loop over registry names — including machines the paper never had
(the dataflow-flexible `flexnn`, the scaled `simba4x4`) and a whole
different cost backend (`tpu`, the roofline retarget).

    pip install -e .   (or: export PYTHONPATH=src)
    python examples/hw_costmodel_sweep.py [--workload mobilenet_v3]
"""
import argparse

from repro.search import ACCELERATORS, COSTMODELS, WORKLOADS, search


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="mobilenet_v3",
                    choices=WORKLOADS.names())
    ap.add_argument("--generations", type=int, default=40)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    print(f"workload: {args.workload}  (GA fast preset, "
          f"{args.generations} generations, seed {args.seed})\n")
    print(f"{'accelerator':<12} {'costmodel':<10} {'edp_x':>6} "
          f"{'energy_x':>8} {'groups':>6} {'best EDP':>12}")
    for accel in ACCELERATORS.names():
        for cm in COSTMODELS.names():
            art = search(args.workload, accel, costmodel=cm,
                         backend="ga", seed=args.seed,
                         backend_config={"preset": "fast",
                                         "generations": args.generations})
            s = art.summary()
            print(f"{accel:<12} {cm:<10} {s['edp_x']:>6.3f} "
                  f"{s['energy_x']:>8.3f} {s['groups']:>6} "
                  f"{art.best.edp:>12.3e}")
    print("\n(per-group breakdowns: save an artifact with `repro search "
          "--out a.json` and run `repro report a.json --breakdown`)")


if __name__ == "__main__":
    main()
