"""Batched serving example: prefill a batch of prompts, greedy-decode
continuations with the KV cache, verify against the full forward pass, and
report throughput.

    pip install -e .   (or: export PYTHONPATH=src)
    python examples/serve_decode.py --arch qwen2-7b --tokens 32
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_reduced
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = dataclasses.replace(get_reduced(args.arch), param_dtype="float32",
                              capacity_factor=16.0)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    B, S, G = args.batch, args.prompt_len, args.tokens
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    batch = {"tokens": prompts}
    if cfg.img_tokens:
        batch["img_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.img_tokens, cfg.d_model))
    if cfg.is_encdec:
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(3), (B, cfg.enc_seq, cfg.d_model))

    step = jax.jit(lambda p, tok, pos, c, e: T.decode_step(
        p, cfg, tok, pos, c, enc_kv=e))

    t0 = time.time()
    logits, caches, enc_kv = T.prefill(params, cfg, batch,
                                       max_len=S + cfg.img_tokens + G,
                                       cache_dtype=jnp.float32)
    prefill_s = time.time() - t0
    cur = jnp.argmax(logits[:, 0], axis=-1)[:, None]
    out = [cur]
    t0 = time.time()
    for i in range(G - 1):
        lg, caches = step(params, cur, jnp.int32(cfg.img_tokens + S + i),
                          caches, enc_kv)
        cur = jnp.argmax(lg[:, 0], axis=-1)[:, None]
        out.append(cur)
    jax.block_until_ready(cur)
    decode_s = time.time() - t0
    gen = jnp.concatenate(out, axis=1)

    # consistency: forward over prompt+generation reproduces the choices
    full = jnp.concatenate([prompts, gen], axis=1)
    fl, _ = T.forward(params, cfg, dict(batch, tokens=full))
    ok = True
    for i in range(G - 1):
        expect = jnp.argmax(fl[:, cfg.img_tokens + S - 1 + i], axis=-1)
        ok &= bool((gen[:, i] == expect).all())

    print(f"arch={args.arch} (reduced config)")
    print(f"prefill: {B} x {S} tokens in {prefill_s * 1e3:.0f} ms")
    print(f"decode : {B} x {G} tokens in {decode_s * 1e3:.0f} ms "
          f"({B * (G - 1) / max(decode_s, 1e-9):.0f} tok/s batched)")
    print(f"consistency vs full forward: {'OK' if ok else 'MISMATCH'}")
    assert ok


if __name__ == "__main__":
    main()
