"""Quickstart: the paper's pipeline in one page, on the `repro.search` facade.

Builds MobileNet-v3, runs the GA interlayer scheduler against the SIMBA-like
accelerator, and prints the energy/EDP improvements over the layerwise
(per-layer Timeloop-style) baseline — the paper's headline experiment.  The
search result is saved as a JSON artifact that `repro report` can summarize
later without re-searching.

    pip install -e .   (or: export PYTHONPATH=src)
    python examples/quickstart.py [--full] [--out artifact.json]

CLI equivalent:

    repro search --workload mobilenet_v3 --accel simba --backend ga \\
        --preset fast --generations 60 --out artifact.json
    repro report artifact.json --schedule
"""
import argparse

from repro.core.report import schedule_report
from repro.search import SearchSession, SearchSpec, build_accelerator


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper GA settings (P=100, G=500)")
    ap.add_argument("--out", default="",
                    help="also save the SIMBA artifact to this path")
    args = ap.parse_args()

    backend_config = {"preset": "paper", "generations": 500} if args.full \
        else {"preset": "fast", "generations": 60}

    for accel in ("simba", "eyeriss"):
        spec = SearchSpec(workload="mobilenet_v3", accelerator=accel,
                          backend="ga", backend_config=backend_config,
                          seed=0)
        session = SearchSession(spec)
        artifact = session.run()
        s = artifact.summary()
        print(f"\n=== {accel} ===")
        print(f"  energy improvement : {s['energy_x']:.2f}x "
              f"(paper: 1.8x on SIMBA for MobileNet-v3)")
        print(f"  EDP improvement    : {s['edp_x']:.2f}x (paper: 1.9x)")
        print(f"  DRAM activation writes: {s['act_dram_writes_base']} -> "
              f"{s['act_dram_writes_best']}")
        print(f"  fused groups       : {s['groups']} "
              f"(from {len(session.graph.names)} layers)")
        print(f"  GA evaluations     : {artifact.evaluations} "
              f"in {artifact.wall_s:.1f}s")
        if accel == "simba":
            print("\n  schedule (paper Fig. 9 analogue, first groups):")
            print("  " + schedule_report(session.schedule_result(),
                                         build_accelerator(accel),
                                         max_rows=10).replace("\n", "\n  "))
            if args.out:
                artifact.save(args.out)
                print(f"\n  artifact saved to {args.out} "
                      f"(summarize with: repro report {args.out})")


if __name__ == "__main__":
    main()
