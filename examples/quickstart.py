"""Quickstart: the paper's pipeline in one page.

Builds MobileNet-v3, runs the GA interlayer scheduler against the SIMBA-like
accelerator, and prints the energy/EDP improvements over the layerwise
(per-layer Timeloop-style) baseline — the paper's headline experiment.

    PYTHONPATH=src python examples/quickstart.py [--full]
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.core import GAConfig, optimize
from repro.core.report import schedule_report
from repro.costmodel import EYERISS, SIMBA
from repro.workloads import mobilenet_v3_large


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper GA settings (P=100, G=500)")
    args = ap.parse_args()

    ga = GAConfig(generations=500, seed=0) if args.full else \
        GAConfig.fast(generations=60, seed=0)

    g = mobilenet_v3_large()
    print(f"workload: {g}")
    for acc in (SIMBA, EYERISS):
        res = optimize(g, acc, ga)
        s = res.summary()
        print(f"\n=== {acc.name} ===")
        print(f"  energy improvement : {s['energy_x']:.2f}x "
              f"(paper: 1.8x on SIMBA for MobileNet-v3)")
        print(f"  EDP improvement    : {s['edp_x']:.2f}x (paper: 1.9x)")
        print(f"  DRAM activation writes: {s['act_dram_writes_base']} -> "
              f"{s['act_dram_writes_best']}")
        print(f"  fused groups       : {s['groups']} "
              f"(from {len(g.names)} layers)")
        print(f"  GA evaluations     : {s['ga_evaluations']}")
        if acc is SIMBA:
            print("\n  schedule (paper Fig. 9 analogue, first groups):")
            print("  " + schedule_report(res, acc, max_rows=10
                                         ).replace("\n", "\n  "))


if __name__ == "__main__":
    main()
