"""Bring your own workload: trace a tiny JAX CNN into GraphIR JSON.

The written file is a first-class workload everywhere a name is accepted:

    python examples/bring_your_own_workload.py --out tiny_cnn.json
    repro search --workload file:tiny_cnn.json --backend ga --preset fast
    repro submit --store schedules/ --workload file:tiny_cnn.json

When jax is unavailable the same network is built directly against the
`repro.ir` schema, so the produced document (and its fingerprint) is
identical either way — which is also what CI asserts.
"""
import argparse

import repro.ir as ir
from repro.core.graph import Layer, LayerGraph


def trace_with_jax() -> "ir.GraphIR":
    import jax.numpy as jnp
    from jax import lax

    def cnn(x, w1, w2, w3):
        y = lax.conv_general_dilated(x, w1, (1, 1), "SAME")
        y = jnp.maximum(y, 0.0)                          # relu: folded
        y = lax.reduce_window(y, -jnp.inf, lax.max,
                              (1, 1, 2, 2), (1, 1, 2, 2), "VALID")
        y = lax.conv_general_dilated(y, w2, (1, 1), "SAME")
        y = jnp.maximum(y, 0.0)
        y = jnp.mean(y, axis=(2, 3))                     # global pool
        return y.reshape(1, -1) @ w3                     # classifier

    example = (jnp.zeros((1, 3, 32, 32)),                # NCHW, batch 1
               jnp.zeros((8, 3, 3, 3)),
               jnp.zeros((16, 8, 3, 3)),
               jnp.zeros((16, 10)))
    return ir.from_jax(cnn, example, name="tiny_cnn")


def build_by_hand() -> "ir.GraphIR":
    """The traced network, authored directly (shapes match the tracer)."""
    g = LayerGraph("tiny_cnn")
    g.add(Layer(name="input_1", kind="input", m=3, p=32, q=32))
    g.add(Layer(name="conv_2", kind="conv", c=3, h=32, w=32, m=8,
                p=32, q=32, r=3, s=3, padding=(1, 1)), ["input_1"])
    g.add(Layer(name="pool_3", kind="pool", c=8, h=32, w=32, m=8,
                p=16, q=16, r=2, s=2, stride=(2, 2)), ["conv_2"])
    g.add(Layer(name="conv_4", kind="conv", c=8, h=16, w=16, m=16,
                p=16, q=16, r=3, s=3, padding=(1, 1)), ["pool_3"])
    g.add(Layer(name="gpool_5", kind="global_pool", c=16, h=16, w=16,
                m=16, p=1, q=1, r=16, s=16), ["conv_4"])
    g.add(Layer(name="fc_6", kind="fc", c=16, h=1, w=1, m=10, p=1, q=1),
          ["gpool_5"])
    return g.to_ir()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="tiny_cnn.json")
    args = ap.parse_args()
    try:
        gir = trace_with_jax()
        how = "traced from JAX"
    except ImportError:
        gir = build_by_hand()
        how = "built by hand (jax unavailable)"
    ir.save(gir, args.out)
    print(f"{how}: wrote {args.out} ({len(gir.nodes)} nodes)")
    print(f"fingerprint: {gir.fingerprint()}")
    print(f"search it:   repro search --workload file:{args.out}")


if __name__ == "__main__":
    main()
