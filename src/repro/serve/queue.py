"""Crash-safe persistent priority queue for the scheduling daemon.

A :class:`JobQueue` is the daemon's durable state: every submission,
start, and resolution is one JSON line appended (and flushed) to
``queue.jsonl`` inside the store directory, and a restart rebuilds the
whole queue by replaying the journal — jobs that were queued *or running*
when the process died come back as queued, terminal jobs keep their
resolution, and job ids keep counting from where they left off.  The
journal is the only file the queue touches; nothing is rewritten in
place, so a crash mid-append at worst loses the final partial line
(tolerated and reported at replay).

Semantics mirror :class:`repro.serve.scheduler.BatchScheduler`:

* **priorities** — higher runs first; ties run in submission order;
* **dedup by normalized store key** — a submission whose
  :func:`~repro.serve.store.artifact_key` matches a queued/running job
  attaches to it and inherits its resolution (one search serves both);
* **terminal states** — ``done`` (outcome ``cache_hit``/``searched``),
  ``failed`` (error string), ``cancelled``.

The queue is thread-safe (one lock, one condition) but persistence-only:
cooperative cancellation of *running* searches (stop flags, observer
ticks) lives in :mod:`repro.serve.daemon`, which journals the final
``cancelled`` event here once the search actually unwinds.
"""
from __future__ import annotations

import heapq
import json
import os
import threading
from dataclasses import dataclass, field
from typing import IO, Any, Dict, List, Optional, Set, Tuple

from repro.obs import clock

#: journal line schema version
QUEUE_VERSION = 1

#: journal file name inside the store directory
QUEUE_FILE = "queue.jsonl"

_TERMINAL = ("done", "failed", "cancelled")


class QueueError(ValueError):
    """The journal is unusable (bad version / schema)."""


@dataclass
class QueuedJob:
    """One submitted job as the journal knows it."""

    id: int
    spec_dict: Dict[str, Any]
    priority: int = 0
    warm_start: bool = False
    key: Optional[str] = None          # normalized store key (dedup identity)
    state: str = "queued"              # queued|running|done|failed|cancelled
    outcome: Optional[str] = None      # cache_hit | searched | None
    error: Optional[str] = None
    attached_to: Optional[int] = None  # deduped onto this primary job id
    submitted_unix: int = 0

    @property
    def terminal(self) -> bool:
        return self.state in _TERMINAL

    def to_dict(self) -> Dict[str, Any]:
        return {
            "id": self.id, "spec": dict(self.spec_dict),
            "priority": self.priority, "warm_start": self.warm_start,
            "key": self.key, "state": self.state, "outcome": self.outcome,
            "error": self.error, "attached_to": self.attached_to,
            "submitted_unix": self.submitted_unix,
        }


@dataclass
class ReplayReport:
    """What a journal replay found (surfaced in daemon startup logs)."""

    jobs: int = 0
    requeued: int = 0            # queued/running at crash -> queued again
    terminal: int = 0
    warnings: List[str] = field(default_factory=list)


class JobQueue:
    """Journal-backed priority queue (see module docstring)."""

    def __init__(self, root: str, *, name: str = QUEUE_FILE):
        self.path = os.path.join(root, name)
        self._lock = threading.RLock()
        self._cond = threading.Condition(self._lock)
        self.jobs: Dict[int, QueuedJob] = {}
        # (-priority, submission seq, id): heapq pops highest priority,
        # oldest first; cancelled/attached entries are skipped lazily
        self._heap: List[Tuple[int, int, int]] = []
        self._seq = 0
        self._next_id = 0
        self._closed = False
        self.replay = self._replay()
        os.makedirs(root, exist_ok=True)
        self._journal: IO[str] = open(self.path, "a", encoding="utf-8")

    # ---- journal ----------------------------------------------------------------
    def _append(self, event: str, **fields: Any) -> None:
        if self._journal.closed:
            return                       # post-close resolution: see close()
        rec = {"v": QUEUE_VERSION, "event": event, **fields,
               "t": clock.unix_time()}
        self._journal.write(json.dumps(rec, sort_keys=True,
                                       separators=(",", ":")) + "\n")
        self._journal.flush()
        os.fsync(self._journal.fileno())

    def _replay(self) -> ReplayReport:
        report = ReplayReport()
        try:
            with open(self.path, encoding="utf-8") as f:
                lines = f.readlines()
        except FileNotFoundError:
            return report
        for n, line in enumerate(lines, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                # a torn trailing line is the expected crash artifact; a
                # torn line mid-journal means later events were lost too —
                # either way replay keeps everything that parsed
                report.warnings.append(f"line {n}: unparsable, skipped")
                continue
            if rec.get("v") != QUEUE_VERSION:
                raise QueueError(
                    f"{self.path} line {n}: journal version "
                    f"{rec.get('v')!r}; this build reads {QUEUE_VERSION}")
            event = rec.get("event")
            if event == "submit":
                jid = int(rec["id"])
                self.jobs[jid] = QueuedJob(
                    id=jid, spec_dict=rec["spec"],
                    priority=int(rec.get("priority", 0)),
                    warm_start=bool(rec.get("warm_start", False)),
                    key=rec.get("key"),
                    attached_to=rec.get("attached_to"),
                    submitted_unix=int(rec.get("t", 0)))
                self._next_id = max(self._next_id, jid + 1)
            else:
                job = self.jobs.get(int(rec.get("id", -1)))
                if job is None:
                    report.warnings.append(
                        f"line {n}: {event} for unknown job, skipped")
                    continue
                if event == "start":
                    job.state = "running"
                elif event == "done":
                    job.state = "done"
                    job.outcome = rec.get("outcome")
                    job.key = rec.get("key", job.key)
                elif event == "failed":
                    job.state = "failed"
                    job.error = rec.get("error")
                elif event == "cancelled":
                    job.state = "cancelled"
                else:
                    report.warnings.append(
                        f"line {n}: unknown event {event!r}, skipped")
        # anything not terminal goes back on the heap: a job that was
        # *running* at the crash re-runs from scratch (searches are pure
        # functions of their spec, so a re-run is safe)
        for job in sorted(self.jobs.values(), key=lambda j: j.id):
            report.jobs += 1
            if job.terminal:
                report.terminal += 1
                continue
            if job.attached_to is not None:
                job.state = "queued"
                continue                 # resolved through its primary
            job.state = "queued"
            report.requeued += 1
            self._push(job)
        return report

    def _push(self, job: QueuedJob) -> None:
        heapq.heappush(self._heap, (-job.priority, self._seq, job.id))
        self._seq += 1

    # ---- intake -----------------------------------------------------------------
    def submit(self, spec_dict: Dict[str, Any], *, priority: int = 0,
               warm_start: bool = False, key: Optional[str] = None,
               resolved: Optional[Tuple[str, str]] = None) -> QueuedJob:
        """Journal and enqueue one job.

        ``key`` is the normalized store key; when a queued/running job
        already carries it, the new job *attaches* to that primary instead
        of entering the heap (dedup — one search resolves both).
        ``resolved=(outcome, key)`` submits an already-resolved job (a
        store hit served at intake with zero evaluations): the submit and
        done events are journaled atomically under the lock, so no worker
        can ever pick it up.
        """
        with self._cond:
            if self._closed:
                raise QueueError("queue is closed")
            job = QueuedJob(id=self._next_id, spec_dict=dict(spec_dict),
                            priority=int(priority),
                            warm_start=bool(warm_start), key=key,
                            submitted_unix=clock.unix_time())
            self._next_id += 1
            primary = None
            if resolved is None and key is not None:
                primary = self._primary_for(key, exclude=job.id)
            if primary is not None:
                job.attached_to = primary.id
            self.jobs[job.id] = job
            self._append("submit", id=job.id, spec=job.spec_dict,
                         priority=job.priority, warm_start=job.warm_start,
                         key=job.key, attached_to=job.attached_to)
            if resolved is not None:
                outcome, rkey = resolved
                job.state, job.outcome, job.key = "done", outcome, rkey
                self._append("done", id=job.id, outcome=outcome, key=rkey)
            elif job.attached_to is None:
                self._push(job)
                self._cond.notify()
            return job

    def _primary_for(self, key: str, exclude: int) -> Optional[QueuedJob]:
        for job in self.jobs.values():
            if (job.id != exclude and job.key == key and not job.terminal
                    and job.attached_to is None):
                return job
        return None

    # ---- worker side ------------------------------------------------------------
    def next_job(self, timeout: Optional[float] = None
                 ) -> Optional[QueuedJob]:
        """Block until a job is runnable (or the queue closes -> None);
        marks it running and journals the start."""
        with self._cond:
            while True:
                while self._heap:
                    _, _, jid = heapq.heappop(self._heap)
                    job = self.jobs[jid]
                    if job.state != "queued" or job.attached_to is not None:
                        continue         # cancelled/attached while queued
                    job.state = "running"
                    self._append("start", id=job.id)
                    return job
                if self._closed:
                    return None
                if not self._cond.wait(timeout=timeout):
                    return None

    # ---- resolution -------------------------------------------------------------
    def resolve_done(self, job_id: int, outcome: str, key: str) -> None:
        """Terminal success; attached jobs resolve as served hits."""
        with self._cond:
            job = self.jobs[job_id]
            job.state, job.outcome, job.key = "done", outcome, key
            self._append("done", id=job_id, outcome=outcome, key=key)
            for dup in self._attached(job_id):
                dup.state, dup.outcome, dup.key = "done", "cache_hit", key
                self._append("done", id=dup.id, outcome="cache_hit", key=key)

    def resolve_failed(self, job_id: int, error: str) -> None:
        """Terminal failure; attached jobs fail with the same error (the
        :class:`~repro.serve.scheduler.BatchScheduler` contract)."""
        with self._cond:
            job = self.jobs[job_id]
            job.state, job.error = "failed", str(error)
            self._append("failed", id=job_id, error=job.error)
            for dup in self._attached(job_id):
                dup.state, dup.error = "failed", job.error
                self._append("failed", id=dup.id, error=job.error)

    def resolve_cancelled(self, job_id: int) -> None:
        """Terminal cancellation of a job the daemon's stop flag unwound;
        attached jobs re-enter the heap (their request still stands)."""
        with self._cond:
            job = self.jobs[job_id]
            job.state = "cancelled"
            self._append("cancelled", id=job_id)
            for dup in self._attached(job_id):
                dup.attached_to = None
                self._push(dup)
            self._cond.notify_all()

    def _attached(self, job_id: int) -> List[QueuedJob]:
        return [j for j in self.jobs.values()
                if j.attached_to == job_id and not j.terminal]

    def cancel(self, job_id: int) -> str:
        """Cancel a job: ``"cancelled"`` if it was still queued/attached
        (journaled immediately), ``"running"`` if the caller must abort the
        in-flight search first, ``"terminal"`` if already resolved."""
        with self._cond:
            job = self.jobs.get(job_id)
            if job is None:
                raise KeyError(job_id)
            if job.terminal:
                return "terminal"
            if job.state == "running":
                return "running"
            # queued or attached: nothing is executing, cancel outright
            # (heap entry is skipped lazily by next_job)
            job.state = "cancelled"
            job.attached_to = None
            self._append("cancelled", id=job_id)
            return "cancelled"

    # ---- views ------------------------------------------------------------------
    def get(self, job_id: int) -> QueuedJob:
        with self._lock:
            return self.jobs[job_id]

    def list_jobs(self) -> List[QueuedJob]:
        with self._lock:
            return [self.jobs[i] for i in sorted(self.jobs)]

    def live_keys(self) -> Set[str]:
        """Store keys referenced by non-terminal jobs — objects GC must
        never evict (:mod:`repro.serve.gc`)."""
        with self._lock:
            return {j.key for j in self.jobs.values()
                    if j.key is not None and not j.terminal}

    def counts(self) -> Dict[str, int]:
        with self._lock:
            out = {s: 0 for s in
                   ("queued", "running", "done", "failed", "cancelled")}
            for j in self.jobs.values():
                out[j.state] = out.get(j.state, 0) + 1
            return out

    # ---- lifecycle --------------------------------------------------------------
    def stop_intake(self) -> None:
        """Refuse new submissions and wake every blocked :meth:`next_job`
        (-> None).  The journal stays open so in-flight resolutions still
        land; call :meth:`close` once the workers have drained."""
        with self._cond:
            if self._closed:
                return
            self._closed = True
            self._cond.notify_all()

    def close(self) -> None:
        """Stop intake (if not already) and close the journal.  Any
        resolution arriving after this is dropped from the journal — the
        job simply re-runs on the next restart, which is the same contract
        a crash gives."""
        self.stop_intake()
        with self._cond:
            if not self._journal.closed:
                self._journal.close()
