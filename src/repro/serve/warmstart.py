"""Warm-starting searches from the store's nearest cached winner.

The daemon's core cost is GA convergence time; most of its traffic is the
same handful of workloads re-searched under slightly different specs (a
new seed, a different backend budget, ``name@k=v`` parameter sweeps).
When a cache miss is *near* a stored artifact, seeding the GA's initial
population with the cached winner's genome gives the search a head start
— the paper's Alg. 1 keeps its canonical layerwise start, the seed just
joins the first generation's pool (``SearchProblem.seed_genomes``).

Donor ranking, most to least compatible:

1. **same graph fingerprint** — the genome re-binds exactly (the spec
   differs in seed/backend/objective only);
2. **same workload family** — the registry base name before ``@`` params
   matches, with the same accelerator + cost model + objective; the donor
   genome is clipped onto the new graph's edge range (a heuristic: bits
   past the new edge count are dropped, an invalid result just scores 0
   and is selected away).

Everything here is *opt-in per job* (``warm_start=True`` on POST /jobs):
the default path never reads this module, so fixed-seed trajectories,
RNG draw sequences, and store keys stay bit-identical.  Warm-starting
also never changes the job's store key — the spec is untouched; only the
initial population differs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

from repro.search.spec import SearchSpec
from repro.serve.store import ArtifactStore, StoreError


@dataclass(frozen=True)
class WarmStartSeed:
    """A donor genome chosen for seeding, with its provenance."""

    donor_key: str          # store key of the donor artifact
    mask: int               # donor winner's genome bitmask
    exact: bool             # same graph fingerprint (mask re-binds exactly)
    best_fitness: float     # donor's recorded fitness (ranking evidence)


def workload_family(workload: str) -> str:
    """The registry base name before inline ``@k=v`` params; ``file:`` /
    ``ir:`` specs have no name family (their fingerprint is the family)."""
    if workload.startswith(("file:", "ir:")):
        return workload
    return workload.split("@", 1)[0]


def adapt_mask(mask: int, n_edges: int) -> int:
    """Clip a donor genome onto a graph with ``n_edges`` fusion edges.
    Bits past the target range are dropped; the result may be invalid on
    the new graph, in which case it scores 0 and is selected away."""
    if n_edges <= 0:
        return 0
    return mask & ((1 << n_edges) - 1)


def find_warm_start(store: ArtifactStore, fingerprint: str,
                    spec: SearchSpec) -> Optional[WarmStartSeed]:
    """Scan the store for the nearest donor artifact (see module
    docstring), or None.  Corrupt objects are skipped, never fatal.  The
    scan is deterministic: candidates are ranked (compatibility, donor
    fitness desc, key asc), so the same store always yields the same
    donor."""
    family = workload_family(spec.workload)
    named = not spec.workload.startswith(("file:", "ir:"))
    ranked: List[Tuple[int, float, str, int]] = []
    for key in store.keys():
        try:
            art = store.load_key(key)
        except StoreError:
            continue                     # GC reports these; seeding skips
        if art is None:
            continue
        if art.graph_fingerprint == fingerprint:
            rank = 0
        elif (named
              and workload_family(art.spec.workload) == family
              and art.spec.accelerator == spec.accelerator
              and art.spec.costmodel == spec.costmodel
              and art.spec.objective == spec.objective):
            rank = 1
        else:
            continue
        ranked.append((rank, -float(art.best_fitness), key,
                       int(art.genome_mask)))
    if not ranked:
        return None
    rank, neg_fit, key, mask = min(ranked)
    return WarmStartSeed(donor_key=key, mask=mask, exact=(rank == 0),
                         best_fitness=-neg_fit)
