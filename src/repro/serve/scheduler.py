"""Batch scheduling over the search facade: dedupe, serve, or search.

A :class:`BatchScheduler` accepts :class:`~repro.search.spec.SearchSpec`
requests and resolves each one the cheapest way available:

1. **in-flight dedup** — identical specs submitted in the same batch
   collapse onto one search (canonical spec hash), the rest are served
   its result;
2. **store hit** — a request whose (graph fingerprint, spec) key is
   already in the :class:`~repro.serve.store.ArtifactStore` is served
   from disk with *zero* new evaluations (no evaluator is even built);
3. **search** — remaining unique misses fan out across a worker pool
   (``multiprocessing`` fork workers; inline when ``workers <= 1``) and
   their artifacts are stored for every later identical request.

The CLI speaks this layer: ``repro serve --requests jobs.json`` drains a
batch, ``repro submit`` is the single-request path.

Job specs name workloads in any ``repro.search.registry`` spec form —
registry names with inline params (``mobilenet_v3@hw=160``) or
``file:model.json`` GraphIR documents — so external models batch-schedule
without registration.  (``ir:<fingerprint>`` specs are artifact-bound and
fail the job with the error explaining where to rebuild from.)
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.obs import clock
from repro.search.artifact import ScheduleArtifact, graph_fingerprint
from repro.search.registry import build_workload
from repro.search.session import SearchSession
from repro.search.spec import SearchSpec

from repro.serve.store import ArtifactStore, artifact_key, spec_hash


@dataclass
class Job:
    """One submitted request and how it was resolved."""

    id: int
    spec: SearchSpec
    status: str = "pending"            # pending | done | failed
    outcome: Optional[str] = None      # cache_hit | searched | None (failed)
    deduped: bool = False              # collapsed onto an identical in-flight job
    key: Optional[str] = None          # store key once resolved
    error: Optional[str] = None
    artifact: Optional[ScheduleArtifact] = None

    def describe(self) -> str:
        what = f"{self.spec.workload}/{self.spec.accelerator} " \
               f"[{self.spec.backend}, seed {self.spec.seed}]"
        if self.status == "failed":
            return f"job {self.id}: {what} -> FAILED: {self.error}"
        how = self.outcome + (" (deduped in-flight)" if self.deduped else "")
        s = self.artifact.summary() if self.artifact is not None else {}
        tail = f"  edp x{s['edp_x']}" if s else ""
        return f"job {self.id}: {what} -> {how}{tail}  key={self.key[:12]}"

    def to_dict(self) -> Dict:
        return {
            "id": self.id,
            "spec": self.spec.to_dict(),
            "status": self.status,
            "outcome": self.outcome,
            "deduped": self.deduped,
            "key": self.key,
            "error": self.error,
            "summary": self.artifact.summary()
            if self.artifact is not None else None,
        }


@dataclass
class ServeOutcome:
    """A drained batch: every job plus the service counters."""

    jobs: List[Job] = field(default_factory=list)
    stats: Dict[str, int] = field(default_factory=dict)

    def to_dict(self) -> Dict:
        return {"jobs": [j.to_dict() for j in self.jobs],
                "stats": self.stats}


def load_requests(path: str) -> List[SearchSpec]:
    """Read a jobs file: a JSON list of SearchSpec dicts, or an object with
    a ``jobs`` list (both shapes round-trip ``SearchSpec.to_dict``)."""
    with open(path) as f:
        payload = json.load(f)
    if isinstance(payload, dict):
        payload = payload.get("jobs")
    if not isinstance(payload, list):
        raise ValueError(
            f"{path}: expected a JSON list of SearchSpec objects "
            f"(or {{\"jobs\": [...]}})")
    return [SearchSpec.from_dict(d) for d in payload]


def _search_worker(spec_dict: Dict) -> tuple:
    """Worker-pool entry: run one search, return the artifact as a plain
    dict (picklable regardless of genome/backends involved)."""
    try:
        spec = SearchSpec.from_dict(spec_dict)
        artifact = SearchSession(spec).run()
        return ("ok", artifact.to_dict())
    except Exception as e:                       # noqa: BLE001 — job isolation
        return ("err", f"{type(e).__name__}: {e}")


class BatchScheduler:
    """Queue identical-spec-deduping scheduler over one
    :class:`ArtifactStore`.

    ``workers``: search processes for cache misses (``<= 1`` = run misses
    inline in submission order — fully deterministic, no subprocesses).
    ``obs``: an optional :class:`repro.obs.TelemetryCollector`; when set,
    every drained job emits a ``serve.job`` event and the batch closes with
    a ``serve.batch`` span plus store hit/miss counters.  Purely
    observational — job resolution is identical with or without it.
    """

    def __init__(self, store: ArtifactStore, *, workers: int = 1, obs=None):
        self.store = store
        self.workers = int(workers)
        self.obs = obs
        self.jobs: List[Job] = []
        self.searches_run = 0
        self._inflight: Dict[str, Job] = {}      # spec hash -> primary job

    # ---- intake -----------------------------------------------------------------
    def submit(self, spec: SearchSpec) -> Job:
        """Enqueue one request; an identical pending spec collapses onto
        the earlier job (served together at :meth:`run`)."""
        job = Job(id=len(self.jobs), spec=spec)
        primary = self._inflight.get(spec_hash(spec))
        if primary is not None:
            job.deduped = True
        else:
            self._inflight[spec_hash(spec)] = job
        self.jobs.append(job)
        return job

    # ---- draining ---------------------------------------------------------------
    def run(self, progress: Optional[Callable[[Job], None]] = None
            ) -> ServeOutcome:
        """Resolve every pending job: store hits served, unique misses
        searched (worker pool), duplicates attached to their primary."""
        col = self.obs
        if col is not None:
            t0w, t0p = clock.now(), clock.perf_counter()
        store_hits = store_misses = 0
        pending = [j for j in self.jobs if j.status == "pending"]
        primaries = [j for j in pending if not j.deduped]
        to_search: List[Job] = []
        fingerprints: Dict[int, str] = {}
        for job in primaries:
            try:
                graph = build_workload(job.spec.workload,
                                       **job.spec.workload_kwargs)
                fingerprints[job.id] = graph_fingerprint(graph)
                # a corrupt store object (StoreError) fails THIS job only:
                # the rest of the batch must still resolve
                hit = self.store.get(fingerprints[job.id], job.spec)
            except Exception as e:               # noqa: BLE001 — job isolation
                self._fail(job, f"{type(e).__name__}: {e}")
                continue
            if hit is not None:
                store_hits += 1
                self._serve(job, hit, "cache_hit")
            else:
                store_misses += 1
                to_search.append(job)
        # second dedup level, by normalized store key: specs whose raw
        # hashes differ but that address the same object (the same IR
        # document under two file: paths) collapse onto one search
        unique: List[Job] = []
        key_primary: Dict[str, Job] = {}
        key_dups: List[tuple] = []
        for job in to_search:
            key = artifact_key(fingerprints[job.id], job.spec)
            if key in key_primary:
                key_dups.append((job, key_primary[key]))
            else:
                key_primary[key] = job
                unique.append(job)
        self._run_searches(unique, fingerprints)
        for job, primary in key_dups:
            if primary.status == "failed":
                self._fail(job, primary.error)
            else:
                self._serve(job, primary.artifact, "cache_hit")
        # duplicates inherit their primary's resolution as a served hit
        for job in pending:
            if not job.deduped:
                continue
            primary = self._inflight[spec_hash(job.spec)]
            if primary.status == "failed":
                self._fail(job, primary.error)
            else:
                self._serve(job, primary.artifact, "cache_hit")
        for job in pending:
            self._inflight.pop(spec_hash(job.spec), None)
            if col is not None:
                col.record_job(job)
            if progress is not None:
                progress(job)
        stats = self.stats()
        if col is not None:
            col.record_serve_batch(stats, store_hits, store_misses, t0w,
                                   clock.perf_counter() - t0p)
        return ServeOutcome(jobs=list(self.jobs), stats=stats)

    def _run_searches(self, jobs: List[Job],
                      fingerprints: Dict[int, str]) -> None:
        if not jobs:
            return
        results = self._map_searches([j.spec.to_dict() for j in jobs])
        for job, (status, payload) in zip(jobs, results):
            self.searches_run += 1
            if status != "ok":
                self._fail(job, payload)
                continue
            artifact = ScheduleArtifact.from_dict(payload)
            if artifact.graph_fingerprint != fingerprints[job.id]:
                # registry mutated between fingerprinting and searching;
                # storing under the stale key would serve wrong schedules
                self._fail(job, "graph fingerprint changed during search")
                continue
            self._serve(job, artifact, "searched", put=True)

    def _map_searches(self, spec_dicts: List[Dict]) -> List[tuple]:
        if self.workers <= 1 or len(spec_dicts) == 1:
            return [_search_worker(d) for d in spec_dicts]
        import multiprocessing
        try:
            ctx = multiprocessing.get_context("fork")
        except ValueError:                       # no fork (not this platform)
            return [_search_worker(d) for d in spec_dicts]
        n = min(self.workers, len(spec_dicts))
        with ctx.Pool(processes=n) as pool:
            return pool.map(_search_worker, spec_dicts)

    def _serve(self, job: Job, artifact: ScheduleArtifact, outcome: str,
               put: bool = False) -> None:
        job.artifact = artifact
        job.key = self.store.put(artifact) if put else \
            artifact_key(artifact.graph_fingerprint, artifact.spec)
        job.outcome = outcome
        job.status = "done"

    def _fail(self, job: Job, error: str) -> None:
        job.status = "failed"
        job.error = error

    # ---- stats ------------------------------------------------------------------
    def stats(self) -> Dict[str, int]:
        # session counters only: enumerating the store (len(self.store))
        # is O(objects) on disk — callers that want it can pay for it once
        done = [j for j in self.jobs if j.status != "pending"]
        return {
            "jobs": len(done),
            "searched": sum(j.outcome == "searched" for j in done),
            "cache_hits": sum(j.outcome == "cache_hit" for j in done),
            "deduped_in_flight": sum(j.deduped for j in done),
            "failed": sum(j.status == "failed" for j in done),
        }
