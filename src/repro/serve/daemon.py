"""`repro daemon` — the always-on scheduling service.

A :class:`ScheduleDaemon` turns the batch serve layer into a long-running
service: an HTTP/JSON API (stdlib :class:`ThreadingHTTPServer`, no new
dependencies) in front of the content-addressed
:class:`~repro.serve.store.ArtifactStore`, a crash-safe persistent
:class:`~repro.serve.queue.JobQueue` (JSONL journal in the store dir,
replayed on restart), and a pool of worker threads draining the queue.

API (all JSON)::

    POST   /jobs             {"spec": {...SearchSpec...},
                              "priority": 0, "warm_start": false}
                             -> {"id": N, "state": ..., ...}
    GET    /jobs             -> {"jobs": [...]}
    GET    /jobs/<id>        -> job state + live per-generation convergence
    DELETE /jobs/<id>        -> cancel (cooperative abort when running)
    GET    /metrics          -> MetricRegistry snapshot + queue/store stats
    GET    /artifacts/<key>  -> raw stored ScheduleArtifact JSON
    GET    /healthz          -> {"ok": true}

Resolution per job mirrors :class:`~repro.serve.scheduler.BatchScheduler`:
a store hit is served at submission with **zero** new evaluations; an
identical in-flight request (same normalized store key) attaches to the
running search; only genuine misses search.  ``warm_start=True`` (opt-in,
per job) additionally seeds the GA population from the store's nearest
cached winner (:mod:`repro.serve.warmstart`) — the default path is
untouched, so all fixed-seed pins and store keys stay bit-identical.

Cancellation of a *running* job is cooperative: the daemon sets the job's
stop flag, and the search's observer tick raises :class:`JobCancelled`
at the next generation boundary.  A daemon shutdown mid-search leaves the
job non-terminal in the journal, so the restart re-runs it — the same
contract a crash gives.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Dict, Optional, Tuple

from repro.obs import MetricRegistry, TelemetryCollector, clock
from repro.search.artifact import graph_fingerprint
from repro.search.registry import build_workload
from repro.search.session import Progress, SearchSession
from repro.search.spec import SearchSpec

from repro.serve.queue import JobQueue, QueuedJob
from repro.serve.store import ArtifactStore, StoreError, artifact_key
from repro.serve.warmstart import adapt_mask, find_warm_start


class JobCancelled(Exception):
    """Raised inside a search's observer tick to unwind a cancelled job."""


class DaemonError(ValueError):
    """A request the daemon must refuse (bad spec, unknown workload)."""


def _hex_key(s: str) -> bool:
    return bool(s) and all(c in "0123456789abcdef" for c in s)


class ScheduleDaemon:
    """The service: queue + store + worker pool + HTTP front end."""

    def __init__(self, store_dir: str, *, host: str = "127.0.0.1",
                 port: int = 0, workers: int = 1):
        self.store = ArtifactStore(store_dir)
        self.queue = JobQueue(store_dir)
        self.registry = MetricRegistry()
        self.workers = int(workers)
        self.searches_run = 0
        self.store_hits = 0
        self._fp_cache: Dict[Tuple[str, str], str] = {}
        self._stops: Dict[int, threading.Event] = {}
        self._collectors: Dict[int, TelemetryCollector] = {}
        self._lock = threading.Lock()
        self._shutdown = threading.Event()
        self._threads: list = []
        handler = _make_handler(self)
        self.httpd = ThreadingHTTPServer((host, port), handler)
        self.httpd.daemon_threads = True
        self.host, self.port = self.httpd.server_address[:2]

    # ---- lifecycle --------------------------------------------------------------
    def start(self) -> None:
        """Spawn the HTTP listener and the worker pool (non-blocking)."""
        t = threading.Thread(target=self.httpd.serve_forever,
                             name="repro-daemon-http", daemon=True)
        t.start()
        self._threads.append(t)
        for i in range(self.workers):
            w = threading.Thread(target=self._worker_loop,
                                 name=f"repro-daemon-worker-{i}", daemon=True)
            w.start()
            self._threads.append(w)

    def request_shutdown(self) -> None:
        """Signal-handler-safe shutdown trigger (SIGTERM/SIGINT)."""
        self._shutdown.set()

    def wait(self) -> None:
        """Block until shutdown is requested, then stop cleanly: refuse
        new work, abort in-flight searches (left non-terminal in the
        journal -> re-run on restart), stop HTTP, close the journal."""
        self._shutdown.wait()
        self.stop()

    def stop(self) -> None:
        self._shutdown.set()
        self.queue.stop_intake()
        with self._lock:
            for ev in self._stops.values():
                ev.set()
        self.httpd.shutdown()
        self.httpd.server_close()
        for t in self._threads:
            if t is not threading.current_thread():
                t.join(timeout=10.0)
        self.queue.close()

    # ---- submission -------------------------------------------------------------
    def _fingerprint(self, spec: SearchSpec) -> str:
        """Graph fingerprint for the spec's workload, memoized per
        (workload, kwargs) so a flood of same-workload jobs builds the
        graph once."""
        ck = (spec.workload, json.dumps(spec.workload_kwargs,
                                        sort_keys=True, default=str))
        fp = self._fp_cache.get(ck)
        if fp is None:
            graph = build_workload(spec.workload, **spec.workload_kwargs)
            fp = graph_fingerprint(graph)
            self._fp_cache[ck] = fp
        return fp

    def submit(self, payload: Dict[str, Any]) -> Dict[str, Any]:
        """Resolve one POST /jobs: store hit served instantly (zero new
        evaluations), in-flight duplicate attached, miss enqueued."""
        if not isinstance(payload, dict) or "spec" not in payload:
            raise DaemonError('body must be {"spec": {...}, ...}')
        try:
            spec = SearchSpec.from_dict(payload["spec"])
        except Exception as e:           # noqa: BLE001 — surface as 400
            raise DaemonError(f"bad spec: {type(e).__name__}: {e}") from None
        priority = int(payload.get("priority", 0))
        warm = bool(payload.get("warm_start", False))
        try:
            fp = self._fingerprint(spec)
        except Exception as e:           # noqa: BLE001 — surface as 400
            raise DaemonError(
                f"cannot build workload {spec.workload!r}: "
                f"{type(e).__name__}: {e}") from None
        key = artifact_key(fp, spec)
        try:
            hit = self.store.get(fp, spec)
        except StoreError:
            # corrupt stored object: treat as a miss; the re-search puts a
            # fresh object under the same key, healing the store
            hit = None
        if hit is not None:
            self.store_hits += 1
            self.registry.counter("daemon.jobs", outcome="cache_hit").inc()
            job = self.queue.submit(spec.to_dict(), priority=priority,
                                    warm_start=warm, key=key,
                                    resolved=("cache_hit", key))
            return self.job_view(job)
        job = self.queue.submit(spec.to_dict(), priority=priority,
                                warm_start=warm, key=key)
        if job.attached_to is not None:
            self.registry.counter("daemon.jobs", outcome="deduped").inc()
        else:
            with self._lock:
                self._stops[job.id] = threading.Event()
        return self.job_view(job)

    # ---- worker -----------------------------------------------------------------
    def _worker_loop(self) -> None:
        while True:
            job = self.queue.next_job()
            if job is None:
                return                   # queue closed: daemon stopping
            self._run_job(job)

    def _run_job(self, job: QueuedJob) -> None:
        with self._lock:
            stop = self._stops.setdefault(job.id, threading.Event())
        if stop.is_set() and not self._shutdown.is_set():
            self.queue.resolve_cancelled(job.id)
            return
        t0 = clock.perf_counter()
        try:
            spec = SearchSpec.from_dict(job.spec_dict)
            fp = self._fingerprint(spec)
            # a twin job (or an earlier daemon run) may have stored this
            # key while we sat queued: re-check before paying a search
            try:
                hit = self.store.get(fp, spec)
            except StoreError:
                hit = None
            if hit is not None:
                self.store_hits += 1
                self.registry.counter("daemon.jobs",
                                      outcome="cache_hit").inc()
                self.queue.resolve_done(job.id, "cache_hit",
                                        artifact_key(fp, spec))
                return
            collector = TelemetryCollector(registry=self.registry)
            session = SearchSession(spec, obs=collector)
            if job.warm_start:
                seed = find_warm_start(self.store, fp, spec)
                if seed is not None:
                    mask = adapt_mask(seed.mask, session.problem.cg.m)
                    session.problem.seed_genomes = (
                        session.problem.decode_genome(mask),)
            with self._lock:
                self._collectors[job.id] = collector

            def tick(p: Progress) -> None:
                if stop.is_set():
                    raise JobCancelled()

            artifact = session.run(progress=tick)
            key = self.store.put(artifact)
            self.searches_run += 1
            self.registry.counter("daemon.jobs", outcome="searched").inc()
            self.registry.histogram("daemon.job_wall_s").observe(
                clock.perf_counter() - t0)
            self.queue.resolve_done(job.id, "searched", key)
        except JobCancelled:
            if self._shutdown.is_set():
                # shutdown abort: leave the job non-terminal so the journal
                # replay re-queues it — identical to the crash contract
                return
            self.registry.counter("daemon.jobs", outcome="cancelled").inc()
            self.queue.resolve_cancelled(job.id)
        except Exception as e:           # noqa: BLE001 — job isolation
            self.registry.counter("daemon.jobs", outcome="failed").inc()
            self.queue.resolve_failed(job.id, f"{type(e).__name__}: {e}")

    # ---- cancellation -----------------------------------------------------------
    def cancel(self, job_id: int) -> Dict[str, Any]:
        status = self.queue.cancel(job_id)   # KeyError -> 404 upstream
        if status == "running":
            with self._lock:
                ev = self._stops.setdefault(job_id, threading.Event())
            ev.set()
            return {"id": job_id, "state": "cancelling"}
        if status == "terminal":
            job = self.queue.get(job_id)
            return {"id": job_id, "state": job.state,
                    "error": "job already resolved"}
        self.registry.counter("daemon.jobs", outcome="cancelled").inc()
        return {"id": job_id, "state": "cancelled"}

    # ---- views ------------------------------------------------------------------
    def job_view(self, job: QueuedJob, *, progress: bool = False
                 ) -> Dict[str, Any]:
        d = job.to_dict()
        d["deduped"] = job.attached_to is not None
        if progress:
            with self._lock:
                col = self._collectors.get(job.id)
            if col is None and job.attached_to is not None:
                with self._lock:
                    col = self._collectors.get(job.attached_to)
            d["progress"] = col.progress_records() if col is not None else []
            if job.state == "done" and job.key is not None:
                try:
                    art = self.store.load_key(job.key)
                except StoreError:
                    art = None
                if art is not None:
                    d["summary"] = art.summary()
        return d

    def metrics_view(self) -> Dict[str, Any]:
        return {
            "metrics": self.registry.snapshot(),
            "jobs": self.queue.counts(),
            "store": self.store.stats(),
            "daemon": {"searches_run": self.searches_run,
                       "store_hits": self.store_hits,
                       "workers": self.workers},
        }


def _make_handler(svc: ScheduleDaemon) -> type:
    """Bind the request handler class to one daemon instance."""

    class Handler(BaseHTTPRequestHandler):
        server_version = "repro-daemon/1"
        protocol_version = "HTTP/1.1"

        def log_message(self, format: str, *args: Any) -> None:
            pass                         # the journal is the record

        # ---- plumbing ----------------------------------------------------
        def _send(self, code: int, obj: Dict[str, Any]) -> None:
            body = json.dumps(obj, sort_keys=True).encode()
            self.send_response(code)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def _error(self, code: int, msg: str) -> None:
            self._send(code, {"error": msg})

        def _body(self) -> Dict[str, Any]:
            n = int(self.headers.get("Content-Length") or 0)
            raw = self.rfile.read(n) if n else b""
            if not raw:
                return {}
            obj = json.loads(raw)
            if not isinstance(obj, dict):
                raise ValueError("body must be a JSON object")
            return obj

        def _job_id(self, path: str) -> Optional[int]:
            tail = path[len("/jobs/"):]
            return int(tail) if tail.isdigit() else None

        # ---- methods -----------------------------------------------------
        def do_GET(self) -> None:        # noqa: N802 — http.server contract
            path = self.path.split("?", 1)[0].rstrip("/") or "/"
            try:
                if path == "/healthz":
                    self._send(200, {"ok": True})
                elif path == "/metrics":
                    self._send(200, svc.metrics_view())
                elif path == "/jobs":
                    self._send(200, {"jobs": [svc.job_view(j) for j in
                                              svc.queue.list_jobs()]})
                elif path.startswith("/jobs/"):
                    jid = self._job_id(path)
                    if jid is None or jid not in svc.queue.jobs:
                        self._error(404, "no such job")
                        return
                    self._send(200, svc.job_view(svc.queue.get(jid),
                                                 progress=True))
                elif path.startswith("/artifacts/"):
                    key = path[len("/artifacts/"):]
                    if not _hex_key(key):
                        self._error(404, "bad artifact key")
                        return
                    try:
                        art = svc.store.load_key(key)
                    except StoreError as e:
                        self._error(500, str(e))
                        return
                    if art is None:
                        self._error(404, "no such artifact")
                        return
                    self._send(200, art.to_dict())
                else:
                    self._error(404, "unknown path")
            except Exception as e:       # noqa: BLE001 — request isolation
                self._error(500, f"{type(e).__name__}: {e}")

        def do_POST(self) -> None:       # noqa: N802 — http.server contract
            path = self.path.split("?", 1)[0].rstrip("/")
            if path != "/jobs":
                self._error(404, "unknown path")
                return
            try:
                payload = self._body()
            except ValueError as e:
                self._error(400, f"bad JSON body: {e}")
                return
            try:
                self._send(201, svc.submit(payload))
            except DaemonError as e:
                self._error(400, str(e))
            except Exception as e:       # noqa: BLE001 — request isolation
                self._error(500, f"{type(e).__name__}: {e}")

        def do_DELETE(self) -> None:     # noqa: N802 — http.server contract
            path = self.path.split("?", 1)[0].rstrip("/")
            if not path.startswith("/jobs/"):
                self._error(404, "unknown path")
                return
            jid = self._job_id(path)
            if jid is None:
                self._error(404, "no such job")
                return
            try:
                out = self.cancel_view(jid)
            except KeyError:
                self._error(404, "no such job")
                return
            code = 409 if out.get("error") else 200
            self._send(code, out)

        def cancel_view(self, jid: int) -> Dict[str, Any]:
            return svc.cancel(jid)

    return Handler
