"""Content-addressed, on-disk schedule store.

An :class:`ArtifactStore` holds finished
:class:`~repro.search.artifact.ScheduleArtifact`s keyed by *what was
searched*: the sha256 of the canonical :class:`~repro.search.spec.
SearchSpec` JSON combined with the structural fingerprint of the graph it
ran on.  Identical requests therefore address the same object — a repeat
search is a read, not a re-search — while any change to the spec (seed,
backend config, cost model, ...) or to the workload's structure addresses
a different one.

Layout (``root/``)::

    store.json                  # {"store_version": 1}
    objects/<kk>/<key>.json     # one ScheduleArtifact JSON per object,
                                # sharded by the key's first two hex chars

Durability rules:

* **atomic writes** — objects are written to a temp file in the target
  directory and ``os.replace``d into place, so readers (and concurrent
  writers of the same key) never observe a torn object;
* **versioned schema** — ``store.json`` pins the layout version; objects
  are plain ``ScheduleArtifact`` JSON (self-versioned via their
  ``version`` field), so ``repro report`` can read them directly and
  artifacts written by older builds (pre cost-breakdown schema) load
  leniently with warnings instead of failing the store.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
from typing import Dict, Iterator, Optional

from repro.search.artifact import ScheduleArtifact
from repro.search.spec import SearchSpec

STORE_VERSION = 1


class StoreError(ValueError):
    """The store layout/object is unusable (wrong version, corrupt object,
    or an object whose content does not match its key)."""


def spec_hash(spec: SearchSpec) -> str:
    """sha256 of the spec's canonical JSON (sorted keys, compact
    separators) — the request half of the store key."""
    blob = json.dumps(spec.to_dict(), sort_keys=True,
                      separators=(",", ":"), default=list)
    return hashlib.sha256(blob.encode()).hexdigest()


def artifact_key(graph_fingerprint: str, spec: SearchSpec) -> str:
    """The store key: sha256 over (graph fingerprint, canonical spec
    hash).  Content-addressed — no counters, no registration order.

    ``file:``/``ir:`` workload specs are normalized to
    ``ir:<fingerprint>`` before hashing: the graph fingerprint already
    pins the content, so the same model submitted under two filenames
    (or re-exported elsewhere) addresses one object instead of paying a
    second search."""
    if spec.workload.startswith(("file:", "ir:")):
        spec = spec.replace(workload=f"ir:{graph_fingerprint}",
                            workload_kwargs={})
    blob = f"{graph_fingerprint}\n{spec_hash(spec)}"
    return hashlib.sha256(blob.encode()).hexdigest()


def _atomic_write(path: str, text: str) -> None:
    """Write-then-rename in ``path``'s directory: concurrent writers of the
    same path race benignly (last replace wins, both contents are whole)."""
    d = os.path.dirname(path) or "."
    fd, tmp = tempfile.mkstemp(dir=d, prefix=".tmp-", suffix=".json")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(text)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


class ArtifactStore:
    """On-disk map ``(graph fingerprint, spec) -> ScheduleArtifact``.

    Safe for concurrent writers (atomic object writes; the layout needs no
    central index).  Hit/miss/put counters accumulate on the live instance
    for service stats.
    """

    def __init__(self, root: str, *, create: bool = True):
        self.root = root
        self.objects_dir = os.path.join(root, "objects")
        self.hits = 0
        self.misses = 0
        self.puts = 0
        meta_path = os.path.join(root, "store.json")
        if os.path.isfile(meta_path):
            with open(meta_path) as f:
                try:
                    meta = json.load(f)
                except json.JSONDecodeError as e:
                    raise StoreError(f"corrupt store meta {meta_path}: {e}") \
                        from None
            v = meta.get("store_version")
            if v != STORE_VERSION:
                raise StoreError(
                    f"store {root} has layout version {v!r}; this build "
                    f"reads version {STORE_VERSION}")
        elif create:
            os.makedirs(self.objects_dir, exist_ok=True)
            _atomic_write(meta_path,
                          json.dumps({"store_version": STORE_VERSION},
                                     sort_keys=True) + "\n")
        else:
            raise StoreError(f"no store at {root} (pass create=True)")

    # ---- addressing -------------------------------------------------------------
    def path_for(self, key: str) -> str:
        return os.path.join(self.objects_dir, key[:2], f"{key}.json")

    # ---- reads ------------------------------------------------------------------
    def get(self, graph_fingerprint: str, spec: SearchSpec
            ) -> Optional[ScheduleArtifact]:
        """The stored artifact for this exact request, or None (a miss).
        Corrupt objects and key/content mismatches raise :class:`StoreError`
        — a store that silently serves the wrong schedule is worse than one
        that fails loudly."""
        key = artifact_key(graph_fingerprint, spec)
        art = self.load_key(key)
        if art is None:
            self.misses += 1
            return None
        # recompute the stored spec's canonical key rather than comparing
        # raw spec hashes: file: specs under different paths are the same
        # request when their graphs fingerprint identically
        if art.graph_fingerprint != graph_fingerprint or \
                artifact_key(art.graph_fingerprint, art.spec) != key:
            raise StoreError(
                f"store object {key} does not match its key (expected "
                f"fingerprint {graph_fingerprint}, spec {spec.to_dict()}); "
                f"the object was corrupted or hand-edited")
        self.hits += 1
        # LRU access clock for ``repro store gc``: a served hit refreshes
        # the object's mtime so eviction age means "time since last use",
        # not "time since creation"; best-effort (read-only stores still
        # serve)
        try:
            os.utime(self.path_for(key))
        except OSError:
            pass
        return art

    def load_key(self, key: str) -> Optional[ScheduleArtifact]:
        """Load one object by key (no hit/miss accounting, no content
        check); None when absent."""
        path = self.path_for(key)
        try:
            with open(path) as f:
                text = f.read()
        except FileNotFoundError:
            return None
        try:
            return ScheduleArtifact.from_json(text)
        except (ValueError, KeyError, TypeError) as e:
            raise StoreError(f"corrupt store object {path}: {e}") from None

    def contains(self, graph_fingerprint: str, spec: SearchSpec) -> bool:
        return os.path.isfile(
            self.path_for(artifact_key(graph_fingerprint, spec)))

    # ---- writes -----------------------------------------------------------------
    def put(self, artifact: ScheduleArtifact) -> str:
        """Store an artifact under its content key (atomic; idempotent —
        re-putting the same request overwrites with equivalent content).
        Returns the key."""
        key = artifact_key(artifact.graph_fingerprint, artifact.spec)
        path = self.path_for(key)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        _atomic_write(path, artifact.to_json())
        self.puts += 1
        return key

    # ---- enumeration / stats ----------------------------------------------------
    def keys(self) -> Iterator[str]:
        if not os.path.isdir(self.objects_dir):
            return
        for shard in sorted(os.listdir(self.objects_dir)):
            d = os.path.join(self.objects_dir, shard)
            if not os.path.isdir(d):
                continue
            for name in sorted(os.listdir(d)):
                if name.endswith(".json") and not name.startswith(".tmp-"):
                    yield name[:-len(".json")]

    def __len__(self) -> int:
        return sum(1 for _ in self.keys())

    def stats(self) -> Dict[str, int]:
        return {"objects": len(self), "hits": self.hits,
                "misses": self.misses, "puts": self.puts}
