"""``repro.serve`` — the batch scheduling service over the search facade.

Three pieces turn one-shot searches into a service that amortizes work
across requests:

* :mod:`repro.serve.store` — an on-disk, content-addressed
  :class:`ArtifactStore`: finished :class:`~repro.search.ScheduleArtifact`s
  keyed by (graph fingerprint, canonical :class:`~repro.search.SearchSpec`
  hash), written atomically, readable across schema revisions;
* :mod:`repro.serve.scheduler` — a :class:`BatchScheduler` that dedups
  in-flight identical specs, serves store hits without searching, and fans
  misses out across a worker pool;
* :mod:`repro.serve.daemon` — the always-on service: HTTP/JSON API over a
  crash-safe persistent priority queue (:mod:`repro.serve.queue`) with
  opt-in warm-started searches (:mod:`repro.serve.warmstart`);
* :mod:`repro.serve.gc` — LRU-by-access store eviction that never touches
  objects pinned by queued/running jobs;
* the CLI verbs ``repro serve --requests jobs.json``, ``repro submit``,
  ``repro daemon``, ``repro jobs``, and ``repro store gc``
  (see ``repro.__main__``).

    from repro.serve import ArtifactStore, BatchScheduler
    store = ArtifactStore("schedules/")
    sched = BatchScheduler(store, workers=4)
    for spec in specs:
        sched.submit(spec)
    outcome = sched.run()       # outcome.stats: searched / cache_hits / ...
"""
from repro.serve.daemon import DaemonError, JobCancelled, ScheduleDaemon
from repro.serve.gc import GCResult, collect_garbage, live_keys_for_store
from repro.serve.queue import JobQueue, QueuedJob, QueueError
from repro.serve.scheduler import BatchScheduler, Job, ServeOutcome
from repro.serve.store import (ArtifactStore, StoreError, artifact_key,
                               spec_hash)
from repro.serve.warmstart import WarmStartSeed, find_warm_start

__all__ = [
    "ArtifactStore", "BatchScheduler", "Job", "ServeOutcome", "StoreError",
    "artifact_key", "spec_hash",
    "ScheduleDaemon", "DaemonError", "JobCancelled",
    "JobQueue", "QueuedJob", "QueueError",
    "GCResult", "collect_garbage", "live_keys_for_store",
    "WarmStartSeed", "find_warm_start",
]
