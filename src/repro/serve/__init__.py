"""``repro.serve`` — the batch scheduling service over the search facade.

Three pieces turn one-shot searches into a service that amortizes work
across requests:

* :mod:`repro.serve.store` — an on-disk, content-addressed
  :class:`ArtifactStore`: finished :class:`~repro.search.ScheduleArtifact`s
  keyed by (graph fingerprint, canonical :class:`~repro.search.SearchSpec`
  hash), written atomically, readable across schema revisions;
* :mod:`repro.serve.scheduler` — a :class:`BatchScheduler` that dedups
  in-flight identical specs, serves store hits without searching, and fans
  misses out across a worker pool;
* the CLI verbs ``repro serve --requests jobs.json`` and ``repro submit``
  (see ``repro.__main__``).

    from repro.serve import ArtifactStore, BatchScheduler
    store = ArtifactStore("schedules/")
    sched = BatchScheduler(store, workers=4)
    for spec in specs:
        sched.submit(spec)
    outcome = sched.run()       # outcome.stats: searched / cache_hits / ...
"""
from repro.serve.scheduler import BatchScheduler, Job, ServeOutcome
from repro.serve.store import (ArtifactStore, StoreError, artifact_key,
                               spec_hash)

__all__ = [
    "ArtifactStore", "BatchScheduler", "Job", "ServeOutcome", "StoreError",
    "artifact_key", "spec_hash",
]
