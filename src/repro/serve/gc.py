"""Store garbage collection: LRU-by-access eviction with live-job pins.

``repro store gc --store DIR [--max-objects N] [--max-bytes B]`` trims an
:class:`~repro.serve.store.ArtifactStore` down to the given limits by
deleting the least-recently-*used* objects first (the store refreshes an
object's mtime on every served hit, so mtime order is access order).

Safety rules:

* objects referenced by queued/running daemon jobs (the queue journal's
  :meth:`~repro.serve.queue.JobQueue.live_keys`) are **never** evicted,
  even when that leaves the store over its limits;
* unreadable or corrupt objects are *reported*, never silently deleted
  and never a crash — a GC run must not destroy evidence of corruption;
* deletion is per-object file removal (the layout has no central index
  to rewrite), so an interrupted GC leaves a smaller, still-valid store.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import AbstractSet, Dict, List, Optional, Tuple

from repro.serve.queue import QUEUE_FILE, JobQueue
from repro.serve.store import ArtifactStore, StoreError


@dataclass
class GCResult:
    """What one GC pass examined and removed."""

    examined: int = 0
    bytes_total: int = 0               # store size before eviction
    evicted: List[str] = field(default_factory=list)
    evicted_bytes: int = 0
    kept_live: List[str] = field(default_factory=list)   # pinned by jobs
    corrupt: List[str] = field(default_factory=list)     # reported only
    dry_run: bool = False

    def to_dict(self) -> Dict[str, object]:
        return {
            "examined": self.examined,
            "bytes_total": self.bytes_total,
            "evicted": list(self.evicted),
            "evicted_bytes": self.evicted_bytes,
            "kept_live": list(self.kept_live),
            "corrupt": list(self.corrupt),
            "bytes_after": self.bytes_total - self.evicted_bytes,
            "objects_after": self.examined - len(self.evicted),
            "dry_run": self.dry_run,
        }


def live_keys_for_store(root: str) -> AbstractSet[str]:
    """Keys pinned by the store's queue journal (queued/running jobs);
    empty when no daemon has ever journaled there."""
    if not os.path.isfile(os.path.join(root, QUEUE_FILE)):
        return frozenset()
    queue = JobQueue(root)
    try:
        return queue.live_keys()
    finally:
        queue.close()


def collect_garbage(store: ArtifactStore, *,
                    max_objects: Optional[int] = None,
                    max_bytes: Optional[int] = None,
                    live: Optional[AbstractSet[str]] = None,
                    dry_run: bool = False) -> GCResult:
    """Evict least-recently-used objects until the store fits
    ``max_objects`` / ``max_bytes`` (whichever are given).  ``live`` keys
    are never evicted; corrupt objects are reported and left in place
    (they still count toward the totals, so a store can legitimately end
    over-limit — the report says why)."""
    live = live if live is not None else live_keys_for_store(store.root)
    result = GCResult(dry_run=dry_run)
    entries: List[Tuple[float, int, str]] = []      # (mtime, size, key)
    for key in store.keys():
        path = store.path_for(key)
        try:
            st = os.stat(path)
        except OSError:
            result.corrupt.append(key)
            continue
        result.examined += 1
        result.bytes_total += st.st_size
        try:
            store.load_key(key)
        except StoreError:
            result.corrupt.append(key)
            continue                     # reported, never auto-deleted
        if key in live:
            result.kept_live.append(key)
            continue
        entries.append((st.st_mtime, st.st_size, key))
    entries.sort()                       # oldest access first
    objects_now = result.examined
    bytes_now = result.bytes_total
    for mtime, size, key in entries:
        over_objects = max_objects is not None and objects_now > max_objects
        over_bytes = max_bytes is not None and bytes_now > max_bytes
        if not (over_objects or over_bytes):
            break
        if not dry_run:
            try:
                os.unlink(store.path_for(key))
            except OSError:
                result.corrupt.append(key)
                continue
        result.evicted.append(key)
        result.evicted_bytes += size
        objects_now -= 1
        bytes_now -= size
    return result
