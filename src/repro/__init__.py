"""Reproduction of "Improvements in Interlayer Pipelining of CNN
Accelerators Using Genetic Algorithms", grown toward a production-scale
scheduling system.

Start at ``repro.search`` (the pluggable search facade) or the CLI:

    repro search --workload mobilenet_v3 --accel simba --backend ga \\
        --out artifact.json
    repro search --workload file:model.json   # any repro.ir GraphIR doc
    repro report artifact.json

Workloads are open via ``repro.ir``: JSON graph documents and JAX-traced
functions search exactly like zoo entries.
"""
__version__ = "0.3.0"
