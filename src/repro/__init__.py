"""Reproduction of "Improvements in Interlayer Pipelining of CNN
Accelerators Using Genetic Algorithms", grown toward a production-scale
scheduling system.

Start at ``repro.search`` (the pluggable search facade) or the CLI:

    repro search --workload mobilenet_v3 --accel simba --backend ga \\
        --out artifact.json
    repro report artifact.json
"""
__version__ = "0.2.0"
