"""`GraphIR` — the serializable, versioned graph interchange format.

A :class:`GraphIR` is the JSON-stable twin of
:class:`repro.core.graph.LayerGraph`: a list of node records (one per
:class:`~repro.core.graph.Layer`, each naming its input nodes in order)
plus the graph's declared outputs.  It is the canonical format everything
speaks at the boundary:

* zoo builders export it (``LayerGraph.to_ir()``), files and tracers
  import it (:func:`repro.ir.load`, :func:`repro.ir.trace.from_jax`);
* the search facade fingerprints it — the graph fingerprint embedded in
  every :class:`~repro.search.artifact.ScheduleArtifact` is the sha256 of
  :meth:`GraphIR.canonical_json`;
* artifacts may embed it, making them reproducible without the
  originating registry (``workload: "file:model.json"`` / ``"ir:..."``).

Two serializations, one schema:

* :meth:`to_json` — human-facing file form (indented; every field
  explicit so files diff cleanly);
* :meth:`canonical_json` — compact, sorted-keys, fully-explicit byte
  form.  **This is the fingerprint domain**: it serializes the graph's
  exact structure (node order, input order, geometry), so two graphs
  share a fingerprint iff their compiled edge spaces are identical and a
  genome bitmask can be safely re-bound between them.  The
  *transforming* canonicalization passes (no-op folding, dead-node
  elimination — ``repro.ir.passes``) run at import time, before a graph
  ever reaches a search, never inside the fingerprint.

Hand-written files may omit node fields (defaults apply) and list nodes
in any producer-before-consumer-violating order; :func:`repro.ir.load`
runs the import pipeline that normalizes all of that.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Sequence

from repro.core.graph import Layer, LayerGraph

IR_VERSION = 1

#: node-record keys, beyond ``inputs``, that mirror :class:`Layer` fields
_LAYER_KEYS = tuple(f.name for f in dataclasses.fields(Layer))
_NODE_KEYS = _LAYER_KEYS + ("inputs",)
_PAIR_KEYS = ("stride", "padding", "dilation")


class IRError(ValueError):
    """Malformed IR: unknown fields, bad version, or an unbuildable graph."""


def _layer_to_node(layer: Layer, inputs: Sequence[str]) -> Dict[str, Any]:
    d = dataclasses.asdict(layer)
    for k in _PAIR_KEYS:
        d[k] = list(d[k])
    d["inputs"] = list(inputs)
    return d


def _node_to_layer(node: Dict[str, Any], idx: int) -> Layer:
    if not isinstance(node, dict):
        raise IRError(f"node {idx}: expected an object, got {type(node).__name__}")
    unknown = sorted(set(node) - set(_NODE_KEYS))
    if unknown:
        raise IRError(
            f"node {idx} ({node.get('name', '?')!r}): unknown fields "
            f"{unknown}; valid: {sorted(_NODE_KEYS)}")
    for k in ("name", "kind"):
        if k not in node:
            raise IRError(f"node {idx}: missing required field {k!r}")
    kw = {k: node[k] for k in _LAYER_KEYS if k in node}
    for k in _PAIR_KEYS:
        if k in kw:
            v = kw[k]
            if not (isinstance(v, (list, tuple)) and len(v) == 2):
                raise IRError(
                    f"node {idx} ({node['name']!r}): {k} must be a "
                    f"2-element list, got {v!r}")
            kw[k] = (int(v[0]), int(v[1]))
    try:
        return Layer(**kw)
    except (ValueError, TypeError) as e:
        raise IRError(f"node {idx} ({node['name']!r}): {e}") from None


@dataclass
class GraphIR:
    """A serializable layer graph: ordered node records + declared outputs.

    ``nodes`` are plain dicts (the JSON shape); ``outputs`` lists the node
    names whose tensors the model produces — the liveness roots for
    dead-node elimination (empty = every sink is an output).
    """

    name: str
    nodes: List[Dict[str, Any]] = field(default_factory=list)
    outputs: List[str] = field(default_factory=list)
    version: int = IR_VERSION

    # ---- conversion -----------------------------------------------------------
    @classmethod
    def from_graph(cls, graph: LayerGraph) -> "GraphIR":
        """Exact IR of ``graph`` (insertion order, full geometry); outputs
        are the graph's declared ``outputs`` when set (multi-head models
        keep non-sink outputs through round-trips), else its sinks."""
        nodes = [_layer_to_node(graph.layers[nm], graph.preds(nm))
                 for nm in graph.layers]
        outputs = list(getattr(graph, "outputs", None) or
                       (nm for nm in graph.layers if not graph.succs(nm)))
        return cls(name=graph.name, nodes=nodes, outputs=outputs)

    def build(self) -> LayerGraph:
        """Materialize a :class:`LayerGraph` (nodes must already be in
        producer-before-consumer order — :func:`repro.ir.load` guarantees
        it; raises :class:`IRError` otherwise)."""
        g = LayerGraph(self.name)
        for i, node in enumerate(self.nodes):
            layer = _node_to_layer(node, i)
            try:
                g.add(layer, node.get("inputs", []))
            except ValueError as e:
                raise IRError(
                    f"node {i} ({layer.name!r}): {e} — run "
                    f"repro.ir.canonicalize() (or load()) to topo-sort "
                    f"imported IR first") from None
        missing = [o for o in self.outputs if o not in g.layers]
        if missing:
            raise IRError(f"outputs name unknown nodes {missing}")
        if self.outputs:
            g.outputs = list(self.outputs)
        return g

    # ---- serialization --------------------------------------------------------
    def to_dict(self, *, explicit: bool = True) -> Dict[str, Any]:
        """JSON-ready dict.  ``explicit=True`` (the default, and the only
        form this module ever writes) fills every node field so the dict
        is canonical-ready; parsers still accept sparse hand-written
        nodes via :meth:`from_dict`."""
        nodes = self.nodes
        if explicit:
            nodes = [_layer_to_node(_node_to_layer(n, i),
                                    n.get("inputs", []))
                     for i, n in enumerate(nodes)]
        return {
            "ir_version": self.version,
            "name": self.name,
            "nodes": nodes,
            "outputs": list(self.outputs),
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "GraphIR":
        if not isinstance(d, dict):
            raise IRError(f"expected a JSON object, got {type(d).__name__}")
        unknown = sorted(set(d) - {"ir_version", "name", "nodes", "outputs"})
        if unknown:
            raise IRError(f"unknown GraphIR fields {unknown}; valid: "
                          f"['ir_version', 'name', 'nodes', 'outputs']")
        v = d.get("ir_version")
        if v != IR_VERSION:
            raise IRError(f"unsupported ir_version {v!r} "
                          f"(this build reads version {IR_VERSION})")
        if "name" not in d or "nodes" not in d:
            raise IRError("GraphIR requires 'name' and 'nodes'")
        if not isinstance(d["nodes"], list):
            raise IRError("'nodes' must be a list of node objects")
        bad = next((i for i, n in enumerate(d["nodes"])
                    if not isinstance(n, dict)), None)
        if bad is not None:
            raise IRError(f"node {bad}: expected an object, got "
                          f"{type(d['nodes'][bad]).__name__}")
        return cls(name=d["name"], nodes=[dict(n) for n in d["nodes"]],
                   outputs=list(d.get("outputs", [])), version=v)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True) + "\n"

    @classmethod
    def from_json(cls, text: str) -> "GraphIR":
        try:
            payload = json.loads(text)
        except json.JSONDecodeError as e:
            raise IRError(f"not valid JSON: {e}") from None
        return cls.from_dict(payload)

    # ---- identity -------------------------------------------------------------
    def canonical_json(self) -> str:
        """The canonical byte form: compact, sorted keys, every node field
        explicit.  Equal strings <=> identical searched structure."""
        return json.dumps(self.to_dict(), sort_keys=True,
                          separators=(",", ":"))

    #: fingerprint-format tag: ``ir1`` = sha256 over the version-1
    #: canonical IR JSON.  Pre-``repro.ir`` artifacts carry ``sha256:``
    #: fingerprints (a different payload) — the tag makes the formats
    #: distinguishable so stale artifacts fail with a clear error instead
    #: of a generic mismatch.
    FINGERPRINT_FORMAT = "ir1"

    def fingerprint(self) -> str:
        """sha256 over :meth:`canonical_json` (tagged with
        :attr:`FINGERPRINT_FORMAT`) — *the* graph fingerprint artifacts
        embed and the schedule store keys on."""
        return self.FINGERPRINT_FORMAT + ":" + hashlib.sha256(
            self.canonical_json().encode()).hexdigest()

    def __repr__(self) -> str:
        return (f"GraphIR({self.name!r}, {len(self.nodes)} nodes, "
                f"{len(self.outputs)} outputs)")
