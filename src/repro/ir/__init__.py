"""``repro.ir`` — the serializable graph IR + importer pipeline.

This package opens the workload side of the system the way ``repro.hw``
opened the hardware side: a CNN is no longer a Python builder baked into
the zoo but a *document* — a versioned, JSON-serializable
:class:`GraphIR` that anything can produce and everything downstream
(search, cost, serving, artifacts) consumes:

    import repro.ir as ir

    graph = ir.load("model.json").build()          # file -> LayerGraph
    ir.save(graph, "model.json")                   # LayerGraph -> file

    from repro.ir.trace import from_jax            # code -> IR
    gir = from_jax(forward, (x, w1, w2), name="my_cnn")

    # or through the facade, with no Python at all:
    #   repro search --workload file:model.json --accel simba

Pieces:

* :class:`GraphIR` (``graph_ir.py``) — the schema: ordered node records
  mirroring :class:`repro.core.graph.Layer`, each naming its inputs,
  plus declared outputs.  ``canonical_json()``/``fingerprint()`` define
  the byte-stable identity every artifact and store key uses.
* ``passes.py`` — the import pipeline (:func:`canonicalize` =
  topo-sort -> fold no-op glue -> dead-node elimination -> validate),
  idempotent, applied to everything entering from outside.
* ``trace.py`` — :func:`~repro.ir.trace.from_jax`, a jaxpr walker
  mapping ``conv_general_dilated`` / ``dot_general`` /
  ``reduce_window`` / elementwise ops onto Layer kinds.

``load``/``loads`` canonicalize; ``GraphIR.from_graph`` (and
``LayerGraph.to_ir``) are exact and run no passes — fingerprints always
describe the structure a genome actually indexes.
"""
from __future__ import annotations

from typing import Any, Callable, Tuple, Union

from repro.core.graph import LayerGraph

from repro.ir.graph_ir import IR_VERSION, GraphIR, IRError
from repro.ir.passes import (PIPELINE, canonicalize, eliminate_dead,
                             fold_noops, topo_sort, validate)


def loads(text: str) -> GraphIR:
    """Parse GraphIR JSON and run the import pipeline (canonicalized,
    validated — ready to ``build()``)."""
    return canonicalize(GraphIR.from_json(text))


def load(path: str) -> GraphIR:
    """Read a GraphIR JSON file and run the import pipeline."""
    try:
        with open(path) as f:
            text = f.read()
    except OSError as e:
        raise IRError(f"cannot read workload IR {path!r}: {e}") from None
    try:
        return loads(text)
    except IRError as e:
        raise IRError(f"{path}: {e}") from None


def save(obj: Union[GraphIR, LayerGraph], path: str) -> None:
    """Write a graph (or IR) as GraphIR JSON (human-indented form)."""
    ir = GraphIR.from_graph(obj) if isinstance(obj, LayerGraph) else obj
    with open(path, "w") as f:
        f.write(ir.to_json())


def fingerprint(obj: Union[GraphIR, LayerGraph]) -> str:
    """The canonical structural fingerprint (see
    :meth:`GraphIR.fingerprint`)."""
    ir = GraphIR.from_graph(obj) if isinstance(obj, LayerGraph) else obj
    return ir.fingerprint()


def from_jax(fn: Callable[..., Any], example_args: Tuple[Any, ...], *,
             name: str = "traced_cnn") -> GraphIR:
    """Trace a JAX function into canonical GraphIR (see
    :mod:`repro.ir.trace`; imports jax lazily)."""
    from repro.ir.trace import from_jax as _from_jax
    return _from_jax(fn, example_args, name=name)


__all__ = [
    "GraphIR", "IRError", "IR_VERSION", "PIPELINE", "canonicalize",
    "eliminate_dead", "fingerprint", "fold_noops", "from_jax", "load",
    "loads", "save", "topo_sort", "validate",
]
