"""``from_jax`` — trace a JAX function into :class:`~repro.ir.graph_ir.GraphIR`.

This is the "bring your own workload" importer for code instead of JSON:
give it any JAX-traceable CNN forward function and example inputs, and it
walks the jaxpr mapping compute primitives onto :class:`repro.core.graph.
Layer` kinds:

    ==========================  =====================================
    jaxpr primitive             Layer kind
    ==========================  =====================================
    conv_general_dilated        conv (dwconv when feature_group_count
                                == input channels)
    dot_general                 fc
    reduce_window_max/sum/min   pool (global_pool when the window
                                covers the whole spatial extent)
    reduce_sum/max over H,W     global_pool
    add/sub/max/min (2 tensors) add
    mul/div      (2 tensors)    mul
    concatenate                 concat
    ==========================  =====================================

Everything elementwise or shape-plumbing (relu via ``max(x, 0)``, bias
adds, activations, reshape/transpose/broadcast, dtype casts) is *folded*
into its producer — those ops move no DRAM traffic the fusion cost model
accounts separately.  ``pjit`` / ``custom_jvp_call`` bodies are walked
recursively, so ``jax.jit``- or ``jax.nn``-wrapped models trace the same
as raw ``lax`` code.

The walker is intentionally a CNN-shaped subset: batch size must be 1
(the paper's edge-inference setting) and an unsupported primitive raises
:class:`TraceError` naming it, rather than guessing.  The resulting IR is
run through the full canonicalization pipeline (``repro.ir.passes``), so
dead branches and identity glue never reach a search.

Example::

    import jax.numpy as jnp
    from jax import lax

    def cnn(x, w1, w2):
        y = lax.conv_general_dilated(x, w1, (1, 1), "SAME")
        y = jnp.maximum(y, 0.0)
        y = lax.reduce_window(y, -jnp.inf, lax.max,
                              (1, 1, 2, 2), (1, 1, 2, 2), "VALID")
        return lax.conv_general_dilated(y, w2, (1, 1), "SAME")

    ir = from_jax(cnn, (jnp.zeros((1, 3, 32, 32)),
                        jnp.zeros((8, 3, 3, 3)),
                        jnp.zeros((16, 8, 3, 3))), name="tiny")
    graph = ir.build()            # ready for repro.search
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.ir.graph_ir import GraphIR
from repro.ir.passes import canonicalize


class TraceError(ValueError):
    """The traced function uses a primitive/shape outside the supported
    CNN subset; the message names it."""


#: primitives folded into their producer (elementwise / shape plumbing)
_ALIAS_PRIMS = frozenset({
    "abs", "broadcast_in_dim", "ceil", "clamp", "convert_element_type",
    "copy", "cos", "cosh", "device_put", "erf", "exp", "expand_dims",
    "floor", "integer_pow", "log", "log1p", "logistic", "neg", "pow",
    "reshape", "round", "rsqrt", "select_n", "sign", "sin", "sinh", "sqrt",
    "squeeze", "stop_gradient", "tan", "tanh", "transpose",
})

_ADD_PRIMS = frozenset({"add", "add_any", "sub", "max", "min"})
_MUL_PRIMS = frozenset({"mul", "div"})
_WINDOW_PRIMS = frozenset({"reduce_window_max", "reduce_window_sum",
                           "reduce_window_min"})
_REDUCE_PRIMS = frozenset({"reduce_sum", "reduce_max", "reduce_min"})


@dataclass
class _Val:
    """What the walker knows about one jaxpr value."""
    node: Optional[str]          # producing IR node name; None = parameter
    chw: Tuple[int, int, int]    # logical activation shape (C, H, W)
    shape: Tuple[int, ...]       # raw array shape
    #: rank-4 dim order ("NCHW"/"NHWC"), learned from conv dimension
    #: numbers and propagated — pooling/reduction/concat dims depend on it
    layout: Optional[str] = None


def _is_literal(v: Any) -> bool:
    return not hasattr(v, "count")       # jax Var has .count, Literal doesn't


class _Walker:
    def __init__(self, name: str) -> None:
        self.name = name
        self.nodes: List[Dict[str, Any]] = []
        self._uid = 0
        self.env: Dict[Any, _Val] = {}

    # ---- node emission ---------------------------------------------------------
    def _emit(self, base: str, kind: str, inputs: List[str],
              **geom: Any) -> str:
        self._uid += 1
        name = f"{base}_{self._uid}"
        node: Dict[str, Any] = {"name": name, "kind": kind,
                                "inputs": inputs, **geom}
        self.nodes.append(node)
        return name

    def _chw_of_shape(self, shape: Tuple[int, ...]) -> Tuple[int, int, int]:
        if len(shape) == 4:
            if shape[0] != 1:
                raise TraceError(
                    f"activations must have batch size 1 (the paper's edge "
                    f"setting), got shape {shape}")
            return (shape[1], shape[2], shape[3])     # assume NCHW
        if len(shape) == 3:
            return (shape[0], shape[1], shape[2])
        if len(shape) == 2:
            if shape[0] != 1:
                raise TraceError(
                    f"2-d activations must be (1, features), got {shape}")
            return (shape[1], 1, 1)
        if len(shape) == 1:
            return (shape[0], 1, 1)
        raise TraceError(f"unsupported activation rank {len(shape)} "
                         f"(shape {shape})")

    def _as_data(self, val: _Val,
                 chw: Optional[Tuple[int, int, int]] = None) -> _Val:
        """Promote a parameter value to a traced activation: the model
        input becomes an ``input`` node on first data use."""
        if val.node is not None:
            return val
        c, h, w = chw if chw is not None else self._chw_of_shape(val.shape)
        node = self._emit("input", "input", [], m=c, p=h, q=w)
        val.node, val.chw = node, (c, h, w)
        return val

    # ---- value lookup ----------------------------------------------------------
    def _val(self, v: Any) -> _Val:
        if _is_literal(v):
            shape = tuple(getattr(getattr(v, "aval", None), "shape", ()))
            return _Val(None, (0, 0, 0), shape)
        if v not in self.env:
            shape = tuple(v.aval.shape)
            self.env[v] = _Val(None, (0, 0, 0), shape)
        return self.env[v]

    def _bind(self, outvar: Any, val: _Val) -> None:
        if not _is_literal(outvar):       # dropvars are fine to bind too
            self.env[outvar] = val

    # ---- primitive handlers ----------------------------------------------------
    def walk(self, jaxpr: Any) -> None:
        for eqn in jaxpr.eqns:
            self._eqn(eqn)

    def _eqn(self, eqn: Any) -> None:
        prim = eqn.primitive.name
        if prim == "conv_general_dilated":
            return self._conv(eqn)
        if prim == "dot_general":
            return self._dot(eqn)
        if prim in _WINDOW_PRIMS:
            return self._reduce_window(eqn)
        if prim in _REDUCE_PRIMS:
            return self._reduce(eqn)
        if prim in _ADD_PRIMS or prim in _MUL_PRIMS:
            return self._binary(eqn, "add" if prim in _ADD_PRIMS else "mul")
        if prim == "concatenate":
            return self._concat(eqn)
        if prim in ("pjit", "closed_call", "core_call", "xla_call",
                    "custom_jvp_call", "custom_vjp_call", "checkpoint",
                    "remat"):
            return self._call(eqn)
        if prim in _ALIAS_PRIMS:
            return self._alias(eqn)
        raise TraceError(
            f"unsupported primitive {prim!r} in traced function; the "
            f"importer understands convolutions (conv_general_dilated), "
            f"matmuls (dot_general), pooling (reduce_window_*, reduce_sum "
            f"over H,W), elementwise add/mul, and concatenate — write this "
            f"op in those terms or author the workload as GraphIR JSON")

    def _conv(self, eqn: Any) -> None:
        p = eqn.params
        dn = p["dimension_numbers"]
        lb, lf, *lspat = dn.lhs_spec
        rof, rif, *rspat = dn.rhs_spec
        ob, of, *ospat = dn.out_spec
        if len(lspat) != 2:
            raise TraceError(
                f"only 2-d convolutions are supported, got "
                f"{len(lspat)} spatial dims")
        lhs, rhs = eqn.invars[:2]
        lshape = tuple(lhs.aval.shape)
        if lshape[lb] != 1:
            raise TraceError(f"conv batch size must be 1, got {lshape[lb]}")
        c, h, w = lshape[lf], lshape[lspat[0]], lshape[lspat[1]]
        lval = self._as_data(self._val(lhs), (c, h, w))
        assert lval.node is not None      # _as_data promoted it
        lval.layout = "NHWC" if lf == 3 else "NCHW" if lf == 1 else None
        rshape = tuple(rhs.aval.shape)
        oshape = tuple(eqn.outvars[0].aval.shape)
        m = oshape[of]
        pq = (oshape[ospat[0]], oshape[ospat[1]])
        r, s = rshape[rspat[0]], rshape[rspat[1]]
        groups = int(p.get("feature_group_count", 1))
        # Layer.padding is symmetric; 'SAME' on even inputs lowers to
        # (lo, hi)=(0, 1) — max() keeps the halo the receptive-field
        # backtrace needs (the zoo writes the same geometry as pad=k//2)
        pad = tuple(max(int(lo), int(hi)) for lo, hi in p["padding"])
        kind, base = ("dwconv", "dw") if groups == c and groups > 1 \
            else ("conv", "conv")
        node = self._emit(
            base, kind, [lval.node], c=c, h=h, w=w, m=m, p=pq[0], q=pq[1],
            r=r, s=s, stride=list(map(int, p["window_strides"])),
            padding=list(pad),
            dilation=list(map(int, p["rhs_dilation"])), groups=groups)
        layout = "NHWC" if of == 3 else "NCHW" if of == 1 else None
        self._bind(eqn.outvars[0],
                   _Val(node, (m, pq[0], pq[1]), oshape, layout))

    def _dot(self, eqn: Any) -> None:
        (lc, rc), (lbat, rbat) = eqn.params["dimension_numbers"]
        lhs, rhs = eqn.invars[:2]
        lval, rval = self._val(lhs), self._val(rhs)
        if lval.node is not None and rval.node is not None:
            # both operands are traced activations: this is an attention/
            # bilinear product, not a weighted fc layer — an fc node would
            # keep only one branch and dead-eliminate the other silently
            raise TraceError(
                "dot_general of two traced activations (activation x "
                "activation, e.g. attention) is not an fc layer this IR "
                "models; only activation x parameter matmuls trace")
        # the operand with a traced producer is the data; weights stay
        # parameters.  With neither traced yet, lhs is the data (x @ W).
        if lval.node is None and rval.node is not None:
            data, dcontract = rval, rc
        else:
            data, dcontract = lval, lc
        data = self._as_data(data)
        assert data.node is not None      # _as_data promoted it
        cdim = math.prod(data.shape[d] for d in dcontract)
        oshape = tuple(eqn.outvars[0].aval.shape)
        m = math.prod(s for i, s in enumerate(oshape)
                      if i not in range(len(lbat))) if oshape else 1
        node = self._emit("fc", "fc", [data.node], c=cdim, h=1, w=1,
                          m=m, p=1, q=1)
        self._bind(eqn.outvars[0], _Val(node, (m, 1, 1), oshape))

    def _reduce_window(self, eqn: Any) -> None:
        p = eqn.params
        win = tuple(p["window_dimensions"])
        strides = tuple(p["window_strides"])
        pads = tuple(p.get("padding") or ((0, 0),) * len(win))
        val = self._val(eqn.invars[0])
        windowed = [i for i, k in enumerate(win) if k > 1]
        if not windowed:
            if val.node is None:
                val = self._as_data(val)
            return self._bind(eqn.outvars[0], val)     # degenerate window
        if len(win) != 4 or len(windowed) > 2:
            raise TraceError(
                f"unsupported reduce_window over rank-{len(win)} input "
                f"with window {win}; expected NCHW pooling")
        # pick the two spatial axes: trust the layout learned from the
        # producing conv; fall back to window-shape inference (NHWC when
        # the window sits on dims (1,2) leaving the trailing channel dim
        # alone, else NCHW — which also covers 1-d pools ((1,1,1,k):
        # r=1, s=k, q halves))
        if val.layout is not None:
            spatial = (1, 2) if val.layout == "NHWC" else (2, 3)
        elif win[3] == 1 and strides[3] == 1 and 1 in windowed:
            spatial = (1, 2)
        else:
            spatial = (2, 3)
        if val.node is None:
            # promote the raw input with the layout the window implies —
            # _chw_of_shape's NCHW default would garble NHWC geometry
            ishape = val.shape
            chw = (ishape[3], ishape[1], ishape[2]) if spatial == (1, 2) \
                else (ishape[1], ishape[2], ishape[3])
            val = self._as_data(val, chw)
            val.layout = "NHWC" if spatial == (1, 2) else "NCHW"
        if any(i not in spatial for i in windowed):
            raise TraceError(
                f"reduce_window window {win} pools a non-spatial dim for "
                f"the inferred layout (spatial dims {spatial})")
        assert val.node is not None       # promoted above when raw
        c, h, w = val.chw
        oshape = tuple(eqn.outvars[0].aval.shape)
        r, s = win[spatial[0]], win[spatial[1]]
        pq = (oshape[spatial[0]], oshape[spatial[1]])
        if (r, s) == (h, w) and pq == (1, 1):
            node = self._emit("gpool", "global_pool", [val.node],
                              c=c, h=h, w=w, m=c, p=1, q=1, r=h, s=w)
        else:
            node = self._emit(
                "pool", "pool", [val.node], c=c, h=h, w=w, m=c,
                p=pq[0], q=pq[1], r=r, s=s,
                stride=[int(strides[spatial[0]]), int(strides[spatial[1]])],
                # symmetric Layer.padding keeps the SAME halo (see _conv)
                padding=[max(int(lo), int(hi)) for lo, hi in
                         (pads[spatial[0]], pads[spatial[1]])])
        self._bind(eqn.outvars[0],
                   _Val(node, (c, pq[0], pq[1]), oshape, val.layout))

    def _reduce(self, eqn: Any) -> None:
        axes = tuple(eqn.params.get("axes", ()))
        val = self._val(eqn.invars[0])
        if val.node is None:              # reducing a parameter: constant
            return self._bind(eqn.outvars[0], val)
        spatial = ({1, 2} if val.layout == "NHWC" else {2, 3}) \
            if len(val.shape) == 4 else set()
        oshape = tuple(eqn.outvars[0].aval.shape)
        if spatial and spatial.issubset(set(axes)):
            assert val.node is not None
            c, h, w = val.chw
            node = self._emit("gpool", "global_pool", [val.node],
                              c=c, h=h, w=w, m=c, p=1, q=1, r=h, s=w)
            return self._bind(eqn.outvars[0], _Val(node, (c, 1, 1), oshape))
        if spatial & set(axes):
            # a partial spatial reduction (sum over H only) is real
            # compute with no Layer kind — folding it would silently
            # drop it and garble every downstream geometry
            raise TraceError(
                f"reduction over axes {axes} covers only part of the "
                f"spatial dims {sorted(spatial)}; only full global "
                f"pooling (both spatial dims) is supported")
        # softmax-style reductions along features: fold into the producer
        self._bind(eqn.outvars[0], _Val(val.node, val.chw, oshape))

    def _binary(self, eqn: Any, kind: str) -> None:
        a, b = (self._val(v) for v in eqn.invars[:2])
        oshape = tuple(eqn.outvars[0].aval.shape)
        if a.node is not None and b.node is not None and a.node != b.node:
            # two distinct traced operands = a real merge layer, even when
            # one side broadcasts (squeeze-excite: y * se(y) with se shaped
            # (1,C,1,1)) — folding it would dead-eliminate the whole branch
            big = a if math.prod(a.shape or (1,)) >= \
                math.prod(b.shape or (1,)) else b
            c, h, w = big.chw
            node = self._emit(kind, kind, [a.node, b.node],
                              c=c, h=h, w=w, m=c, p=h, q=w)
            return self._bind(eqn.outvars[0],
                              _Val(node, big.chw, oshape, big.layout))
        # bias add / relu(x) = max(x, 0) / scaling / x over its own
        # reduction (softmax): fold into the producer
        src = a if a.node is not None else b
        if src.node is None:
            return self._bind(eqn.outvars[0],
                              _Val(None, (0, 0, 0), oshape))  # const fold
        self._bind(eqn.outvars[0], _Val(src.node, src.chw, oshape))

    def _concat(self, eqn: Any) -> None:
        vals = [self._val(v) for v in eqn.invars]
        traced = [v for v in vals if v.node is not None]
        if not traced:
            return self._bind(eqn.outvars[0],
                              _Val(None, (0, 0, 0),
                                   tuple(eqn.outvars[0].aval.shape)))
        oshape = tuple(eqn.outvars[0].aval.shape)
        dim = int(eqn.params["dimension"])
        layout = next((v.layout for v in traced if v.layout), "NCHW")
        if len(oshape) == 4:
            feature_dim = 3 if layout == "NHWC" else 1
            if dim != feature_dim:
                raise TraceError(
                    f"only feature-dim concatenation is supported (got "
                    f"dimension={dim} on a {layout} activation, feature "
                    f"dim {feature_dim}); spatial concat is not a CNN "
                    f"layer this cost model knows")
        _c, h, w = traced[0].chw
        ctot = oshape[dim] if dim < len(oshape) else sum(
            v.chw[0] for v in traced)
        node = self._emit("cat", "concat",
                          [v.node for v in traced if v.node is not None],
                          c=ctot, h=h, w=w, m=ctot, p=h, q=w)
        self._bind(eqn.outvars[0], _Val(node, (ctot, h, w), oshape,
                                        layout if len(oshape) == 4
                                        else None))

    def _call(self, eqn: Any) -> None:
        params = eqn.params
        inner = params.get("jaxpr") or params.get("call_jaxpr") \
            or params.get("fun_jaxpr")
        if inner is None:
            raise TraceError(
                f"cannot find inner jaxpr of {eqn.primitive.name!r}")
        jaxpr = getattr(inner, "jaxpr", inner)     # ClosedJaxpr -> Jaxpr
        for iv, ov in zip(jaxpr.invars, eqn.invars):
            self.env[iv] = self._val(ov)
        self.walk(jaxpr)
        for ov, iv in zip(eqn.outvars, jaxpr.outvars):
            self._bind(ov, self._val(iv))

    def _alias(self, eqn: Any) -> None:
        vals = [self._val(v) for v in eqn.invars]
        src = next((v for v in vals if v.node is not None), vals[0])
        oshape = tuple(eqn.outvars[0].aval.shape)
        chw = src.chw
        if src.node is not None and len(oshape) <= 2 \
                and oshape != src.shape:
            # flatten before a classifier head: (1, C, H, W) -> (1, CHW)
            chw = (math.prod(oshape) if oshape else 1, 1, 1)
        layout = src.layout if len(oshape) == 4 else None
        if eqn.primitive.name == "transpose" and layout is not None:
            perm = tuple(eqn.params["permutation"])
            cpos = perm.index(1 if layout == "NCHW" else 3)
            layout = {1: "NCHW", 3: "NHWC"}.get(cpos)
        for ov in eqn.outvars:
            self._bind(ov, _Val(src.node, chw, oshape, layout))


def from_jax(fn: Callable[..., Any], example_args: Tuple[Any, ...], *,
             name: str = "traced_cnn",
             canonical: bool = True) -> GraphIR:
    """Trace ``fn(*example_args)`` into a (by default canonicalized)
    :class:`GraphIR`.

    ``example_args`` only supply shapes/dtypes — zeros work fine.  Raises
    :class:`TraceError` when the function strays outside the supported
    CNN primitive subset, and ``ImportError`` when jax itself is absent.
    """
    import jax                                     # deferred: optional dep

    closed = jax.make_jaxpr(fn)(*example_args)
    walker = _Walker(name)
    walker.walk(closed.jaxpr)
    outputs: List[str] = []
    for ov in closed.jaxpr.outvars:
        val = walker._val(ov)
        if val.node is None:
            raise TraceError(
                "a model output does not depend on any traced layer — "
                "is the function returning a constant?")
        if val.node not in outputs:
            outputs.append(val.node)
    ir = GraphIR(name=name, nodes=walker.nodes, outputs=outputs)
    return canonicalize(ir) if canonical else ir
