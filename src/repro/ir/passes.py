"""Import-time canonicalization passes over :class:`~repro.ir.graph_ir.GraphIR`.

Imported graphs (hand-written JSON, JAX traces) arrive in whatever shape
their author produced: nodes out of topological order, identity glue the
tracer could not fold, subgraphs feeding nothing.  The pipeline
normalizes all of that *before* the graph reaches a search:

    canonicalize = topo_sort -> fold_noops -> eliminate_dead -> validate

Each pass is ``GraphIR -> GraphIR`` (pure; input unmodified) and the
pipeline is idempotent — canonicalizing a canonical graph is a no-op, so
zoo graphs (already topological, glue-free, fully live) round-trip
through export/import with byte-identical canonical JSON and therefore
unchanged fingerprints.

These passes run in the *importer*, never in the fingerprint:
:meth:`GraphIR.fingerprint` hashes the exact structure a search indexes
its genome against (see ``repro.ir.graph_ir``).
"""
from __future__ import annotations

from typing import Any, Dict, List, Set

from repro.ir.graph_ir import GraphIR, IRError


def topo_sort(ir: GraphIR) -> GraphIR:
    """Stable topological reorder (producers before consumers).

    Ready nodes are emitted in original-index order, so an already-sorted
    graph comes back in the same order.  Raises :class:`IRError` on
    duplicate names, unknown inputs, or cycles.
    """
    names = [n.get("name") for n in ir.nodes]
    seen: Dict[str, int] = {}
    for i, nm in enumerate(names):
        if not isinstance(nm, str) or not nm:
            raise IRError(f"node {i}: missing/empty 'name'")
        if nm in seen:
            raise IRError(f"duplicate node name {nm!r} (nodes {seen[nm]} "
                          f"and {i})")
        seen[nm] = i
    indeg: List[int] = []
    succs: List[List[int]] = [[] for _ in ir.nodes]
    for i, node in enumerate(ir.nodes):
        preds = node.get("inputs", [])
        for p in preds:
            if p not in seen:
                raise IRError(
                    f"node {i} ({names[i]!r}): unknown input {p!r}")
            succs[seen[p]].append(i)
        indeg.append(len(preds))
    import heapq
    ready = [i for i, d in enumerate(indeg) if d == 0]
    heapq.heapify(ready)
    order: List[int] = []
    while ready:
        i = heapq.heappop(ready)
        order.append(i)
        for j in succs[i]:
            indeg[j] -= 1
            if indeg[j] == 0:
                heapq.heappush(ready, j)
    if len(order) != len(ir.nodes):
        stuck = sorted(set(range(len(ir.nodes))) - set(order))
        raise IRError(f"graph {ir.name!r} has a cycle through nodes "
                      f"{[names[i] for i in stuck]}")
    return GraphIR(name=ir.name, nodes=[dict(ir.nodes[i]) for i in order],
                   outputs=list(ir.outputs), version=ir.version)


def _is_noop(node: Dict[str, Any]) -> bool:
    """Identity glue: a single-input pool/upsample/concat whose output
    tensor equals its input tensor (k=1, stride 1, same geometry)."""
    if len(node.get("inputs", [])) != 1:
        return False
    kind = node.get("kind")
    if kind not in ("pool", "upsample", "concat"):
        return False
    g = lambda k, d: node.get(k, d)                    # noqa: E731
    same_shape = (g("m", 0) == g("c", 0) and g("p", 0) == g("h", 0)
                  and g("q", 0) == g("w", 0))
    if kind == "pool":
        return (same_shape and g("r", 1) == 1 and g("s", 1) == 1
                and tuple(g("stride", (1, 1))) == (1, 1))
    return same_shape


def fold_noops(ir: GraphIR) -> GraphIR:
    """Remove identity glue nodes, rewiring consumers (and outputs) to the
    folded node's producer.  A no-op that is itself a declared output is
    kept — folding it would rename the model's result."""
    alias: Dict[str, str] = {}
    outputs = set(ir.outputs)
    kept: List[Dict[str, Any]] = []
    for node in ir.nodes:
        if _is_noop(node) and node["name"] not in outputs:
            src = node["inputs"][0]
            alias[node["name"]] = alias.get(src, src)
            continue
        node = dict(node)
        node["inputs"] = [alias.get(p, p) for p in node.get("inputs", [])]
        kept.append(node)
    return GraphIR(name=ir.name, nodes=kept,
                   outputs=[alias.get(o, o) for o in ir.outputs],
                   version=ir.version)


def eliminate_dead(ir: GraphIR) -> GraphIR:
    """Drop nodes with no path to an output (liveness roots: the declared
    ``outputs``, or every sink when none are declared).  The surviving
    outputs list is normalized to node order; every surviving sink is an
    output, though an output need not be a sink (multi-head models)."""
    idx = {n["name"]: i for i, n in enumerate(ir.nodes)}
    unknown = [o for o in ir.outputs if o not in idx]
    if unknown:
        # a typo'd output must not silently prune the branch (or the
        # whole graph) it was meant to keep alive
        raise IRError(f"graph {ir.name!r}: outputs name unknown nodes "
                      f"{unknown}; known: {sorted(idx)[:10]}...")
    roots = ir.outputs or [
        n["name"] for n in ir.nodes
        if not any(n["name"] in m.get("inputs", []) for m in ir.nodes)]
    live: Set[str] = set()
    stack = list(roots)
    while stack:
        nm = stack.pop()
        if nm in live:
            continue
        live.add(nm)
        stack.extend(ir.nodes[idx[nm]].get("inputs", []))
    nodes = [dict(n) for n in ir.nodes if n["name"] in live]
    root_set = {o for o in roots if o in live}
    return GraphIR(name=ir.name, nodes=nodes,
                   outputs=[n["name"] for n in nodes
                            if n["name"] in root_set],
                   version=ir.version)


def validate(ir: GraphIR) -> GraphIR:
    """Build + shape-check the graph (layer kinds, channel agreement along
    edges — :meth:`LayerGraph.validate`); returns ``ir`` unchanged."""
    try:
        ir.build().validate()
    except IRError:
        raise
    except ValueError as e:
        raise IRError(f"graph {ir.name!r} failed validation: {e}") from None
    return ir


#: the import pipeline, in order
PIPELINE = (topo_sort, fold_noops, eliminate_dead, validate)


def canonicalize(ir: GraphIR) -> GraphIR:
    """Run the full import pipeline; the result builds, validates, and is
    a fixed point of every pass."""
    for p in PIPELINE:
        ir = p(ir)
    return ir
