"""Mixture-of-Experts FFN with capacity-bounded index dispatch.

Top-k routing (dbrx: 16e top-4; llama4: 128e top-1 + shared expert) with the
scatter/gather formulation: tokens are placed into per-expert capacity slots
(position = running count of earlier tokens picking the same expert); tokens
beyond capacity are dropped (their residual passes through).  Under the
production mesh the expert dimension is sharded over ``model`` (EP) and the
token dimension over ``data``/``pod`` (DP) — dispatch stays local per data
shard, expert compute is fully local in (expert, d_ff), and XLA materializes
the token shuffle as collective-permute/all-to-all on the real topology.

This mirrors the paper's *weight-buffer capacity* check: an expert's
parameters are pinned HBM-resident on their `model` shard; the router's
capacity factor bounds the on-chip activation working set exactly like the
receptive-field rule bounds the fused group's activation buffer.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import act_fn, dense_init, pspec, shard


def moe_init(key, cfg, dtype) -> Dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    keys = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(d)
    params = {
        "router": dense_init(keys[0], d, e, jnp.float32),   # router in fp32
        "w_gate": (jax.random.normal(keys[1], (e, d, f)) * scale).astype(dtype),
        "w_up": (jax.random.normal(keys[2], (e, d, f)) * scale).astype(dtype),
        "w_down": (jax.random.normal(keys[3], (e, f, d))
                   / math.sqrt(f)).astype(dtype),
    }
    if cfg.n_shared_experts:
        ks = jax.random.split(keys[4], 3)
        params["shared"] = {
            "w_gate": dense_init(ks[0], d, cfg.n_shared_experts * f, dtype),
            "w_up": dense_init(ks[1], d, cfg.n_shared_experts * f, dtype),
            "w_down": dense_init(ks[2], cfg.n_shared_experts * f, d, dtype),
        }
    return params


def moe_param_specs(cfg) -> Dict:
    fsdp = ("pod", "data")
    specs = {
        "router": pspec(None, "model"),
        "w_gate": pspec("model", fsdp, None),
        "w_up": pspec("model", fsdp, None),
        "w_down": pspec("model", None, fsdp),
    }
    if cfg.n_shared_experts:
        specs["shared"] = {
            "w_gate": pspec(fsdp, "model"),
            "w_up": pspec(fsdp, "model"),
            "w_down": pspec("model", fsdp),
        }
    return specs


def _num_batch_shards() -> int:
    from repro.models.common import _axis_size
    return max(_axis_size("pod") * _axis_size("data"), 1)


def moe_apply(params, x, cfg, act="silu"):
    """x: (B, S, D) -> (B, S, D).  Returns (y, aux_loss).

    ``cfg.moe_impl``:
    * ``a2a``     — sort-based dispatch local to each data shard, buffers
      resharded group<->expert (the real MoE all-to-all; per-device traffic
      = only the shard's dispatched rows).  Default.
    * ``global``  — global scatter/gather dispatch (simpler, but GSPMD turns
      the combine into a one-hot dot and the reshards into whole-buffer
      all-gathers; kept for the §Perf comparison).
    """
    if cfg.moe_impl == "a2a":
        return _moe_apply_a2a(params, x, cfg, act)
    return _moe_apply_global(params, x, cfg, act)


def _aux_loss(probs, sel, E):
    """Switch-style load-balance loss."""
    frac_tokens = jnp.mean(
        jax.nn.one_hot(sel[..., 0], E, dtype=jnp.float32).reshape(-1, E),
        axis=0)
    frac_probs = jnp.mean(probs.reshape(-1, E), axis=0)
    return E * jnp.sum(frac_tokens * frac_probs)


def _moe_apply_a2a(params, x, cfg, act="silu"):
    """Sorted local dispatch + expert all-to-all (MegaBlocks/MaxText-style,
    EXPERIMENTS.md §Perf iteration 3)."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    N = B * S
    G = _num_batch_shards()
    if N % G:
        G = 1
    nl = N // G
    cap = int(math.ceil(cfg.capacity_factor * nl * K / E))
    xt = shard(x.reshape(G, nl, D), ("pod", "data"), None, None)

    logits = (xt.astype(jnp.float32) @ params["router"])        # (G, nl, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, sel = jax.lax.top_k(probs, K)                          # (G, nl, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    M = nl * K
    flat_e = sel.reshape(G, M)
    order = jnp.argsort(flat_e, axis=1, stable=True)             # (G, M)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    idx = jnp.broadcast_to(jnp.arange(M)[None], (G, M))
    is_start = jnp.concatenate(
        [jnp.ones((G, 1), bool), sorted_e[:, 1:] != sorted_e[:, :-1]], axis=1)
    run_start = jnp.where(is_start, idx, 0)
    seg_start = jax.lax.associative_scan(jnp.maximum, run_start, axis=1)
    pos = idx - seg_start                                        # rank in expert
    keep = pos < cap
    slot = jnp.where(keep, sorted_e * cap + pos, E * cap)        # drop bin
    token = order // K                                           # (G, M)

    xsorted = jnp.take_along_axis(xt, token[..., None], axis=1)  # local gather
    upd = jnp.where(keep[..., None], xsorted, 0).astype(x.dtype)
    buf = jax.vmap(lambda b, s, v: b.at[s].add(v))(
        jnp.zeros((G, E * cap + 1, D), x.dtype), slot, upd)      # local scatter
    buf = buf[:, :-1].reshape(G, E, cap, D)
    # group->expert reshard: THE all-to-all (each device keeps its E-slice)
    buf = shard(buf, ("pod", "data"), "model", None, None)

    a = act_fn(act)
    h = a(jnp.einsum("gecd,edf->gecf", buf, params["w_gate"])) \
        * jnp.einsum("gecd,edf->gecf", buf, params["w_up"])
    h = shard(h, ("pod", "data"), "model", None, None)
    out = jnp.einsum("gecf,efd->gecd", h, params["w_down"])
    # expert->group reshard back (return all-to-all)
    out = shard(out, ("pod", "data"), None, None, None)

    rows = out.reshape(G, E * cap, D)
    vals = jnp.take_along_axis(rows, jnp.clip(slot, 0, E * cap - 1)[..., None],
                               axis=1)                           # local gather
    gate_sorted = jnp.take_along_axis(gate.reshape(G, M), order, axis=1)
    contrib = jnp.where(keep[..., None], vals, 0) \
        * gate_sorted[..., None].astype(x.dtype)
    y = jax.vmap(lambda z, t, v: z.at[t].add(v))(
        jnp.zeros((G, nl, D), x.dtype), token, contrib)          # local scatter

    y = y.reshape(B, S, D)
    if cfg.n_shared_experts:
        sh = params["shared"]
        x2 = x.reshape(N, D)
        hs = a(x2 @ sh["w_gate"]) * (x2 @ sh["w_up"])
        y = y + (hs @ sh["w_down"]).reshape(B, S, D)
    return y, _aux_loss(probs, sel, E)


def _moe_apply_global(params, x, cfg, act="silu"):
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    N = B * S
    cap = int(math.ceil(cfg.capacity_factor * N * K / E))
    xt = x.reshape(N, D)

    logits = (xt.astype(jnp.float32) @ params["router"])          # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, sel = jax.lax.top_k(probs, K)                            # (N, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # position of each (token, slot) in its expert queue ------------------------
    flat_sel = sel.reshape(-1)                                     # (N*K,)
    onehot = jax.nn.one_hot(flat_sel, E, dtype=jnp.int32)          # (N*K, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot                 # exclusive
    pos = jnp.take_along_axis(pos_in_e, flat_sel[:, None], axis=1)[:, 0]
    keep = pos < cap
    slot = jnp.where(keep, flat_sel * cap + pos, E * cap)          # overflow bin

    # scatter tokens into expert buffers ------------------------------------------
    xrep = jnp.repeat(xt, K, axis=0)                               # (N*K, D)
    buf = jnp.zeros((E * cap + 1, D), x.dtype).at[slot].add(xrep)
    buf = buf[:-1].reshape(E, cap, D)
    # EP over `model` AND capacity over the batch axes: without the latter
    # the expert GEMM replicates across data shards (16x wasted MXU work —
    # found via HLO flops 12x above analytic; EXPERIMENTS.md §Perf iter 1)
    buf = shard(buf, "model", ("pod", "data"), None)

    # expert FFN (swiglu), local in (E/model, cap/data) x (E, D, F) ------------------
    a = act_fn(act)
    h = a(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
    h = shard(h, "model", ("pod", "data"), None)
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    out_buf = shard(out_buf, "model", ("pod", "data"), None)

    # gather back + combine with gates ----------------------------------------------
    out_flat = out_buf.reshape(E * cap, D)
    out_tok = jnp.where(keep[:, None], out_flat[jnp.clip(slot, 0, E * cap - 1)],
                        0.0)                                        # (N*K, D)
    gates = gate.reshape(-1)[:, None].astype(x.dtype)
    y = (out_tok * gates).reshape(N, K, D).sum(axis=1)

    if cfg.n_shared_experts:
        sh = params["shared"]
        hs = a(xt @ sh["w_gate"]) * (xt @ sh["w_up"])
        y = y + hs @ sh["w_down"]

    # load-balance aux loss (Switch): E * sum(frac_tokens * frac_probs)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(sel[:, 0], E, dtype=jnp.float32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs)
    return y.reshape(B, S, D), aux


def moe_ref(params, x, cfg, act="silu"):
    """Dense oracle: every token through every expert, gated combine (no
    capacity drops).  Used by tests on tiny shapes."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    xt = x.reshape(-1, D)
    logits = xt.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    gate, sel = jax.lax.top_k(probs, K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    a = act_fn(act)
    h = a(jnp.einsum("nd,edf->enf", xt, params["w_gate"])) \
        * jnp.einsum("nd,edf->enf", xt, params["w_up"])
    per_e = jnp.einsum("enf,efd->end", h, params["w_down"])        # (E, N, D)
    mask = jax.nn.one_hot(sel, E, dtype=jnp.float32)               # (N, K, E)
    w = (mask * gate[..., None]).sum(1)                            # (N, E)
    y = jnp.einsum("ne,end->nd", w.astype(x.dtype), per_e)
    if cfg.n_shared_experts:
        sh = params["shared"]
        y = y + (a(xt @ sh["w_gate"]) * (xt @ sh["w_up"])) @ sh["w_down"]
    return y.reshape(B, S, D)
