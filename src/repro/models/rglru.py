"""RG-LRU recurrent block (recurrentgemma-2b / Griffin [arXiv:2402.19427]).

Recurrence (eq. 1-4 of the paper):
    r_t = sigmoid(W_a x_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)          (input gate)
    a_t = exp(c * r_t * log(a_hat)),  log(a_hat) = -softplus(Lambda), c = 8
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The full Griffin *recurrent block* is: linear (D->W) on two branches, a
temporal conv (width 4) on the recurrent branch, the RG-LRU, and a gated
output projection (W->D).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import SEQ, HEADS, dense_init, pspec, shard
from repro.models.mamba import _causal_conv
from repro.models.scan_ops import linear_scan_chunked

_C = 8.0


def rglru_init(key, cfg, dtype) -> Dict:
    d, w, k = cfg.d_model, cfg.rnn_width, cfg.ssm_conv
    keys = jax.random.split(key, 6)
    # init so a^c ~ uniform(0.9, 0.999) as in the paper
    u = jax.random.uniform(keys[4], (w,), minval=0.9, maxval=0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))       # softplus^-1(-log u / c)
    return {
        "in_x": dense_init(keys[0], d, w, dtype),
        "in_gate": dense_init(keys[1], d, w, dtype),
        "conv_w": (jax.random.normal(keys[2], (k, w)) / math.sqrt(k)
                   ).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "w_a": dense_init(keys[3], w, w, dtype),
        "b_a": jnp.zeros((w,), jnp.float32),
        "w_i": dense_init(keys[5], w, w, dtype),
        "b_i": jnp.zeros((w,), jnp.float32),
        "lambda": lam.astype(jnp.float32),
        "out_proj": dense_init(jax.random.fold_in(key, 7), w, d, dtype),
    }


def rglru_param_specs(cfg) -> Dict:
    fsdp = ("pod", "data")
    return {
        "in_x": pspec(fsdp, "model"),
        "in_gate": pspec(fsdp, "model"),
        "conv_w": pspec(None, "model"),
        "conv_b": pspec("model"),
        "w_a": pspec(None, "model"),
        "b_a": pspec("model"),
        "w_i": pspec(None, "model"),
        "b_i": pspec("model"),
        "lambda": pspec("model"),
        "out_proj": pspec("model", fsdp),
    }


def rglru_mix(params, x, cfg, *, state=None, conv_hist=None,
              return_state=False):
    """x: (B,S,D) -> (B,S,D); optional decode cache (h, conv history)."""
    B, S, D = x.shape
    w = cfg.rnn_width
    xb = x @ params["in_x"]
    gate = jax.nn.gelu(x @ params["in_gate"])
    xb = shard(xb, ("pod", "data"), SEQ, HEADS)
    xc = _causal_conv(xb, params["conv_w"], params["conv_b"], conv_hist)

    r = jax.nn.sigmoid((xc @ params["w_a"]).astype(jnp.float32)
                       + params["b_a"])
    i = jax.nn.sigmoid((xc @ params["w_i"]).astype(jnp.float32)
                       + params["b_i"])
    log_a_hat = -jax.nn.softplus(params["lambda"])           # (w,)
    a = jnp.exp(_C * r * log_a_hat)                          # (B,S,w) fp32
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * i * xc.astype(jnp.float32)
    h0 = state if state is not None else jnp.zeros((B, w), jnp.float32)
    if S == 1:
        h_last = a[:, 0] * h0 + b[:, 0]
        hs = h_last[:, None]
    else:
        hs, h_last = linear_scan_chunked(a, b, h0, chunk=256,
                                         exact=cfg.exact_costs)
    y = (hs.astype(x.dtype) * gate) @ params["out_proj"]
    if return_state:
        K = params["conv_w"].shape[0]
        if conv_hist is None:
            xp = jnp.pad(xb, ((0, 0), (K - 1, 0), (0, 0)))
        else:
            xp = jnp.concatenate([conv_hist.astype(xb.dtype), xb], 1)
        return y, (h_last, xp[:, -(K - 1):])
    return y


def rglru_ref_sequential(params, x, cfg):
    """Step-by-step oracle for tests."""
    B, S, D = x.shape
    state = jnp.zeros((B, cfg.rnn_width), jnp.float32)
    hist = jnp.zeros((B, cfg.ssm_conv - 1, cfg.rnn_width), x.dtype)
    outs = []
    for t in range(S):
        o, (state, hist) = rglru_mix(params, x[:, t:t + 1], cfg, state=state,
                                     conv_hist=hist, return_state=True)
        outs.append(o)
    return jnp.concatenate(outs, axis=1)
