"""Attention: dense, blockwise (flash-style lax.scan), local/chunked, decode.

Shapes: q (B, Sq, Hq, D), k/v (B, Skv, Hkv, D) with Hq = Hkv * G (GQA).
Grouped heads are never materialized: scores are computed per (Hkv, G).

Three execution paths:
* ``dense``      — full-score einsum; short sequences and the test oracle.
* ``blockwise``  — online-softmax lax.scan over KV blocks (the XLA analogue
  of the Pallas flash kernel in ``repro/kernels``); memory O(block) instead
  of O(S^2).  This is the paper's receptive-field-tiling idea applied to the
  TPU memory hierarchy: KV tiles stream through fast memory while the
  softmax state (m, l, o) stays resident.
* ``local``      — banded/chunked attention computed exactly (two-block
  reshape), so sliding-window (recurrentgemma) and chunked (llama4) layers
  cost O(S*W) FLOPs rather than masked O(S^2).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def _softcap(x, cap: float):
    return cap * jnp.tanh(x / cap) if cap > 0 else x


def _mask(q_pos, k_pos, *, causal: bool, window: int, chunk: int):
    """(..., Sq, Skv) boolean allowed-mask from position vectors."""
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    ok = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    if causal:
        ok &= kp <= qp
    if window:
        ok &= kp > qp - window
    if chunk:
        ok &= (kp // chunk) == (qp // chunk)
    ok &= kp >= 0                      # invalid/unwritten cache slots carry pos -1
    return ok


def dense_attention(q, k, v, q_pos, k_pos, *, causal=True, window=0, chunk=0,
                    softcap=0.0):
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D)
    scale = 1.0 / math.sqrt(D)
    scores = jnp.einsum("bshgd,bthd->bhgst", qg, k).astype(jnp.float32) * scale
    scores = _softcap(scores, softcap)
    mask = _mask(q_pos, k_pos, causal=causal, window=window, chunk=chunk)
    scores = jnp.where(mask[:, None, None] if mask.ndim == 3
                       else mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgst,bthd->bshgd", probs, v)
    return out.reshape(B, Sq, Hq, D)


def blockwise_attention(q, k, v, q_pos, k_pos, *, causal=True, window=0,
                        chunk=0, softcap=0.0, block_kv=1024, unroll=False):
    """Online-softmax scan over KV blocks (numerics match dense to ~1e-6)."""
    B, Sq, Hq, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    nb = -(-Skv // block_kv)
    pad = nb * block_kv - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, pad),), constant_values=-1)
    qg = q.reshape(B, Sq, Hkv, G, D)
    scale = 1.0 / math.sqrt(D)

    kb = k.reshape(B, nb, block_kv, Hkv, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nb, block_kv, Hkv, D).transpose(1, 0, 2, 3, 4)
    pb = k_pos.reshape(nb, block_kv)

    def body(carry, inputs):
        m, l, acc = carry
        kblk, vblk, pblk = inputs
        s = jnp.einsum("bshgd,bthd->bhgst", qg, kblk).astype(jnp.float32) * scale
        s = _softcap(s, softcap)
        ok = _mask(q_pos, pblk, causal=causal, window=window, chunk=chunk)
        s = jnp.where(ok[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhgst,bthd->bhgsd", p.astype(q.dtype), vblk)
        acc_new = acc * corr[..., None].astype(q.dtype) + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Sq, D), q.dtype)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, pb),
                                  unroll=nb if unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None].astype(q.dtype)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, D)


def local_attention(q, k, v, q_pos, k_pos, *, window=0, chunk=0, softcap=0.0,
                    causal=True):
    """Exact banded (sliding-window) or block-diagonal (chunked) attention in
    O(S*W): sequence reshaped into W-sized chunks, each attending to itself
    (+ its predecessor for the sliding-window case)."""
    W = window or chunk
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    assert k.shape[1] == S, "local_attention expects self-attention shapes"
    nb = -(-S // W)
    pad = nb * W - S
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, pad),), constant_values=-(2 ** 30))
        k_pos = jnp.pad(k_pos, ((0, pad),), constant_values=-1)
    G = Hq // Hkv
    qc = q.reshape(B, nb, W, Hkv, G, D)
    kc = k.reshape(B, nb, W, Hkv, D)
    vc = v.reshape(B, nb, W, Hkv, D)
    qp = q_pos.reshape(nb, W)
    kp = k_pos.reshape(nb, W)
    if window:
        # each chunk sees [previous chunk, itself]
        kc = jnp.concatenate([jnp.roll(kc, 1, axis=1), kc], axis=2)
        vc = jnp.concatenate([jnp.roll(vc, 1, axis=1), vc], axis=2)
        kp2 = jnp.concatenate([jnp.roll(kp, 1, axis=0), kp], axis=1)
        kp2 = kp2.at[0, :W].set(-1)            # chunk 0 has no predecessor
        kp = kp2
    scale = 1.0 / math.sqrt(D)
    s = jnp.einsum("bcshgd,bcthd->bchgst", qc, kc).astype(jnp.float32) * scale
    s = _softcap(s, softcap)
    ok = _mask(qp, kp, causal=causal, window=window, chunk=chunk)
    s = jnp.where(ok[None, :, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bchgst,bcthd->bcshgd", p, vc)
    out = out.reshape(B, nb * W, Hq, D)
    return out[:, :S]


def attention(q, k, v, q_pos, k_pos, *, causal=True, window=0, chunk=0,
              softcap=0.0, impl="auto", block_kv=1024, unroll=False):
    """Dispatch to the right path.  ``impl``: auto|dense|blockwise|local.
    ``unroll``: unroll the blockwise KV scan (exact-cost lowering mode)."""
    Sq, Skv = q.shape[1], k.shape[1]
    if impl == "auto":
        if (window or chunk) and Sq == Skv and Sq > (window or chunk):
            impl = "local"
        elif Sq * Skv > 4096 * 4096:
            impl = "blockwise"
        else:
            impl = "dense"
    if impl == "local":
        return local_attention(q, k, v, q_pos, k_pos, window=window,
                               chunk=chunk, softcap=softcap, causal=causal)
    if impl == "blockwise":
        return blockwise_attention(q, k, v, q_pos, k_pos, causal=causal,
                                   window=window, chunk=chunk,
                                   softcap=softcap, block_kv=block_kv,
                                   unroll=unroll)
    return dense_attention(q, k, v, q_pos, k_pos, causal=causal,
                           window=window, chunk=chunk, softcap=softcap)
