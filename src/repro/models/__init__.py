"""Pure-JAX model zoo for the ten assigned architectures."""
