"""Chunked linear recurrence h_t = a_t * h_{t-1} + b_t (XLA path).

The full (B, S, ...) coefficient tensors of an SSM scan can dwarf HBM at real
sizes, so — mirroring the paper's receptive-field tiling — we stream the time
axis in chunks: ``lax.scan`` over chunks carrying the state, with a parallel
``associative_scan`` inside each chunk.  The Pallas kernels in
``repro/kernels`` are the TPU-native version of the same blocking.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def _combine(e1, e2):
    a1, b1 = e1
    a2, b2 = e2
    return a2 * a1, a2 * b1 + b2


def linear_scan(a, b, h0):
    """Exact associative scan over axis 1.  a, b: (B, S, ...); h0: (B, ...)."""
    # fold h0 into the first step
    b = b.at[:, 0].set(a[:, 0] * h0 + b[:, 0])
    a = a.at[:, 0].set(jnp.zeros_like(a[:, 0]))
    av, bv = jax.lax.associative_scan(_combine, (a, b), axis=1)
    return bv, bv[:, -1]


def linear_scan_chunked(a, b, h0, chunk: int = 128, exact: bool = False):
    """Same result as :func:`linear_scan`, O(chunk) live coefficients.
    ``exact``: unroll the outer chunk scan (and widen chunks) so
    HLO cost_analysis counts every iteration — dry-run cost mode only."""
    if exact:
        chunk = max(chunk, 2048)
    B, S = a.shape[:2]
    if S <= chunk:
        return linear_scan(a, b, h0)
    nb = -(-S // chunk)
    pad = nb * chunk - S
    if pad:
        a = jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2),
                    constant_values=1.0)
        b = jnp.pad(b, [(0, 0), (0, pad)] + [(0, 0)] * (b.ndim - 2))
    ac = jnp.moveaxis(a.reshape((B, nb, chunk) + a.shape[2:]), 1, 0)
    bc = jnp.moveaxis(b.reshape((B, nb, chunk) + b.shape[2:]), 1, 0)

    def body(h, ab):
        ai, bi = ab
        hs, h_last = linear_scan(ai, bi, h)
        return h_last, hs

    h_final, hs = jax.lax.scan(body, h0, (ac, bc),
                               unroll=nb if exact else 1)
    hs = jnp.moveaxis(hs, 0, 1).reshape((B, nb * chunk) + a.shape[2:])
    if pad:
        h_final = hs[:, S - 1]
    return hs[:, :S], h_final
