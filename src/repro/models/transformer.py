"""Generic decoder-LM assembly for all ten architectures.

A config's layer stack is grouped into *segments*: maximal runs of a repeated
layer-kind pattern (dense: ``("attn",) x L``; llama4: ``("attn_chunk" x3,
"attn_global") x 12``; recurrentgemma: ``("rglru","rglru","attn_local") x 8 +
("rglru","rglru")``).  Per-segment parameters are stacked on a leading
repeat axis and applied with ``lax.scan`` — one compiled layer body per
segment regardless of depth, which keeps the 64-layer dry-runs compilable.

All functions are pure; caches are explicit pytrees.  Sharding is expressed
through ``param_pspecs`` (consumed by pjit) plus in-graph constraints
(Megatron-style TP: heads/d_ff/experts over ``model``, batch over
``pod``x``data``, FSDP parameter sharding over the batch axes).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as attn_lib
from repro.models.common import (BATCH, HEADS, SEQ, dense_init, dtype_of,
                                 embed_init, norm_apply, norm_init,
                                 apply_rope, pspec, shard, sharding_mode)
from repro.models.mamba import mamba_init, mamba_mix, mamba_param_specs
from repro.models.moe import moe_apply, moe_init, moe_param_specs, moe_ref
from repro.models.rglru import rglru_init, rglru_mix, rglru_param_specs

FSDP = BATCH   # parameter sharding axes (ZeRO-3 over the data axes)


# ---- segments ---------------------------------------------------------------------------

def segments(cfg: ModelConfig) -> List[Tuple[Tuple[str, ...], int]]:
    kinds = list(cfg.layer_kinds())
    if cfg.block_pattern or (cfg.attn_chunk and cfg.global_every):
        plen = len(cfg.block_pattern) or cfg.global_every
    elif cfg.n_experts and cfg.moe_every > 1:
        plen = cfg.moe_every
    else:
        plen = 1
    if cfg.n_experts and cfg.moe_every > 1:
        assert plen % cfg.moe_every == 0, \
            "pattern length must be a multiple of moe_every"
    if plen > 1:
        reps = len(kinds) // plen
        segs = []
        if reps:
            segs.append((tuple(kinds[:plen]), reps))
        if len(kinds) % plen:
            segs.append((tuple(kinds[reps * plen:]), 1))
        return segs
    return [(tuple(kinds[:1]), len(kinds))]


# ---- per-layer init ------------------------------------------------------------------------

def _attn_init(key, cfg, dtype):
    d, hq, hkv, hd = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                      cfg.resolved_head_dim)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, hq * hd, dtype),
        "wk": dense_init(ks[1], d, hkv * hd, dtype),
        "wv": dense_init(ks[2], d, hkv * hd, dtype),
        "wo": dense_init(ks[3], hq * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p.update(bq=jnp.zeros((hq * hd,), dtype),
                 bk=jnp.zeros((hkv * hd,), dtype),
                 bv=jnp.zeros((hkv * hd,), dtype))
    return p


def _attn_specs(cfg):
    p = {"wq": pspec(FSDP, "model"), "wk": pspec(FSDP, "model"),
         "wv": pspec(FSDP, "model"), "wo": pspec("model", FSDP)}
    if cfg.qkv_bias:
        p.update(bq=pspec("model"), bk=pspec("model"), bv=pspec("model"))
    return p


def _mlp_init(key, cfg, dtype):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp in ("swiglu", "geglu"):
        return {"w_gate": dense_init(ks[0], d, f, dtype),
                "w_up": dense_init(ks[1], d, f, dtype),
                "w_down": dense_init(ks[2], f, d, dtype)}
    return {"w_up": dense_init(ks[0], d, f, dtype),
            "w_down": dense_init(ks[1], f, d, dtype)}


def _mlp_specs(cfg):
    p = {"w_up": pspec(FSDP, "model"), "w_down": pspec("model", FSDP)}
    if cfg.mlp in ("swiglu", "geglu"):
        p["w_gate"] = pspec(FSDP, "model")
    return p


def _use_moe(cfg: ModelConfig, pattern_pos: int) -> bool:
    """MoE on every ``moe_every``-th layer (llama4 interleaves MoE/dense).
    Decided by position within the repeated pattern — valid because the
    pattern length is a multiple of ``moe_every`` (asserted in segments)."""
    if not cfg.n_experts:
        return False
    return (pattern_pos + 1) % cfg.moe_every == 0


def _layer_init(key, kind: str, cfg: ModelConfig, dtype, with_cross=False,
                pattern_pos: int = 0):
    ks = jax.random.split(key, 5)
    p: Dict[str, Any] = {"norm1": norm_init(cfg.norm, cfg.d_model, dtype)}
    if kind.startswith("attn"):
        p["attn"] = _attn_init(ks[0], cfg, dtype)
    elif kind == "mamba":
        p["mamba"] = mamba_init(ks[0], cfg, dtype)
    elif kind == "rglru":
        p["rglru"] = rglru_init(ks[0], cfg, dtype)
    if with_cross:
        p["norm_cross"] = norm_init(cfg.norm, cfg.d_model, dtype)
        p["cross"] = _attn_init(ks[1], cfg, dtype)
    if cfg.family != "ssm":
        p["norm2"] = norm_init(cfg.norm, cfg.d_model, dtype)
        if _use_moe(cfg, pattern_pos):
            p["moe"] = moe_init(ks[2], cfg, dtype)
        else:
            p["mlp"] = _mlp_init(ks[2], cfg, dtype)
    return p


def _layer_specs(kind: str, cfg: ModelConfig, with_cross=False,
                 pattern_pos: int = 0):
    norm_spec = {k: pspec(None) for k in
                 (("scale", "bias") if cfg.norm == "layernorm" else ("scale",))}
    p: Dict[str, Any] = {"norm1": dict(norm_spec)}
    if kind.startswith("attn"):
        p["attn"] = _attn_specs(cfg)
    elif kind == "mamba":
        p["mamba"] = mamba_param_specs(cfg)
    elif kind == "rglru":
        p["rglru"] = rglru_param_specs(cfg)
    if with_cross:
        p["norm_cross"] = dict(norm_spec)
        p["cross"] = _attn_specs(cfg)
    if cfg.family != "ssm":
        p["norm2"] = dict(norm_spec)
        if _use_moe(cfg, pattern_pos):
            p["moe"] = moe_param_specs(cfg)
        else:
            p["mlp"] = _mlp_specs(cfg)
    return p


# ---- model init -----------------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> Dict:
    dtype = dtype_of(cfg.param_dtype)
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": embed_init(keys[0], cfg.vocab, cfg.d_model, dtype),
        "final_norm": norm_init(cfg.norm, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(keys[1], cfg.d_model, cfg.vocab, dtype)

    def stacked(key, pattern, reps, with_cross=False):
        seg = {}
        for pi, kind in enumerate(pattern):
            lkeys = jax.random.split(jax.random.fold_in(key, pi), reps)
            leaves = [_layer_init(k, kind, cfg, dtype, with_cross,
                                  pattern_pos=pi) for k in lkeys]
            seg[f"pos{pi}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *leaves)
        return seg

    params["segments"] = {
        f"seg{si}": stacked(jax.random.fold_in(keys[2], si), pat, reps,
                            with_cross=cfg.is_encdec)
        for si, (pat, reps) in enumerate(segments(cfg))
    }
    if cfg.is_encdec:
        params["enc"] = {
            "pos_embed": embed_init(keys[3], cfg.enc_seq, cfg.d_model, dtype),
            "segments": {"seg0": {
                "pos0": jax.tree.map(
                    lambda *xs: jnp.stack(xs),
                    *[_layer_init(k, "attn_bidir", cfg, dtype)
                      for k in jax.random.split(keys[4], cfg.n_enc_layers)])}},
            "final_norm": norm_init(cfg.norm, cfg.d_model, dtype),
        }
        params["dec_pos_embed"] = embed_init(keys[5], 32_768, cfg.d_model,
                                             dtype)
    if cfg.img_tokens:
        params["img_proj"] = dense_init(keys[6], cfg.d_model, cfg.d_model,
                                        dtype)
    return params


def param_pspecs(cfg: ModelConfig) -> Dict:
    specs: Dict[str, Any] = {
        "embed": pspec("model", FSDP),
        "final_norm": {k: pspec(None) for k in
                       (("scale", "bias") if cfg.norm == "layernorm"
                        else ("scale",))},
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = pspec(FSDP, "model")
    norm_spec = specs["final_norm"]

    def seg_specs(pattern, with_cross=False):
        return {f"pos{pi}": _layer_specs(kind, cfg, with_cross,
                                         pattern_pos=pi)
                for pi, kind in enumerate(pattern)}

    specs["segments"] = {
        f"seg{si}": seg_specs(pat, with_cross=cfg.is_encdec)
        for si, (pat, _) in enumerate(segments(cfg))
    }
    if cfg.is_encdec:
        specs["enc"] = {
            "pos_embed": pspec(None, FSDP),
            "segments": {"seg0": seg_specs(("attn_bidir",))},
            "final_norm": dict(norm_spec),
        }
        specs["dec_pos_embed"] = pspec(None, FSDP)
    if cfg.img_tokens:
        specs["img_proj"] = pspec(FSDP, "model")
    if sharding_mode() == "fsdp":
        # ZeRO-3: every >=2D parameter fully sharded on dim 0 over ALL mesh
        # axes (gathered per layer inside the step); 1D tensors replicated.
        # Activations are sequence-parallel instead of head-parallel (SEQ/
        # HEADS sentinels in the in-graph constraints).
        all_ax = ("pod", "data", "model")

        def to_fsdp(s: P) -> P:
            entries = tuple(s)
            if len(entries) < 2:
                return pspec(None) if entries else s
            return pspec(all_ax, *([None] * (len(entries) - 1)))

        specs = jax.tree.map(to_fsdp, specs,
                             is_leaf=lambda x: isinstance(x, P))

    # stacked leaves keep layer axis unsharded: prepend None
    def add_layer_axis(tree):
        return jax.tree.map(lambda s: P(*((None,) + tuple(s))), tree)
    specs["segments"] = add_layer_axis(specs["segments"])
    if cfg.is_encdec:
        specs["enc"]["segments"] = add_layer_axis(specs["enc"]["segments"])
    return specs


# ---- layer application --------------------------------------------------------------------------------

def _attn_apply(p, x, cfg: ModelConfig, kind: str, q_pos, cache=None,
                kv_src=None, impl="auto"):
    """Returns (out, new_cache).  ``kv_src``: (states, positions) to project
    K/V from — cross-attention to the encoder (bidirectional, no rope)."""
    B, S, D = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = (x @ p["wq"] + (p.get("bq", 0))).reshape(B, S, hq, hd)
    q = shard(q, BATCH, SEQ, HEADS, None)
    window = cfg.attn_window if kind == "attn_local" else 0
    chunk = cfg.attn_chunk if kind == "attn_chunk" else 0
    causal = kind not in ("attn_bidir", "attn_cross")
    rope = kind not in ("attn_bidir", "attn_cross") and not cfg.is_encdec

    if kv_src is not None:
        src, k_pos = kv_src
        T = src.shape[1]
        k = (src @ p["wk"]).reshape(B, T, hkv, hd)
        v = (src @ p["wv"]).reshape(B, T, hkv, hd)
        new_cache = cache
    else:
        k = (x @ p["wk"] + (p.get("bk", 0))).reshape(B, S, hkv, hd)
        v = (x @ p["wv"] + (p.get("bv", 0))).reshape(B, S, hkv, hd)
        k = shard(k, BATCH, SEQ, HEADS, None)
        v = shard(v, BATCH, SEQ, HEADS, None)
        if rope:
            q = apply_rope(q, q_pos[None], fraction=cfg.rope_fraction,
                           theta=cfg.rope_theta)
            k = apply_rope(k, q_pos[None], fraction=cfg.rope_fraction,
                           theta=cfg.rope_theta)
        if cache is None:
            k_pos = q_pos
            new_cache = None
        else:
            L_buf = cache["k"].shape[1]
            # rolling write; if this call covers more than the buffer, only
            # the last L_buf tokens matter (S and L_buf are static)
            kw, vw, pw = k, v, q_pos
            if S > L_buf:
                kw, vw, pw = k[:, -L_buf:], v[:, -L_buf:], q_pos[-L_buf:]
            slots = jnp.mod(pw, L_buf)
            ck = cache["k"].at[:, slots].set(kw.astype(cache["k"].dtype))
            cv = cache["v"].at[:, slots].set(vw.astype(cache["v"].dtype))
            kpos = cache["kpos"].at[slots].set(pw)
            new_cache = {"k": ck, "v": cv, "kpos": kpos}
            if S > 1:
                # prefill: attend over the full current K/V (the rolling
                # buffers only retain the tail for future decode steps)
                k_pos = q_pos
            else:
                k, v, k_pos = ck, cv, kpos

    out = attn_lib.attention(q, k.astype(q.dtype), v.astype(q.dtype),
                             q_pos, k_pos, causal=causal, window=window,
                             chunk=chunk, softcap=cfg.attn_logit_softcap,
                             impl=impl, unroll=cfg.exact_costs)
    out = out.reshape(B, S, hq * hd) @ p["wo"]
    return out, new_cache


def _mlp_apply(p, x, cfg):
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    elif cfg.mlp == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        h = jax.nn.gelu(x @ p["w_up"])
    h = shard(h, BATCH, SEQ, HEADS)
    return h @ p["w_down"]


def _layer_apply(p, x, cfg: ModelConfig, kind: str, q_pos, cache=None,
                 enc_kv=None, impl="auto"):
    """Pre-norm residual layer.  Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = norm_apply(cfg.norm, p["norm1"], x)
    if kind.startswith("attn"):
        mix, new_cache = _attn_apply(p["attn"], h, cfg, kind, q_pos,
                                     cache=None if cache is None
                                     else cache.get("attn"), impl=impl)
    elif kind == "mamba":
        if cache is None:
            mix = mamba_mix(p["mamba"], h, cfg)
            new_cache = None
        else:
            mix, (st, hist) = mamba_mix(
                p["mamba"], h, cfg, state=cache["ssm"], conv_hist=cache["conv"],
                return_state=True)
            new_cache = {"ssm": st, "conv": hist}
    elif kind == "rglru":
        if cache is None:
            mix = rglru_mix(p["rglru"], h, cfg)
            new_cache = None
        else:
            mix, (st, hist) = rglru_mix(
                p["rglru"], h, cfg, state=cache["h"], conv_hist=cache["conv"],
                return_state=True)
            new_cache = {"h": st, "conv": hist}
    else:
        raise ValueError(kind)
    if kind.startswith("attn") and cache is not None:
        new_cache = {"attn": new_cache}
    x = x + mix
    if "cross" in p and enc_kv is not None:
        h = norm_apply(cfg.norm, p["norm_cross"], x)
        mix, _ = _attn_apply(p["cross"], h, cfg, "attn_cross", q_pos,
                             kv_src=enc_kv, impl=impl)
        x = x + mix
    if cfg.family != "ssm":
        h = norm_apply(cfg.norm, p["norm2"], x)
        if "moe" in p:
            mlp_out, aux = moe_apply(p["moe"], h, cfg)
        else:
            mlp_out = _mlp_apply(p["mlp"], h, cfg)
        x = x + mlp_out
    x = shard(x, BATCH, SEQ, None)
    return x, new_cache, aux


def _remat_wrap(fn, cfg):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "selective":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn


def _run_segments(params_segs, x, cfg, seg_list, q_pos, caches=None,
                  enc_kv=None, impl="auto"):
    """Apply all segments with lax.scan over each segment's repeat axis.
    Returns (x, new_caches, aux_total)."""
    aux_total = jnp.zeros((), jnp.float32)
    new_caches: Dict[str, Any] = {}
    for si, (pattern, reps) in enumerate(seg_list):
        seg_p = params_segs[f"seg{si}"]
        seg_c = None if caches is None else caches[f"seg{si}"]

        def body(carry, scanned):
            xx, aux = carry
            layer_p, layer_c = scanned
            new_c = {}
            for pi, kind in enumerate(pattern):
                cc = None if layer_c is None else layer_c[f"pos{pi}"]
                xx, nc, a = _layer_apply(layer_p[f"pos{pi}"], xx, cfg, kind,
                                         q_pos, cache=cc, enc_kv=enc_kv,
                                         impl=impl)
                new_c[f"pos{pi}"] = nc
                aux = aux + a
            return (xx, aux), new_c

        body = _remat_wrap(body, cfg)
        if not cfg.scan_layers:
            # unrolled: exact cost_analysis / collective counts (dry-run
            # cost-extrapolation mode) at the price of HLO size
            ncs = []
            for r in range(reps):
                take = lambda t: jax.tree.map(lambda a: a[r], t)
                (x, aux_total), nc = body(
                    (x, aux_total),
                    (take(seg_p), None if seg_c is None else take(seg_c)))
                ncs.append(nc)
            new_caches[f"seg{si}"] = None if seg_c is None else \
                jax.tree.map(lambda *xs: jnp.stack(xs), *ncs)
        elif seg_c is None:
            (x, aux_total), _ = jax.lax.scan(
                lambda c, s: (body(c, (s, None))[0], None),
                (x, aux_total), seg_p)
            new_caches[f"seg{si}"] = None
        else:
            (x, aux_total), nc = jax.lax.scan(
                lambda c, s: body(c, s), (x, aux_total), (seg_p, seg_c))
            new_caches[f"seg{si}"] = nc
    return x, new_caches, aux_total


# ---- encoder (whisper) -----------------------------------------------------------------------------------

def encode(params, cfg: ModelConfig, frames):
    """frames: (B, enc_seq, d_model) precomputed conv-stub embeddings."""
    enc = params["enc"]
    S = frames.shape[1]
    x = frames + enc["pos_embed"][None, :S].astype(frames.dtype)
    pos = jnp.arange(S)
    x, _, _ = _run_segments(enc["segments"], x,
                            dataclasses.replace(cfg, n_experts=0,
                                                is_encdec=False),
                            [(("attn_bidir",), cfg.n_enc_layers)], pos)
    return norm_apply(cfg.norm, enc["final_norm"], x)


def _enc_kv(params, cfg, enc_out):
    """Precompute cross-attention K/V once (shared by all decode steps)...
    projected per-layer inside the scan instead (weights differ per layer), so
    here we just package the encoder output."""
    S = enc_out.shape[1]
    return enc_out, jnp.arange(S)


# ---- public forward passes -------------------------------------------------------------------------------------

def _embed_tokens(params, cfg, tokens):
    x = jnp.take(params["embed"], tokens, axis=0)
    return shard(x, BATCH, SEQ, None)


def _unembed(params, cfg, x):
    x = norm_apply(cfg.norm, params["final_norm"], x)
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = (x @ w.astype(x.dtype)).astype(jnp.float32)
    return shard(logits, BATCH, SEQ, HEADS)


def forward(params, cfg: ModelConfig, batch, impl="auto"):
    """Training/prefill forward (no cache).  batch keys: tokens (B,S) [+
    img_embeds (B,N,D) | frames (B,T,D)].  Returns (logits, aux)."""
    tokens = batch["tokens"]
    x = _embed_tokens(params, cfg, tokens)
    if cfg.img_tokens:
        img = batch["img_embeds"].astype(x.dtype) @ params["img_proj"]
        x = jnp.concatenate([img, x], axis=1)
    enc_kv = None
    if cfg.is_encdec:
        enc_out = encode(params, cfg, batch["frames"].astype(x.dtype))
        enc_kv = (enc_out, jnp.arange(enc_out.shape[1]))
        S = x.shape[1]
        x = x + params["dec_pos_embed"][None, :S].astype(x.dtype)
    S = x.shape[1]
    pos = jnp.arange(S)
    x, _, aux = _run_segments(params["segments"], x, cfg, segments(cfg), pos,
                              enc_kv=enc_kv, impl=impl)
    return _unembed(params, cfg, x), aux


def loss_fn(params, cfg: ModelConfig, batch, impl="auto",
            aux_weight: float = 0.01):
    """Next-token cross-entropy (+ MoE aux).  Image positions are excluded
    via the label mask; labels: (B, S_text) aligned with batch['tokens']."""
    logits, aux = forward(params, cfg, batch, impl=impl)
    labels = batch["labels"]
    if cfg.img_tokens:                       # drop image positions
        logits = logits[:, cfg.img_tokens:]
    mask = batch.get("loss_mask")
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is not None:
        ll = ll * mask
        denom = jnp.maximum(mask.sum(), 1.0)
    else:
        denom = float(ll.shape[0] * ll.shape[1])
    loss = -(ll.sum() / denom)
    return loss + aux_weight * aux, {"ce": loss, "aux": aux}


# ---- caches / serving -----------------------------------------------------------------------------------

def _cache_buf_len(kind: str, cfg: ModelConfig, max_len: int) -> int:
    if kind == "attn_local":
        return min(2 * cfg.attn_window, max_len)   # rolling window buffer
    if kind == "attn_chunk":
        return min(cfg.attn_chunk, max_len)        # rolling chunk buffer
    return max_len                                 # full causal cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Dict:
    """Decode caches for every layer, stacked per segment like the params."""
    hkv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    caches: Dict[str, Any] = {}
    for si, (pattern, reps) in enumerate(segments(cfg)):
        seg = {}
        for pi, kind in enumerate(pattern):
            if kind.startswith("attn"):
                L = _cache_buf_len(kind, cfg, max_len)
                c = {"attn": {
                    "k": jnp.zeros((reps, batch, L, hkv, hd), dtype),
                    "v": jnp.zeros((reps, batch, L, hkv, hd), dtype),
                    "kpos": jnp.full((reps, L), -1, jnp.int32)}}
            elif kind == "mamba":
                c = {"ssm": jnp.zeros((reps, batch, cfg.d_inner,
                                       cfg.ssm_state), jnp.float32),
                     "conv": jnp.zeros((reps, batch, cfg.ssm_conv - 1,
                                        cfg.d_inner), dtype)}
            elif kind == "rglru":
                c = {"h": jnp.zeros((reps, batch, cfg.rnn_width),
                                    jnp.float32),
                     "conv": jnp.zeros((reps, batch, cfg.ssm_conv - 1,
                                        cfg.rnn_width), dtype)}
            else:
                c = {}
            seg[f"pos{pi}"] = c
        caches[f"seg{si}"] = seg
    return caches


def cache_pspecs(cfg: ModelConfig, *, shard_seq: bool = False) -> Dict:
    """Sharding for decode caches.

    Batch over (pod, data) when it divides; the KV-head dim over ``model``
    when the arch has enough KV heads, otherwise the *sequence* dim takes
    the model axis (flash-decoding-style distributed KV: XLA turns the
    softmax over the sharded sequence into partial reductions + a combine).
    ``shard_seq``: for global_batch==1 cells (long_500k) the sequence axis
    also absorbs the batch axes."""
    from repro.models.common import _axis_size
    msz = _axis_size("model")
    heads_shardable = msz > 1 and cfg.n_kv_heads % msz == 0
    batch_ax = None if shard_seq else BATCH
    seq_axes: list = list(a for a in ("pod", "data")) if shard_seq else []
    if not heads_shardable and msz > 1:
        seq_axes.append("model")
    seq_ax = tuple(seq_axes) if seq_axes else None
    head_ax = "model" if heads_shardable else None
    state_ax = tuple(seq_axes + (["model"] if heads_shardable else [])) \
        if shard_seq else "model"

    def spec_for(name):
        if name in ("k", "v"):
            return pspec(None, batch_ax, seq_ax, head_ax, None)
        if name == "kpos":
            return pspec(None, None)
        if name == "ssm":
            return pspec(None, batch_ax, state_ax, None)
        if name == "h":
            return pspec(None, batch_ax, state_ax)
        if name == "conv":
            return pspec(None, batch_ax, None, state_ax)
        return pspec()

    caches = init_cache_shapes(cfg, 1, 2)    # structure only
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: spec_for(path[-1].key), caches)


def init_cache_shapes(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len, dtype))


def prefill(params, cfg: ModelConfig, batch, max_len: int, impl="auto",
            cache_dtype=jnp.bfloat16):
    """Run the prompt, returning (last_logits, caches, enc_out?).

    Implemented as forward + bulk cache fill: K/V are recomputed per layer
    into the cache buffers during the pass (rolling buffers keep the tail)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    caches = init_cache(cfg, B, max_len, cache_dtype)
    x = _embed_tokens(params, cfg, tokens)
    if cfg.img_tokens:
        img = batch["img_embeds"].astype(x.dtype) @ params["img_proj"]
        x = jnp.concatenate([img, x], axis=1)
        S = x.shape[1]
    enc_kv = None
    if cfg.is_encdec:
        enc_out = encode(params, cfg, batch["frames"].astype(x.dtype))
        enc_kv = (enc_out, jnp.arange(enc_out.shape[1]))
        x = x + params["dec_pos_embed"][None, :S].astype(x.dtype)
    pos = jnp.arange(S)
    x, new_caches, _ = _run_segments(params["segments"], x, cfg,
                                     segments(cfg), pos, caches=caches,
                                     enc_kv=enc_kv, impl=impl)
    logits = _unembed(params, cfg, x[:, -1:])
    return logits, new_caches, enc_kv


def decode_step(params, cfg: ModelConfig, token, pos, caches, enc_kv=None,
                impl="auto"):
    """One token for the whole batch.  token: (B, 1) int32; pos: () int32.
    Returns (logits (B,1,V), new_caches)."""
    x = _embed_tokens(params, cfg, token)
    if cfg.is_encdec:
        x = x + jax.lax.dynamic_slice_in_dim(
            params["dec_pos_embed"], pos, 1, axis=0)[None].astype(x.dtype)
    q_pos = pos[None] if pos.ndim == 0 else pos
    x, new_caches, _ = _run_segments(params["segments"], x, cfg,
                                     segments(cfg), q_pos, caches=caches,
                                     enc_kv=enc_kv, impl=impl)
    return _unembed(params, cfg, x), new_caches


# ---- dry-run input specs ----------------------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape, dtype=jnp.bfloat16) -> Dict:
    """ShapeDtypeStructs for every model input of a (cfg, shape) cell —
    weak-type-correct, shardable, no allocation."""
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        batch = {"tokens": sds((B, S), jnp.int32),
                 "labels": sds((B, S), jnp.int32)}
        if cfg.img_tokens:
            batch["tokens"] = sds((B, S - cfg.img_tokens), jnp.int32)
            batch["labels"] = sds((B, S - cfg.img_tokens), jnp.int32)
            batch["img_embeds"] = sds((B, cfg.img_tokens, cfg.d_model), dtype)
        if cfg.is_encdec:
            batch["frames"] = sds((B, cfg.enc_seq, cfg.d_model), dtype)
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": sds((B, S), jnp.int32)}
        if cfg.img_tokens:
            batch["tokens"] = sds((B, S - cfg.img_tokens), jnp.int32)
            batch["img_embeds"] = sds((B, cfg.img_tokens, cfg.d_model), dtype)
        if cfg.is_encdec:
            batch["frames"] = sds((B, cfg.enc_seq, cfg.d_model), dtype)
        return batch
    # decode: one new token against a cache of length S
    batch = {"token": sds((B, 1), jnp.int32),
             "pos": sds((), jnp.int32),
             "caches": init_cache_shapes(cfg, B, S)}
    if cfg.is_encdec:
        batch["enc_out"] = sds((B, cfg.enc_seq, cfg.d_model), dtype)
    return batch
