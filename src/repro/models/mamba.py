"""Mamba-1 selective-SSM block (falcon-mamba-7b).

Follows Gu & Dao [arXiv:2312.00752]: in-projection to (x, z), causal
depthwise conv, input-dependent (dt, B, C), ZOH discretization
``dA = exp(dt*A)``, diagonal state scan, gated output.

Train path: chunked linear scan (``scan_ops``) — or the Pallas kernel via
``repro.kernels.mamba_scan`` on TPU.  Decode path: O(1) state update with a
rolling conv window.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import SEQ, HEADS, dense_init, pspec, shard
from repro.models.scan_ops import linear_scan_chunked


def mamba_init(key, cfg, dtype) -> Dict:
    d, di, n, r, k = (cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank,
                      cfg.ssm_conv)
    keys = jax.random.split(key, 6)
    dt = jnp.exp(jax.random.uniform(keys[4], (di,)) *
                 (math.log(0.1) - math.log(0.001)) + math.log(0.001))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))          # softplus^-1(dt)
    return {
        "in_proj": dense_init(keys[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(keys[1], (k, di)) / math.sqrt(k)
                   ).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(keys[2], di, r + 2 * n, dtype),
        "dt_proj": dense_init(keys[3], r, di, dtype, scale=r ** -0.5),
        "dt_bias": dt_bias.astype(jnp.float32),
        "a_log": jnp.log(jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32),
                                  (di, 1))),         # (di, n)
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(keys[5], di, d, dtype),
    }


def mamba_param_specs(cfg) -> Dict:
    fsdp = ("pod", "data")
    return {
        "in_proj": pspec(fsdp, "model"),
        "conv_w": pspec(None, "model"),
        "conv_b": pspec("model"),
        "x_proj": pspec("model", None),
        "dt_proj": pspec(None, "model"),
        "dt_bias": pspec("model"),
        "a_log": pspec("model", None),
        "d_skip": pspec("model"),
        "out_proj": pspec("model", fsdp),
    }


def _causal_conv(x, w, b, history: Optional[jnp.ndarray] = None):
    """Depthwise causal conv along time.  x: (B,S,Di), w: (K,Di).
    ``history``: (B, K-1, Di) left-context (decode rolling window)."""
    K = w.shape[0]
    if history is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([history.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    return out + b


def mamba_mix(params, x, cfg, *, state=None, conv_hist=None, return_state=False):
    """x: (B,S,D) -> (B,S,D).  With ``state``/``conv_hist`` given, continues
    from a decode cache; with ``return_state`` also returns the new cache."""
    B, S, D = x.shape
    di, n = cfg.d_inner, cfg.ssm_state
    xz = x @ params["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = shard(xin, ("pod", "data"), SEQ, HEADS)
    xc = jax.nn.silu(_causal_conv(xin, params["conv_w"], params["conv_b"],
                                  conv_hist))
    dbl = xc @ params["x_proj"]
    dt, bmat, cmat = jnp.split(dbl, [cfg.dt_rank, cfg.dt_rank + n], axis=-1)
    dt = jax.nn.softplus((dt @ params["dt_proj"]).astype(jnp.float32)
                         + params["dt_bias"])                     # (B,S,di)
    a = -jnp.exp(params["a_log"])                                 # (di,n)
    da = jnp.exp(dt[..., None] * a)                               # (B,S,di,n)
    dbx = (dt[..., None] * bmat[:, :, None, :].astype(jnp.float32)
           * xc[..., None].astype(jnp.float32))                   # (B,S,di,n)
    h0 = state if state is not None else jnp.zeros((B, di, n), jnp.float32)
    if S == 1:                                                    # decode fast path
        h_last = da[:, 0] * h0 + dbx[:, 0]
        hs = h_last[:, None]
    else:
        hs, h_last = linear_scan_chunked(da, dbx, h0, chunk=128,
                                         exact=cfg.exact_costs)
    y = jnp.einsum("bsdn,bsn->bsd", hs,
                   cmat.astype(jnp.float32))                      # (B,S,di)
    y = (y + params["d_skip"] * xc.astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = y @ params["out_proj"]
    if return_state:
        K = params["conv_w"].shape[0]
        if conv_hist is None:
            xin_pad = jnp.pad(xin, ((0, 0), (K - 1, 0), (0, 0)))
        else:
            xin_pad = jnp.concatenate([conv_hist.astype(xin.dtype), xin], 1)
        new_hist = xin_pad[:, -(K - 1):]
        return out, (h_last, new_hist)
    return out


def mamba_ref_sequential(params, x, cfg):
    """Step-by-step oracle (python loop over time) for tests."""
    B, S, D = x.shape
    state = jnp.zeros((B, cfg.d_inner, cfg.ssm_state), jnp.float32)
    hist = jnp.zeros((B, cfg.ssm_conv - 1, cfg.d_inner), x.dtype)
    outs = []
    for t in range(S):
        o, (state, hist) = mamba_mix(params, x[:, t:t + 1], cfg, state=state,
                                     conv_hist=hist, return_state=True)
        outs.append(o)
    return jnp.concatenate(outs, axis=1)
