"""Shared model building blocks: norms, RoPE, initializers, sharding helpers.

Everything is functional: params are nested dicts of jnp arrays; every block
is ``f(params, x, ...) -> y``.  Sharding is expressed with *logical* axis
names resolved against the active mesh — specs mention only axes the mesh
actually has, so the same model code runs on 1 CPU device, a 16x16 pod, or
the 2x16x16 multi-pod mesh.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


# ---- mesh-aware sharding helpers ------------------------------------------------------

import contextlib
import threading

_MESH_TLS = threading.local()

_RNG_FLAG_DONE = False


def ensure_sharding_invariant_rng() -> None:
    """Make ``jax.random`` draws identical under any ``out_shardings``.

    On jax 0.4.x ``jax_threefry_partitionable`` defaults to False, and the
    legacy threefry lowering produces *different* values when the same
    ``jax.random.normal`` is jitted with sharded vs replicated output
    (observed on jax 0.4.37: param init under ``out_shardings=P("model",
    None)`` diverges from the unsharded init by O(1), which then makes
    sharded-vs-single training losses drift ~5%).  The partitionable
    threefry lowering is value-identical across shardings (and became the
    default in jax 0.5); enabling it here — version-aware, once — restores
    the invariant every mesh-parameterized test relies on.
    """
    global _RNG_FLAG_DONE
    if _RNG_FLAG_DONE:
        return
    _RNG_FLAG_DONE = True
    try:
        if not jax.config.jax_threefry_partitionable:
            jax.config.update("jax_threefry_partitionable", True)
    except AttributeError:
        pass                 # flag removed (always-on) in newer jax


@contextlib.contextmanager
def use_mesh(mesh):
    """Enter ``mesh`` both as the JAX mesh context and for our logical-axis
    resolution.  All launchers/tests use this instead of a bare ``with mesh``.
    """
    ensure_sharding_invariant_rng()
    prev = getattr(_MESH_TLS, "mesh", None)
    _MESH_TLS.mesh = mesh
    try:
        with mesh:
            yield mesh
    finally:
        _MESH_TLS.mesh = prev


def current_mesh():
    return getattr(_MESH_TLS, "mesh", None)


def mesh_axis_names() -> Tuple[str, ...]:
    """Axis names of the mesh entered via :func:`use_mesh` (with fallbacks for
    a bare ``with mesh:`` context or explicit abstract meshes)."""
    mesh = current_mesh()
    if mesh is not None:
        return tuple(mesh.axis_names)
    get_abstract_mesh = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract_mesh is not None:        # added after jax 0.4.x
        env = get_abstract_mesh()
        if env is not None and env.axis_names:
            return tuple(env.axis_names)
    try:  # bare `with mesh:` (physical mesh context)
        phys = jax._src.mesh.thread_resources.env.physical_mesh
        if phys is not None and not phys.empty:
            return tuple(phys.axis_names)
    except Exception:
        pass
    return ()


def _resolve(entry, axes):
    if entry is None:
        return None
    if entry == SEQ:
        entry = "model" if sharding_mode() == "fsdp" else None
        return entry if entry in axes else None
    if entry == HEADS:
        entry = "model" if sharding_mode() == "tp" else None
        return entry if entry in axes else None
    if isinstance(entry, str):
        return entry if entry in axes else None
    # tuple of axis names: keep the ones present
    kept = tuple(a for a in entry if a in axes)
    return kept if kept else None


def pspec(*entries) -> P:
    """PartitionSpec mentioning only axes present in the active mesh.

    ``pspec(("pod", "data"), None, "model")`` -> P(("pod","data"), None,
    "model") on the multi-pod mesh, P("data", None, "model") on a single pod,
    P(None, None, None) on 1 CPU device.
    """
    axes = mesh_axis_names()
    return P(*[_resolve(e, axes) for e in entries])


BATCH = ("pod", "data")     # logical batch axes (composed where present)

# logical placeholders resolved per sharding mode:
#   tp   (default): HEADS -> "model" (Megatron TP), SEQ -> unsharded
#   fsdp          : HEADS -> unsharded, SEQ -> "model" (sequence-parallel
#                   activations; params ZeRO-3-sharded over all axes)
SEQ = "__seq__"
HEADS = "__heads__"

_MODE_TLS = threading.local()


def set_sharding_mode(mode: str):
    assert mode in ("tp", "fsdp")
    _MODE_TLS.mode = mode


def sharding_mode() -> str:
    return getattr(_MODE_TLS, "mode", "tp")


def _axis_size(name: str) -> int:
    mesh = current_mesh()
    if mesh is None:
        return 1
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def shard(x, *entries):
    """with_sharding_constraint with mesh-filtered axes (no-op off-mesh).

    Axes that do not divide the corresponding dimension are dropped (e.g.
    batch=1 decode cells cannot shard batch over data — the spec silently
    falls back to replication on that dim)."""
    if not mesh_axis_names():
        return x
    spec = pspec(*entries)
    fixed = []
    for dim, entry in zip(x.shape, tuple(spec) + (None,) * (x.ndim - len(spec))):
        if entry is None:
            fixed.append(None)
            continue
        names = (entry,) if isinstance(entry, str) else tuple(entry)
        total = 1
        for n in names:
            total *= _axis_size(n)
        fixed.append(entry if total and dim % total == 0 else None)
    return jax.lax.with_sharding_constraint(x, P(*fixed))


# ---- numerics ---------------------------------------------------------------------------

def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


def rmsnorm(w, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def layernorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(dt)


def norm_apply(kind: str, params, x):
    if kind == "rmsnorm":
        return rmsnorm(params["scale"], x)
    return layernorm(params, x)


def norm_init(kind: str, d: int, dtype) -> dict:
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


# ---- initializers ----------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


# ---- rotary position embeddings -------------------------------------------------------------------

def rope_freqs(head_dim: int, fraction: float, theta: float):
    """Frequencies for (possibly partial) rotary embedding."""
    rot = int(head_dim * fraction) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv, rot


def apply_rope(x, positions, *, fraction: float = 1.0,
               theta: float = 10_000.0):
    """x: (..., S, H, head_dim); positions: broadcastable to (..., S)."""
    head_dim = x.shape[-1]
    inv, rot = rope_freqs(head_dim, fraction, theta)
    if rot == 0:
        return x
    ang = positions[..., :, None].astype(jnp.float32) * inv    # (..., S, rot/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[..., :, None, :]                                 # (..., S, 1, rot/2)
    cos = cos[..., :, None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., : rot // 2], xr[..., rot // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


# ---- activations ------------------------------------------------------------------------------------

def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": partial(jax.nn.gelu, approximate=True),
            "relu": jax.nn.relu}[name]
