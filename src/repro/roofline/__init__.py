from repro.roofline.analysis import (HW, RooflineTerms, collective_bytes,
                                     roofline_from_artifact)

__all__ = ["HW", "RooflineTerms", "collective_bytes",
           "roofline_from_artifact"]
