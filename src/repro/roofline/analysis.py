"""Three-term roofline from a compiled dry-run artifact.

    compute    = HLO_FLOPs   / (chips x peak_FLOP/s)
    memory     = HLO_bytes   / (chips x HBM_bw)
    collective = coll_bytes  / (chips x link_bw)

``cost_analysis()`` supplies FLOPs and bytes.  Collective bytes are *not*
there — we parse the optimized HLO text and sum the result-shape bytes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op (per-chip traffic, since SPMD HLO shapes are
per-device).  Hardware constants: TPU v5e-class — 197 TFLOP/s bf16, 819 GB/s
HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, Optional

# bytes per element for HLO dtypes we may meet
_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %all-reduce.5 = bf16[16,512,128]{2,1,0} all-reduce(...)
_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^ ]*)\s+(" +
    "|".join(_COLLECTIVES) + r")[.\s(]")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


@dataclass(frozen=True)
class HW:
    """Per-chip peaks (TPU v5e-class)."""
    peak_flops: float = 197e12        # bf16 FLOP/s
    hbm_bw: float = 819e9             # bytes/s
    ici_bw: float = 50e9              # bytes/s/link
    hbm_bytes: float = 16e9           # capacity


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Result-shape bytes per collective kind (per-device traffic proxy)."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for m in _OP_RE.finditer(hlo_text):
        tuple_part, dtype, dims, kind = m.groups()
        if tuple_part is not None:
            total = sum(_shape_bytes(dt, dm)
                        for dt, dm in _SHAPE_RE.findall(tuple_part))
        else:
            total = _shape_bytes(dtype, dims)
        out[kind] += total
        out["count"] += 1
    return out


@dataclass(frozen=True)
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    bytes_hbm: float
    bytes_coll: float
    chips: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Perfect-overlap lower bound: the max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """How much of the bound step time is the dominant (useful) term —
        1.0 means perfectly bound by the dominant resource."""
        t = self.step_time_s
        return self.compute_s / t if t > 0 else 0.0


def roofline_from_artifact(art: Dict, hw: HW = HW()) -> RooflineTerms:
    """``art``: one dry-run artifact (see launch/dryrun.py).

    cost_analysis numbers on SPMD-partitioned modules are per-device; the
    collective parse is per-device too, so no extra division by chips —
    ``chips`` is retained for reporting.
    """
    chips = art["chips"]
    flops = float(art["cost"].get("flops", 0.0))
    bts = float(art["cost"].get("bytes accessed", 0.0))
    coll = float(sum(v for k, v in art["collectives"].items()
                     if k != "count"))
    return RooflineTerms(
        compute_s=flops / hw.peak_flops,
        memory_s=bts / hw.hbm_bw,
        collective_s=coll / hw.ici_bw,
        flops=flops, bytes_hbm=bts, bytes_coll=coll, chips=chips)
