"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def attention_ref(q, k, v, *, causal=True, window=0, chunk=0, q_offset=0):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D) -> (B, Hq, Sq, D)."""
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    k = jnp.repeat(k, G, axis=1)
    v = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    q_pos = q_offset + jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    if chunk:
        mask &= (k_pos // chunk) == (q_pos // chunk)
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)
