"""jit'd public wrapper around the flash-attention Pallas kernel.

Takes model-layout tensors (B, S, H, D) and handles transposition, GQA and
block-size selection.  ``interpret=True`` runs the kernel body on CPU (how
this container validates it); on TPU leave it False.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_kernel


@partial(jax.jit, static_argnames=("causal", "window", "chunk", "q_offset",
                                   "block_q", "block_kv", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, chunk=0, q_offset=0,
                    block_q=128, block_kv=128, interpret=False):
    """q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D) -> (B, Sq, Hq, D)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention_kernel(qt, kt, vt, causal=causal, window=window,
                                 chunk=chunk, q_offset=q_offset,
                                 block_q=block_q, block_kv=block_kv,
                                 interpret=interpret)
    return out.transpose(0, 2, 1, 3)
