"""Flash-attention Pallas TPU kernel (FlashAttention [arXiv:2205.14135]
re-blocked for the TPU memory hierarchy).

Grid: (batch, q_heads, q_blocks, kv_blocks) with the kv axis sequential
("arbitrary") — the online-softmax state (m, l, acc) lives in VMEM scratch
across kv steps, exactly the paper's receptive-field tiling re-derived for
VMEM: a (block_q x d) query tile stays resident while (block_kv x d) K/V
tiles stream through.

Supports causal, sliding-window, chunked-local masking and GQA (K/V block
index maps fold q_head -> kv_head), plus a query position offset for
cache-relative decode.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams


NEG_INF = -2.0 ** 30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale, block_q, block_kv, causal, window, chunk, q_offset,
                 kv_len):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                    # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)                    # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = (q_offset + qi * block_q
             + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0))
    k_pos = (ki * block_kv
             + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1))
    mask = k_pos < kv_len                                   # padding
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    if chunk:
        mask &= (k_pos // chunk) == (q_pos // chunk)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
    acc_scr[...] = (acc_scr[...] * corr[:, None]
                    + jax.lax.dot(p, v,
                                  preferred_element_type=jnp.float32))
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal=True, window=0, chunk=0,
                           q_offset=0, block_q=128, block_kv=128,
                           interpret=False):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D) -> (B, Hq, Sq, D).

    Sq/Skv are padded to block multiples; padded keys are masked via
    ``kv_len``; padded queries produce garbage rows the wrapper slices off.
    """
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    block_q = min(block_q, max(Sq, 8))
    block_kv = min(block_kv, max(Skv, 8))

    pq = -Sq % block_q
    pk = -Skv % block_kv
    kv_len = Skv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nq = q.shape[2] // block_q
    nk = k.shape[2] // block_kv

    grid = (B, Hq, nq, nk)
    kernel = functools.partial(
        _attn_kernel, scale=scale, block_q=block_q, block_kv=block_kv,
        causal=causal, window=window, chunk=chunk, q_offset=q_offset,
        kv_len=kv_len)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, nq * block_q, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # m
            pltpu.VMEM((block_q,), jnp.float32),      # l
            pltpu.VMEM((block_q, D), jnp.float32),    # acc
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq]
