"""Pallas TPU kernels for the compute hot-spots.

Each kernel lives in its own subpackage with the required trio:
``kernel.py`` (pl.pallas_call + BlockSpec VMEM tiling), ``ops.py`` (jit'd
wrapper), ``ref.py`` (pure-jnp oracle).  All kernels are TPU-target and
validated on CPU with ``interpret=True``.

The BlockSpec tile sizes are the TPU re-derivation of the paper's
receptive-field rule: the largest tile whose fused working set fits VMEM,
MXU-aligned (multiples of 128).
"""
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.mamba_scan.ops import mamba_scan
from repro.kernels.rglru_scan.ops import rglru_scan
from repro.kernels.rmsnorm.ops import fused_rmsnorm

__all__ = ["flash_attention", "mamba_scan", "rglru_scan", "fused_rmsnorm"]
