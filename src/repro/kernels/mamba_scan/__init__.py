from repro.kernels.mamba_scan.ops import mamba_scan

__all__ = ["mamba_scan"]
