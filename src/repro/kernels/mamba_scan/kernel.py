"""Mamba selective-scan Pallas TPU kernel.

Computes, per channel d and state n:
    h[t] = da[t] * h[t-1] + dbx[t]
    y[t] = sum_n h[t, n] * c[t, n]

This is the hardware-aware scan of Mamba [arXiv:2312.00752] re-blocked for
TPU: the (B, S, Di, N) discretized coefficients never materialize in HBM at
full sequence length per block — the grid streams (ts x blk x N) tiles
through VMEM with the recurrent state h (blk x N, fp32) resident in scratch
across sequential time steps.  Channel blocks are independent ("parallel");
the time axis is "arbitrary" (sequential).

Grid: (B, Di/blk, S/ts).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams



def _scan_kernel(da_ref, dbx_ref, c_ref, y_ref, h_scr, *, ts):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    da = da_ref[0].astype(jnp.float32)       # (ts, blk, N)
    dbx = dbx_ref[0].astype(jnp.float32)     # (ts, blk, N)
    c = c_ref[0].astype(jnp.float32)         # (ts, N)

    def step(t, h):
        h = da[t] * h + dbx[t]               # (blk, N)
        y_ref[0, t] = jnp.sum(h * c[t][None, :], axis=-1).astype(y_ref.dtype)
        return h

    h_scr[...] = jax.lax.fori_loop(0, ts, step, h_scr[...])


def mamba_scan_kernel(da, dbx, c, *, block_d=128, time_chunk=128,
                      interpret=False):
    """da, dbx: (B, S, Di, N); c: (B, S, N) -> y (B, S, Di).

    S must be a multiple of ``time_chunk`` and Di of ``block_d`` (the ops
    wrapper pads; padded channels are sliced off, padded time steps carry
    da=0/dbx=0 so the state is simply re-zeroed past the end).
    """
    B, S, Di, N = da.shape
    block_d = min(block_d, Di)
    time_chunk = min(time_chunk, S)
    assert S % time_chunk == 0 and Di % block_d == 0
    grid = (B, Di // block_d, S // time_chunk)
    kernel = functools.partial(_scan_kernel, ts=time_chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, time_chunk, block_d, N),
                         lambda b, d, t: (b, t, d, 0)),
            pl.BlockSpec((1, time_chunk, block_d, N),
                         lambda b, d, t: (b, t, d, 0)),
            pl.BlockSpec((1, time_chunk, N), lambda b, d, t: (b, t, 0)),
        ],
        out_specs=pl.BlockSpec((1, time_chunk, block_d),
                               lambda b, d, t: (b, t, d)),
        out_shape=jax.ShapeDtypeStruct((B, S, Di), da.dtype),
        scratch_shapes=[pltpu.VMEM((block_d, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(da, dbx, c)
