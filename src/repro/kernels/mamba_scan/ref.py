"""Pure-jnp sequential oracle for the mamba scan kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mamba_scan_ref(da, dbx, c):
    """da, dbx: (B, S, Di, N); c: (B, S, N) -> y (B, S, Di)."""
    B, S, Di, N = da.shape

    def step(h, inp):
        da_t, dbx_t, c_t = inp
        h = da_t * h + dbx_t                       # (B, Di, N)
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    h0 = jnp.zeros((B, Di, N), jnp.float32)
    xs = (da.swapaxes(0, 1).astype(jnp.float32),
          dbx.swapaxes(0, 1).astype(jnp.float32),
          c.swapaxes(0, 1).astype(jnp.float32))
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1).astype(da.dtype)
