"""jit'd wrapper: pads (S, Di) to block multiples and dispatches the kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.mamba_scan.kernel import mamba_scan_kernel


@partial(jax.jit, static_argnames=("block_d", "time_chunk", "interpret"))
def mamba_scan(da, dbx, c, *, block_d=128, time_chunk=128, interpret=False):
    B, S, Di, N = da.shape
    ps = -S % min(time_chunk, S) if S >= time_chunk else time_chunk - S
    pd = -Di % min(block_d, Di) if Di >= block_d else block_d - Di
    if S < time_chunk:
        ps = time_chunk - S
    if Di < block_d:
        pd = block_d - Di
    if ps or pd:
        pad4 = ((0, 0), (0, ps), (0, pd), (0, 0))
        da = jnp.pad(da, pad4)
        dbx = jnp.pad(dbx, pad4)
        c = jnp.pad(c, ((0, 0), (0, ps), (0, 0)))
    y = mamba_scan_kernel(da, dbx, c, block_d=block_d,
                          time_chunk=time_chunk, interpret=interpret)
    return y[:, :S, :Di]
