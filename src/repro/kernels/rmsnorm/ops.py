"""jit'd wrapper for the fused RMSNorm kernel (flattens leading dims)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.rmsnorm.kernel import rmsnorm_kernel


@partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def fused_rmsnorm(x, w, residual=None, *, eps=1e-6, block_rows=256,
                  interpret=False):
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    r2 = None if residual is None else residual.reshape(-1, shape[-1])
    out = rmsnorm_kernel(x2, w, r2, eps=eps, block_rows=block_rows,
                         interpret=interpret)
    if residual is None:
        return out.reshape(shape)
    y, res = out
    return y.reshape(shape), res.reshape(shape)
