"""Fused RMSNorm (+ optional residual-add) Pallas TPU kernel.

One HBM round-trip instead of three (add, mean-square, scale): a (block_rows
x D) tile is normalized entirely in VMEM.  Grid: (rows/block,).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams



def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps) * w_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def _rmsnorm_residual_kernel(x_ref, r_ref, w_ref, o_ref, res_ref, *, eps):
    x = x_ref[...].astype(jnp.float32) + r_ref[...].astype(jnp.float32)
    res_ref[...] = x.astype(res_ref.dtype)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps) * w_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm_kernel(x, w, residual=None, *, eps=1e-6, block_rows=256,
                   interpret=False):
    """x: (N, D), w: (D,); residual: optional (N, D) added before the norm.
    Returns y, or (y, x+residual) when residual is given."""
    N, D = x.shape
    block_rows = min(block_rows, N)
    pad = -N % block_rows
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        if residual is not None:
            residual = jnp.pad(residual, ((0, pad), (0, 0)))
    grid = ((N + pad) // block_rows,)
    row_spec = pl.BlockSpec((block_rows, D), lambda i: (i, 0))
    w_spec = pl.BlockSpec((D,), lambda i: (0,))
    if residual is None:
        out = pl.pallas_call(
            functools.partial(_rmsnorm_kernel, eps=eps),
            grid=grid, in_specs=[row_spec, w_spec], out_specs=row_spec,
            out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            compiler_params=_CompilerParams(
                dimension_semantics=("parallel",)),
            interpret=interpret)(x, w)
        return out[:N]
    out, res = pl.pallas_call(
        functools.partial(_rmsnorm_residual_kernel, eps=eps),
        grid=grid, in_specs=[row_spec, row_spec, w_spec],
        out_specs=(row_spec, row_spec),
        out_shape=(jax.ShapeDtypeStruct(x.shape, x.dtype),
                   jax.ShapeDtypeStruct(x.shape, x.dtype)),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret)(x, residual, w)
    return out[:N], res[:N]
