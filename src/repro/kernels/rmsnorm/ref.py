"""Pure-jnp oracle for the fused RMSNorm kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_ref(x, w, residual=None, *, eps=1e-6):
    if residual is not None:
        x = x.astype(jnp.float32) + residual.astype(jnp.float32)
        res = x
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, -1, keepdims=True) + eps)
    y = (y * w.astype(jnp.float32))
    if residual is not None:
        return y, res
    return y
