from repro.kernels.rglru_scan.ops import rglru_scan

__all__ = ["rglru_scan"]
