"""RG-LRU gated linear recurrence Pallas TPU kernel (Griffin
[arXiv:2402.19427]): h[t] = a[t] * h[t-1] + b[t], elementwise over the
recurrent width.  Width blocks are parallel; time is sequential with the
state vector resident in VMEM scratch.

Grid: (B, W/blk, S/ts).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams



def _rglru_kernel(a_ref, b_ref, h_ref, h_scr, *, ts):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[0].astype(jnp.float32)          # (ts, blk)
    b = b_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t] * h + b[t]
        h_ref[0, t] = h.astype(h_ref.dtype)
        return h

    h_scr[...] = jax.lax.fori_loop(0, ts, step, h_scr[...])


def rglru_scan_kernel(a, b, *, block_w=128, time_chunk=256, interpret=False):
    """a, b: (B, S, W) -> h (B, S, W); S % time_chunk == 0, W % block_w == 0."""
    B, S, W = a.shape
    block_w = min(block_w, W)
    time_chunk = min(time_chunk, S)
    assert S % time_chunk == 0 and W % block_w == 0
    grid = (B, W // block_w, S // time_chunk)
    kernel = functools.partial(_rglru_kernel, ts=time_chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, time_chunk, block_w), lambda b_, w, t: (b_, t, w)),
            pl.BlockSpec((1, time_chunk, block_w), lambda b_, w, t: (b_, t, w)),
        ],
        out_specs=pl.BlockSpec((1, time_chunk, block_w),
                               lambda b_, w, t: (b_, t, w)),
        out_shape=jax.ShapeDtypeStruct((B, S, W), a.dtype),
        scratch_shapes=[pltpu.VMEM((block_w,), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
