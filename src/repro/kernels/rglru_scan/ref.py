"""Pure-jnp oracle for the RG-LRU scan kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan_ref(a, b):
    """a, b: (B, S, W) -> h (B, S, W)."""
    def step(h, inp):
        a_t, b_t = inp
        h = a_t * h + b_t
        return h, h

    h0 = jnp.zeros((a.shape[0], a.shape[2]), jnp.float32)
    xs = (a.swapaxes(0, 1).astype(jnp.float32),
          b.swapaxes(0, 1).astype(jnp.float32))
    _, hs = jax.lax.scan(step, h0, xs)
    return hs.swapaxes(0, 1).astype(a.dtype)
