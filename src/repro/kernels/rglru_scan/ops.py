"""jit'd wrapper for the RG-LRU scan kernel (pads S and W to blocks)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.rglru_scan.kernel import rglru_scan_kernel


@partial(jax.jit, static_argnames=("block_w", "time_chunk", "interpret"))
def rglru_scan(a, b, *, block_w=128, time_chunk=256, interpret=False):
    B, S, W = a.shape
    ps = (time_chunk - S) if S < time_chunk else (-S % time_chunk)
    pw = (block_w - W) if W < block_w else (-W % block_w)
    if ps or pw:
        a = jnp.pad(a, ((0, 0), (0, ps), (0, pw)))
        b = jnp.pad(b, ((0, 0), (0, ps), (0, pw)))
    h = rglru_scan_kernel(a, b, block_w=block_w, time_chunk=time_chunk,
                          interpret=interpret)
    return h[:, :S, :W]
