"""Version shims shared by the pallas kernels.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` across
the 0.4.x/0.5.x series; resolve whichever this jax provides, once, here.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as _pltpu

CompilerParams = getattr(_pltpu, "CompilerParams", None) \
    or getattr(_pltpu, "TPUCompilerParams")
