"""The machine catalog: paper Table I plus beyond-paper machines, all
expressed as hierarchical :class:`~repro.hw.spec.HardwareSpec` descriptions.

Table I (at the paper's system setting — 200 MHz, LPDDR4 @ 128 GB/s,
16-bit words; Eyeriss carries the paper's modified 512 KiB weight buffer):

* ``eyeriss``   — 14x12 row-stationary array;
* ``simba``     — 4x4 weight-stationary PEs x 64 MAC lanes (one chiplet);
* ``simba2x2``  — 2x2 chiplets (8x8 PEs) with 4x the buffering.

Beyond Table I:

* ``simba4x4``  — 4x4 chiplets (16x16 PEs), the next scaling step of the
  paper's Fig. 10 simba2x2 point: 16x compute/buffers of one chiplet;
* ``flexnn``    — a FlexNN-style dataflow-flexible array (arXiv
  2403.09026): same datapath budget class as SIMBA, but the mapper picks
  row- vs weight-stationary per layer, recovering utilization on shapes
  that starve a fixed dataflow (depthwise convs on SIMBA, pointwise convs
  on Eyeriss).

``ALL_SPECS`` feeds the accelerator registry (``repro.search.registry``),
so every machine here — and any you register — composes with every
workload, cost model, and search backend.
"""
from __future__ import annotations

import math
from typing import Dict

from repro.hw.spec import ComputeArray, HardwareSpec, MemLevel


def _edge_machine(name: str, *, pe_x: int, pe_y: int, macs_per_pe: int,
                  act_kib: float, weight_kib: float, dataflow: str,
                  clock_mhz: float = 200.0,
                  dram_gbps: float = 128.0) -> HardwareSpec:
    """The paper's system template: LPDDR4 DRAM over split act/weight
    SRAMs over per-PE register files (energies derive from capacity)."""
    return HardwareSpec(
        name=name,
        compute=ComputeArray(pe_x=pe_x, pe_y=pe_y, macs_per_pe=macs_per_pe),
        levels=(
            MemLevel("dram", math.inf, bandwidth_gbps=dram_gbps),
            MemLevel("weight_buf", weight_kib),
            MemLevel("act_buf", act_kib),
            MemLevel("rf", 0.5),           # per-PE scratchpad, ~1 KiB class
        ),
        dataflow=dataflow,
        clock_mhz=clock_mhz)


# ---- paper Table I ----------------------------------------------------------------
EYERISS_HW = _edge_machine("eyeriss", pe_x=14, pe_y=12, macs_per_pe=1,
                           act_kib=128, weight_kib=512,
                           dataflow="row_stationary")
SIMBA_HW = _edge_machine("simba", pe_x=4, pe_y=4, macs_per_pe=64,
                         act_kib=64, weight_kib=512,
                         dataflow="weight_stationary")
SIMBA2X2_HW = _edge_machine("simba2x2", pe_x=8, pe_y=8, macs_per_pe=64,
                            act_kib=256, weight_kib=2048,
                            dataflow="weight_stationary")

# ---- beyond Table I ---------------------------------------------------------------
SIMBA4X4_HW = _edge_machine("simba4x4", pe_x=16, pe_y=16, macs_per_pe=64,
                            act_kib=1024, weight_kib=8192,
                            dataflow="weight_stationary")
FLEXNN_HW = _edge_machine("flexnn", pe_x=8, pe_y=8, macs_per_pe=16,
                          act_kib=128, weight_kib=512,
                          dataflow="flexible")

ALL_SPECS: Dict[str, HardwareSpec] = {
    s.name: s for s in (EYERISS_HW, SIMBA_HW, SIMBA2X2_HW,
                        SIMBA4X4_HW, FLEXNN_HW)
}


def get_spec(name: str) -> HardwareSpec:
    try:
        return ALL_SPECS[name]
    except KeyError:
        raise KeyError(
            f"unknown hardware spec {name!r}; valid: "
            + ", ".join(sorted(ALL_SPECS))) from None
