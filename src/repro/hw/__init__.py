"""``repro.hw`` — hierarchical hardware descriptions for the cost layer.

A machine is a :class:`HardwareSpec` (compute array + memory levels +
dataflow) instead of a flat dataclass; the catalog expresses paper Table I,
the Fig.-11 repartition variants, and beyond-paper machines (``simba4x4``,
the dataflow-flexible ``flexnn``) in it.  ``HardwareSpec.to_accelerator()``
yields the flat view the mappers consume — Table-I specs produce exactly
the legacy constants, so costs are bit-for-bit unchanged.
"""
from repro.hw.catalog import (ALL_SPECS, EYERISS_HW, FLEXNN_HW, SIMBA2X2_HW,
                              SIMBA4X4_HW, SIMBA_HW, get_spec)
from repro.hw.spec import (DATAFLOWS, ComputeArray, HardwareError,
                           HardwareSpec, MemLevel)

__all__ = [
    "ALL_SPECS", "ComputeArray", "DATAFLOWS", "EYERISS_HW", "FLEXNN_HW",
    "HardwareError", "HardwareSpec", "MemLevel", "SIMBA2X2_HW", "SIMBA4X4_HW",
    "SIMBA_HW", "get_spec",
]
