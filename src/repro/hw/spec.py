"""Hierarchical hardware descriptions: memory levels + compute array.

The paper's Table I machines (and anything else the search should target)
are described structurally instead of as a flat 9-field dataclass: a
:class:`HardwareSpec` is a compute array (spatial dims, MAC lanes, dataflow)
plus an ordered hierarchy of :class:`MemLevel` entries (capacity, bandwidth,
per-access energy).  The cost side consumes the flat
:class:`repro.costmodel.accelerator.Accelerator` view produced by
:meth:`HardwareSpec.to_accelerator`, so describing a machine here changes
*nothing* about how Table-I machines are costed — it changes how they are
*expressed*, which is what makes adding one a registration instead of a
fork (see ``repro.hw.catalog`` and the README's 20-line example).

Conventions:

* levels are ordered outermost -> innermost (``dram`` first);
* the fusion cost model requires three named levels: ``dram`` (off-chip,
  bandwidth-limited), ``act_buf`` and ``weight_buf`` (on-chip SRAMs whose
  capacities gate fused-tile feasibility and weight residency);
* ``energy_pj_per_word=None`` on an SRAM level means "derive from capacity"
  via the Accelergy-style banked-SRAM curve in
  :class:`repro.costmodel.energy.EnergyModel` — exactly what the flat
  machines did, so Table I round-trips bit-for-bit.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Any, Dict, Optional, Tuple

from repro.costmodel.accelerator import Accelerator

#: dataflows the mapper understands; ``flexible`` (FlexNN-style, arXiv
#: 2403.09026) lets the mapper pick the better-utilizing fixed dataflow
#: per layer.
DATAFLOWS = ("row_stationary", "weight_stationary", "flexible")

#: level names the fusion cost model requires (others are carried along
#: for documentation / future cost models but not consumed today)
REQUIRED_LEVELS = ("dram", "act_buf", "weight_buf")


class HardwareError(ValueError):
    """An inconsistent or incomplete hardware description."""


@dataclass(frozen=True)
class MemLevel:
    """One storage level of the hierarchy.

    ``capacity_kib`` is ``math.inf`` for off-chip DRAM; ``bandwidth_gbps``
    is 0 for on-chip levels that never bind (the array consumes them at
    wire speed); ``energy_pj_per_word=None`` derives the per-access energy
    from capacity (Accelergy-style banked-SRAM curve).
    """

    name: str
    capacity_kib: float
    bandwidth_gbps: float = 0.0
    energy_pj_per_word: Optional[float] = None

    def __post_init__(self) -> None:
        if not self.name:
            raise HardwareError("memory level needs a name")
        if not (self.capacity_kib > 0):          # also rejects NaN
            raise HardwareError(
                f"level {self.name!r}: capacity must be positive, "
                f"got {self.capacity_kib}")
        if self.bandwidth_gbps < 0:
            raise HardwareError(
                f"level {self.name!r}: bandwidth cannot be negative")
        if self.energy_pj_per_word is not None \
                and self.energy_pj_per_word <= 0:
            raise HardwareError(
                f"level {self.name!r}: per-access energy must be positive")


@dataclass(frozen=True)
class ComputeArray:
    """The spatial PE array: ``pe_x`` x ``pe_y`` PEs, each with
    ``macs_per_pe`` vector MAC lanes."""

    pe_x: int
    pe_y: int
    macs_per_pe: int = 1

    def __post_init__(self) -> None:
        for f in ("pe_x", "pe_y", "macs_per_pe"):
            if getattr(self, f) <= 0:
                raise HardwareError(f"ComputeArray.{f} must be positive")

    @property
    def pe_count(self) -> int:
        return self.pe_x * self.pe_y

    @property
    def peak_macs_per_cycle(self) -> int:
        return self.pe_count * self.macs_per_pe


@dataclass(frozen=True)
class HardwareSpec:
    """A whole machine: compute array + memory hierarchy + dataflow."""

    name: str
    compute: ComputeArray
    levels: Tuple[MemLevel, ...]
    dataflow: str
    clock_mhz: float = 200.0
    word_bytes: int = 2

    def __post_init__(self) -> None:
        object.__setattr__(self, "levels", tuple(self.levels))
        if self.dataflow not in DATAFLOWS:
            raise HardwareError(
                f"{self.name!r}: unknown dataflow {self.dataflow!r}; "
                f"valid: {', '.join(DATAFLOWS)}")
        if self.clock_mhz <= 0:
            raise HardwareError(f"{self.name!r}: clock must be positive")
        if self.word_bytes <= 0:
            raise HardwareError(f"{self.name!r}: word_bytes must be positive")
        seen = set()
        for lv in self.levels:
            if lv.name in seen:
                raise HardwareError(
                    f"{self.name!r}: duplicate memory level {lv.name!r}")
            seen.add(lv.name)
        missing = [n for n in REQUIRED_LEVELS if n not in seen]
        if missing:
            raise HardwareError(
                f"{self.name!r}: missing required memory level(s) "
                f"{', '.join(missing)} (have: {', '.join(sorted(seen))})")
        if not math.isinf(self.level("dram").capacity_kib) \
                and self.level("dram").capacity_kib < \
                self.level("act_buf").capacity_kib:
            raise HardwareError(
                f"{self.name!r}: dram smaller than the activation buffer")
        if self.level("dram").bandwidth_gbps <= 0:
            raise HardwareError(
                f"{self.name!r}: dram needs a positive bandwidth_gbps")

    # ---- lookups ---------------------------------------------------------------
    def level(self, name: str) -> MemLevel:
        for lv in self.levels:
            if lv.name == name:
                return lv
        raise HardwareError(
            f"{self.name!r} has no memory level {name!r}; have: "
            + ", ".join(lv.name for lv in self.levels))

    def has_level(self, name: str) -> bool:
        return any(lv.name == name for lv in self.levels)

    @property
    def onchip_capacity_kib(self) -> float:
        """Total on-chip buffer capacity (every finite-capacity level)."""
        return sum(lv.capacity_kib for lv in self.levels
                   if not math.isinf(lv.capacity_kib))

    # ---- derived views ---------------------------------------------------------
    def _whole_kib(self, level_name: str) -> int:
        """A buffer capacity as whole KiB (the flat view's unit); a
        fractional or sub-1-KiB value would silently truncate — refuse it
        instead (0-KiB buffers divide by zero in the mapper)."""
        cap = self.level(level_name).capacity_kib
        if cap != int(cap) or cap < 1:
            raise HardwareError(
                f"{self.name!r}: level {level_name!r} capacity must be a "
                f"whole KiB >= 1 for the flat accelerator view, got {cap}")
        return int(cap)

    def to_accelerator(self) -> Accelerator:
        """The flat view the mapper/evaluator consume.  Table-I specs
        produce exactly the legacy constants, so costs are unchanged."""
        dram = self.level("dram")
        return Accelerator(
            name=self.name,
            pe_x=self.compute.pe_x, pe_y=self.compute.pe_y,
            macs_per_pe=self.compute.macs_per_pe,
            act_buf_kib=self._whole_kib("act_buf"),
            weight_buf_kib=self._whole_kib("weight_buf"),
            dataflow=self.dataflow,
            clock_mhz=self.clock_mhz,
            dram_gbps=dram.bandwidth_gbps,
            word_bytes=self.word_bytes)

    # ---- transformations -------------------------------------------------------
    def repartition(self, act_delta_kib: float) -> "HardwareSpec":
        """Iso-capacity repartitioning (paper Fig. 11): move
        ``act_delta_kib`` KiB of weight buffer into the activation buffer
        (negative = the other way).  Total on-chip capacity is preserved;
        a partition that drives either buffer non-positive is refused
        (``MemLevel`` validation)."""
        act = self.level("act_buf")
        new_levels = tuple(
            replace(lv, capacity_kib=lv.capacity_kib + act_delta_kib)
            if lv.name == "act_buf" else
            replace(lv, capacity_kib=lv.capacity_kib - act_delta_kib)
            if lv.name == "weight_buf" else lv
            for lv in self.levels)
        return replace(
            self,
            name=f"{self.name}_act{int(act.capacity_kib + act_delta_kib)}k",
            levels=new_levels)

    def describe(self) -> str:
        """Human-readable one-machine summary (``repro list`` detail)."""
        rows = [f"{self.name}: {self.compute.pe_x}x{self.compute.pe_y} PEs "
                f"x {self.compute.macs_per_pe} MAC lanes, "
                f"{self.dataflow}, {self.clock_mhz:g} MHz"]
        for lv in self.levels:
            cap = ("inf" if math.isinf(lv.capacity_kib)
                   else f"{lv.capacity_kib:g} KiB")
            bw = f", {lv.bandwidth_gbps:g} GB/s" if lv.bandwidth_gbps else ""
            rows.append(f"  {lv.name:<11} {cap}{bw}")
        return "\n".join(rows)

    # ---- serialization ---------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "compute": {"pe_x": self.compute.pe_x,
                        "pe_y": self.compute.pe_y,
                        "macs_per_pe": self.compute.macs_per_pe},
            "levels": [{"name": lv.name,
                        "capacity_kib": (None if math.isinf(lv.capacity_kib)
                                         else lv.capacity_kib),
                        "bandwidth_gbps": lv.bandwidth_gbps,
                        "energy_pj_per_word": lv.energy_pj_per_word}
                       for lv in self.levels],
            "dataflow": self.dataflow,
            "clock_mhz": self.clock_mhz,
            "word_bytes": self.word_bytes,
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "HardwareSpec":
        return cls(
            name=d["name"],
            compute=ComputeArray(**d["compute"]),
            levels=tuple(
                MemLevel(name=lv["name"],
                         capacity_kib=(math.inf
                                       if lv.get("capacity_kib") is None
                                       else lv["capacity_kib"]),
                         bandwidth_gbps=lv.get("bandwidth_gbps", 0.0),
                         energy_pj_per_word=lv.get("energy_pj_per_word"))
                for lv in d["levels"]),
            dataflow=d["dataflow"],
            clock_mhz=d.get("clock_mhz", 200.0),
            word_bytes=d.get("word_bytes", 2))
