"""The ``repro`` CLI: run searches, serve batches, inspect artifacts, list
registries, export workload IR.

    repro search --workload mobilenet_v3 --accel simba --backend ga \\
        --out artifact.json
    repro search --workload file:model.json --backend ga   # bring your own
    repro submit --store schedules/ --workload mobilenet_v3 --backend island
    repro serve --store schedules/ --requests jobs.json --workers 4
    repro daemon --store schedules/ --port 8765 --workers 2
    repro jobs submit --workload vgg16 --wait [--warm-start] [--priority 5]
    repro jobs status 3 | repro jobs cancel 3 | repro jobs list
    repro store gc --store schedules/ --max-objects 500 [--dry-run]
    repro report artifact.json [--schedule] [--history]
    repro verify artifact.json | repro verify --store schedules/
    repro analyze mobilenet_v3 --accel simba [--json]
    repro trace trace.jsonl [--top 10] [--json]
    repro lint [paths...]
    repro export --workload mobilenet_v3@hw=160 --out model.json
    repro list [--json] [--store schedules/]

``--workload`` accepts every spec form (``name``, ``name@key=value,...``,
``file:model.json``); see ``repro.search.registry``.

(Also reachable as ``python -m repro ...`` with ``PYTHONPATH=src``.)
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional


def _add_spec_args(p) -> None:
    """Arguments that assemble one SearchSpec (shared by search/submit)."""
    p.add_argument("--workload", required=True,
                   help="workload spec: a registered name (see `repro "
                        "list`), name@key=value,... params, or "
                        "file:model.json GraphIR")
    p.add_argument("--workload-kwargs", default="{}", metavar="JSON",
                   help="builder kwargs, e.g. '{\"hw\": 128}' "
                        "(equivalent to @-params in --workload)")
    p.add_argument("--accelerator", "--accel", dest="accelerator",
                   default="simba",
                   help="accelerator (repro.hw catalog name), optionally "
                        "repartitioned (e.g. eyeriss@act+64)")
    p.add_argument("--objective", default="edp",
                   help="registered objective (edp|energy|cycles|dram|...)")
    p.add_argument("--backend", default="ga",
                   help="search backend (ga|island|random|hill_climb|"
                        "exhaustive|...)")
    p.add_argument("--costmodel", default="default",
                   help="cost backend scoring the schedules (default|tpu|...)")
    p.add_argument("--backend-config", default="{}", metavar="JSON",
                   help="backend options, e.g. '{\"islands\": 4}' "
                        "(knobs: `repro list`)")
    p.add_argument("--preset", choices=("paper", "fast"), default=None,
                   help="ga preset (paper: P=100 G=500; fast: CPU-friendly)")
    p.add_argument("--generations", type=int, default=None,
                   help="ga generations override")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--budget", type=int, default=None,
                   help="stop after this many offspring evaluations")
    p.add_argument("--patience", type=int, default=None,
                   help="stop after N backend steps without improvement "
                        "(ga: a step is one generation; island: one sync "
                        "barrier, i.e. up to ~10 generations; "
                        "random/exhaustive: one scoring chunk)")
    p.add_argument("--spacemap", action="store_true",
                   help="statically freeze provably forced-off genes and "
                        "factorize the space into regions before searching "
                        "(repro analyze shows the map; exhaustive then "
                        "enumerates per region)")
    p.add_argument("--telemetry", action="store_true",
                   help="record per-generation convergence telemetry and "
                        "embed the summary in the artifact (repro report "
                        "--telemetry renders it); never changes the search "
                        "result")


def _spec_from_args(args):
    """Build the SearchSpec an invocation of _add_spec_args describes."""
    from repro.search import SearchSpec

    backend_config = json.loads(args.backend_config)
    if args.preset is not None:
        backend_config.setdefault("preset", args.preset)
    if args.generations is not None:
        backend_config.setdefault("generations", args.generations)
    return SearchSpec(
        workload=args.workload, accelerator=args.accelerator,
        objective=args.objective, backend=args.backend,
        costmodel=args.costmodel, backend_config=backend_config,
        workload_kwargs=json.loads(args.workload_kwargs),
        seed=args.seed, budget=args.budget, patience=args.patience,
        spacemap=args.spacemap, telemetry=args.telemetry)


def _add_search_parser(sub) -> None:
    p = sub.add_parser(
        "search", help="run a schedule search and write a JSON artifact")
    _add_spec_args(p)
    p.add_argument("--out", default="artifact.json",
                   help="artifact path (default: artifact.json)")
    p.add_argument("--progress", type=int, default=0, metavar="N",
                   help="print progress every N backend steps")
    p.add_argument("--embed-ir", action="store_true",
                   help="embed the workload's GraphIR in the artifact "
                        "(self-contained report/rebind; automatic for "
                        "file: workloads)")
    p.add_argument("--trace", default=None, metavar="TRACE_JSONL",
                   help="stream span events to this JSONL file (implies "
                        "--telemetry; inspect with `repro trace`; "
                        "REPRO_TRACE=path is the env equivalent)")


def _add_export_parser(sub) -> None:
    p = sub.add_parser(
        "export", help="export a workload's canonical GraphIR JSON "
                       "(file: round-trips byte-identically)")
    p.add_argument("--workload", required=True,
                   help="workload spec (name, name@key=value, or "
                        "file:model.json)")
    p.add_argument("--workload-kwargs", default="{}", metavar="JSON",
                   help="builder kwargs, e.g. '{\"hw\": 128}'")
    p.add_argument("--out", default=None,
                   help="output path (default: <workload name>.json)")


def _add_submit_parser(sub) -> None:
    p = sub.add_parser(
        "submit", help="resolve one search request against a schedule "
                       "store: serve a stored artifact, or search and "
                       "store the result")
    _add_spec_args(p)
    p.add_argument("--store", required=True,
                   help="ArtifactStore directory (created if absent)")
    p.add_argument("--out", default=None,
                   help="also write the artifact JSON to this path")


def _add_serve_parser(sub) -> None:
    p = sub.add_parser(
        "serve", help="drain a batch of search requests against a schedule "
                      "store (dedup + cache + parallel search)")
    p.add_argument("--requests", required=True, metavar="JOBS_JSON",
                   help="JSON list of SearchSpec objects "
                        "(or {\"jobs\": [...]})")
    p.add_argument("--store", required=True,
                   help="ArtifactStore directory (created if absent)")
    p.add_argument("--workers", type=int, default=1,
                   help="parallel search processes for cache misses "
                        "(default 1 = inline)")
    p.add_argument("--json", action="store_true",
                   help="emit per-job outcomes + stats as JSON")


def _add_daemon_parser(sub) -> None:
    p = sub.add_parser(
        "daemon", help="run the always-on scheduling service: HTTP/JSON "
                       "API over a crash-safe persistent job queue "
                       "(journal replayed on restart) and the schedule "
                       "store")
    p.add_argument("--store", required=True,
                   help="ArtifactStore directory (created if absent; also "
                        "holds the queue journal)")
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=8765,
                   help="listen port (0 = pick a free one; default 8765)")
    p.add_argument("--workers", type=int, default=1,
                   help="worker threads draining the queue (default 1)")


def _add_jobs_parser(sub) -> None:
    p = sub.add_parser(
        "jobs", help="talk to a running `repro daemon`: submit / list / "
                     "status / wait / cancel")
    p.add_argument("--daemon", default="http://127.0.0.1:8765",
                   metavar="URL", help="daemon base URL "
                                       "(default http://127.0.0.1:8765)")
    js = p.add_subparsers(dest="jobs_command", required=True)
    ps = js.add_parser("submit", help="submit one search job")
    _add_spec_args(ps)
    ps.add_argument("--priority", type=int, default=0,
                    help="higher runs first (default 0)")
    ps.add_argument("--warm-start", action="store_true",
                    help="seed the GA population from the store's nearest "
                         "cached winner (opt-in; never changes the store "
                         "key)")
    ps.add_argument("--wait", action="store_true",
                    help="poll until the job resolves")
    ps.add_argument("--json", action="store_true")
    pl = js.add_parser("list", help="list every job the daemon knows")
    pl.add_argument("--json", action="store_true")
    pt = js.add_parser("status", help="one job's state + live progress")
    pt.add_argument("id", type=int)
    pt.add_argument("--json", action="store_true")
    pw = js.add_parser("wait", help="block until a job resolves")
    pw.add_argument("id", type=int)
    pw.add_argument("--timeout", type=float, default=600.0,
                    help="give up after this many seconds (default 600)")
    pw.add_argument("--json", action="store_true")
    pc = js.add_parser("cancel", help="cancel a job (cooperative abort "
                                      "when already running)")
    pc.add_argument("id", type=int)
    pc.add_argument("--json", action="store_true")


def _add_store_parser(sub) -> None:
    p = sub.add_parser(
        "store", help="schedule-store maintenance (gc)")
    ss = p.add_subparsers(dest="store_command", required=True)
    pg = ss.add_parser(
        "gc", help="evict least-recently-used objects down to the given "
                   "limits; never touches objects pinned by queued/running "
                   "daemon jobs; corrupt objects are reported, not deleted")
    pg.add_argument("--store", required=True,
                    help="ArtifactStore directory")
    pg.add_argument("--max-objects", type=int, default=None,
                    help="keep at most this many objects")
    pg.add_argument("--max-bytes", type=int, default=None,
                    help="keep at most this many bytes of objects")
    pg.add_argument("--dry-run", action="store_true",
                    help="report what would be evicted without deleting")
    pg.add_argument("--json", action="store_true")


def _add_report_parser(sub) -> None:
    p = sub.add_parser(
        "report", help="summarize a search artifact (no re-search)")
    p.add_argument("artifact", help="path to a ScheduleArtifact JSON")
    p.add_argument("--schedule", action="store_true",
                   help="rebuild the workload and render the fused schedule "
                        "(paper Fig. 9 analogue)")
    p.add_argument("--breakdown", action="store_true",
                   help="show the per-group cost breakdown table in full "
                        "(a top-10 view prints by default)")
    p.add_argument("--history", action="store_true",
                   help="print the convergence history trace")
    p.add_argument("--telemetry", action="store_true",
                   help="render the embedded telemetry summary "
                        "(convergence curve + cache stats; requires a "
                        "search run with --telemetry/--trace)")
    p.add_argument("--json", action="store_true",
                   help="emit the summary as JSON")


def _add_verify_parser(sub) -> None:
    p = sub.add_parser(
        "verify", help="independently re-check artifacts: groups, "
                       "schedulability, footprints, cost consistency, and "
                       "the DRAM-traffic lower-bound certificate "
                       "(repro.analysis)")
    p.add_argument("artifacts", nargs="*", metavar="ARTIFACT",
                   help="ScheduleArtifact JSON paths")
    p.add_argument("--store", default=None, metavar="DIR",
                   help="verify every object in an ArtifactStore (also "
                        "checks each object's content address)")
    p.add_argument("--json", action="store_true",
                   help="emit per-artifact check results as JSON")


def _add_analyze_parser(sub) -> None:
    p = sub.add_parser(
        "analyze", help="static fusion-space analysis: classify every "
                        "genome bit (forced_off / free / undecided), "
                        "factorize the space into independent regions, "
                        "and size the exact vs GA search problems "
                        "(repro.analysis.spacemap)")
    p.add_argument("workload",
                   help="workload spec: a registered name (see `repro "
                        "list`), name@key=value,... params, or "
                        "file:model.json GraphIR")
    p.add_argument("--workload-kwargs", default="{}", metavar="JSON",
                   help="builder kwargs, e.g. '{\"hw\": 128}'")
    p.add_argument("--accelerator", "--accel", dest="accelerator",
                   default="simba",
                   help="accelerator whose activation capacity decides the "
                        "freeze (default: simba)")
    p.add_argument("--costmodel", default="default",
                   help="cost backend whose capacity rule applies "
                        "(default|tpu; others freeze nothing)")
    p.add_argument("--json", action="store_true",
                   help="emit the full map (per-edge verdicts, regions, "
                        "summary) as JSON")


def _add_trace_parser(sub) -> None:
    p = sub.add_parser(
        "trace", help="aggregate a telemetry JSONL trace: validate every "
                      "event against the schema, render the span tree, "
                      "top-k slowest spans, and metric rollups "
                      "(repro.obs.traceview)")
    p.add_argument("trace", metavar="TRACE_JSONL",
                   help="trace file written via --trace / REPRO_TRACE")
    p.add_argument("--top", type=int, default=10,
                   help="slowest spans to list (default 10)")
    p.add_argument("--json", action="store_true",
                   help="emit the aggregate as JSON")


def _add_lint_parser(sub) -> None:
    p = sub.add_parser(
        "lint", help="determinism + import-boundary lint over the engine "
                     "packages (global RNG state, wall-clock reads, "
                     "unordered iteration, mutable defaults, pinned "
                     "checker/engine isolation)")
    p.add_argument("paths", nargs="*", metavar="PATH",
                   help="files/directories to lint (default: "
                        "src/repro/{core,search,serve,costmodel,ir,hw})")
    p.add_argument("--root", default=".",
                   help="repo root holding pyproject.toml (allowlist) "
                        "and src/ (default: .)")
    p.add_argument("--json", action="store_true",
                   help="emit findings as JSON")


def _env_collector():
    """A TelemetryCollector streaming to ``$REPRO_TRACE``, or None when the
    env var is unset — the CLI's obs hook for verify/serve paths (searches
    build their own collector inside SearchSession)."""
    from repro.obs import TelemetryCollector
    return TelemetryCollector.from_env()


def _summary_line(artifact) -> str:
    s = artifact.summary()
    return (f"{s['workload']} on {s['accelerator']} [{s['backend']}, "
            f"costmodel {s['costmodel']}, seed {s['seed']}]: "
            f"energy x{s['energy_x']}  {artifact.spec.objective} best "
            f"{artifact.best_fitness:.4f}  edp x{s['edp_x']}  "
            f"groups {s['groups']}  "
            f"({artifact.evaluations} evals, {artifact.wall_s:.1f}s)")


def _cmd_search(args) -> int:
    from repro.search import SearchSession

    spec = _spec_from_args(args)
    every = args.progress

    def progress(p) -> None:
        if every and p.step % every == 0:
            print(f"  step {p.step:>5}  best {p.best_fitness:.4f}  "
                  f"evals {p.evaluations}", file=sys.stderr)

    session = SearchSession(spec, embed_ir=True if args.embed_ir else None,
                            trace_path=args.trace)
    artifact = session.run(progress=progress if every else None)
    artifact.save(args.out)
    print(_summary_line(artifact))
    print(f"wrote {args.out}")
    if args.trace:
        print(f"trace: {args.trace} (inspect with `repro trace "
              f"{args.trace}`)")
    return 0


def _cmd_submit(args) -> int:
    from repro.serve import ArtifactStore, BatchScheduler

    store = ArtifactStore(args.store)
    col = _env_collector()
    try:
        sched = BatchScheduler(store, workers=1, obs=col)
        sched.submit(_spec_from_args(args))
        job = sched.run().jobs[0]
    finally:
        if col is not None:
            col.close()
    if job.status == "failed":
        print(f"error: {job.error}", file=sys.stderr)
        return 2
    how = "served from store" if job.outcome == "cache_hit" \
        else "searched and stored"
    print(f"{how}  key={job.key}")
    print(_summary_line(job.artifact))
    if args.out:
        job.artifact.save(args.out)
        print(f"wrote {args.out}")
    return 0


def _cmd_serve(args) -> int:
    from repro.serve import ArtifactStore, BatchScheduler
    from repro.serve.scheduler import load_requests

    store = ArtifactStore(args.store)
    col = _env_collector()
    try:
        sched = BatchScheduler(store, workers=args.workers, obs=col)
        for spec in load_requests(args.requests):
            sched.submit(spec)
        quiet = args.json
        outcome = sched.run(
            progress=None if quiet else lambda job: print(job.describe()))
    finally:
        if col is not None:
            col.close()
    if args.json:
        print(json.dumps(outcome.to_dict(), indent=2, sort_keys=True))
    else:
        s = outcome.stats
        print(f"stats: {s['jobs']} jobs — {s['searched']} searched, "
              f"{s['cache_hits']} cache hits "
              f"({s['deduped_in_flight']} deduped in-flight), "
              f"{s['failed']} failed; store holds {len(store)} schedules")
    return 1 if outcome.stats["failed"] else 0


def _cmd_daemon(args) -> int:
    import signal

    from repro.serve import ScheduleDaemon

    svc = ScheduleDaemon(args.store, host=args.host, port=args.port,
                         workers=args.workers)
    rep = svc.queue.replay
    if rep.jobs:
        print(f"journal replay: {rep.jobs} job(s) — {rep.requeued} "
              f"requeued, {rep.terminal} already resolved")
    for w in rep.warnings:
        print(f"  journal warning: {w}", file=sys.stderr)
    for signum in (signal.SIGTERM, signal.SIGINT):
        signal.signal(signum, lambda s, f: svc.request_shutdown())
    svc.start()
    print(f"repro daemon listening on http://{svc.host}:{svc.port} "
          f"(store {args.store}, {args.workers} worker(s))", flush=True)
    svc.wait()
    print("daemon stopped")
    return 0


def _http_json(method: str, url: str, payload=None, timeout: float = 60.0):
    """One JSON request against the daemon; HTTP/connection errors become
    ValueError so main() renders them as `error: ...` with exit 2."""
    import urllib.error
    import urllib.request

    data = json.dumps(payload).encode() if payload is not None else None
    req = urllib.request.Request(url, data=data, method=method)
    if data is not None:
        req.add_header("Content-Type", "application/json")
    try:
        with urllib.request.urlopen(req, timeout=timeout) as resp:
            return json.load(resp)
    except urllib.error.HTTPError as e:
        body = e.read().decode(errors="replace")
        try:
            msg = json.loads(body).get("error", body)
        except json.JSONDecodeError:
            msg = body
        raise ValueError(f"daemon returned {e.code}: {msg}") from None
    except urllib.error.URLError as e:
        raise ValueError(f"cannot reach daemon at {url}: {e.reason}") \
            from None


def _job_line(j: dict) -> str:
    spec = j.get("spec", {})
    tail = ""
    if j.get("outcome"):
        tail += f" outcome={j['outcome']}"
    if j.get("error"):
        tail += f" error={j['error']}"
    if j.get("key"):
        tail += f" key={j['key'][:12]}"
    prog = j.get("progress") or []
    if prog and j.get("state") == "running":
        tail += (f" [gen {prog[-1]['step']}, "
                 f"best {prog[-1]['best']:.4f}]")
    return (f"job {j['id']}: {spec.get('workload')}/"
            f"{spec.get('accelerator')} [{spec.get('backend')}, seed "
            f"{spec.get('seed')}] state={j['state']}{tail}")


def _wait_job(base: str, job_id: int, timeout: float) -> dict:
    import time

    deadline = time.monotonic() + timeout
    while True:
        j = _http_json("GET", f"{base}/jobs/{job_id}")
        if j["state"] in ("done", "failed", "cancelled"):
            return j
        if time.monotonic() >= deadline:
            raise ValueError(f"timed out after {timeout:.0f}s waiting for "
                             f"job {job_id} (state {j['state']})")
        time.sleep(0.2)


def _cmd_jobs(args) -> int:
    base = args.daemon.rstrip("/")
    cmd = args.jobs_command
    if cmd == "submit":
        spec = _spec_from_args(args)
        job = _http_json("POST", f"{base}/jobs",
                         {"spec": spec.to_dict(), "priority": args.priority,
                          "warm_start": args.warm_start})
        if args.wait and job["state"] not in ("done", "failed", "cancelled"):
            job = _wait_job(base, job["id"], timeout=600.0)
        print(json.dumps(job, indent=2, sort_keys=True) if args.json
              else _job_line(job))
        return 2 if job["state"] == "failed" else 0
    if cmd == "list":
        jobs = _http_json("GET", f"{base}/jobs")["jobs"]
        if args.json:
            print(json.dumps(jobs, indent=2, sort_keys=True))
        else:
            for j in jobs:
                print(_job_line(j))
            print(f"{len(jobs)} job(s)")
        return 0
    if cmd == "status":
        j = _http_json("GET", f"{base}/jobs/{args.id}")
    elif cmd == "wait":
        j = _wait_job(base, args.id, timeout=args.timeout)
    else:                                # cancel
        j = _http_json("DELETE", f"{base}/jobs/{args.id}")
        print(json.dumps(j, indent=2, sort_keys=True) if args.json
              else f"job {j['id']}: {j['state']}")
        return 0
    print(json.dumps(j, indent=2, sort_keys=True) if args.json
          else _job_line(j))
    return 2 if j["state"] == "failed" else 0


def _cmd_store(args) -> int:
    from repro.serve import ArtifactStore, collect_garbage

    store = ArtifactStore(args.store, create=False)
    res = collect_garbage(store, max_objects=args.max_objects,
                          max_bytes=args.max_bytes, dry_run=args.dry_run)
    if args.json:
        print(json.dumps(res.to_dict(), indent=2, sort_keys=True))
        return 0
    d = res.to_dict()
    verb = "would evict" if res.dry_run else "evicted"
    print(f"store gc: {res.examined} object(s), {res.bytes_total} bytes — "
          f"{verb} {len(res.evicted)} ({res.evicted_bytes} bytes), "
          f"{d['objects_after']} object(s) / {d['bytes_after']} bytes "
          f"remain")
    if res.kept_live:
        print(f"  pinned by queued/running jobs: "
              f"{len(res.kept_live)} object(s)")
    for key in res.corrupt:
        print(f"  warning: corrupt/unreadable object {key[:12]} "
              f"(reported, not deleted)", file=sys.stderr)
    return 0


def _cmd_report(args) -> int:
    from repro.analysis import verify_artifact
    from repro.search import ScheduleArtifact

    artifact = ScheduleArtifact.load(args.artifact)
    for w in artifact.load_warnings:
        print(f"warning: {w}", file=sys.stderr)
    if args.telemetry and artifact.telemetry is None:
        print("error: artifact carries no telemetry summary — re-run the "
              "search with --telemetry (or --trace / REPRO_TRACE)",
              file=sys.stderr)
        return 2
    s = artifact.summary()
    # independent re-verification + Chen-et-al lower-bound certificate
    # (repro.analysis): static, no re-search
    report = verify_artifact(artifact)
    cert = report.certificate
    if args.json:
        s["verified"] = report.ok
        s["certificate"] = cert.to_dict() if cert else None
        if args.telemetry:
            s["telemetry"] = artifact.telemetry
        print(json.dumps(s, indent=2, sort_keys=True))
    else:
        print(f"workload     : {s['workload']} "
              f"(kwargs {artifact.spec.workload_kwargs})")
        print(f"accelerator  : {s['accelerator']}")
        print(f"backend      : {s['backend']} (seed {s['seed']}, "
              f"{artifact.evaluations} unique evals, "
              f"{artifact.wall_s:.1f}s)")
        print(f"costmodel    : {s['costmodel']}")
        print(f"objective    : {artifact.spec.objective} "
              f"(best fitness {artifact.best_fitness:.4f})")
        print(f"improvements : energy x{s['energy_x']}  edp x{s['edp_x']}  "
              f"cycles x{s['cycles_x']}  dram x{s['dram_x']}")
        print(f"schedule     : {s['groups']} fused groups, DRAM act-writes "
              f"{s['act_dram_writes_base']} -> {s['act_dram_writes_best']}")
        print(f"genome       : {artifact.genome_mask:#x} "
              f"({len(artifact.fused_edges)}/{artifact.n_edges} edges fused)")
        print(f"fingerprint  : {artifact.graph_fingerprint}")
        if cert is not None:
            print(f"certificate  : {cert.describe()}")
        verdict = "all checks passed" if report.ok else \
            "FAILED " + ", ".join(c.name for c in report.failures())
        print(f"verification : {verdict} (repro verify for detail)")
        if args.telemetry:
            from repro.obs.report import render_telemetry
            print()
            print(render_telemetry(artifact.telemetry))
    if not args.json:
        from repro.core.report import breakdown_report
        print()
        print(breakdown_report(artifact.group_breakdowns,
                               max_rows=0 if args.breakdown else 10))
    if args.history and artifact.history:
        h = artifact.history
        marks = sorted({0, len(h) // 4, len(h) // 2, 3 * len(h) // 4,
                        len(h) - 1})
        print("history      : "
              + "  ".join(f"s{i}={h[i]:.4f}" for i in marks))
    if args.schedule:
        from repro.core.report import schedule_report
        from repro.search.registry import build_accelerator
        res = _schedule_result(artifact)
        print()
        print(schedule_report(res, build_accelerator(
            artifact.spec.accelerator)))
    return 0


def _schedule_result(artifact):
    """Rebuild a ScheduleResult view from a stored artifact (validates the
    graph fingerprint; no re-search)."""
    from repro.core.ga import GAResult
    from repro.core.schedule import ScheduleResult
    state = artifact.rebuild_state()
    ga = GAResult(best_state=state, best_fitness=artifact.best_fitness,
                  history=list(artifact.history),
                  evaluations=artifact.evaluations,
                  offspring_evaluated=artifact.offspring_evaluated)
    return ScheduleResult(
        workload=artifact.spec.workload,
        accelerator=artifact.spec.accelerator,
        baseline=artifact.baseline, best=artifact.best,
        best_state=state, ga=ga)


def _cmd_verify(args) -> int:
    from repro.analysis import verify_artifact, verify_store
    from repro.search import ScheduleArtifact

    if not args.artifacts and not args.store:
        print("error: pass artifact paths and/or --store DIR",
              file=sys.stderr)
        return 2
    results = []                      # (label, load_warnings, report)
    col = _env_collector()
    try:
        for path in args.artifacts:
            artifact = ScheduleArtifact.load(path)
            results.append((path, list(artifact.load_warnings),
                            verify_artifact(artifact, obs=col)))
        if args.store:
            for key, report in verify_store(args.store, obs=col):
                results.append((f"{args.store}:{key[:12]}", [], report))
    finally:
        if col is not None:
            col.close()
    all_ok = all(r.ok for _, _, r in results)
    if args.json:
        print(json.dumps({
            "ok": all_ok,
            "results": [dict(label=label, load_warnings=warns,
                             **report.to_dict())
                        for label, warns, report in results],
        }, indent=2, sort_keys=True))
        return 0 if all_ok else 1
    for label, warns, report in results:
        print(f"{label}: {'verified' if report.ok else 'FAILED'}")
        for w in warns:
            print(f"  warning: {w}", file=sys.stderr)
        print(report.describe())
    n_bad = sum(1 for _, _, r in results if not r.ok)
    print(f"{len(results)} artifact(s): "
          f"{len(results) - n_bad} verified, {n_bad} failed")
    return 0 if all_ok else 1


def _cmd_analyze(args) -> int:
    from repro.analysis import build_spacemap
    from repro.search import build_workload

    graph = build_workload(args.workload, **json.loads(args.workload_kwargs))
    sm = build_spacemap(graph, args.costmodel, args.accelerator)
    if args.json:
        print(json.dumps(sm.to_dict(), indent=2, sort_keys=True))
        return 0
    print(sm.describe())
    return 0


def _cmd_trace(args) -> int:
    from repro.obs.traceview import read_trace

    rep = read_trace(args.trace, top=args.top)
    if args.json:
        print(json.dumps(rep.to_dict(), indent=2, sort_keys=True))
    else:
        print(rep.describe())
    return 0 if rep.valid else 1


def _cmd_lint(args) -> int:
    from repro.analysis import run_lint

    findings = run_lint(args.root, paths=args.paths or None)
    if args.json:
        print(json.dumps([f.to_dict() for f in findings], indent=2,
                         sort_keys=True))
        return 1 if findings else 0
    for f in findings:
        print(f.describe())
    if findings:
        print(f"{len(findings)} determinism finding(s) — fix them or add "
              f"justified [tool.repro.lint] allow entries")
        return 1
    print("determinism lint: clean")
    return 0


def _cmd_export(args) -> int:
    import repro.ir as ir
    from repro.search import build_workload

    graph = build_workload(args.workload, **json.loads(args.workload_kwargs))
    out = args.out or f"{graph.name}.json"
    gir = graph.to_ir()
    ir.save(gir, out)
    print(f"wrote {out}  ({len(gir.nodes)} nodes, "
          f"fingerprint {gir.fingerprint()})")
    print(f"search it with: repro search --workload file:{out}")
    return 0


def _list_payload() -> dict:
    """The machine-readable registry dump behind ``repro list --json``."""
    import inspect

    from repro.search import (ACCELERATORS, BACKENDS, COSTMODELS, OBJECTIVES,
                              workload_schemas)
    return {
        "workloads": workload_schemas(),
        "workload_spec_forms": ["<name>", "<name>@key=value[,key=value...]",
                                "file:<model.json>"],
        "accelerators": ACCELERATORS.names(),
        "accelerator_spec_forms": ["<name>", "<name>@act+<KiB>",
                                   "<name>@act-<KiB>"],
        "objectives": OBJECTIVES.names(),
        "backends": {name: {"doc": inspect.getdoc(BACKENDS.get(name)) or ""}
                     for name in BACKENDS},
        "costmodels": COSTMODELS.names(),
    }


def _list_store(root: str, as_json: bool) -> int:
    """``repro list --store DIR``: browse a schedule store, surfacing each
    object's load warnings (corrupt/legacy objects stay visible instead of
    only erroring at report time)."""
    from repro.serve import ArtifactStore, StoreError

    store = ArtifactStore(root, create=False)
    rows = []
    for key in store.keys():
        try:
            artifact = store.load_key(key)
        except StoreError as e:
            rows.append({"key": key, "error": str(e)})
            continue
        if artifact is None:
            continue
        rows.append({"key": key, "summary": artifact.summary(),
                     "load_warnings": list(artifact.load_warnings),
                     "artifact": artifact})
    if as_json:
        print(json.dumps([{k: v for k, v in row.items() if k != "artifact"}
                          for row in rows], indent=2, sort_keys=True))
        return 0
    for row in rows:
        if "error" in row:
            print(f"{row['key'][:12]}  UNREADABLE: {row['error']}")
            continue
        print(f"{row['key'][:12]}  {_summary_line(row['artifact'])}")
        for w in row["load_warnings"]:
            print(f"{'':12}  warning: {w}")
    n_bad = sum(1 for r in rows if "error" in r)
    n_warn = sum(1 for r in rows if r.get("load_warnings"))
    print(f"{len(rows)} object(s) in {root}"
          + (f" — {n_bad} unreadable" if n_bad else "")
          + (f", {n_warn} with load warnings" if n_warn else ""))
    return 0


def _cmd_list(args) -> int:
    import inspect

    from repro.search import (ACCELERATORS, BACKENDS, COSTMODELS, OBJECTIVES,
                              WORKLOADS, workload_schemas)
    if getattr(args, "store", None):
        return _list_store(args.store, as_json=getattr(args, "json", False))
    if getattr(args, "json", False):
        print(json.dumps(_list_payload(), indent=2, sort_keys=True))
        return 0
    for reg in (WORKLOADS, ACCELERATORS, OBJECTIVES, BACKENDS, COSTMODELS):
        print(f"{reg.kind}s: " + ", ".join(reg.names()))
    print("(accelerators accept an iso-capacity repartition suffix: "
          "eyeriss@act+64; `repro.hw` holds their hierarchical descriptions)")
    print()
    print("workloads (params go in --workload name@key=value,... or "
          "--workload-kwargs JSON; file:model.json imports GraphIR):")
    for name, info in sorted(workload_schemas().items()):
        params = ", ".join(f"{k}={v['default']!r} ({v['type']})"
                           for k, v in info["params"].items()) or "(none)"
        print(f"  {name}: {params}")
    print()
    print("backends (config knobs go in --backend-config JSON):")
    for name in BACKENDS:
        doc = inspect.getdoc(BACKENDS.get(name)) or "(undocumented)"
        print(f"\n  {name}:")
        for line in doc.splitlines():
            print(f"    {line}".rstrip())
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro",
        description="GA-driven interlayer pipelining: search schedules, "
                    "serve batches, report artifacts.")
    sub = ap.add_subparsers(dest="command", required=True)
    _add_search_parser(sub)
    _add_submit_parser(sub)
    _add_serve_parser(sub)
    _add_daemon_parser(sub)
    _add_jobs_parser(sub)
    _add_store_parser(sub)
    _add_report_parser(sub)
    _add_verify_parser(sub)
    _add_analyze_parser(sub)
    _add_trace_parser(sub)
    _add_lint_parser(sub)
    _add_export_parser(sub)
    lp = sub.add_parser(
        "list", help="list registered workloads / accelerators / "
                     "objectives / backends (with config knobs), or "
                     "browse a schedule store with --store")
    lp.add_argument("--json", action="store_true",
                    help="machine-readable dump: workloads with param "
                         "schemas, accelerators, objectives, backends "
                         "(with docs), costmodels")
    lp.add_argument("--store", default=None, metavar="DIR",
                    help="list the artifacts in an ArtifactStore instead "
                         "(shows per-object load warnings)")
    args = ap.parse_args(argv)

    from repro.search import BackendError, FingerprintMismatch, RegistryError
    from repro.serve import StoreError
    handler = {"search": _cmd_search, "submit": _cmd_submit,
               "serve": _cmd_serve, "daemon": _cmd_daemon,
               "jobs": _cmd_jobs, "store": _cmd_store,
               "report": _cmd_report,
               "verify": _cmd_verify, "analyze": _cmd_analyze,
               "trace": _cmd_trace, "lint": _cmd_lint,
               "export": _cmd_export, "list": _cmd_list}[args.command]
    try:
        return handler(args)
    except BrokenPipeError:
        # `repro report ... | head`: exit quietly; route stdout to devnull
        # so the interpreter's shutdown flush doesn't raise again
        devnull = os.open(os.devnull, os.O_WRONLY)
        os.dup2(devnull, sys.stdout.fileno())
        return 0
    except (RegistryError, BackendError, FingerprintMismatch, StoreError,
            FileNotFoundError, json.JSONDecodeError, ValueError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2


if __name__ == "__main__":
    sys.exit(main())
