"""The ``repro`` CLI: run searches, serve batches, inspect artifacts, list
registries, export workload IR.

    repro search --workload mobilenet_v3 --accel simba --backend ga \\
        --out artifact.json
    repro search --workload file:model.json --backend ga   # bring your own
    repro submit --store schedules/ --workload mobilenet_v3 --backend island
    repro serve --store schedules/ --requests jobs.json --workers 4
    repro report artifact.json [--schedule] [--history]
    repro export --workload mobilenet_v3@hw=160 --out model.json
    repro list [--json]

``--workload`` accepts every spec form (``name``, ``name@key=value,...``,
``file:model.json``); see ``repro.search.registry``.

(Also reachable as ``python -m repro ...`` with ``PYTHONPATH=src``.)
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional


def _add_spec_args(p) -> None:
    """Arguments that assemble one SearchSpec (shared by search/submit)."""
    p.add_argument("--workload", required=True,
                   help="workload spec: a registered name (see `repro "
                        "list`), name@key=value,... params, or "
                        "file:model.json GraphIR")
    p.add_argument("--workload-kwargs", default="{}", metavar="JSON",
                   help="builder kwargs, e.g. '{\"hw\": 128}' "
                        "(equivalent to @-params in --workload)")
    p.add_argument("--accelerator", "--accel", dest="accelerator",
                   default="simba",
                   help="accelerator (repro.hw catalog name), optionally "
                        "repartitioned (e.g. eyeriss@act+64)")
    p.add_argument("--objective", default="edp",
                   help="registered objective (edp|energy|cycles|dram|...)")
    p.add_argument("--backend", default="ga",
                   help="search backend (ga|island|random|hill_climb|"
                        "exhaustive|...)")
    p.add_argument("--costmodel", default="default",
                   help="cost backend scoring the schedules (default|tpu|...)")
    p.add_argument("--backend-config", default="{}", metavar="JSON",
                   help="backend options, e.g. '{\"islands\": 4}' "
                        "(knobs: `repro list`)")
    p.add_argument("--preset", choices=("paper", "fast"), default=None,
                   help="ga preset (paper: P=100 G=500; fast: CPU-friendly)")
    p.add_argument("--generations", type=int, default=None,
                   help="ga generations override")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--budget", type=int, default=None,
                   help="stop after this many offspring evaluations")
    p.add_argument("--patience", type=int, default=None,
                   help="stop after N backend steps without improvement "
                        "(ga: a step is one generation; island: one sync "
                        "barrier, i.e. up to ~10 generations; "
                        "random/exhaustive: one scoring chunk)")


def _spec_from_args(args):
    """Build the SearchSpec an invocation of _add_spec_args describes."""
    from repro.search import SearchSpec

    backend_config = json.loads(args.backend_config)
    if args.preset is not None:
        backend_config.setdefault("preset", args.preset)
    if args.generations is not None:
        backend_config.setdefault("generations", args.generations)
    return SearchSpec(
        workload=args.workload, accelerator=args.accelerator,
        objective=args.objective, backend=args.backend,
        costmodel=args.costmodel, backend_config=backend_config,
        workload_kwargs=json.loads(args.workload_kwargs),
        seed=args.seed, budget=args.budget, patience=args.patience)


def _add_search_parser(sub) -> None:
    p = sub.add_parser(
        "search", help="run a schedule search and write a JSON artifact")
    _add_spec_args(p)
    p.add_argument("--out", default="artifact.json",
                   help="artifact path (default: artifact.json)")
    p.add_argument("--progress", type=int, default=0, metavar="N",
                   help="print progress every N backend steps")
    p.add_argument("--embed-ir", action="store_true",
                   help="embed the workload's GraphIR in the artifact "
                        "(self-contained report/rebind; automatic for "
                        "file: workloads)")


def _add_export_parser(sub) -> None:
    p = sub.add_parser(
        "export", help="export a workload's canonical GraphIR JSON "
                       "(file: round-trips byte-identically)")
    p.add_argument("--workload", required=True,
                   help="workload spec (name, name@key=value, or "
                        "file:model.json)")
    p.add_argument("--workload-kwargs", default="{}", metavar="JSON",
                   help="builder kwargs, e.g. '{\"hw\": 128}'")
    p.add_argument("--out", default=None,
                   help="output path (default: <workload name>.json)")


def _add_submit_parser(sub) -> None:
    p = sub.add_parser(
        "submit", help="resolve one search request against a schedule "
                       "store: serve a stored artifact, or search and "
                       "store the result")
    _add_spec_args(p)
    p.add_argument("--store", required=True,
                   help="ArtifactStore directory (created if absent)")
    p.add_argument("--out", default=None,
                   help="also write the artifact JSON to this path")


def _add_serve_parser(sub) -> None:
    p = sub.add_parser(
        "serve", help="drain a batch of search requests against a schedule "
                      "store (dedup + cache + parallel search)")
    p.add_argument("--requests", required=True, metavar="JOBS_JSON",
                   help="JSON list of SearchSpec objects "
                        "(or {\"jobs\": [...]})")
    p.add_argument("--store", required=True,
                   help="ArtifactStore directory (created if absent)")
    p.add_argument("--workers", type=int, default=1,
                   help="parallel search processes for cache misses "
                        "(default 1 = inline)")
    p.add_argument("--json", action="store_true",
                   help="emit per-job outcomes + stats as JSON")


def _add_report_parser(sub) -> None:
    p = sub.add_parser(
        "report", help="summarize a search artifact (no re-search)")
    p.add_argument("artifact", help="path to a ScheduleArtifact JSON")
    p.add_argument("--schedule", action="store_true",
                   help="rebuild the workload and render the fused schedule "
                        "(paper Fig. 9 analogue)")
    p.add_argument("--breakdown", action="store_true",
                   help="show the per-group cost breakdown table in full "
                        "(a top-10 view prints by default)")
    p.add_argument("--history", action="store_true",
                   help="print the convergence history trace")
    p.add_argument("--json", action="store_true",
                   help="emit the summary as JSON")


def _summary_line(artifact) -> str:
    s = artifact.summary()
    return (f"{s['workload']} on {s['accelerator']} [{s['backend']}, "
            f"costmodel {s['costmodel']}, seed {s['seed']}]: "
            f"energy x{s['energy_x']}  {artifact.spec.objective} best "
            f"{artifact.best_fitness:.4f}  edp x{s['edp_x']}  "
            f"groups {s['groups']}  "
            f"({artifact.evaluations} evals, {artifact.wall_s:.1f}s)")


def _cmd_search(args) -> int:
    from repro.search import SearchSession

    spec = _spec_from_args(args)
    every = args.progress

    def progress(p) -> None:
        if every and p.step % every == 0:
            print(f"  step {p.step:>5}  best {p.best_fitness:.4f}  "
                  f"evals {p.evaluations}", file=sys.stderr)

    session = SearchSession(spec, embed_ir=True if args.embed_ir else None)
    artifact = session.run(progress=progress if every else None)
    artifact.save(args.out)
    print(_summary_line(artifact))
    print(f"wrote {args.out}")
    return 0


def _cmd_submit(args) -> int:
    from repro.serve import ArtifactStore, BatchScheduler

    store = ArtifactStore(args.store)
    sched = BatchScheduler(store, workers=1)
    sched.submit(_spec_from_args(args))
    job = sched.run().jobs[0]
    if job.status == "failed":
        print(f"error: {job.error}", file=sys.stderr)
        return 2
    how = "served from store" if job.outcome == "cache_hit" \
        else "searched and stored"
    print(f"{how}  key={job.key}")
    print(_summary_line(job.artifact))
    if args.out:
        job.artifact.save(args.out)
        print(f"wrote {args.out}")
    return 0


def _cmd_serve(args) -> int:
    from repro.serve import ArtifactStore, BatchScheduler
    from repro.serve.scheduler import load_requests

    store = ArtifactStore(args.store)
    sched = BatchScheduler(store, workers=args.workers)
    for spec in load_requests(args.requests):
        sched.submit(spec)
    quiet = args.json
    outcome = sched.run(
        progress=None if quiet else lambda job: print(job.describe()))
    if args.json:
        print(json.dumps(outcome.to_dict(), indent=2, sort_keys=True))
    else:
        s = outcome.stats
        print(f"stats: {s['jobs']} jobs — {s['searched']} searched, "
              f"{s['cache_hits']} cache hits "
              f"({s['deduped_in_flight']} deduped in-flight), "
              f"{s['failed']} failed; store holds {len(store)} schedules")
    return 1 if outcome.stats["failed"] else 0


def _cmd_report(args) -> int:
    from repro.search import ScheduleArtifact

    artifact = ScheduleArtifact.load(args.artifact)
    for w in artifact.load_warnings:
        print(f"warning: {w}", file=sys.stderr)
    s = artifact.summary()
    if args.json:
        print(json.dumps(s, indent=2, sort_keys=True))
    else:
        print(f"workload     : {s['workload']} "
              f"(kwargs {artifact.spec.workload_kwargs})")
        print(f"accelerator  : {s['accelerator']}")
        print(f"backend      : {s['backend']} (seed {s['seed']}, "
              f"{artifact.evaluations} unique evals, "
              f"{artifact.wall_s:.1f}s)")
        print(f"costmodel    : {s['costmodel']}")
        print(f"objective    : {artifact.spec.objective} "
              f"(best fitness {artifact.best_fitness:.4f})")
        print(f"improvements : energy x{s['energy_x']}  edp x{s['edp_x']}  "
              f"cycles x{s['cycles_x']}  dram x{s['dram_x']}")
        print(f"schedule     : {s['groups']} fused groups, DRAM act-writes "
              f"{s['act_dram_writes_base']} -> {s['act_dram_writes_best']}")
        print(f"genome       : {artifact.genome_mask:#x} "
              f"({len(artifact.fused_edges)}/{artifact.n_edges} edges fused)")
        print(f"fingerprint  : {artifact.graph_fingerprint}")
    if not args.json:
        from repro.core.report import breakdown_report
        print()
        print(breakdown_report(artifact.group_breakdowns,
                               max_rows=0 if args.breakdown else 10))
    if args.history and artifact.history:
        h = artifact.history
        marks = sorted({0, len(h) // 4, len(h) // 2, 3 * len(h) // 4,
                        len(h) - 1})
        print("history      : "
              + "  ".join(f"s{i}={h[i]:.4f}" for i in marks))
    if args.schedule:
        from repro.core.report import schedule_report
        from repro.search.registry import build_accelerator
        res = _schedule_result(artifact)
        print()
        print(schedule_report(res, build_accelerator(
            artifact.spec.accelerator)))
    return 0


def _schedule_result(artifact):
    """Rebuild a ScheduleResult view from a stored artifact (validates the
    graph fingerprint; no re-search)."""
    from repro.core.ga import GAResult
    from repro.core.schedule import ScheduleResult
    state = artifact.rebuild_state()
    ga = GAResult(best_state=state, best_fitness=artifact.best_fitness,
                  history=list(artifact.history),
                  evaluations=artifact.evaluations,
                  offspring_evaluated=artifact.offspring_evaluated)
    return ScheduleResult(
        workload=artifact.spec.workload,
        accelerator=artifact.spec.accelerator,
        baseline=artifact.baseline, best=artifact.best,
        best_state=state, ga=ga)


def _cmd_export(args) -> int:
    import repro.ir as ir
    from repro.search import build_workload

    graph = build_workload(args.workload, **json.loads(args.workload_kwargs))
    out = args.out or f"{graph.name}.json"
    gir = graph.to_ir()
    ir.save(gir, out)
    print(f"wrote {out}  ({len(gir.nodes)} nodes, "
          f"fingerprint {gir.fingerprint()})")
    print(f"search it with: repro search --workload file:{out}")
    return 0


def _list_payload() -> dict:
    """The machine-readable registry dump behind ``repro list --json``."""
    import inspect

    from repro.search import (ACCELERATORS, BACKENDS, COSTMODELS, OBJECTIVES,
                              workload_schemas)
    return {
        "workloads": workload_schemas(),
        "workload_spec_forms": ["<name>", "<name>@key=value[,key=value...]",
                                "file:<model.json>"],
        "accelerators": ACCELERATORS.names(),
        "accelerator_spec_forms": ["<name>", "<name>@act+<KiB>",
                                   "<name>@act-<KiB>"],
        "objectives": OBJECTIVES.names(),
        "backends": {name: {"doc": inspect.getdoc(BACKENDS.get(name)) or ""}
                     for name in BACKENDS},
        "costmodels": COSTMODELS.names(),
    }


def _cmd_list(args) -> int:
    import inspect

    from repro.search import (ACCELERATORS, BACKENDS, COSTMODELS, OBJECTIVES,
                              WORKLOADS, workload_schemas)
    if getattr(args, "json", False):
        print(json.dumps(_list_payload(), indent=2, sort_keys=True))
        return 0
    for reg in (WORKLOADS, ACCELERATORS, OBJECTIVES, BACKENDS, COSTMODELS):
        print(f"{reg.kind}s: " + ", ".join(reg.names()))
    print("(accelerators accept an iso-capacity repartition suffix: "
          "eyeriss@act+64; `repro.hw` holds their hierarchical descriptions)")
    print()
    print("workloads (params go in --workload name@key=value,... or "
          "--workload-kwargs JSON; file:model.json imports GraphIR):")
    for name, info in sorted(workload_schemas().items()):
        params = ", ".join(f"{k}={v['default']!r} ({v['type']})"
                           for k, v in info["params"].items()) or "(none)"
        print(f"  {name}: {params}")
    print()
    print("backends (config knobs go in --backend-config JSON):")
    for name in BACKENDS:
        doc = inspect.getdoc(BACKENDS.get(name)) or "(undocumented)"
        print(f"\n  {name}:")
        for line in doc.splitlines():
            print(f"    {line}".rstrip())
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro",
        description="GA-driven interlayer pipelining: search schedules, "
                    "serve batches, report artifacts.")
    sub = ap.add_subparsers(dest="command", required=True)
    _add_search_parser(sub)
    _add_submit_parser(sub)
    _add_serve_parser(sub)
    _add_report_parser(sub)
    _add_export_parser(sub)
    lp = sub.add_parser(
        "list", help="list registered workloads / accelerators / "
                     "objectives / backends (with config knobs)")
    lp.add_argument("--json", action="store_true",
                    help="machine-readable dump: workloads with param "
                         "schemas, accelerators, objectives, backends "
                         "(with docs), costmodels")
    args = ap.parse_args(argv)

    from repro.search import BackendError, FingerprintMismatch, RegistryError
    from repro.serve import StoreError
    handler = {"search": _cmd_search, "submit": _cmd_submit,
               "serve": _cmd_serve, "report": _cmd_report,
               "export": _cmd_export, "list": _cmd_list}[args.command]
    try:
        return handler(args)
    except BrokenPipeError:
        # `repro report ... | head`: exit quietly; route stdout to devnull
        # so the interpreter's shutdown flush doesn't raise again
        devnull = os.open(os.devnull, os.O_WRONLY)
        os.dup2(devnull, sys.stdout.fileno())
        return 0
    except (RegistryError, BackendError, FingerprintMismatch, StoreError,
            FileNotFoundError, json.JSONDecodeError, ValueError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2


if __name__ == "__main__":
    sys.exit(main())
