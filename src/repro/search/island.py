"""Island-model parallel GA: N independent Alg.-1 populations with ring
migration of elites (the ``island`` search backend).

Each island runs the paper's GA (:func:`repro.core.ga.run_ga_problem`) on
its own process with a deterministically derived seed; every
``migrate_every`` generations the islands synchronize and island ``i``'s
top ``migrants`` genomes replace the worst pool entries of island
``(i+1) % islands`` (a ring).  Because migration is synchronous and
consumes no RNG, a fixed-seed island run is exactly reproducible — and at
``islands=1`` the backend *is* the ``ga`` backend: it delegates straight
to ``run_ga_problem`` with the same config and seed, so results are
bit-identical (pinned by ``tests/test_island.py``).

Workers default to ``multiprocessing`` with the ``fork`` start method (the
search problem and its evaluator caches are inherited copy-on-write; only
integer genome masks and fitness floats cross process boundaries, via
``SearchProblem.encode_genome``/``decode_genome``).  Where ``fork`` is
unavailable — or this process may not fork (daemonic pool workers, e.g.
inside a ``BatchScheduler`` search worker) — the backend falls back to
threads: identical semantics and results, no parallel speedup.  Note that
forking a process that has already imported jax draws jax's
multithreading warning; island children run only the stdlib search stack
(graph/fusion/cost model) and never call into jax, so the fusion-search
path is unaffected.

Session budget/patience apply at sync barriers: the parent aggregates
island stats there and broadcasts stop.  Barriers happen every
``migrate_every`` generations *and at least* every ``OBSERVE_EVERY_MAX``
(observation-only — no migrants move), so early-stop granularity is
``min(migrate_every, OBSERVE_EVERY_MAX)`` generations rather than one,
and a huge ``migrate_every`` can never disable the budget entirely.
Note the unit shift this implies for patience: a session "step" here is
one *barrier*, not one generation (``SearchSpec.patience`` counts
backend-defined steps — same convention as random/exhaustive's chunks),
so ``patience=5`` tolerates up to ``5 * min(migrate_every,
OBSERVE_EVERY_MAX)`` stale generations per island.
"""
from __future__ import annotations

import hashlib
import queue
import threading
from typing import List, Optional, Tuple

from repro.core.ga import GAConfig, GAResult, run_ga_problem
from repro.core.problem import SearchProblem

from repro.search.backends import (GABackend, Observer, SearchBackend,
                                   BackendError)
from repro.search.registry import register_backend

#: parent <-> island handshake timeout (seconds); a worker that dies mid-run
#: surfaces as a BackendError instead of a silent deadlock
SYNC_TIMEOUT_S = 600.0

#: ceiling on generations between parent observations: even when
#: ``migrate_every`` is large (or larger than the run), islands still
#: barrier at least this often so session budget/patience can stop them
#: (observation-only syncs exchange no migrants — trajectories unchanged)
OBSERVE_EVERY_MAX = 10


def island_seed(seed: int, island: int) -> int:
    """Deterministic per-island seed: island 0 keeps the caller's seed (so
    island 0 reproduces the ``ga`` backend's RNG stream exactly); the rest
    draw 64 bits from sha256 over (seed, island)."""
    if island == 0:
        return seed
    h = hashlib.sha256(f"island:{seed}:{island}".encode()).digest()
    return int.from_bytes(h[:8], "big")


def inject_migrants(problem: SearchProblem,
                    pool: List[Tuple[float, object]],
                    immigrants: List[Tuple[float, object]]
                    ) -> List[Tuple[float, object]]:
    """Replace the pool's worst entries with decoded immigrants (dropping
    any already present by genome key).  Deterministic: sorts by fitness
    only, consumes no RNG, and never evicts the pool's best."""
    present = {problem.key(g) for _, g in pool}
    fresh = []
    for f, enc in immigrants:
        g = problem.decode_genome(enc)
        k = problem.key(g)
        if k not in present:
            present.add(k)
            fresh.append((f, g))
    if not fresh:
        return pool
    ranked = sorted(pool, key=lambda fs: -fs[0])
    return ranked[:max(len(ranked) - len(fresh), 1)] + fresh


class _Chan:
    """Duplex channel a worker shares with the parent: a multiprocessing
    Pipe connection or (thread fallback) a pair of queues."""

    def __init__(self, conn=None, inbox=None, outbox=None):
        self._conn = conn
        self._inbox = inbox
        self._outbox = outbox

    def send(self, msg) -> None:
        if self._conn is not None:
            self._conn.send(msg)
        else:
            self._outbox.put(msg)

    def recv(self, timeout: float = SYNC_TIMEOUT_S):
        if self._conn is not None:
            # poll() also returns True when the peer hard-died (closed
            # pipe); recv() then raises EOFError — normalize both ends of
            # "the worker is gone" onto TimeoutError for recv_all
            if not self._conn.poll(timeout):
                raise TimeoutError("island worker did not sync in time")
            try:
                return self._conn.recv()
            except (EOFError, OSError):
                raise TimeoutError(
                    "island worker died (connection closed) — killed by "
                    "the OS (OOM?) or crashed outside Python") from None
        try:
            return self._inbox.get(timeout=timeout)
        except queue.Empty:
            raise TimeoutError("island worker did not sync in time") from None


def _sync_gens(generations: int, migrate_every: int) -> List[int]:
    """Generations at which all islands barrier with the parent: every
    ``migrate_every``-th (elite exchange) plus at least every
    ``OBSERVE_EVERY_MAX``-th (observation only: budget/patience checks,
    no migrants), except on the very last generation (the final
    cross-island max already sees every island's best, and stopping there
    stops nothing)."""
    cadence = min(migrate_every, OBSERVE_EVERY_MAX)
    return [g for g in range(generations)
            if ((g + 1) % migrate_every == 0 or (g + 1) % cadence == 0)
            and g + 1 < generations]


def _island_worker(problem: SearchProblem, config: GAConfig,
                   sync_gens: List[int], migration_gens: List[int],
                   migrants: int, chan: _Chan) -> None:
    """One island: run the full GA, pausing at each sync generation to trade
    elites through the parent; ends with a ("done", ...) result message."""
    sync_set = set(sync_gens)
    migration_set = set(migration_gens)
    stop = [False]

    stats = [0.0, 0, 0]                  # best / evals / offspring so far

    def migrate(gen, pool):
        if gen not in sync_set:
            return None
        # elites ride the sync message only when this barrier actually
        # migrates; observation-only barriers ship stats alone (the parent
        # would discard the elites anyway, so payloads stay minimal and
        # results are unchanged)
        if gen in migration_set:
            elite = sorted(pool, key=lambda fs: -fs[0])[:migrants]
            payload = [(f, problem.encode_genome(g)) for f, g in elite]
        else:
            payload = []
        # best is current; evals/offspring lag one generation (the observer
        # updates them after migration) — budget checks are coarse anyway
        chan.send(("sync", gen, payload,
                   (max(f for f, _ in pool), stats[1], stats[2])))
        cmd, immigrants = chan.recv()
        if cmd == "stop":
            stop[0] = True
        return inject_migrants(problem, pool, immigrants)

    def observe(gen, best, evals, offspring):
        stats[0], stats[1], stats[2] = best, evals, offspring
        return stop[0]

    try:
        res = run_ga_problem(problem, config, observe, migrate=migrate)
        chan.send(("done", problem.encode_genome(res.best_state),
                   res.best_fitness, res.history, res.evaluations,
                   res.offspring_evaluated))
    except BaseException as e:                      # surface, don't deadlock
        chan.send(("error", f"{type(e).__name__}: {e}"))
        raise


def _fork_context():
    """The fork multiprocessing context, or None when island processes
    cannot be spawned here: no fork on this platform, or this process is
    itself a daemonic pool worker (e.g. a BatchScheduler search worker) —
    daemons may not have children, so islands degrade to threads."""
    import multiprocessing
    if multiprocessing.current_process().daemon:
        return None
    try:
        return multiprocessing.get_context("fork")
    except ValueError:
        return None


@register_backend("island")
class IslandBackend(SearchBackend):
    """Island-model parallel GA (ring migration of elites).

    Config keys: ``islands`` (parallel populations, default 4),
    ``migrate_every`` (generations between elite exchanges, default 20),
    ``migrants`` (elites shipped around the ring per exchange, default 2),
    ``workers`` (``"process"`` | ``"thread"``, default ``"process"`` with a
    thread fallback where fork is unavailable) — plus every ``ga`` backend
    key (``preset``, ``generations``, ``population``, ``top_n``,
    ``mutations_per_gen``, ``random_survivors``, ``crossover_rate``,
    ``ga_config``), which configures each island identically.  Island ``i``
    searches with the deterministic seed ``island_seed(seed, i)``; at
    ``islands=1`` the run is bit-identical to the ``ga`` backend.
    """

    name = "island"

    def run(self, problem: SearchProblem, *, seed: int = 0,
            observer: Optional[Observer] = None, **config) -> GAResult:
        islands = int(config.pop("islands", 4))
        migrate_every = int(config.pop("migrate_every", 20))
        migrants = int(config.pop("migrants", 2))
        workers = config.pop("workers", "process")
        if islands < 1:
            raise BackendError(f"islands must be >= 1, got {islands}")
        if migrate_every < 1:
            raise BackendError(
                f"migrate_every must be >= 1, got {migrate_every}")
        if migrants < 1:
            raise BackendError(f"migrants must be >= 1, got {migrants}")
        if workers not in ("process", "thread"):
            raise BackendError(
                f"unknown workers mode {workers!r}; valid: process, thread")
        gc = config.get("ga_config")
        if islands > 1 and (isinstance(gc, GAConfig) or
                            (isinstance(gc, dict) and "seed" in gc)):
            # a ga_config seed wins inside make_config (ga-backend
            # semantics), which would collapse every island onto one seed
            # — N identical searches, migration a no-op
            raise BackendError(
                "island derives per-island seeds from SearchSpec.seed; "
                "pass ga_config as a dict without a seed (a live GAConfig "
                "always carries one)")
        configs = [GABackend.make_config(island_seed(seed, i), **dict(config))
                   for i in range(islands)]
        if islands == 1:
            # the degenerate archipelago IS the ga backend — delegate so
            # fixed-seed results are bit-identical (no migration machinery)
            return run_ga_problem(problem, configs[0], observer)
        sync_gens = _sync_gens(configs[0].generations, migrate_every)
        migration_gens = [g for g in sync_gens
                          if (g + 1) % migrate_every == 0]
        ctx = _fork_context() if workers == "process" else None
        # build every read-only shared structure BEFORE forking so workers
        # inherit the compiled graph, baseline costs, and population-engine
        # tables copy-on-write instead of each rebuilding them
        prewarm = getattr(problem, "prewarm", None)
        if prewarm is not None:
            prewarm()
        chans, workers_alive = self._spawn(problem, configs, sync_gens,
                                           migration_gens, migrants, ctx)
        try:
            return self._drive(problem, chans, sync_gens, migrate_every,
                               observer)
        finally:
            for w in workers_alive:
                w.join(timeout=30)

    # ---- parent side ------------------------------------------------------------
    @staticmethod
    def _spawn(problem, configs, sync_gens, migration_gens, migrants, ctx):
        chans: List[_Chan] = []
        alive = []
        for cfg in configs:
            if ctx is not None:
                parent_conn, child_conn = ctx.Pipe(duplex=True)
                chan_child = _Chan(conn=child_conn)
                w = ctx.Process(target=_island_worker,
                                args=(problem, cfg, sync_gens,
                                      migration_gens, migrants,
                                      chan_child), daemon=True)
                w.start()
                child_conn.close()      # parent keeps only its end
                chans.append(_Chan(conn=parent_conn))
                alive.append(w)
                continue
            to_child: queue.Queue = queue.Queue()
            to_parent: queue.Queue = queue.Queue()
            chan_child = _Chan(inbox=to_child, outbox=to_parent)
            w = threading.Thread(target=_island_worker,
                                 args=(problem, cfg, sync_gens,
                                       migration_gens, migrants,
                                       chan_child), daemon=True)
            chans.append(_Chan(inbox=to_parent, outbox=to_child))
            w.start()
            alive.append(w)
        return chans, alive

    @staticmethod
    def _drive(problem, chans, sync_gens, migrate_every, observer
               ) -> GAResult:
        n = len(chans)
        # telemetry collector the session attached (repro.obs), or None;
        # records barriers/migrations only — never feeds the stop decision
        col = getattr(problem, "obs", None)

        def recv_all(expect: str):
            msgs = []
            for i, chan in enumerate(chans):
                try:
                    msg = chan.recv()
                except TimeoutError as e:
                    raise BackendError(f"island {i}: {e}") from None
                if msg[0] == "error":
                    raise BackendError(f"island {i} failed: {msg[1]}")
                if msg[0] != expect:
                    raise BackendError(
                        f"island {i}: expected {expect!r}, got {msg[0]!r}")
                msgs.append(msg)
            return msgs

        try:
            stopped = False
            for gen in sync_gens:
                msgs = recv_all("sync")
                best = max(m[3][0] for m in msgs)
                evals = sum(m[3][1] for m in msgs)
                offspring = sum(m[3][2] for m in msgs)
                if observer is not None and observer(gen, best, evals,
                                                    offspring):
                    stopped = True
                migration = (gen + 1) % migrate_every == 0
                if col is not None:
                    col.record_migration(gen, best, n, migration)
                for i, chan in enumerate(chans):
                    # ring: island i receives island (i-1)'s elites; at
                    # observation-only syncs nothing migrates
                    emigrants = msgs[(i - 1) % n][2] if migration else []
                    chan.send(("stop" if stopped else "cont", emigrants))
                if stopped:
                    break
            results = recv_all("done")
        except BackendError:
            # one island died: release the healthy islands blocked (or soon
            # to block) at their sync barrier so they wind down now instead
            # of stalling the join and running until the recv timeout
            for chan in chans:
                try:
                    chan.send(("stop", []))
                except (OSError, ValueError):
                    pass                     # that island's pipe is gone
            raise
        # per-island GAResults; the archipelago's answer is the best across
        # islands (ties break toward the lowest island id, so islands=N is
        # never worse than any single member island at the same seed)
        best_i = max(range(n), key=lambda i: results[i][2])
        _, enc, best_f, history, _evals, _off = results[best_i]
        merged_hist = [max(h) for h in zip(*(m[3] for m in results))]
        return GAResult(
            best_state=problem.decode_genome(enc),
            best_fitness=best_f,
            history=merged_hist,
            # unique-per-island sums: cross-island duplicates are not
            # distinguishable without shipping every key home, so this is
            # an upper bound on globally unique genomes
            evaluations=sum(m[4] for m in results),
            offspring_evaluated=sum(m[5] for m in results))
