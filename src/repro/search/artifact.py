"""Durable search results: the winning genome + costs, JSON-round-trippable.

A :class:`ScheduleArtifact` is what a search session produces and what a
scheduler service would store/serve: the spec that ran, the winning
edge-bitmask genome, a structural fingerprint of the graph it was searched
on, baseline/best costs, and the convergence history.  Reports and
improvement ratios come straight from the artifact — no re-search — and
re-binding the genome onto a rebuilt graph is refused unless the graph's
fingerprint matches (a stale genome on a changed graph is silently wrong,
so it is an error instead).
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from repro.obs import clock

from repro.core.fusion import FusionState
from repro.core.graph import LayerGraph
from repro.core.schedule import ImprovementRatios
from repro.costmodel.base import CostBreakdown
from repro.costmodel.evaluator import ScheduleCost

from repro.search.spec import SearchSpec

ARTIFACT_VERSION = 1


class FingerprintMismatch(ValueError):
    """The artifact's genome belongs to a structurally different graph."""


def graph_fingerprint(graph: LayerGraph) -> str:
    """Stable hash of the graph *structure* the genome indexes — the
    sha256 of the graph's canonical :class:`repro.ir.GraphIR` byte form
    (layer geometry and input lists in insertion order, which fixes the
    edge-bit order of :class:`repro.core.graph.CompiledGraph`).  Defined
    over the serialized IR, so a graph and its exported-then-reimported
    twin fingerprint identically."""
    from repro.ir import GraphIR                   # lazy: keeps import light
    return GraphIR.from_graph(graph).fingerprint()


def _cost_to_dict(cost: ScheduleCost) -> Dict[str, Any]:
    return dataclasses.asdict(cost)


def _cost_from_dict(d: Dict[str, Any],
                    warnings: Optional[List[str]] = None) -> ScheduleCost:
    known = {f.name for f in dataclasses.fields(ScheduleCost)}
    extra = sorted(set(d) - known)
    if extra:
        # forward-compat: a newer writer's additions degrade to a warning
        if warnings is not None:
            warnings.append(f"ignoring unknown ScheduleCost fields {extra}")
        d = {k: v for k, v in d.items() if k in known}
    try:
        return ScheduleCost(**d)
    except TypeError as e:
        # missing required fields: baseline/best are load-bearing, so this
        # IS corrupt — but surface it as the artifact-error type callers
        # (CLI included) already handle, not a raw TypeError
        raise ValueError(f"malformed ScheduleCost record: {e}") from None


@dataclass
class ScheduleArtifact(ImprovementRatios):
    """A finished search, storable / diffable / re-loadable without
    re-searching."""

    spec: SearchSpec
    graph_fingerprint: str
    n_edges: int
    genome_mask: int
    best_fitness: float
    baseline: ScheduleCost
    best: ScheduleCost
    fused_edges: List[List[str]] = field(default_factory=list)
    history: List[float] = field(default_factory=list)
    evaluations: int = 0
    offspring_evaluated: int = 0
    wall_s: float = 0.0
    backend_stats: Dict[str, Any] = field(default_factory=dict)
    #: per-group CostBreakdown of the winning schedule (group order),
    #: so reports can show where energy/cycles go without re-costing
    group_breakdowns: List[CostBreakdown] = field(default_factory=list)
    #: the searched graph's :class:`repro.ir.GraphIR` dict — embedded for
    #: every workload without a registry entry (``file:``/``ir:`` specs)
    #: so the artifact rebuilds/re-binds with no originating code at all
    graph_ir: Optional[Dict[str, Any]] = None
    #: static fusion-space summary (``SearchSpec(spacemap=True)`` runs):
    #: frozen gene indices, region intervals, search-space sizes — what
    #: ``repro verify`` re-derives independently and compares
    #: (:meth:`repro.analysis.spacemap.SpaceMap.summary`)
    spacemap: Optional[Dict[str, Any]] = None
    #: compact search-telemetry summary (``SearchSpec(telemetry=True)`` or
    #: traced runs): convergence curve, rejection / cache-hit rates per
    #: generation, final metric snapshot — what ``repro report
    #: --telemetry`` renders without the raw trace
    #: (:meth:`repro.obs.collect.TelemetryCollector.summary`)
    telemetry: Optional[Dict[str, Any]] = None
    created_unix: int = 0
    version: int = ARTIFACT_VERSION
    #: non-fatal schema degradations seen while loading (pre-cost-breakdown
    #: writers, unknown fields, malformed breakdown rows); never serialized
    load_warnings: List[str] = field(default_factory=list)

    def summary(self) -> Dict[str, Any]:
        return {
            "workload": self.spec.workload,
            "accelerator": self.spec.accelerator,
            "backend": self.spec.backend,
            "costmodel": self.spec.costmodel,
            "seed": self.spec.seed,
            "energy_x": round(self.energy_improvement, 3),
            "edp_x": round(self.edp_improvement, 3),
            "cycles_x": round(self.cycles_improvement, 3),
            "dram_x": round(self.dram_improvement, 3),
            "groups": self.best.n_groups,
            "act_dram_writes_base": self.baseline.act_write_events,
            "act_dram_writes_best": self.best.act_write_events,
            "best_fitness": self.best_fitness,
            "evaluations": self.evaluations,
        }

    # ---- genome re-binding -----------------------------------------------------
    def state(self, graph: LayerGraph) -> FusionState:
        """Re-bind the winning genome onto ``graph``; refuses structurally
        different graphs (the bitmask would index the wrong edges)."""
        fp = graph_fingerprint(graph)
        if fp != self.graph_fingerprint:
            fmt = fp.split(":", 1)[0]
            if self.graph_fingerprint.split(":", 1)[0] != fmt:
                raise FingerprintMismatch(
                    f"artifact carries a {self.graph_fingerprint.split(':', 1)[0]!r}-"
                    f"format fingerprint but this build computes {fmt!r} "
                    f"(the fingerprint moved to the canonical repro.ir "
                    f"form); the stored genome cannot be safely re-bound "
                    f"— re-run the search to regenerate the artifact")
            raise FingerprintMismatch(
                f"artifact genome was searched on graph "
                f"{self.graph_fingerprint} but {graph.name!r} hashes to {fp}; "
                f"rebuild the workload exactly as specified "
                f"({self.spec.workload!r}, kwargs={self.spec.workload_kwargs})")
        return FusionState.from_mask(graph, self.genome_mask)

    def rebuild_graph(self) -> LayerGraph:
        """Rebuild the searched graph: from the embedded IR when present
        (no registry / file needed), else from the workload spec."""
        if self.graph_ir is not None:
            from repro.ir import GraphIR
            return GraphIR.from_dict(self.graph_ir).build()
        if self.spec.workload.startswith("ir:"):
            raise ValueError(
                f"artifact names embedded-IR workload "
                f"{self.spec.workload!r} but carries no graph_ir — it was "
                f"stripped or written by a session that did not embed it")
        from repro.search.registry import build_workload
        return build_workload(self.spec.workload, **self.spec.workload_kwargs)

    def rebuild_state(self) -> FusionState:
        return self.state(self.rebuild_graph())

    # ---- serialization ----------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        d = {
            "version": self.version,
            "created_unix": self.created_unix,
            "spec": self.spec.to_dict(),
            "graph_fingerprint": self.graph_fingerprint,
            "n_edges": self.n_edges,
            "genome_mask": hex(self.genome_mask),
            "fused_edges": self.fused_edges,
            "best_fitness": self.best_fitness,
            "baseline": _cost_to_dict(self.baseline),
            "best": _cost_to_dict(self.best),
            "history": self.history,
            "evaluations": self.evaluations,
            "offspring_evaluated": self.offspring_evaluated,
            "wall_s": self.wall_s,
            "backend_stats": self.backend_stats,
            "group_breakdowns": [bd.to_dict()
                                 for bd in self.group_breakdowns],
        }
        if self.graph_ir is not None:     # only self-contained artifacts
            d["graph_ir"] = self.graph_ir
        if self.spacemap is not None:     # only spacemap=True searches
            d["spacemap"] = self.spacemap
        if self.telemetry is not None:    # only telemetry-enabled searches
            d["telemetry"] = self.telemetry
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ScheduleArtifact":
        if d.get("version") != ARTIFACT_VERSION:
            raise ValueError(
                f"unsupported artifact version {d.get('version')!r} "
                f"(this build reads version {ARTIFACT_VERSION})")
        # auxiliary reporting data degrades to warnings, never to a crash:
        # artifacts written before the CostModel protocol carry no per-group
        # breakdowns, and a malformed row should not make the genome and
        # costs (the load-bearing content) unreadable
        warnings: List[str] = []
        if "group_breakdowns" not in d:
            warnings.append(
                "artifact predates per-group cost breakdowns (older "
                "writer); breakdown table unavailable — re-run the search "
                "to regenerate it")
        breakdowns = []
        for i, b in enumerate(d.get("group_breakdowns", [])):
            try:
                breakdowns.append(CostBreakdown.from_dict(b))
            except (KeyError, TypeError, AttributeError) as e:
                warnings.append(
                    f"dropping malformed group breakdown row {i}: "
                    f"{type(e).__name__}: {e}")
        try:
            return cls._from_dict_checked(d, warnings, breakdowns)
        except KeyError as e:
            # a truncated artifact missing a whole required object is
            # corrupt, but callers (CLI included) handle ValueError
            raise ValueError(
                f"artifact missing required field {e.args[0]!r}") from None

    @classmethod
    def _from_dict_checked(cls, d, warnings, breakdowns
                           ) -> "ScheduleArtifact":
        return cls(
            spec=SearchSpec.from_dict(d["spec"]),
            graph_fingerprint=d["graph_fingerprint"],
            n_edges=d["n_edges"],
            genome_mask=int(d["genome_mask"], 16),
            best_fitness=d["best_fitness"],
            baseline=_cost_from_dict(d["baseline"], warnings),
            best=_cost_from_dict(d["best"], warnings),
            fused_edges=[list(e) for e in d.get("fused_edges", [])],
            history=d.get("history", []),
            evaluations=d.get("evaluations", 0),
            offspring_evaluated=d.get("offspring_evaluated", 0),
            wall_s=d.get("wall_s", 0.0),
            backend_stats=d.get("backend_stats", {}),
            group_breakdowns=breakdowns,
            graph_ir=d.get("graph_ir"),
            spacemap=d.get("spacemap"),
            telemetry=d.get("telemetry"),
            created_unix=d.get("created_unix", 0),
            load_warnings=warnings,
        )

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True) + "\n"

    @classmethod
    def from_json(cls, text: str) -> "ScheduleArtifact":
        return cls.from_dict(json.loads(text))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path: str) -> "ScheduleArtifact":
        with open(path) as f:
            return cls.from_json(f.read())


def make_artifact(spec: SearchSpec, graph: LayerGraph, result,
                  baseline: ScheduleCost, best: ScheduleCost,
                  wall_s: float = 0.0,
                  backend_stats: Optional[Dict[str, Any]] = None,
                  group_breakdowns: Optional[List[CostBreakdown]] = None,
                  embed_ir: bool = False,
                  spacemap: Optional[Dict[str, Any]] = None,
                  telemetry: Optional[Dict[str, Any]] = None
                  ) -> ScheduleArtifact:
    """Package a finished backend run (``result``: GAResult over fusion
    genomes) into a durable artifact.  ``embed_ir`` snapshots the graph's
    exact :class:`repro.ir.GraphIR` into the artifact (self-contained:
    report/rebind need no registry)."""
    state: FusionState = result.best_state
    return ScheduleArtifact(
        spec=spec,
        graph_fingerprint=graph_fingerprint(graph),
        n_edges=graph.compiled().m,
        genome_mask=state.mask,
        fused_edges=sorted([u, v] for u, v in state.fused),
        best_fitness=result.best_fitness,
        baseline=baseline,
        best=best,
        history=list(result.history),
        evaluations=result.evaluations,
        offspring_evaluated=result.offspring_evaluated,
        wall_s=wall_s,
        backend_stats=dict(backend_stats or {}),
        group_breakdowns=list(group_breakdowns or []),
        graph_ir=graph.to_ir().to_dict() if embed_ir else None,
        spacemap=spacemap,
        telemetry=telemetry,
        created_unix=clock.unix_time(),
    )
