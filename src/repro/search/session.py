"""Search sessions: resolve a spec, drive a backend, produce an artifact.

    spec    = SearchSpec(workload="mobilenet_v3", accelerator="simba")
    session = SearchSession(spec)
    artifact = session.run(progress=print)      # -> ScheduleArtifact

The session owns the live objects (graph, evaluator, problem, backend
result) so in-process callers can inspect caches or render schedules, while
the returned artifact is the durable, serializable product.  Budget and
patience from the spec are enforced here through the backend observer hook,
so individual backends stay oblivious to stopping policy.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Tuple

from repro.core.graph import LayerGraph
from repro.core.problem import FusionProblem
from repro.costmodel.accelerator import Accelerator
from repro.costmodel.energy import DEFAULT_ENERGY, EnergyModel
from repro.costmodel.evaluator import NATIVE_OBJECTIVES, Evaluator
from repro.obs import (TelemetryCollector, Tracer, clock,
                       trace_path_from_env)

from repro.search.artifact import ScheduleArtifact, make_artifact
from repro.search.backends import BackendError
from repro.search.registry import (BACKENDS, OBJECTIVES, build_accelerator,
                                   build_costmodel, build_workload)
from repro.search.spec import SearchSpec


class Progress(NamedTuple):
    """One progress tick from the running backend."""
    step: int                 # generation / chunk index (backend-defined)
    best_fitness: float
    evaluations: int          # unique genomes scored
    offspring_evaluated: int  # total genomes submitted


class _CustomObjectiveProblem(FusionProblem):
    """Fusion problem scored by a registry objective the evaluator does not
    know natively: costs still come from the memoized group cache, but the
    metric is the registered ``(ScheduleCost) -> float`` function."""

    def __init__(self, graph, evaluator, objective: str, spacemap=None):
        super().__init__(graph, evaluator, objective, spacemap=spacemap)
        self._metric = OBJECTIVES.get(objective)
        self._baseline = self._metric(evaluator.layerwise())

    def fitness(self, genome) -> float:
        cost = self.evaluator.evaluate(genome)
        if cost is None:
            return 0.0
        new = self._metric(cost)
        return self._baseline / new if new > 0 else 0.0

    def fitness_batch(self, genomes):
        return [self.fitness(g) for g in genomes]

    fitness_batch_unique = fitness_batch   # evaluator can't score this metric


class SearchSession:
    """One search: spec -> (resolved objects) -> backend run -> artifact."""

    def __init__(self, spec: SearchSpec, *, graph: Optional[LayerGraph] = None,
                 accelerator: Optional[Accelerator] = None,
                 em: Optional[EnergyModel] = None,
                 embed_ir: Optional[bool] = None,
                 trace_path: Optional[str] = None,
                 obs: Optional[TelemetryCollector] = None):
        self.spec = spec
        # JSONL span destination (CLI --trace); REPRO_TRACE is the env
        # fallback, checked at run() so tests can set it per-run
        self.trace_path = trace_path
        # externally-owned collector (repro.serve.daemon): the session
        # attaches it for the run so callers can stream per-generation
        # records live, but does NOT embed its summary in the artifact
        # unless the spec itself asks for telemetry — daemon-produced
        # artifacts stay byte-compatible with direct SearchSession runs
        self._external_obs = obs
        self.telemetry: Optional[TelemetryCollector] = None
        # artifacts for workloads with no registry entry (file: documents,
        # direct graphs recorded as ir:<fingerprint>) embed the canonical
        # GraphIR so they stay reproducible anywhere; registry workloads
        # can opt in (embed_ir=True / CLI --embed-ir)
        self.embed_ir = bool(embed_ir) if embed_ir is not None else \
            spec.workload.startswith(("file:", "ir:"))
        # resolve everything eagerly so bad names fail at session creation,
        # not generations into a search
        if "seed" in spec.backend_config or "observer" in spec.backend_config:
            raise BackendError(
                "set the seed via SearchSpec.seed (CLI: --seed) and progress "
                "hooks via run(progress=...), not backend_config")
        ga_cfg = spec.backend_config.get("ga_config")
        ga_obj = ga_cfg.get("objective", spec.objective) \
            if isinstance(ga_cfg, dict) else \
            getattr(ga_cfg, "objective", spec.objective)
        if ga_obj != spec.objective:
            # run_ga_problem never reads GAConfig.objective (the problem
            # carries the spec's); a divergent value would be silently
            # ignored, so refuse it instead
            raise BackendError(
                f"ga_config objective {ga_obj!r} conflicts with "
                f"SearchSpec.objective {spec.objective!r}")
        self.backend = BACKENDS.get(spec.backend)()
        OBJECTIVES.get(spec.objective)
        costmodel_factory = build_costmodel(spec.costmodel)
        self.graph = graph if graph is not None else \
            build_workload(spec.workload, **spec.workload_kwargs)
        self.accelerator = accelerator if accelerator is not None else \
            build_accelerator(spec.accelerator)
        self.evaluator = Evaluator(self.graph, self.accelerator,
                                   em or DEFAULT_ENERGY,
                                   costmodel=costmodel_factory)
        # static fusion-space analysis (opt-in): frozen genes + regions,
        # derived independently of the engine (repro.analysis.spacemap)
        self.spacemap = None
        if spec.spacemap:
            from repro.analysis.spacemap import build_spacemap
            self.spacemap = build_spacemap(self.graph, spec.costmodel,
                                           spec.accelerator)
        if spec.objective in NATIVE_OBJECTIVES:
            self.problem = FusionProblem(self.graph, self.evaluator,
                                         spec.objective,
                                         spacemap=self.spacemap)
        else:
            self.problem = _CustomObjectiveProblem(self.graph, self.evaluator,
                                                   spec.objective,
                                                   spacemap=self.spacemap)
        self.result = None                 # GAResult after run()
        self.artifact: Optional[ScheduleArtifact] = None

    @classmethod
    def from_objects(cls, graph: LayerGraph, accelerator: Accelerator,
                     spec: Optional[SearchSpec] = None, *,
                     em: Optional[EnergyModel] = None,
                     **spec_kwargs) -> "SearchSession":
        """Session over pre-built objects (graphs not in the registry).

        The fabricated spec records the workload as ``ir:<fingerprint>``
        — not the graph's bare name, which may collide with (or be absent
        from) the registry — and the artifact embeds the graph's IR, so
        the result is reproducible without the code that built it."""
        if spec is None:
            from repro.search.artifact import graph_fingerprint
            spec = SearchSpec(workload=f"ir:{graph_fingerprint(graph)}",
                              accelerator=accelerator.name, **spec_kwargs)
        return cls(spec, graph=graph, accelerator=accelerator, em=em)

    # ---- running ---------------------------------------------------------------
    def _telemetry_setup(self) -> Tuple[Optional[TelemetryCollector],
                                        Optional[Tracer]]:
        """Build and attach the collector when telemetry is on; (None, None)
        otherwise — the disabled path allocates nothing.  An external
        collector (``obs=``) is attached as-is: the session never owns its
        tracer and tracing env/args are ignored for the run."""
        tracer: Optional[Tracer] = None
        if self._external_obs is not None:
            collector = self._external_obs
        else:
            path = self.trace_path or trace_path_from_env()
            if not (self.spec.telemetry or path):
                return None, None
            tracer = Tracer(path) if path else None
            collector = TelemetryCollector(tracer=tracer)
        self.evaluator.attach_telemetry(collector)
        # island workers reach the collector via the problem they fork with
        self.problem.obs = collector
        collector.begin_search({
            "workload": self.spec.workload,
            "accelerator": self.spec.accelerator,
            "objective": self.spec.objective,
            "backend": self.spec.backend,
            "costmodel": self.spec.costmodel,
            "seed": self.spec.seed,
        })
        self.telemetry = collector
        return collector, tracer

    def _observer(self, progress: Optional[Callable[[Progress], None]],
                  collector: Optional[TelemetryCollector] = None):
        spec = self.spec
        state = {"best": -1.0, "stale": 0}

        def observe(step: int, best: float, evals: int, offspring: int
                    ) -> bool:
            # telemetry ticks first so a progress callback already sees the
            # generation's record; it only records — the stop decision below
            # never reads it, so budget/patience behave identically on/off
            if collector is not None:
                collector.on_step(step, best, evals, offspring)
            if progress is not None:
                progress(Progress(step, best, evals, offspring))
            stop = False
            if spec.budget is not None and offspring >= spec.budget:
                stop = True
            if spec.patience is not None:
                if best > state["best"] + 1e-15:
                    state["best"], state["stale"] = best, 0
                else:
                    state["stale"] += 1
                    if state["stale"] >= spec.patience:
                        stop = True
            return stop

        return observe

    def run(self, progress: Optional[Callable[[Progress], None]] = None
            ) -> ScheduleArtifact:
        """Drive the backend to completion and package the artifact."""
        collector, tracer = self._telemetry_setup()
        t0 = clock.perf_counter()
        try:
            self.result = self.backend.run(
                self.problem, seed=self.spec.seed,
                observer=self._observer(progress, collector),
                **self.spec.backend_config)
        finally:
            # detach even on failure so the evaluator/problem never leak a
            # collector into a later run on the same session objects
            if collector is not None:
                self.evaluator.attach_telemetry(None)
                self.problem.obs = None
        wall_s = clock.perf_counter() - t0
        best_cost = self.evaluator.evaluate(self.result.best_state)
        assert best_cost is not None, \
            "backend returned an invalid best state"
        breakdowns = self.evaluator.breakdowns(self.result.best_state)
        telemetry = None
        if collector is not None:
            stats = self.evaluator.cache_stats()
            collector.end_search(stats)
            if tracer is not None:
                tracer.close()
            # external collectors record for their owner (the daemon); the
            # artifact embeds a summary only when the spec opted in, so a
            # daemon-run artifact is byte-identical to a direct run's
            if self._external_obs is None or self.spec.telemetry:
                telemetry = collector.summary(stats)
        self.artifact = make_artifact(
            self.spec, self.graph, self.result,
            baseline=self.evaluator.layerwise(), best=best_cost,
            wall_s=wall_s, backend_stats=self.evaluator.cache_stats(),
            group_breakdowns=breakdowns, embed_ir=self.embed_ir,
            spacemap=self.spacemap.summary() if self.spacemap else None,
            telemetry=telemetry)
        return self.artifact

    # ---- compatibility ----------------------------------------------------------
    def schedule_result(self):
        """The pre-facade :class:`repro.core.schedule.ScheduleResult` view
        (kept for the ``core.schedule.optimize`` shim and report rendering)."""
        from repro.core.schedule import ScheduleResult
        assert self.result is not None and self.artifact is not None, \
            "run() the session first"
        return ScheduleResult(
            workload=self.graph.name, accelerator=self.accelerator.name,
            baseline=self.artifact.baseline, best=self.artifact.best,
            best_state=self.result.best_state, ga=self.result)


def search(workload: str, accelerator: str = "simba", *,
           objective: str = "edp", backend: str = "ga",
           costmodel: str = "default", seed: int = 0,
           budget: Optional[int] = None, patience: Optional[int] = None,
           spacemap: bool = False, telemetry: bool = False,
           backend_config: Optional[dict] = None,
           workload_kwargs: Optional[dict] = None,
           progress: Optional[Callable[[Progress], None]] = None
           ) -> ScheduleArtifact:
    """One-call facade: build the spec, run the session, return the
    artifact.  Use :class:`SearchSession` directly when you need the live
    evaluator/result objects afterwards."""
    spec = SearchSpec(workload=workload, accelerator=accelerator,
                      objective=objective, backend=backend,
                      costmodel=costmodel,
                      backend_config=backend_config or {},
                      workload_kwargs=workload_kwargs or {},
                      seed=seed, budget=budget, patience=patience,
                      spacemap=spacemap, telemetry=telemetry)
    return SearchSession(spec).run(progress=progress)
