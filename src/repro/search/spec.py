"""Declarative search specification: everything needed to (re)run a search.

A :class:`SearchSpec` is the unit a scheduler service accepts and an
artifact embeds: registry names (not live objects) plus backend config,
seed, and budget, so it JSON-round-trips and two specs can be diffed
field-by-field.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Dict, Optional


@dataclass(frozen=True)
class SearchSpec:
    """What to search: (workload, accelerator, objective) — and how:
    (backend + config, seed, budget).

    ``workload``/``accelerator``/``objective``/``backend``/``costmodel``
    are registry names (``repro.search.registry``); ``workload`` accepts
    every spec form — ``name``, ``name@key=value,...`` (params coerced
    against the workload's schema), ``file:model.json`` (a
    ``repro.ir`` GraphIR document), or ``ir:<fingerprint>`` (IR embedded
    in the producing artifact); ``accelerator`` may
    carry a repartition suffix (``eyeriss@act+64``); ``costmodel`` picks
    the cost backend scoring the schedules (``default`` = the paper's
    mini-Timeloop mapper, ``tpu`` = the TPU roofline).  ``budget`` stops
    the search at the end of the first backend step (generation/chunk)
    that reaches this many offspring evaluations — the cap can overshoot
    by up to one step's worth (None = backend default); ``patience``
    stops after that many steps without improvement (None = run the full
    budget).
    """

    workload: str
    accelerator: str = "simba"
    objective: str = "edp"
    backend: str = "ga"
    costmodel: str = "default"
    backend_config: Dict[str, Any] = field(default_factory=dict)
    workload_kwargs: Dict[str, Any] = field(default_factory=dict)
    seed: int = 0
    budget: Optional[int] = None
    patience: Optional[int] = None
    #: opt into the static fusion-space analysis
    #: (:mod:`repro.analysis.spacemap`): provably forced-off genes are
    #: frozen out of the genome and the exhaustive backend enumerates per
    #: independent region.  Fixed-seed trajectories differ from
    #: ``spacemap=False`` runs (fewer RNG draws), hence opt-in.
    spacemap: bool = False
    #: opt into search telemetry (:mod:`repro.obs`): per-generation
    #: convergence records and an embedded artifact ``telemetry`` summary;
    #: span events additionally stream to a JSONL file when ``--trace`` /
    #: ``REPRO_TRACE`` names one.  Unlike ``spacemap`` this never changes
    #: the search itself: winner mask, fitness, RNG draw sequence, and
    #: store keys are bit-identical to ``telemetry=False`` (pinned by
    #: ``tests/test_obs_search.py``).
    telemetry: bool = False

    def __post_init__(self):
        # freeze the nested dicts against aliasing surprises: specs are
        # copied into artifacts and compared across sessions
        object.__setattr__(self, "backend_config",
                           dict(self.backend_config))
        object.__setattr__(self, "workload_kwargs",
                           dict(self.workload_kwargs))

    # ---- serialization --------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        for flag in ("spacemap", "telemetry"):
            if not d[flag]:
                # default-off fields serialize only when set: the canonical
                # spec JSON (and therefore every existing store content
                # address, which hashes it) is unchanged for specs written
                # by any earlier build
                del d[flag]
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "SearchSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown SearchSpec fields: {sorted(unknown)}")
        return cls(**d)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "SearchSpec":
        return cls.from_dict(json.loads(text))

    def replace(self, **changes) -> "SearchSpec":
        return dataclasses.replace(self, **changes)
