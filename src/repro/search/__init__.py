"""``repro.search`` — one pluggable search API over all workloads,
accelerators, and backends.

The paper's contribution is a *search procedure* (GA over fusion states,
§III); this package is its single entry point:

    from repro.search import search
    artifact = search("mobilenet_v3", "simba", backend="ga")
    print(artifact.summary())          # energy_x / edp_x / groups / ...
    artifact.save("schedule.json")     # durable, diffable, re-loadable

or, declaratively (what the CLI and a scheduler service speak):

    spec = SearchSpec(workload="resnet50", accelerator="eyeriss@act+64",
                      backend="hill_climb", seed=1)
    artifact = SearchSession(spec).run(progress=print)

Layers:

* **registries** — string-keyed workloads / accelerators / objectives /
  backends / costmodels with ``@register_*`` decorators (one function =
  one new entry); accelerators come from the hierarchical ``repro.hw``
  catalog;
* **backends** — strategies over the :class:`repro.core.problem.
  SearchProblem` protocol: ``ga`` (paper Alg. 1, reference), ``random``,
  ``hill_climb``, ``exhaustive``;
* **costmodels** — cost backends over the :class:`repro.costmodel.base.
  CostModel` protocol: ``default`` (the paper's mini-Timeloop mapper),
  ``tpu`` (the TPU roofline retarget);
* **spec -> session -> artifact** — a frozen :class:`SearchSpec`, a
  :class:`SearchSession` driving the backend with progress/early-stop
  hooks, and a JSON-round-trippable :class:`ScheduleArtifact` carrying the
  winning genome + graph fingerprint + costs + history;
* **tpu** — the TPU-retargeted problem (``repro.search.tpu``) runs through
  the same backends.

CLI: ``python -m repro search --workload mobilenet_v3 --accel simba
--backend ga --out artifact.json`` then ``python -m repro report
artifact.json``.
"""
from repro.search.artifact import (FingerprintMismatch, ScheduleArtifact,
                                   graph_fingerprint)
from repro.search.backends import (BackendError, ExhaustiveBackend,
                                   GABackend, HillClimbBackend,
                                   RandomBackend, SearchBackend)
from repro.search.island import IslandBackend, island_seed
from repro.search.registry import (ACCELERATORS, BACKENDS, COSTMODELS,
                                   OBJECTIVES, WORKLOADS, Registry,
                                   RegistryError, build_accelerator,
                                   build_costmodel, build_workload,
                                   get_workload, parse_workload_spec,
                                   register_accelerator, register_backend,
                                   register_costmodel, register_objective,
                                   register_workload, workload_schemas)
from repro.search.session import Progress, SearchSession, search
from repro.search.spec import SearchSpec
from repro.workloads.base import (FunctionWorkload, Param, Workload,
                                  WorkloadParamError)

__all__ = [
    "ACCELERATORS", "BACKENDS", "COSTMODELS", "OBJECTIVES", "WORKLOADS",
    "BackendError", "ExhaustiveBackend", "FingerprintMismatch",
    "FunctionWorkload", "GABackend", "HillClimbBackend", "IslandBackend",
    "Param", "Progress", "RandomBackend", "Registry", "RegistryError",
    "ScheduleArtifact", "SearchBackend", "SearchSession", "SearchSpec",
    "Workload", "WorkloadParamError", "build_accelerator",
    "build_costmodel", "build_workload", "get_workload",
    "graph_fingerprint", "island_seed", "parse_workload_spec",
    "register_accelerator", "register_backend", "register_costmodel",
    "register_objective", "register_workload", "search",
    "workload_schemas",
]
