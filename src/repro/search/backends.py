"""Pluggable search strategies over a :class:`~repro.core.problem.SearchProblem`.

Every backend implements one method —

    run(problem, seed=..., observer=..., **config) -> GAResult

— where ``observer(step, best_fitness, evaluations, offspring_evaluated)``
is called as the search progresses and may return True to stop early (the
session layers budgets/patience on top of it).  All backends return the
same :class:`repro.core.ga.GAResult`, so sessions, artifacts, and reports
are strategy-agnostic.

Built-ins:

* ``ga``         — the paper's Alg. 1 (reference implementation:
                   :func:`repro.core.ga.run_ga_problem`);
* ``random``     — uniform random genomes (or random walks when the problem
                   cannot sample uniformly), the paper's natural lower bound;
* ``hill_climb`` — greedy best-improvement over one-mutation (combine /
                   separate) neighborhoods;
* ``exhaustive`` — enumerate the whole space, up to a guard ``limit``
                   (default 2^16 states, the paper's §III-A sizing of
                   VGG-16's space over conv layers; this IR also genomes
                   pool/input edges — vgg16 here has 21 edges, so pass
                   ``limit`` explicitly to exhaust it).

New strategies subclass :class:`SearchBackend` and register with
``@register_backend("name")``.
"""
from __future__ import annotations

import itertools
import random
import time
from typing import Callable, List, Optional

from repro.core.ga import GAConfig, GAResult, run_ga_problem
from repro.core.problem import SearchProblem

from repro.search.registry import register_backend

Observer = Callable[[int, float, int, int], Optional[bool]]

#: default exhaustive-search ceiling, the paper's §III-A sizing of VGG-16's
#: space (2^16 over conv layers; overridable per-run via config limit)
EXHAUSTIVE_LIMIT = 1 << 16

#: batch size for backends that score genomes through ``fitness_batch``
_CHUNK = 128

#: batch size when the problem advertises an array-native batched evaluator
#: (amortizing per-batch engine overhead matters more than history
#: granularity for full enumerations)
_CHUNK_BATCHED = 1024


def _batch_chunk(problem: SearchProblem) -> int:
    """Chunk size for ``fitness_batch`` loops: bigger when the problem's
    evaluator batches through the array-native population engine."""
    ev = getattr(problem, "evaluator", None)
    if getattr(ev, "_pop_mode", "off") != "off":
        return _CHUNK_BATCHED
    return _CHUNK


def _estimate_runtime_s(problem: SearchProblem, size: int,
                        probe: int = 256) -> Optional[float]:
    """Rough full-enumeration runtime from one timed probe batch of random
    genomes; None when the problem cannot sample or scoring fails."""
    sampler = getattr(problem, "random_genome", None)
    if sampler is None:
        return None
    try:
        rng = random.Random(0)
        states = [sampler(rng) for _ in range(min(probe, size))]
        t0 = time.perf_counter()
        problem.fitness_batch(states)
        dt = time.perf_counter() - t0
    except Exception:
        return None
    if dt <= 0 or not states:
        return None
    return size * dt / len(states)


def _fmt_eta(seconds: float) -> str:
    if seconds < 120:
        return f"{seconds:.1f}s"
    if seconds < 7200:
        return f"{seconds / 60:.1f}min"
    return f"{seconds / 3600:.1f}h"


class BackendError(ValueError):
    """A backend cannot run with the given problem/config."""


class SearchBackend:
    """Base class for search strategies; subclasses set ``name`` and
    implement :meth:`run`."""

    name = "backend"

    def run(self, problem: SearchProblem, *, seed: int = 0,
            observer: Optional[Observer] = None, **config) -> GAResult:
        raise NotImplementedError

    @staticmethod
    def _reject_unknown(config, *known):
        unknown = set(config) - set(known)
        if unknown:
            raise BackendError(
                f"unknown backend config keys: {sorted(unknown)}; "
                f"valid: {sorted(known)}")


@register_backend("ga")
class GABackend(SearchBackend):
    """Paper Alg. 1 (§III-B) — the reference backend.

    Config keys mirror :class:`GAConfig` (``population``, ``top_n``,
    ``generations``, ``mutations_per_gen``, ``random_survivors``,
    ``crossover_rate``) plus ``preset`` (``"paper"`` | ``"fast"``); a
    prebuilt ``GAConfig`` can be passed as ``ga_config``.  The objective
    comes from the spec/problem, not from here.
    """

    name = "ga"

    @staticmethod
    def make_config(seed: int = 0, **config) -> GAConfig:
        if "objective" in config:
            raise BackendError(
                "set the objective via SearchSpec.objective "
                "(CLI: --objective), not backend_config")
        cfg = config.pop("ga_config", None)
        if cfg is not None:
            if config:
                raise BackendError(
                    "ga_config is exclusive with other config keys "
                    f"(got {sorted(config)})")
            if isinstance(cfg, GAConfig):
                return cfg
            if not isinstance(cfg, dict):
                raise BackendError(
                    f"ga_config must be a GAConfig or a dict of its "
                    f"fields, got {type(cfg).__name__}")
            # a JSON-round-tripped spec carries the config as a plain dict;
            # its own seed (if any) wins, like a live GAConfig's does
            try:
                return GAConfig(**{"seed": seed, **cfg})
            except TypeError as e:
                raise BackendError(f"bad ga_config: {e}") from None
        preset = config.pop("preset", "paper")
        maker = {"paper": GAConfig.paper, "fast": GAConfig.fast}.get(preset)
        if maker is None:
            raise BackendError(
                f"unknown ga preset {preset!r}; valid: fast, paper")
        try:
            return maker(seed=seed, **config)
        except TypeError as e:
            raise BackendError(f"bad ga config: {e}") from None

    def run(self, problem: SearchProblem, *, seed: int = 0,
            observer: Optional[Observer] = None, **config) -> GAResult:
        return run_ga_problem(problem, self.make_config(seed, **config),
                              observer)


@register_backend("random")
class RandomBackend(SearchBackend):
    """Random sampling (``evaluations`` genomes, default 1000).

    The initial genome is always included, so the result is never worse
    than the layerwise baseline.  ``mode="walk"`` (default) samples random
    walks of ``walk_len`` mutations (default 8) from the initial genome —
    the meaningful no-selection baseline for large fusion spaces, where
    ``mode="uniform"`` (uniform over the whole space, when the problem can
    sample it) almost surely draws invalid states.
    """

    name = "random"

    def run(self, problem: SearchProblem, *, seed: int = 0,
            observer: Optional[Observer] = None, **config) -> GAResult:
        self._reject_unknown(config, "evaluations", "walk_len", "mode")
        evaluations = int(config.get("evaluations", 1000))
        walk_len = int(config.get("walk_len", 8))
        mode = config.get("mode", "walk")
        if mode not in ("walk", "uniform"):
            raise BackendError(f"unknown random mode {mode!r}; "
                               f"valid: walk, uniform")
        rng = random.Random(seed)
        sampler = getattr(problem, "random_genome", None)
        if mode == "uniform" and sampler is None:
            raise BackendError(
                f"problem {problem.name!r} cannot sample uniformly; "
                f"use mode='walk'")

        def sample():
            if mode == "uniform":
                return sampler(rng)
            g = problem.initial()
            for _ in range(walk_len):
                g = problem.mutate(g, rng)
            return g

        best, best_f = problem.initial(), problem.fitness(problem.initial())
        seen = {problem.key(best)}
        history: List[float] = [best_f]
        done, step = 1, 0
        while done < evaluations:
            chunk = [sample() for _ in range(min(_CHUNK, evaluations - done))]
            fits = problem.fitness_batch(chunk)
            done += len(chunk)
            for g, f in zip(chunk, fits):
                seen.add(problem.key(g))
                if f > best_f:
                    best, best_f = g, f
            history.append(best_f)
            step += 1
            if observer is not None and observer(step, best_f, len(seen),
                                                 done):
                break
        return GAResult(best_state=best, best_fitness=best_f, history=history,
                        evaluations=len(seen), offspring_evaluated=done)


@register_backend("hill_climb")
class HillClimbBackend(SearchBackend):
    """Greedy best-improvement search over one-mutation neighborhoods:
    from the layerwise schedule, repeatedly apply the single combine /
    separate that most improves fitness; stop at a local optimum (or after
    ``max_steps``, default 10_000 moves)."""

    name = "hill_climb"

    def run(self, problem: SearchProblem, *, seed: int = 0,
            observer: Optional[Observer] = None, **config) -> GAResult:
        self._reject_unknown(config, "max_steps")
        max_steps = int(config.get("max_steps", 10_000))
        current = problem.initial()
        current_f = problem.fitness(current)
        history: List[float] = [current_f]
        seen = {problem.key(current)}
        done = 1
        for step in range(max_steps):
            moves = list(problem.neighbors(current))
            if not moves:
                break
            fits = problem.fitness_batch(moves)
            done += len(moves)
            for g in moves:
                seen.add(problem.key(g))
            best_i = max(range(len(moves)), key=lambda i: fits[i])
            if fits[best_i] <= current_f:
                break                        # local optimum
            current, current_f = moves[best_i], fits[best_i]
            history.append(current_f)
            if observer is not None and observer(step + 1, current_f,
                                                 len(seen), done):
                break
        return GAResult(best_state=current, best_fitness=current_f,
                        history=history, evaluations=len(seen),
                        offspring_evaluated=done)


def _pareto(rows):
    """Prune ``(delta_vec, mask)`` rows to the Pareto front under
    componentwise ``<=`` minimization (ties keep the lowest mask)."""
    front = []
    for vec, mask in sorted(rows, key=lambda r: (r[0], r[1])):
        if not any(all(fv <= v for fv, v in zip(fvec, vec))
                   for fvec, _ in front):
            front.append((vec, mask))
    return front


#: which summed :class:`ScheduleCost` components each *native* objective
#: reads — mirrors ``repro.costmodel.evaluator.NATIVE_OBJECTIVES``.  Every
#: listed component is additive over fused groups, which is what licenses
#: the per-region composition below: a region's masks only perturb the
#: groups inside it, so total = baseline + sum of per-region deltas.
_OBJECTIVE_COMPONENTS = {
    "edp": ("energy", "cycles"),       # product of two additive components
    "energy": ("energy",),
    "cycles": ("cycles",),
    "dram": ("dram",),
}


@register_backend("exhaustive")
class ExhaustiveBackend(SearchBackend):
    """Enumerate and score the entire genome space (ground truth for small
    graphs).  Refuses spaces larger than ``limit`` (default 2^16, the
    paper's §III-A count of VGG-16's space; raise it explicitly for graphs
    whose IR carries more edges).

    With a :class:`~repro.analysis.spacemap.SpaceMap` on the problem
    (``SearchSpec(spacemap=True)``) and a native objective, the space
    *factorizes*: regions confine every fused group, validity and all cost
    components decompose per region, so each region's ``2^{k_r}`` masks
    are enumerated independently and the winners composed exactly —
    per-region Pareto fronts over the objective's additive cost components
    (for ``edp``, the (energy, cycles) plane; EDP itself is not additive),
    then a dominance-pruned dynamic program across regions.  The ``limit``
    guard then applies to the *largest region*, which is what makes
    VGG-16's raw 2^21 space exactly solvable in a few dozen evaluations."""

    name = "exhaustive"

    def run(self, problem: SearchProblem, *, seed: int = 0,
            observer: Optional[Observer] = None, **config) -> GAResult:
        self._reject_unknown(config, "limit")
        limit = int(config.get("limit", EXHAUSTIVE_LIMIT))
        size = problem.space_size()
        if size is None:
            raise BackendError(
                f"problem {problem.name!r} is not enumerable")
        sm = getattr(problem, "spacemap", None)
        composable = (
            sm is not None and sm.regions
            # non-native objectives (registry metrics) need not be additive
            # over groups, and _CustomObjectiveProblem re-scores through
            # them — composition only holds for the native components
            and getattr(problem, "objective", None) in _OBJECTIVE_COMPONENTS
            and callable(getattr(getattr(problem, "evaluator", None),
                                 "evaluate", None)))
        if composable:
            largest = sm.largest_region_size()
            if largest > limit:
                raise BackendError(
                    f"largest spacemap region holds {largest} states, over "
                    f"the exhaustive limit {limit} (factorized total: "
                    f"{sm.factorized_states()} states across "
                    f"{len(sm.regions)} regions vs {size} flat); pass "
                    f"limit={largest} explicitly (API: backend_config="
                    f"{{\"limit\": {largest}}}; CLI: --backend-config "
                    f"'{{\"limit\": {largest}}}'), or use ga / hill_climb "
                    f"/ random instead")
            return self._run_per_region(problem, sm, observer)
        if size > limit:
            est = _estimate_runtime_s(problem, size)
            eta = (f" (estimated batched runtime for all {size} states: "
                   f"~{_fmt_eta(est)})" if est is not None else "")
            factored = (
                f" (a spacemap factorizes this into "
                f"{sm.factorized_states()} states across {len(sm.regions)} "
                f"regions, but objective "
                f"{getattr(problem, 'objective', None)!r} is not "
                f"group-additive, so per-region composition cannot apply)"
                if sm is not None else "")
            raise BackendError(
                f"space of {size} genomes exceeds the exhaustive limit "
                f"{limit}{factored}; pass limit={size} explicitly (API: "
                f"backend_config={{\"limit\": {size}}}; CLI: "
                f"--backend-config '{{\"limit\": {size}}}') if enumerating "
                f"{size} states is affordable{eta}, or use ga / hill_climb "
                f"/ random instead")
        best, best_f = None, -1.0
        history: List[float] = []
        done, step = 0, 0
        chunk_n = _batch_chunk(problem)
        genomes = iter(problem.enumerate())
        while True:
            chunk = list(itertools.islice(genomes, chunk_n))
            if not chunk:
                break
            fits = problem.fitness_batch(chunk)
            done += len(chunk)
            for g, f in zip(chunk, fits):
                if f > best_f:
                    best, best_f = g, f
            history.append(best_f)
            step += 1
            if observer is not None and observer(step, best_f, done, done):
                break
        if best is None:
            raise BackendError("empty genome space")
        return GAResult(best_state=best, best_fitness=best_f, history=history,
                        evaluations=done, offspring_evaluated=done)

    @staticmethod
    def _run_per_region(problem, sm, observer: Optional[Observer]
                        ) -> GAResult:
        """Exact search by region composition: enumerate each region's
        masks independently, keep its Pareto front of cost-component
        deltas vs the layerwise baseline, and compose fronts across
        regions by a dominance-pruned DP.  Sound because regions confine
        groups (validity is region-local) and every tracked component is
        additive over groups (delta vectors sum)."""
        ev = problem.evaluator
        obj = problem.objective
        comps = _OBJECTIVE_COMPONENTS[obj]

        def components(cost):
            by_name = {"energy": cost.energy_pj, "cycles": cost.cycles,
                       "dram": float(cost.dram_read_words
                                     + cost.dram_write_words)}
            return tuple(by_name[c] for c in comps)

        base_cost = ev.evaluate(problem.initial())
        assert base_cost is not None, "layerwise schedule must be valid"
        base = components(base_cost)

        def metric(delta):
            total = [b + d for b, d in zip(base, delta)]
            if obj == "edp":
                return total[0] * total[1]
            return total[0]

        # composed Pareto front over regions processed so far; the zero
        # delta with mask 0 (every region layerwise) is always present
        acc = [((0.0,) * len(comps), 0)]
        history: List[float] = []
        best_mask = 0
        done = 0
        for step, region in enumerate(sm.regions):
            bits = region.edge_indices
            front = []
            for sub in range(1 << len(bits)):
                mask = 0
                for j, i in enumerate(bits):
                    if (sub >> j) & 1:
                        mask |= 1 << i
                cost = ev.evaluate(problem.decode_genome(mask))
                done += 1
                if cost is None:
                    continue               # illegal grouping in this region
                front.append((tuple(c - b for c, b
                                    in zip(components(cost), base)), mask))
            acc = _pareto([(tuple(x + y for x, y in zip(av, fv)), am | fm)
                           for av, am in acc for fv, fm in front])
            best_mask = min(acc, key=lambda r: (metric(r[0]), r[1]))[1]
            best_f = problem.fitness(problem.decode_genome(best_mask))
            history.append(best_f)
            if observer is not None and observer(step + 1, best_f, done,
                                                 done):
                break
        best_state = problem.decode_genome(best_mask)
        # canonical re-score: the composed winner's fitness comes from the
        # evaluator itself, not from summed deltas (float sum-order ulps)
        best_f = problem.fitness(best_state)
        return GAResult(best_state=best_state, best_fitness=best_f,
                        history=history, evaluations=done,
                        offspring_evaluated=done)
