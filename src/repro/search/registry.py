"""String-keyed registries: workloads, accelerators, objectives, backends,
cost models.

Every extension point of the search facade is a named registry entry, so a
new workload / accelerator / objective / search strategy / cost backend is
one decorated function — not another entry-point script:

    from repro.search import register_workload

    @register_workload("tiny_cnn")
    def tiny_cnn() -> LayerGraph: ...

    repro search --workload tiny_cnn --accel simba --backend ga

Workload entries implement the parametric :class:`repro.workloads.base.
Workload` protocol (param schema + ``build``); bare callables are wrapped
automatically.  Everywhere a workload name is accepted, three spec forms
resolve:

* ``name`` or ``name@key=value,key=value`` — a registry entry, with
  params validated/coerced against its schema (``mobilenet_v3@hw=160``);
* ``file:model.json`` — a :mod:`repro.ir` GraphIR document imported
  through the canonicalization pipeline (no registration needed);
* ``ir:<fingerprint>`` — IR embedded in a search artifact; resolvable
  only through the artifact that carries it.

Accelerator specs additionally support the paper's Fig. 11 iso-capacity
repartitioning inline: ``eyeriss@act+64`` moves 64 KiB of weight buffer to
the activation buffer of the registered ``eyeriss`` template (``-`` moves it
back), so buffer-sweep experiments need no pre-registered variant per point.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Iterator, List, Optional, Tuple, TypeVar

from repro.workloads.base import (Workload, WorkloadParamError, as_workload)

T = TypeVar("T")


class RegistryError(LookupError):
    """Unknown name, or a duplicate registration without ``replace=True``."""


class Registry:
    """A named string -> object table with decorator registration."""

    def __init__(self, kind: str):
        self.kind = kind
        self._entries: Dict[str, object] = {}

    def register(self, name: str, obj: Optional[T] = None, *,
                 replace: bool = False):
        """Register ``obj`` under ``name``; with ``obj`` omitted, returns a
        decorator (``@REGISTRY.register("name")``)."""
        def _add(o: T) -> T:
            if not replace and name in self._entries:
                raise RegistryError(
                    f"{self.kind} {name!r} is already registered "
                    f"(pass replace=True to override)")
            self._entries[name] = o
            return o
        return _add if obj is None else _add(obj)

    def get(self, name: str):
        try:
            return self._entries[name]
        except KeyError:
            raise RegistryError(
                f"unknown {self.kind} {name!r}; valid: "
                + ", ".join(self.names())) from None

    def names(self) -> List[str]:
        return sorted(self._entries)

    def __contains__(self, name: str) -> bool:
        return name in self._entries

    def __iter__(self) -> Iterator[str]:
        return iter(self.names())

    def __len__(self) -> int:
        return len(self._entries)


WORKLOADS = Registry("workload")
ACCELERATORS = Registry("accelerator")
OBJECTIVES = Registry("objective")
BACKENDS = Registry("backend")
COSTMODELS = Registry("costmodel")


def register_workload(name: str, obj=None, *, replace: bool = False):
    """Register a workload: a :class:`~repro.workloads.base.Workload`
    (class or instance) or a plain ``(**kwargs) -> LayerGraph`` builder,
    which is wrapped in a schema-deriving
    :class:`~repro.workloads.base.FunctionWorkload`.  Decorator when
    ``obj`` is omitted (returns the original object)."""
    def _add(o):
        WORKLOADS.register(name, as_workload(o, name), replace=replace)
        return o
    return _add if obj is None else _add(obj)


def register_accelerator(name: str, obj=None, *, replace: bool = False):
    """Register a ``() -> Accelerator`` template factory (decorator when
    ``obj`` is omitted)."""
    return ACCELERATORS.register(name, obj, replace=replace)


def register_objective(name: str, obj=None, *, replace: bool = False):
    """Register a ``(ScheduleCost) -> float`` metric (lower is better;
    fitness is baseline_metric / candidate_metric).  Decorator when
    ``obj`` is omitted."""
    return OBJECTIVES.register(name, obj, replace=replace)


def register_backend(name: str, obj=None, *, replace: bool = False):
    """Register a :class:`repro.search.backends.SearchBackend` subclass
    (instantiated per session).  Decorator when ``obj`` is omitted."""
    return BACKENDS.register(name, obj, replace=replace)


def register_costmodel(name: str, obj=None, *, replace: bool = False):
    """Register a :class:`repro.costmodel.base.CostModel` factory —
    typically the class itself — called as
    ``factory(graph, accelerator, energy_model) -> CostModel`` once per
    search session.  Decorator when ``obj`` is omitted."""
    return COSTMODELS.register(name, obj, replace=replace)


_WL_SPEC = re.compile(r"^(?P<name>[^@]+)@(?P<params>.+)$")


def parse_workload_spec(spec: str) -> Tuple[str, Dict[str, str]]:
    """Split ``name[@key=value,key=value...]`` into (name, raw params);
    values stay strings — the workload's schema coerces them."""
    m = _WL_SPEC.match(spec)
    if m is None:
        if "@" in spec:
            raise WorkloadParamError(
                f"malformed workload spec {spec!r}; expected "
                f"name@key=value[,key=value...]")
        return spec, {}
    params: Dict[str, str] = {}
    for item in m.group("params").split(","):
        key, sep, value = item.partition("=")
        key, value = key.strip(), value.strip()
        if not sep or not key or not value:
            raise WorkloadParamError(
                f"malformed param {item!r} in workload spec {spec!r}; "
                f"expected key=value")
        if key in params:
            raise WorkloadParamError(
                f"duplicate param {key!r} in workload spec {spec!r}")
        params[key] = value
    return m.group("name"), params


def get_workload(name: str) -> Workload:
    """Resolve a registered workload to the protocol object (wrapping
    legacy bare-callable entries on the fly)."""
    return as_workload(WORKLOADS.get(name), name)


def build_workload(spec: str, **kwargs):
    """Build a workload's :class:`LayerGraph` from any spec form:
    registry ``name[@key=value,...]`` (params schema-checked) or a
    ``file:model.json`` GraphIR document.  ``kwargs`` merge with (and
    must not collide with) spec-string params."""
    if spec.startswith("file:"):
        if kwargs:
            raise WorkloadParamError(
                f"file: workload specs take no params "
                f"(got {sorted(kwargs)}); edit the IR document instead")
        from repro.ir import load
        from repro.workloads.base import GraphIRWorkload
        return GraphIRWorkload(load(spec[len("file:"):])).build()
    if spec.startswith("ir:"):
        raise RegistryError(
            f"workload spec {spec!r} names IR embedded in a search "
            f"artifact; it has no registry entry — rebuild it from the "
            f"artifact (ScheduleArtifact.rebuild_graph / repro report)")
    name, raw = parse_workload_spec(spec)
    workload = get_workload(name)
    overlap = sorted(set(raw) & set(kwargs))
    if overlap:
        raise WorkloadParamError(
            f"param(s) {overlap} given both in spec {spec!r} and in "
            f"workload_kwargs; pick one place")
    return workload.build(**{**raw, **kwargs})


def workload_schemas() -> Dict[str, Dict[str, Any]]:
    """Machine-readable registry view: every workload's doc line + param
    schema (what ``repro list --json`` emits)."""
    return {name: get_workload(name).describe() for name in WORKLOADS}


def build_costmodel(name: str):
    """Resolve a registered cost-model factory (not yet bound to a graph/
    accelerator — the session binds it)."""
    return COSTMODELS.get(name)


_REPART = re.compile(r"^(?P<base>[\w.-]+)@act(?P<delta>[+-]\d+)$")


def build_accelerator(spec: str):
    """Resolve an accelerator spec: a registered template name, optionally
    with a Fig.-11 repartition suffix (``eyeriss@act+64``)."""
    m = _REPART.match(spec)
    if m is None:
        return ACCELERATORS.get(spec)()
    acc = ACCELERATORS.get(m.group("base"))()
    return acc.repartition(int(m.group("delta")))


def _install_builtins() -> None:
    """Populate the registries from the paper's tables (idempotent)."""
    from repro.costmodel.default import DefaultCostModel
    from repro.costmodel.evaluator import NATIVE_OBJECTIVES
    from repro.costmodel.tpu_fusion import TpuFusionCostModel
    from repro.hw.catalog import ALL_SPECS
    from repro.workloads import WORKLOADS as _ZOO

    for wname, builder in _ZOO.items():
        if wname not in WORKLOADS:
            WORKLOADS.register(wname, as_workload(builder, wname))
    for aname, spec in ALL_SPECS.items():
        if aname not in ACCELERATORS:
            # the hierarchical description is the source of truth; the
            # registry serves the flat view the mappers consume
            # (repartition variants derive from it via the @act suffix)
            ACCELERATORS.register(aname, (lambda s: s.to_accelerator)(spec))
    for obj in NATIVE_OBJECTIVES:
        if obj not in OBJECTIVES:
            OBJECTIVES.register(
                obj, (lambda o: lambda cost: cost.metric(o))(obj))
    for cm in (DefaultCostModel, TpuFusionCostModel):
        if cm.name not in COSTMODELS:
            COSTMODELS.register(cm.name, cm)


_install_builtins()
