"""String-keyed registries: workloads, accelerators, objectives, backends,
cost models.

Every extension point of the search facade is a named registry entry, so a
new workload / accelerator / objective / search strategy / cost backend is
one decorated function — not another entry-point script:

    from repro.search import register_workload

    @register_workload("tiny_cnn")
    def tiny_cnn() -> LayerGraph: ...

    repro search --workload tiny_cnn --accel simba --backend ga

Accelerator specs additionally support the paper's Fig. 11 iso-capacity
repartitioning inline: ``eyeriss@act+64`` moves 64 KiB of weight buffer to
the activation buffer of the registered ``eyeriss`` template (``-`` moves it
back), so buffer-sweep experiments need no pre-registered variant per point.
"""
from __future__ import annotations

import re
from typing import Dict, Iterator, List, Optional, TypeVar

T = TypeVar("T")


class RegistryError(LookupError):
    """Unknown name, or a duplicate registration without ``replace=True``."""


class Registry:
    """A named string -> object table with decorator registration."""

    def __init__(self, kind: str):
        self.kind = kind
        self._entries: Dict[str, object] = {}

    def register(self, name: str, obj: Optional[T] = None, *,
                 replace: bool = False):
        """Register ``obj`` under ``name``; with ``obj`` omitted, returns a
        decorator (``@REGISTRY.register("name")``)."""
        def _add(o: T) -> T:
            if not replace and name in self._entries:
                raise RegistryError(
                    f"{self.kind} {name!r} is already registered "
                    f"(pass replace=True to override)")
            self._entries[name] = o
            return o
        return _add if obj is None else _add(obj)

    def get(self, name: str):
        try:
            return self._entries[name]
        except KeyError:
            raise RegistryError(
                f"unknown {self.kind} {name!r}; valid: "
                + ", ".join(self.names())) from None

    def names(self) -> List[str]:
        return sorted(self._entries)

    def __contains__(self, name: str) -> bool:
        return name in self._entries

    def __iter__(self) -> Iterator[str]:
        return iter(self.names())

    def __len__(self) -> int:
        return len(self._entries)


WORKLOADS = Registry("workload")
ACCELERATORS = Registry("accelerator")
OBJECTIVES = Registry("objective")
BACKENDS = Registry("backend")
COSTMODELS = Registry("costmodel")


def register_workload(name: str, obj=None, *, replace: bool = False):
    """Register a ``(**kwargs) -> LayerGraph`` builder (decorator when
    ``obj`` is omitted)."""
    return WORKLOADS.register(name, obj, replace=replace)


def register_accelerator(name: str, obj=None, *, replace: bool = False):
    """Register a ``() -> Accelerator`` template factory (decorator when
    ``obj`` is omitted)."""
    return ACCELERATORS.register(name, obj, replace=replace)


def register_objective(name: str, obj=None, *, replace: bool = False):
    """Register a ``(ScheduleCost) -> float`` metric (lower is better;
    fitness is baseline_metric / candidate_metric).  Decorator when
    ``obj`` is omitted."""
    return OBJECTIVES.register(name, obj, replace=replace)


def register_backend(name: str, obj=None, *, replace: bool = False):
    """Register a :class:`repro.search.backends.SearchBackend` subclass
    (instantiated per session).  Decorator when ``obj`` is omitted."""
    return BACKENDS.register(name, obj, replace=replace)


def register_costmodel(name: str, obj=None, *, replace: bool = False):
    """Register a :class:`repro.costmodel.base.CostModel` factory —
    typically the class itself — called as
    ``factory(graph, accelerator, energy_model) -> CostModel`` once per
    search session.  Decorator when ``obj`` is omitted."""
    return COSTMODELS.register(name, obj, replace=replace)


def build_workload(name: str, **kwargs):
    """Build a registered workload's :class:`LayerGraph`."""
    return WORKLOADS.get(name)(**kwargs)


def build_costmodel(name: str):
    """Resolve a registered cost-model factory (not yet bound to a graph/
    accelerator — the session binds it)."""
    return COSTMODELS.get(name)


_REPART = re.compile(r"^(?P<base>[\w.-]+)@act(?P<delta>[+-]\d+)$")


def build_accelerator(spec: str):
    """Resolve an accelerator spec: a registered template name, optionally
    with a Fig.-11 repartition suffix (``eyeriss@act+64``)."""
    m = _REPART.match(spec)
    if m is None:
        return ACCELERATORS.get(spec)()
    acc = ACCELERATORS.get(m.group("base"))()
    return acc.repartition(int(m.group("delta")))


def _install_builtins() -> None:
    """Populate the registries from the paper's tables (idempotent)."""
    from repro.costmodel.default import DefaultCostModel
    from repro.costmodel.evaluator import NATIVE_OBJECTIVES
    from repro.costmodel.tpu_fusion import TpuFusionCostModel
    from repro.hw.catalog import ALL_SPECS
    from repro.workloads import WORKLOADS as _ZOO

    for wname, builder in _ZOO.items():
        if wname not in WORKLOADS:
            WORKLOADS.register(wname, builder)
    for aname, spec in ALL_SPECS.items():
        if aname not in ACCELERATORS:
            # the hierarchical description is the source of truth; the
            # registry serves the flat view the mappers consume
            # (repartition variants derive from it via the @act suffix)
            ACCELERATORS.register(aname, (lambda s: s.to_accelerator)(spec))
    for obj in NATIVE_OBJECTIVES:
        if obj not in OBJECTIVES:
            OBJECTIVES.register(
                obj, (lambda o: lambda cost: cost.metric(o))(obj))
    for cm in (DefaultCostModel, TpuFusionCostModel):
        if cm.name not in COSTMODELS:
            COSTMODELS.register(cm.name, cm)


_install_builtins()
