"""The TPU scheduling retarget as a :class:`SearchProblem` (beyond-paper).

PR 1 left ``repro.core.tpu_ga`` with its own copy of the Alg. 1 selection
loop.  Here the genome (:class:`repro.costmodel.tpu_model.TpuSchedule`:
remat policy x microbatch count x gradient compression x sharding mode) is
expressed through the shared problem protocol over the analytical roofline
evaluator, so every backend in ``repro.search.backends`` — GA, random,
hill-climb, and (the space is only 60 schedules) exhaustive — applies
unchanged and the duplicate loop is gone.

Candidates whose HBM residency exceeds capacity are invalid (fitness 0),
exactly like the paper's activation-buffer capacity check; FSDP sharding is
invalid for MoE configs (expert parallelism needs the model axis).
"""
from __future__ import annotations

import itertools
import random
from typing import Dict, Iterator, List, Optional

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.ga import GAConfig
from repro.core.problem import SearchProblem
from repro.core.tpu_ga import TpuGAResult
from repro.costmodel.tpu_model import (MICROBATCH_OPTIONS, REMAT_OPTIONS,
                                       SHARDING_OPTIONS, TpuCost,
                                       TpuSchedule, estimate)
from repro.roofline.analysis import HW

from repro.search.backends import Observer
from repro.search.registry import BACKENDS


class TpuScheduleProblem(SearchProblem):
    """TPU training-schedule genomes scored by the roofline cost model."""

    name = "tpu_schedule"

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, *,
                 chips: int = 256, data_par: int = 16, model_par: int = 16,
                 hw: HW = HW(), objective: str = "edp",
                 hbm_capacity: Optional[float] = None):
        self.cfg = cfg
        self.shape = shape
        self.chips = chips
        self.data_par = data_par
        self.model_par = model_par
        self.hw = hw
        self.objective = objective
        self.hbm_capacity = hbm_capacity or hw.hbm_bytes
        self._cache: Dict[TpuSchedule, Optional[TpuCost]] = {}
        self.baseline = TpuSchedule()          # paper-faithful start
        # baseline cost is reported unchecked (it may well not fit HBM —
        # that is the point of the search); its *fitness* still goes
        # through the capacity check like everyone else's
        self.baseline_cost = estimate(cfg, shape, self.baseline, chips=chips,
                                      data_par=data_par, model_par=model_par,
                                      hw=hw)

    # ---- cost model ------------------------------------------------------------
    def cost_of(self, s: TpuSchedule) -> Optional[TpuCost]:
        """Memoized cost; None = invalid (over-capacity or unsupported)."""
        if s not in self._cache:
            if s.sharding == "fsdp" and self.cfg.n_experts:
                self._cache[s] = None  # EP needs the model axis (unsupported)
            else:
                c = estimate(self.cfg, self.shape, s, chips=self.chips,
                             data_par=self.data_par,
                             model_par=self.model_par, hw=self.hw)
                self._cache[s] = \
                    None if c.hbm_resident_bytes > self.hbm_capacity else c
        return self._cache[s]

    def _metric(self, c: TpuCost) -> float:
        return c.edp if self.objective == "edp" else c.step_s

    # ---- problem protocol ------------------------------------------------------
    def initial(self) -> TpuSchedule:
        return self.baseline

    def mutate(self, genome: TpuSchedule, rng: random.Random) -> TpuSchedule:
        opts = genome.mutate_options()
        return opts[rng.randrange(len(opts))]

    def fitness(self, genome: TpuSchedule) -> float:
        c = self.cost_of(genome)
        if c is None:
            return 0.0
        return self._metric(self.baseline_cost) / self._metric(c)

    def key(self, genome: TpuSchedule) -> TpuSchedule:
        return genome                          # frozen dataclass: hashable

    def neighbors(self, genome: TpuSchedule) -> List[TpuSchedule]:
        return genome.mutate_options()

    def random_genome(self, rng: random.Random) -> TpuSchedule:
        return TpuSchedule(
            remat=rng.choice(REMAT_OPTIONS),
            microbatches=rng.choice(MICROBATCH_OPTIONS),
            grad_compression=rng.random() < 0.5,
            sharding=rng.choice(SHARDING_OPTIONS))

    def enumerate(self) -> Iterator[TpuSchedule]:
        for remat, mb, gc, sh in itertools.product(
                REMAT_OPTIONS, MICROBATCH_OPTIONS, (False, True),
                SHARDING_OPTIONS):
            yield TpuSchedule(remat, mb, gc, sh)

    def space_size(self) -> int:
        return (len(REMAT_OPTIONS) * len(MICROBATCH_OPTIONS) * 2
                * len(SHARDING_OPTIONS))


def search_tpu_schedule(cfg: ModelConfig, shape: ShapeConfig, *,
                        chips: int = 256, data_par: int = 16,
                        model_par: int = 16, hw: HW = HW(),
                        objective: str = "edp", backend: str = "ga",
                        ga: GAConfig = GAConfig.fast(generations=30),
                        backend_config: Optional[dict] = None,
                        hbm_capacity: Optional[float] = None,
                        observer: Optional[Observer] = None) -> TpuGAResult:
    """Search remat/microbatch/compression/sharding for one (arch x shape)
    cell with any registered backend (``ga`` uses ``ga`` as its config)."""
    problem = TpuScheduleProblem(
        cfg, shape, chips=chips, data_par=data_par, model_par=model_par,
        hw=hw, objective=objective, hbm_capacity=hbm_capacity)
    config = dict(backend_config or {})
    if backend == "ga" and not config:
        # the ga= GAConfig is the default; explicit backend_config keys
        # (preset/generations/... or a caller-built ga_config) win instead
        config["ga_config"] = ga
    result = BACKENDS.get(backend)().run(
        problem, seed=ga.seed, observer=observer, **config)
    best_cost = problem.cost_of(result.best_state)
    assert best_cost is not None, "search returned an invalid best schedule"
    return TpuGAResult(best=result.best_state, best_cost=best_cost,
                       baseline=problem.baseline,
                       baseline_cost=problem.baseline_cost,
                       history=list(result.history),
                       evaluations=len(problem._cache))
