"""Span-scoped JSONL tracer: schema-versioned events, one per line.

Event schema (``v`` = :data:`SCHEMA_VERSION`):

completed span (written once, when the span closes)::

    {"v": 1, "pid": 123, "ev": "span", "name": "batch_eval", "id": 7,
     "parent": 3, "t0": 1700000000.1, "dur_s": 0.004, "attrs": {...}}

point event (instantaneous)::

    {"v": 1, "pid": 123, "ev": "point", "name": "island.migration",
     "parent": 3, "ts": 1700000000.2, "attrs": {...}}

Span ids come from one process-wide counter, so several tracers (or the
same tracer reached from several threads) never collide; a forked child
keeps writing to the inherited descriptor with its own ``pid``, so span
identity across a whole trace file is ``(pid, id)``.  Every event is
written-and-flushed as a single line, which keeps multi-process appends
intact in practice (lines are far below the pipe/page atomicity sizes).

Nesting is by ``parent`` id.  The tracer keeps an explicit ambient stack —
``span()`` is the context-manager convenience; instrumentation that needs
to close spans retroactively (the per-generation windows in
``SearchSession``) drives ``alloc_id``/``push``/``pop``/``emit_span``
directly.  :data:`NULL_TRACER` is the disabled-path singleton: every method
is a no-op and ``span()`` hands back one shared, reusable null context.

``repro trace <file.jsonl>`` (``repro.obs.traceview``) validates and
aggregates these files; :func:`validate_event` is the schema authority.
"""
from __future__ import annotations

import itertools
import json
import os
import threading
from typing import Any, Dict, List, Optional

from repro.obs import clock

SCHEMA_VERSION = 1

#: process-wide span id source (thread-safe in CPython; forked children
#: inherit the count position but differ in pid, so (pid, id) stays unique)
_span_ids = itertools.count(1)

_SPAN_KEYS = {"v", "pid", "ev", "name", "id", "parent", "t0", "dur_s",
              "attrs"}
_POINT_KEYS = {"v", "pid", "ev", "name", "parent", "ts", "attrs"}

#: sentinel: "parent defaults to the tracer's current ambient span"
_AMBIENT = object()


def validate_event(obj: Any) -> List[str]:
    """Schema-check one decoded JSONL event; returns the list of
    violations (empty = valid).  Strict about key sets so schema drift
    forces a ``v`` bump instead of silently passing."""
    if not isinstance(obj, dict):
        return ["event is not a JSON object"]
    errs: List[str] = []
    if obj.get("v") != SCHEMA_VERSION:
        errs.append(f"v={obj.get('v')!r} (this build reads "
                    f"v={SCHEMA_VERSION})")
    if not isinstance(obj.get("pid"), int) or isinstance(obj.get("pid"),
                                                         bool):
        errs.append("pid must be an integer")
    ev = obj.get("ev")
    if ev not in ("span", "point"):
        errs.append(f"ev={ev!r} (must be 'span' or 'point')")
        return errs
    name = obj.get("name")
    if not isinstance(name, str) or not name:
        errs.append("name must be a non-empty string")
    parent = obj.get("parent")
    if parent is not None and (not isinstance(parent, int)
                               or isinstance(parent, bool) or parent < 1):
        errs.append("parent must be null or a positive integer")
    attrs = obj.get("attrs")
    if not isinstance(attrs, dict):
        errs.append("attrs must be an object")
    allowed = _SPAN_KEYS if ev == "span" else _POINT_KEYS
    extra = sorted(set(obj) - allowed)
    if extra:
        errs.append(f"unknown keys {extra} (schema v{SCHEMA_VERSION})")
    if ev == "span":
        sid = obj.get("id")
        if not isinstance(sid, int) or isinstance(sid, bool) or sid < 1:
            errs.append("span id must be a positive integer")
        if not isinstance(obj.get("t0"), (int, float)):
            errs.append("t0 must be a number")
        dur = obj.get("dur_s")
        if not isinstance(dur, (int, float)) or dur < 0:
            errs.append("dur_s must be a non-negative number")
    else:
        if not isinstance(obj.get("ts"), (int, float)):
            errs.append("ts must be a number")
    return errs


class _NullSpan:
    """Reusable no-op context manager (one shared instance)."""

    __slots__ = ()

    def __enter__(self) -> None:
        return None

    def __exit__(self, *exc) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """Context manager behind :meth:`Tracer.span`."""

    __slots__ = ("_tracer", "_name", "_attrs", "_id", "_parent", "_t0",
                 "_p0")

    def __init__(self, tracer: "Tracer", name: str,
                 attrs: Optional[Dict[str, Any]]):
        self._tracer = tracer
        self._name = name
        self._attrs = attrs

    def __enter__(self) -> int:
        tr = self._tracer
        self._parent = tr.current()
        self._id = tr.alloc_id()
        self._t0 = clock.now()
        self._p0 = clock.perf_counter()
        tr.push(self._id)
        return self._id

    def __exit__(self, *exc) -> bool:
        tr = self._tracer
        tr.pop()
        tr.emit_span(self._name, t0=self._t0,
                     dur_s=clock.perf_counter() - self._p0,
                     span_id=self._id, parent=self._parent,
                     attrs=self._attrs)
        return False


class Tracer:
    """JSONL event writer with an ambient span stack."""

    enabled = True

    def __init__(self, path: Optional[str] = None, *, stream=None):
        if stream is not None:
            self._f = stream
            self._own = False
        elif path is not None:
            self._f = open(path, "a")
            self._own = True
        else:
            raise ValueError("Tracer needs a path or a stream")
        self._lock = threading.Lock()
        self._stack: List[int] = []

    # ---- ambient span stack -----------------------------------------------------
    def alloc_id(self) -> int:
        return next(_span_ids)

    def push(self, span_id: int) -> None:
        self._stack.append(span_id)

    def pop(self) -> Optional[int]:
        return self._stack.pop() if self._stack else None

    def current(self) -> Optional[int]:
        return self._stack[-1] if self._stack else None

    # ---- emission ---------------------------------------------------------------
    def _write(self, obj: Dict[str, Any]) -> None:
        line = json.dumps(obj, sort_keys=True, separators=(",", ":"))
        with self._lock:
            self._f.write(line + "\n")
            self._f.flush()              # one line per write: fork/append-safe

    def emit_span(self, name: str, *, t0: float, dur_s: float,
                  span_id: Optional[int] = None, parent: Any = _AMBIENT,
                  attrs: Optional[Dict[str, Any]] = None) -> int:
        """Write one completed span.  ``span_id`` lets callers that
        pre-allocated the id (so children could nest under it while it was
        open) close it retroactively; ``parent`` defaults to the current
        ambient span."""
        if span_id is None:
            span_id = self.alloc_id()
        if parent is _AMBIENT:
            parent = self.current()
        self._write({
            "v": SCHEMA_VERSION, "pid": os.getpid(), "ev": "span",
            "name": name, "id": span_id, "parent": parent,
            "t0": t0, "dur_s": dur_s, "attrs": attrs or {}})
        return span_id

    def point(self, name: str, *, parent: Any = _AMBIENT,
              attrs: Optional[Dict[str, Any]] = None) -> None:
        """Write one instantaneous event."""
        if parent is _AMBIENT:
            parent = self.current()
        self._write({
            "v": SCHEMA_VERSION, "pid": os.getpid(), "ev": "point",
            "name": name, "parent": parent, "ts": clock.now(),
            "attrs": attrs or {}})

    def span(self, name: str,
             attrs: Optional[Dict[str, Any]] = None) -> _Span:
        """``with tracer.span("search"):`` — opens on enter, emits the
        completed span on exit."""
        return _Span(self, name, attrs)

    def close(self) -> None:
        if self._own:
            self._f.close()


class NullTracer:
    """Disabled-path tracer: every operation is a no-op.  One shared
    instance (:data:`NULL_TRACER`); check ``.enabled`` before building
    attrs dicts on hot paths."""

    enabled = False

    def alloc_id(self) -> int:
        return 0

    def push(self, span_id: int) -> None:
        pass

    def pop(self) -> Optional[int]:
        return None

    def current(self) -> Optional[int]:
        return None

    def emit_span(self, name: str, **kw) -> int:
        return 0

    def point(self, name: str, **kw) -> None:
        pass

    def span(self, name: str, attrs=None) -> _NullSpan:
        return _NULL_SPAN

    def close(self) -> None:
        pass


NULL_TRACER = NullTracer()
