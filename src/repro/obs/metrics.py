"""Labeled metric instruments: counters, gauges, histograms.

A :class:`MetricRegistry` holds named series keyed by ``(name, labels)``;
``counter``/``gauge``/``histogram`` are get-or-create, so hot callers fetch
an instrument once and then touch only a slot attribute per event — no dict
churn on the recording path.  ``snapshot()`` renders every series into a
plain JSON-safe dict (sorted by series name), which is what benchmark
reports embed and what the tracer emits as a ``metrics.snapshot`` point at
search end.

The disabled path never constructs a registry at all (instrumented modules
guard on their collector being ``None``); :data:`NULL_REGISTRY` exists for
code that wants an unconditional registry handle.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple, Union

#: a series is (metric name, sorted (label, value) pairs)
SeriesKey = Tuple[str, Tuple[Tuple[str, str], ...]]


def series_name(name: str, labels: Tuple[Tuple[str, str], ...]) -> str:
    """Stable display form: ``name`` or ``name{k=v,...}`` (labels sorted)."""
    if not labels:
        return name
    return name + "{" + ",".join(f"{k}={v}" for k, v in labels) + "}"


class Counter:
    """Monotonically increasing event count."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def snapshot(self) -> int:
        return self.value


class Gauge:
    """Last-write-wins level (rates, sizes, ratios)."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def snapshot(self) -> float:
        return self.value


class Histogram:
    """Streaming distribution: count/total/min/max plus power-of-two
    magnitude buckets (``frexp`` exponent -> count), enough to see shape
    and tails without storing observations."""

    __slots__ = ("count", "total", "min", "max", "buckets")

    def __init__(self) -> None:
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf
        self.buckets: Dict[int, int] = {}

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        b = math.frexp(v)[1] if v > 0.0 else 0
        self.buckets[b] = self.buckets.get(b, 0) + 1

    def snapshot(self) -> Dict[str, object]:
        return {
            "count": self.count,
            "total": self.total,
            "min": self.min if self.count else 0.0,
            "max": self.max if self.count else 0.0,
            "mean": self.total / self.count if self.count else 0.0,
            # string keys: the snapshot must JSON-serialize with sort_keys
            "buckets": {str(k): self.buckets[k]
                        for k in sorted(self.buckets)},
        }


Instrument = Union[Counter, Gauge, Histogram]


class MetricRegistry:
    """Get-or-create registry of labeled series."""

    def __init__(self) -> None:
        self._series: Dict[SeriesKey, Instrument] = {}

    def _get(self, cls, name: str, labels: Dict[str, object]) -> Instrument:
        key: SeriesKey = (name, tuple(sorted(
            (k, str(v)) for k, v in labels.items())))
        inst = self._series.get(key)
        if inst is None:
            inst = cls()
            self._series[key] = inst
        elif not isinstance(inst, cls):
            raise TypeError(
                f"metric {series_name(*key)!r} is a "
                f"{type(inst).__name__}, not a {cls.__name__} — one series, "
                f"one instrument type")
        return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)  # type: ignore[return-value]

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)  # type: ignore[return-value]

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(Histogram, name,
                         labels)  # type: ignore[return-value]

    def snapshot(self) -> Dict[str, Dict[str, object]]:
        """All series as ``{"counters": {...}, "gauges": {...},
        "histograms": {...}}``, keyed by display name, sorted."""
        out: Dict[str, Dict[str, object]] = {
            "counters": {}, "gauges": {}, "histograms": {}}
        for key in sorted(self._series):
            inst = self._series[key]
            kind = {Counter: "counters", Gauge: "gauges",
                    Histogram: "histograms"}[type(inst)]
            out[kind][series_name(*key)] = inst.snapshot()
        return out

    def __len__(self) -> int:
        return len(self._series)


class _NullInstrument:
    """Shared no-op counter/gauge/histogram (disabled-path singleton)."""

    __slots__ = ()
    value = 0

    def inc(self, n: int = 1) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass


_NULL_INSTRUMENT = _NullInstrument()


class NullRegistry:
    """No-op :class:`MetricRegistry`: every lookup returns one shared
    do-nothing instrument and ``snapshot()`` is empty."""

    def counter(self, name: str, **labels) -> _NullInstrument:
        return _NULL_INSTRUMENT

    gauge = counter
    histogram = counter

    def snapshot(self) -> Dict[str, Dict[str, object]]:
        return {"counters": {}, "gauges": {}, "histograms": {}}

    def __len__(self) -> int:
        return 0


NULL_REGISTRY = NullRegistry()
