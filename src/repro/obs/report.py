"""Render an artifact's embedded ``telemetry`` summary (``repro report
--telemetry``) — from the artifact alone, no trace file needed."""
from __future__ import annotations

from typing import Any, Dict, List

#: rows rendered before the curve is downsampled (evenly, endpoints kept)
_MAX_ROWS = 20
_BAR_W = 32


def _sample(n: int, k: int) -> List[int]:
    """Up to ``k`` indices out of ``n``, evenly spaced, first/last kept."""
    if n <= k:
        return list(range(n))
    idx = {round(i * (n - 1) / (k - 1)) for i in range(k)}
    return sorted(idx)


def render_telemetry(summary: Dict[str, Any]) -> str:
    """The convergence curve + cache stats, as fixed-width text."""
    steps = summary.get("steps", 0)
    best = summary.get("best", [])
    mean = summary.get("mean", [])
    rej = summary.get("rejection_rate", [])
    hit = summary.get("group_hit_rate", [])
    uniq = summary.get("unique_states", [])
    lines: List[str] = []
    if not steps or not best:
        return "telemetry    : summary present but carries no " \
               "per-generation records"
    lines.append(
        f"telemetry    : {steps} steps, best {best[0]:.4f} -> "
        f"{best[-1]:.4f}"
        + (f", {uniq[-1]} unique states" if uniq else ""))
    lo, hi = min(best), max(best)
    span = (hi - lo) or 1.0
    lines.append("convergence  :   step      best      mean   rej%  hit%")
    for i in _sample(len(best), _MAX_ROWS):
        bar = "#" * max(1, round((best[i] - lo) / span * _BAR_W))
        lines.append(
            f"               {i:>6}  {best[i]:>8.4f}  "
            f"{(mean[i] if i < len(mean) else 0.0):>8.4f}  "
            f"{(rej[i] * 100 if i < len(rej) else 0.0):>5.1f} "
            f"{(hit[i] * 100 if i < len(hit) else 0.0):>5.1f}  |{bar}")
    cache = summary.get("cache", {})
    if cache:
        lines.append(
            f"cache        : group_hit_rate "
            f"{cache.get('group_hit_rate', 0.0):.4f}  "
            f"unique_groups {cache.get('unique_groups', 0)}  "
            f"engine {cache.get('pop_backend', '?')}  "
            f"batch_evals_per_sec "
            f"{cache.get('batch_evals_per_sec', 0.0):.0f}")
    counters = summary.get("metrics", {}).get("counters", {})
    if counters.get("eval.invalid") is not None \
            and counters.get("eval.states"):
        lines.append(
            f"rejection    : {counters['eval.invalid']} of "
            f"{counters['eval.states']} scored states were unschedulable "
            f"({counters['eval.invalid'] / counters['eval.states']:.1%})")
    return "\n".join(lines)
