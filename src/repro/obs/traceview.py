"""Aggregate a JSONL trace file (the ``repro trace`` verb).

Reads every line, validates it against the event schema
(:func:`repro.obs.trace.validate_event`), and rolls the events up into:

* the **span tree** — spans grouped by their name-path (parents resolved
  via ``(pid, id)``; spans whose parent never closed, e.g. forked-worker
  children of an unemitted window, root at their own name), with count /
  total / max duration per path;
* the **top-k slowest spans**;
* **metric rollups** — every ``metrics.snapshot`` point merged (counters
  summed, gauges last-wins, histograms combined), plus per-name point
  counts.

A file with any invalid line still aggregates (the bad lines are listed),
but ``valid`` is False and the CLI exits non-zero.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.obs.trace import validate_event

#: parent-chain depth bound (defends path building against id cycles in a
#: hand-edited file; real traces nest search > generation > batch > cost)
_MAX_DEPTH = 64


@dataclass
class TraceReport:
    """The aggregate ``repro trace`` renders."""

    path: str
    n_events: int = 0
    n_spans: int = 0
    n_points: int = 0
    errors: List[str] = field(default_factory=list)
    span_counts: Dict[str, int] = field(default_factory=dict)
    tree: List[Dict[str, Any]] = field(default_factory=list)
    slowest: List[Dict[str, Any]] = field(default_factory=list)
    point_counts: Dict[str, int] = field(default_factory=dict)
    metrics: Dict[str, Any] = field(default_factory=dict)

    @property
    def valid(self) -> bool:
        return not self.errors

    def to_dict(self) -> Dict[str, Any]:
        return {
            "path": self.path,
            "valid": self.valid,
            "n_events": self.n_events,
            "n_spans": self.n_spans,
            "n_points": self.n_points,
            "errors": self.errors,
            "span_counts": self.span_counts,
            "tree": self.tree,
            "slowest": self.slowest,
            "point_counts": self.point_counts,
            "metrics": self.metrics,
        }

    def describe(self) -> str:
        lines = [f"{self.path}: {self.n_events} events "
                 f"({self.n_spans} spans, {self.n_points} points) — "
                 + ("schema valid"
                    if self.valid else f"{len(self.errors)} INVALID line(s)")]
        for e in self.errors[:10]:
            lines.append(f"  error: {e}")
        if len(self.errors) > 10:
            lines.append(f"  ... and {len(self.errors) - 10} more")
        if self.tree:
            lines.append("span tree (count, total s, max s):")
            for row in self.tree:
                depth = row["path"].count("/")
                name = row["path"].rsplit("/", 1)[-1]
                lines.append(f"  {'  ' * depth}{name:<24} "
                             f"x{row['count']:<6} "
                             f"{row['total_s']:>9.4f}s  "
                             f"max {row['max_s']:.4f}s")
        if self.slowest:
            lines.append(f"slowest {len(self.slowest)} span(s):")
            for s in self.slowest:
                lines.append(f"  {s['dur_s']:>9.4f}s  {s['name']} "
                             f"(pid {s['pid']}, id {s['id']})")
        if self.point_counts:
            lines.append("points: " + "  ".join(
                f"{k} x{v}" for k, v in sorted(self.point_counts.items())))
        counters = self.metrics.get("counters", {})
        if counters:
            lines.append("metric rollup (counters):")
            for k, v in sorted(counters.items()):
                lines.append(f"  {k:<36} {v}")
        return "\n".join(lines)


def _merge_snapshot(acc: Dict[str, Any], snap: Dict[str, Any]) -> None:
    """Fold one ``metrics.snapshot`` point into the rollup: counters sum
    (per-process registries are disjoint streams), gauges last-wins,
    histograms combine."""
    for name, v in snap.get("counters", {}).items():
        if isinstance(v, (int, float)):
            acc["counters"][name] = acc["counters"].get(name, 0) + v
    for name, v in snap.get("gauges", {}).items():
        acc["gauges"][name] = v
    for name, h in snap.get("histograms", {}).items():
        if not isinstance(h, dict):
            continue
        cur = acc["histograms"].get(name)
        if cur is None:
            acc["histograms"][name] = dict(h)
            continue
        cur["count"] = cur.get("count", 0) + h.get("count", 0)
        cur["total"] = cur.get("total", 0.0) + h.get("total", 0.0)
        if h.get("count"):
            cur["min"] = min(cur.get("min", h["min"]), h["min"])
            cur["max"] = max(cur.get("max", h["max"]), h["max"])
        cur["mean"] = cur["total"] / cur["count"] if cur["count"] else 0.0
        buckets = cur.setdefault("buckets", {})
        for b, n in h.get("buckets", {}).items():
            buckets[b] = buckets.get(b, 0) + n


def read_trace(path: str, top: int = 10) -> TraceReport:
    """Parse, validate, and aggregate one trace file."""
    report = TraceReport(path=path)
    spans: Dict[Tuple[int, int], Dict[str, Any]] = {}
    metrics: Dict[str, Any] = {"counters": {}, "gauges": {},
                               "histograms": {}}
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                obj = json.loads(line)
            except json.JSONDecodeError as e:
                report.errors.append(f"line {lineno}: not JSON: {e.msg}")
                continue
            errs = validate_event(obj)
            if errs:
                report.errors.append(
                    f"line {lineno}: " + "; ".join(errs))
                continue
            report.n_events += 1
            if obj["ev"] == "span":
                report.n_spans += 1
                spans[(obj["pid"], obj["id"])] = obj
            else:
                report.n_points += 1
                name = obj["name"]
                report.point_counts[name] = \
                    report.point_counts.get(name, 0) + 1
                if name == "metrics.snapshot":
                    _merge_snapshot(metrics, obj.get("attrs", {}))
    report.metrics = metrics

    def name_path(span: Dict[str, Any]) -> str:
        parts = [span["name"]]
        pid, parent = span["pid"], span.get("parent")
        for _ in range(_MAX_DEPTH):
            if parent is None:
                break
            up = spans.get((pid, parent))
            if up is None:               # parent never emitted: root here
                break
            parts.append(up["name"])
            parent = up.get("parent")
        return "/".join(reversed(parts))

    paths: Dict[str, Dict[str, Any]] = {}
    for span in spans.values():
        report.span_counts[span["name"]] = \
            report.span_counts.get(span["name"], 0) + 1
        p = name_path(span)
        row = paths.get(p)
        if row is None:
            row = paths[p] = {"path": p, "count": 0, "total_s": 0.0,
                              "max_s": 0.0}
        row["count"] += 1
        row["total_s"] += span["dur_s"]
        if span["dur_s"] > row["max_s"]:
            row["max_s"] = span["dur_s"]
    for row in paths.values():
        row["total_s"] = round(row["total_s"], 6)
        row["max_s"] = round(row["max_s"], 6)
    report.tree = [paths[p] for p in sorted(paths)]
    report.slowest = [
        {"name": s["name"], "pid": s["pid"], "id": s["id"],
         "dur_s": round(s["dur_s"], 6), "attrs": s.get("attrs", {})}
        for s in sorted(spans.values(), key=lambda s: -s["dur_s"])[:top]]
    return report
