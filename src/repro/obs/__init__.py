"""``repro.obs`` — opt-in telemetry: metrics, tracing, convergence records.

Zero-overhead-when-disabled observability for the search engine, the serve
layer, and the verifiers:

* :class:`MetricRegistry` — labeled counters / gauges / histograms
  (:mod:`repro.obs.metrics`);
* :class:`Tracer` — span-scoped, schema-versioned JSONL events
  (``search`` -> ``generation`` -> ``batch_eval`` -> ``costmodel`` span
  nesting plus ``island.migration`` / ``serve.job`` / ``verify.*`` points;
  :mod:`repro.obs.trace`);
* :class:`TelemetryCollector` — the hook surface instrumented layers call
  (:mod:`repro.obs.collect`);
* :mod:`repro.obs.clock` — the engine's single wall-clock seam (enforced
  by ``repro lint``'s ``clock-seam`` rule).

Activation is explicit: ``SearchSpec(telemetry=True)``, the ``--trace``
CLI flag, or ``REPRO_TRACE=path.jsonl`` in the environment.  Off is the
default and is dead cheap — instrumented modules hold ``None`` and skip
with one attribute check per *batch*, never per offspring — and enabling
telemetry changes no search result: store keys and fixed-seed RNG draw
sequences are bit-identical either way (pinned by tests).

``repro trace <file.jsonl>`` aggregates raw traces
(:mod:`repro.obs.traceview`); ``repro report --telemetry`` renders the
summary artifacts embed (:mod:`repro.obs.report`).

This package is stdlib-only and imports nothing from the engine, so
boundary-pinned checkers (``repro.analysis.verify``) may use it freely.
"""
from repro.obs import clock
from repro.obs.collect import (SUMMARY_SCHEMA, TRACE_ENV, TelemetryCollector,
                               trace_path_from_env)
from repro.obs.metrics import (NULL_REGISTRY, Counter, Gauge, Histogram,
                               MetricRegistry, NullRegistry)
from repro.obs.trace import (NULL_TRACER, SCHEMA_VERSION, NullTracer, Tracer,
                             validate_event)

__all__ = [
    "clock",
    "Counter", "Gauge", "Histogram", "MetricRegistry", "NullRegistry",
    "NULL_REGISTRY",
    "Tracer", "NullTracer", "NULL_TRACER", "SCHEMA_VERSION",
    "validate_event",
    "TelemetryCollector", "TRACE_ENV", "SUMMARY_SCHEMA",
    "trace_path_from_env",
]
