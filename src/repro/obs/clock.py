"""The engine's single wall-clock seam.

Every instrumented module (``search.session``, ``search.artifact``,
``serve.scheduler``, ``costmodel.evaluator``, ``core.population``, and
``repro.obs`` itself) reads time through these three functions instead of
calling ``time.*`` directly.  The determinism linter's ``clock-seam`` rule
(``[tool.repro.lint.clock_seam]`` in pyproject.toml) enforces the routing,
so the wall-clock allowlist names exactly one file — this one — and every
wall-time read in the engine is auditable from a single seam.

Wall time here is *metadata only* (trace timestamps, artifact provenance);
it never feeds fingerprints, store keys, costs, or RNG.
"""
from __future__ import annotations

import time as _time


def unix_time() -> int:
    """Whole-second wall time (artifact ``created_unix``, report stamps)."""
    return int(_time.time())


def now() -> float:
    """Float wall time, for trace event timestamps."""
    return _time.time()


def perf_counter() -> float:
    """Monotonic high-resolution timer, for span durations and throughput."""
    return _time.perf_counter()
