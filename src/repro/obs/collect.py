"""The telemetry collector instrumented layers talk to.

One :class:`TelemetryCollector` spans one activity (a search, a serve
batch, a verify sweep): it owns a :class:`~repro.obs.metrics.MetricRegistry`
and an optional :class:`~repro.obs.trace.Tracer`, and exposes the narrow
recording hooks each layer calls:

* ``record_batch`` / ``note_group_costed`` — ``costmodel.Evaluator``, once
  per *batch* (never per offspring): states scored, novel genomes, invalid
  (schedulability-rejected) count, engine backend, novel groups costed.
  Emits nested ``batch_eval``/``costmodel`` spans.
* ``begin_search`` / ``on_step`` / ``end_search`` — ``SearchSession``:
  per-generation convergence records (best/mean/std, rejection rate,
  group-cache hit rate) drained from the batch window at each observer
  tick, plus the ``search`` -> ``generation`` span scaffolding.  Exactly
  one ``generation`` span is emitted per observer tick, so a traced run's
  generation-span count equals ``len(artifact.history)`` on the ga backend.
* ``record_migration`` — ``IslandBackend``: ``island.migration`` points.
* ``record_job`` / ``record_serve_batch`` — ``serve.BatchScheduler``:
  dedup/store-hit/miss counters, per-worker wall time, ``serve.job``
  points.
* ``record_certificate`` — ``analysis.verify``: lower-bound gap metrics.

Recording NEVER feeds back into the search: no RNG is consumed, no
stopping decision reads collector state, and the accumulators are plain
floats/ints — fixed-seed trajectories with telemetry on are bit-identical
to telemetry off (pinned by ``tests/test_obs_search.py``).
"""
from __future__ import annotations

import math
import os
from typing import Any, Dict, List, Optional

from repro.obs import clock
from repro.obs.metrics import Counter, MetricRegistry
from repro.obs.trace import NULL_TRACER, Tracer

#: environment variable naming the JSONL trace file (any CLI command)
TRACE_ENV = "REPRO_TRACE"

#: artifact ``telemetry`` summary schema version
SUMMARY_SCHEMA = 1


def trace_path_from_env() -> Optional[str]:
    """The ``REPRO_TRACE`` trace file path, or None when unset/empty."""
    return os.environ.get(TRACE_ENV) or None


def _r6(x: float) -> float:
    return round(x, 6)


class TelemetryCollector:
    """Metrics + trace sink for one instrumented activity."""

    def __init__(self, tracer: Optional[Tracer] = None,
                 registry: Optional[MetricRegistry] = None):
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._own_tracer = tracer is not None
        self.registry = registry if registry is not None else MetricRegistry()
        #: per-observer-tick convergence records (in tick order)
        self.generations: List[Dict[str, Any]] = []
        self._ev = None                   # bound Evaluator (counter source)
        # instruments are fetched once: the recording path touches only
        # slot attributes, no per-event registry lookups
        reg = self.registry
        self._c_batches = reg.counter("eval.batches")
        self._c_states = reg.counter("eval.states")
        self._c_unique = reg.counter("eval.unique")
        self._c_invalid = reg.counter("eval.invalid")
        self._c_novel_groups = reg.counter("costmodel.novel_groups")
        self._h_batch_size = reg.histogram("eval.batch_size")
        self._h_batch_s = reg.histogram("eval.batch_s")
        self._engine_counters: Dict[str, Counter] = {}
        # batch window accumulators, drained at each observer tick
        self._w_states = 0
        self._w_unique = 0
        self._w_invalid = 0
        self._w_sum = 0.0
        self._w_sumsq = 0.0
        self._w_novel_groups = 0
        self._cost_s = 0.0               # novel-group costing time, this batch
        self._seen_groups = (0, 0)       # evaluator (hits, misses) at last tick
        self._search_id: Optional[int] = None
        self._gen_id: Optional[int] = None

    @classmethod
    def from_env(cls) -> Optional["TelemetryCollector"]:
        """A collector tracing to ``$REPRO_TRACE``, or None when unset —
        the one-liner CLI commands use to opt whole invocations in."""
        path = trace_path_from_env()
        if not path:
            return None
        return cls(tracer=Tracer(path))

    def close(self) -> None:
        """Close an owned tracer (collectors built with an explicit or
        env-derived Tracer own its file handle)."""
        if self._own_tracer:
            self.tracer.close()

    # ---- evaluator hooks (batch granularity only) -------------------------------
    def bind_evaluator(self, ev) -> None:
        """Remember the evaluator whose group-cache counters feed the
        per-generation hit-rate deltas."""
        self._ev = ev
        self._seen_groups = (getattr(ev, "group_hits", 0),
                             getattr(ev, "group_misses", 0))

    def note_group_costed(self, dur_s: float) -> None:
        """One novel group was costed (``Evaluator._group_cost`` miss)."""
        self._cost_s += dur_s

    def record_batch(self, n_states: int, n_unique: int,
                     fits: List[float], engine: str,
                     t0: float, dur_s: float, novel_groups: int) -> None:
        """One evaluator batch completed.  ``fits`` are the scored
        fitnesses (0.0 = schedulability-rejected / over-capacity)."""
        inv = 0
        s = 0.0
        ss = 0.0
        for f in fits:
            if f <= 0.0:
                inv += 1
            s += f
            ss += f * f
        self._w_states += n_states
        self._w_unique += n_unique
        self._w_invalid += inv
        self._w_sum += s
        self._w_sumsq += ss
        self._w_novel_groups += novel_groups
        self._c_batches.inc()
        self._c_states.inc(n_states)
        self._c_unique.inc(n_unique)
        self._c_invalid.inc(inv)
        self._c_novel_groups.inc(novel_groups)
        self._h_batch_size.observe(n_states)
        self._h_batch_s.observe(dur_s)
        ec = self._engine_counters.get(engine)
        if ec is None:
            ec = self.registry.counter("eval.batches_by_engine",
                                       engine=engine)
            self._engine_counters[engine] = ec
        ec.inc()
        cost_s, self._cost_s = self._cost_s, 0.0
        tr = self.tracer
        if tr.enabled:
            bid = tr.alloc_id()
            parent = tr.current()
            if novel_groups:
                tr.emit_span("costmodel", t0=t0, dur_s=cost_s, parent=bid,
                             attrs={"novel_groups": novel_groups})
            tr.emit_span("batch_eval", t0=t0, dur_s=dur_s, span_id=bid,
                         parent=parent,
                         attrs={"n_states": n_states, "n_unique": n_unique,
                                "invalid": inv,
                                "novel_groups": novel_groups,
                                "engine": engine})

    # ---- search session hooks ---------------------------------------------------
    def begin_search(self, attrs: Dict[str, Any]) -> None:
        """Open the ``search`` span and the first generation window."""
        self._search_attrs = dict(attrs)
        self._t0_wall = clock.now()
        self._t0_perf = clock.perf_counter()
        tr = self.tracer
        if tr.enabled:
            self._search_id = tr.alloc_id()
            tr.push(self._search_id)
            self._gen_id = tr.alloc_id()
            tr.push(self._gen_id)        # batch spans nest under it
        self._gen_t0w = self._t0_wall
        self._gen_t0p = self._t0_perf

    def on_step(self, step: int, best: float, evals: int,
                offspring: int) -> None:
        """One backend observer tick: drain the batch window into a
        convergence record and close/reopen the generation span."""
        ev = self._ev
        hit_rate = 0.0
        if ev is not None:
            h0, m0 = self._seen_groups
            h1 = getattr(ev, "group_hits", 0)
            m1 = getattr(ev, "group_misses", 0)
            dh, dm = h1 - h0, m1 - m0
            hit_rate = dh / (dh + dm) if (dh + dm) else 0.0
            self._seen_groups = (h1, m1)
        n = self._w_states
        mean = self._w_sum / n if n else 0.0
        var = self._w_sumsq / n - mean * mean if n else 0.0
        rec = {
            "step": step,
            "best": best,
            "mean": mean,
            "std": math.sqrt(var) if var > 0 else 0.0,
            "evaluations": evals,        # cumulative unique genomes
            "offspring": offspring,      # cumulative submitted genomes
            "batch_states": n,           # states scored this window
            "batch_unique": self._w_unique,
            "rejection_rate": self._w_invalid / n if n else 0.0,
            "group_hit_rate": hit_rate,
            "novel_groups": self._w_novel_groups,
        }
        self.generations.append(rec)
        tr = self.tracer
        if tr.enabled:
            now_w, now_p = clock.now(), clock.perf_counter()
            tr.pop()
            tr.emit_span("generation", t0=self._gen_t0w,
                         dur_s=now_p - self._gen_t0p, span_id=self._gen_id,
                         parent=self._search_id,
                         attrs={k: (_r6(v) if isinstance(v, float) else v)
                                for k, v in rec.items()})
            self._gen_id = tr.alloc_id()
            tr.push(self._gen_id)
            self._gen_t0w, self._gen_t0p = now_w, now_p
        self._w_states = self._w_unique = self._w_invalid = 0
        self._w_sum = self._w_sumsq = 0.0
        self._w_novel_groups = 0

    def end_search(self, cache_stats: Optional[Dict[str, Any]] = None
                   ) -> None:
        """Close the ``search`` span; the dangling post-final-tick
        generation window is discarded unemitted, so generation-span count
        == observer-tick count."""
        tr = self.tracer
        if not tr.enabled:
            return
        tr.pop()                         # dangling generation id: not emitted
        tr.point("metrics.snapshot", attrs=self.registry.snapshot())
        tr.pop()
        tr.emit_span(
            "search", t0=self._t0_wall,
            dur_s=clock.perf_counter() - self._t0_perf,
            span_id=self._search_id, parent=None,
            attrs={**self._search_attrs, "steps": len(self.generations),
                   **({"cache": dict(cache_stats)} if cache_stats else {})})
        self._search_id = None

    def progress_records(self) -> List[Dict[str, Any]]:
        """JSON-safe snapshot of the per-generation convergence records, in
        tick order — what ``repro.serve.daemon`` serves from ``GET
        /jobs/<id>`` while a search is still running.  Floats are rounded
        like trace attributes; the snapshot copies the record list first so
        a concurrent ``on_step`` append never tears the serialization."""
        return [{k: (_r6(v) if isinstance(v, float) else v)
                 for k, v in rec.items()}
                for rec in list(self.generations)]

    def summary(self, cache_stats: Optional[Dict[str, Any]] = None
                ) -> Dict[str, Any]:
        """The compact per-run summary artifacts embed (``repro report
        --telemetry`` renders it with no trace file): parallel
        per-generation arrays + final cache stats + the metric snapshot."""
        g = self.generations
        return {
            "schema": SUMMARY_SCHEMA,
            "steps": len(g),
            "best": [_r6(r["best"]) for r in g],
            "mean": [_r6(r["mean"]) for r in g],
            "std": [_r6(r["std"]) for r in g],
            "rejection_rate": [_r6(r["rejection_rate"]) for r in g],
            "group_hit_rate": [_r6(r["group_hit_rate"]) for r in g],
            "unique_states": [r["evaluations"] for r in g],
            "offspring": [r["offspring"] for r in g],
            "cache": dict(cache_stats or {}),
            "metrics": self.registry.snapshot(),
        }

    # ---- island backend hook ----------------------------------------------------
    def record_migration(self, gen: int, best: float, islands: int,
                         migration: bool) -> None:
        """One island sync barrier; ``migration``: elites moved (vs an
        observation-only barrier)."""
        self.registry.counter("island.barriers").inc()
        if migration:
            self.registry.counter("island.migrations").inc()
            self.tracer.point("island.migration", attrs={
                "gen": gen, "best": _r6(best), "islands": islands})

    # ---- serve hooks ------------------------------------------------------------
    def record_job(self, job) -> None:
        """One resolved :class:`repro.serve.scheduler.Job`."""
        outcome = job.outcome or "failed"
        self.registry.counter("serve.jobs", outcome=outcome).inc()
        if job.deduped:
            self.registry.counter("serve.deduped_in_flight").inc()
        attrs: Dict[str, Any] = {
            "id": job.id, "status": job.status, "outcome": job.outcome,
            "deduped": job.deduped, "workload": job.spec.workload,
            "key": job.key[:12] if job.key else None, "error": job.error}
        if job.outcome == "searched" and job.artifact is not None:
            wall = job.artifact.wall_s   # the worker's in-search wall time
            self.registry.histogram("serve.job_wall_s").observe(wall)
            attrs["wall_s"] = _r6(wall)
        self.tracer.point("serve.job", attrs=attrs)

    def record_serve_batch(self, stats: Dict[str, int], store_hits: int,
                           store_misses: int, t0: float,
                           dur_s: float) -> None:
        """One drained scheduler batch (``BatchScheduler.run``)."""
        # serve.batch.* namespace: the per-job counters above own serve.*
        # (serve.deduped_in_flight is a Counter; stats carries the same key)
        for k, v in stats.items():
            self.registry.gauge(f"serve.batch.{k}").set(v)
        self.registry.counter("serve.store_hits").inc(store_hits)
        self.registry.counter("serve.store_misses").inc(store_misses)
        if self.tracer.enabled:
            self.tracer.emit_span(
                "serve.batch", t0=t0, dur_s=dur_s, parent=None,
                attrs={**stats, "store_hits": store_hits,
                       "store_misses": store_misses})

    # ---- verify hook ------------------------------------------------------------
    def record_certificate(self, label: str, cert, ok: bool) -> None:
        """One verified artifact's lower-bound certificate gaps."""
        self.registry.histogram("verify.gap_vs_schedule").observe(
            cert.gap_vs_schedule)
        self.registry.histogram("verify.gap_vs_graph").observe(
            cert.gap_vs_graph)
        self.registry.counter("verify.artifacts",
                              ok="true" if ok else "false").inc()
        self.tracer.point("verify.certificate", attrs={
            "label": label, "ok": bool(ok),
            "traffic_words": cert.traffic_words,
            "schedule_lb_words": cert.schedule_lb_words,
            "graph_lb_words": cert.graph_lb_words,
            "gap_vs_schedule": _r6(cert.gap_vs_schedule),
            "gap_vs_graph": _r6(cert.gap_vs_graph)})
