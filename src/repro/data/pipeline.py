"""Deterministic, shardable, exactly-resumable synthetic token pipeline.

Every batch is a pure function of (seed, step) — a counter-based generator,
not a stateful stream — so:

* restart-from-checkpoint replays *no* sample twice and skips none: the
  training loop just continues at ``step+1`` (fault-tolerance requirement);
* each data shard materializes only its slice (host-parallel loading);
* no filesystem dependency (the container has no corpora); swapping in a real
  corpus only means replacing ``_tokens_for``.

The token stream is a stationary Markov-ish process (mixed linear
congruential + n-gram structure) so small models actually have something
learnable for the end-to-end example, rather than uniform noise.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234


class SyntheticTokenPipeline:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def _tokens_for(self, step: int, index: int) -> np.ndarray:
        """One (seq_len+1,) sample, deterministic in (seed, step, index)."""
        c = self.cfg
        rng = np.random.Generator(np.random.Philox(
            key=c.seed, counter=[0, 0, step, index]))
        # learnable structure: token_{t+1} = (a * token_t + b + noise) % V
        # (a, b) depend only on the sample index, so the mapping is stable
        # across steps and the loss visibly falls within tens of steps
        a = 31 + (index % 7)
        b = (index * 97 + c.seed) % c.vocab
        toks = np.empty(c.seq_len + 1, np.int64)
        toks[0] = rng.integers(0, c.vocab)
        noise = rng.integers(0, 5, size=c.seq_len)
        for t in range(c.seq_len):
            toks[t + 1] = (a * toks[t] + b + noise[t]) % c.vocab
        return toks

    def global_batch_at(self, step: int) -> Dict[str, np.ndarray]:
        return self.shard_batch_at(step, 0, 1)

    def shard_batch_at(self, step: int, shard: int, n_shards: int
                       ) -> Dict[str, np.ndarray]:
        """The ``shard``-th of ``n_shards`` slices of the global batch at
        ``step`` (batch dim is the sharded dim)."""
        c = self.cfg
        assert c.global_batch % n_shards == 0
        per = c.global_batch // n_shards
        rows = [self._tokens_for(step, shard * per + i) for i in range(per)]
        arr = np.stack(rows)
        return {"tokens": arr[:, :-1].astype(np.int32),
                "labels": arr[:, 1:].astype(np.int32)}
