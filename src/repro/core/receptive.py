"""Receptive-field backtrace and on-chip footprint of fused groups.

Paper §II-B / Fig. 5: executing a fused group tile-by-tile requires, for every
layer in the group, the *receptive field* of the final output tile.  We follow
the caching (not recompute) policy the paper adopts ("previous works have found
that caching is almost always better"), i.e. Alwani-style line buffers
[Fused-layer CNN accelerators, MICRO'16]: while streaming output row-tiles of
``t`` rows, each intermediate feature map keeps a sliding window of
``rows_l(t)`` rows resident on-chip, and every DRAM input word is read exactly
once.

``rows_l`` is obtained by backtracing from the group's sink layers:

    rows_in = (rows_out - 1) * stride_h + (R - 1) * dilation_h + 1

clamped to the full height.  The activation-buffer footprint of the group at
tile height ``t`` is the sum of live windows over all tensors that stay
on-chip, plus the input/output staging tiles.  The scheduler picks the largest
``t`` that fits (paper: "receptive field sizes that maximally use the
activation buffer").
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Set, Tuple

from repro.core.graph import Layer, LayerGraph


def required_input_rows(layer: Layer, rows_out: int) -> int:
    """Rows of ``layer``'s *input* needed to produce ``rows_out`` output rows."""
    rows_out = min(rows_out, layer.p) if layer.p else rows_out
    if layer.kind in ("conv", "dwconv", "pool"):
        need = (rows_out - 1) * layer.stride[0] + (layer.r - 1) * layer.dilation[0] + 1
        return min(max(need, 1), layer.h) if layer.h else need
    if layer.kind in ("fc", "global_pool"):
        return layer.h if layer.h else 1
    if layer.kind == "upsample":
        return min(max(math.ceil(rows_out * max(layer.h, 1) / max(layer.p, 1)), 1),
                   max(layer.h, 1))
    # add / mul / concat / input: elementwise row-for-row
    return rows_out


def backtrace_rows(graph: LayerGraph, members: Sequence[str], t: int
                   ) -> Dict[str, int]:
    """For each member layer, the number of *output* rows that must be live to
    stream ``t`` output rows of the group's sinks.  Members must be given in
    topological order (any)."""
    mset = set(members)
    rows: Dict[str, int] = {}
    # reverse topological scan: consumers before producers
    for name in reversed(list(members)):
        layer = graph.layers[name]
        inner_consumers = [v for v in graph.succs(name) if v in mset]
        if not inner_consumers:                       # sink of the group
            rows[name] = min(t, layer.p) if layer.p else t
        else:
            need = 1
            for cons in inner_consumers:
                need = max(need, required_input_rows(graph.layers[cons], rows[cons]))
            rows[name] = min(need, layer.p) if layer.p else need
    return rows


def group_footprint_words(graph: LayerGraph, members: Sequence[str], t: int,
                          offchip: Optional[Set[str]] = None) -> int:
    """Activation-buffer words needed to stream the group at tile height ``t``.

    Counts, per member tensor, a live window of ``rows`` x width x channels:
    * intermediate tensors fully consumed on-chip keep their sliding window;
    * group inputs (produced outside) keep the window required by their
      in-group consumers (staged from DRAM or a previous group);
    * tensors that also go off-chip (``offchip``) still occupy their window
      while being produced.
    """
    mset = set(members)
    rows = backtrace_rows(graph, members, t)
    total = 0
    staged: Set[str] = set()
    for name in members:
        layer = graph.layers[name]
        if layer.output_size:
            total += layer.m * layer.q * min(rows[name], layer.p or rows[name])
        # stage external inputs of this member
        for src in graph.preds(name):
            if src in mset or src in staged:
                continue
            staged.add(src)
            src_l = graph.layers[src]
            if not src_l.output_size:
                continue
            win = required_input_rows(layer, rows[name])
            total += src_l.m * src_l.q * min(win, src_l.p or win)
    return total


def max_tile_rows(graph: LayerGraph, members: Sequence[str],
                  act_capacity_words: int) -> int:
    """Largest sink tile height whose footprint fits the activation buffer.
    Returns 0 if even t=1 does not fit (group invalid at this capacity)."""
    sink_p = max((graph.layers[n].p or 1) for n in members)
    if group_footprint_words(graph, members, 1) > act_capacity_words:
        return 0
    lo, hi = 1, max(sink_p, 1)
    while lo < hi:                                    # binary search largest feasible
        mid = (lo + hi + 1) // 2
        if group_footprint_words(graph, members, mid) <= act_capacity_words:
            lo = mid
        else:
            hi = mid - 1
    return lo


def _required_input_extent(layer: Layer, out_ext: int, axis: int) -> int:
    """Axis-generic version of :func:`required_input_rows` (0=rows, 1=cols)."""
    full_in = layer.h if axis == 0 else layer.w
    full_out = layer.p if axis == 0 else layer.q
    k = layer.r if axis == 0 else layer.s
    out_ext = min(out_ext, full_out) if full_out else out_ext
    if layer.kind in ("conv", "dwconv", "pool"):
        need = (out_ext - 1) * layer.stride[axis] + (k - 1) * layer.dilation[axis] + 1
        return min(max(need, 1), full_in) if full_in else need
    if layer.kind in ("fc", "global_pool"):
        return full_in if full_in else 1
    if layer.kind == "upsample":
        return min(max(math.ceil(out_ext * max(full_in, 1) / max(full_out, 1)), 1),
                   max(full_in, 1))
    return out_ext


def _backtrace_axis(graph: LayerGraph, members: Sequence[str], t: int,
                    axis: int) -> Dict[str, int]:
    mset = set(members)
    ext: Dict[str, int] = {}
    for name in reversed(list(members)):
        layer = graph.layers[name]
        full_out = layer.p if axis == 0 else layer.q
        inner = [v for v in graph.succs(name) if v in mset]
        if not inner:
            ext[name] = min(t, full_out) if full_out else t
        else:
            need = 1
            for cons in inner:
                need = max(need, _required_input_extent(
                    graph.layers[cons], ext[cons], axis))
            ext[name] = min(need, full_out) if full_out else need
    return ext


def receptive_field_hw(graph: LayerGraph, members: Sequence[str]) -> Tuple[int, int]:
    """(rows, cols) of group-*input* receptive field for a single output pixel
    of the group's sinks — the quantity plotted in paper Fig. 7."""
    mset = set(members)
    rf = [1, 1]
    for axis in (0, 1):
        ext = _backtrace_axis(graph, members, 1, axis)
        for name in members:
            layer = graph.layers[name]
            if layer.kind == "input":
                continue
            if not any(s in mset for s in graph.preds(name)):
                rf[axis] = max(rf[axis], _required_input_extent(
                    layer, ext[name], axis))
    return rf[0], rf[1]
