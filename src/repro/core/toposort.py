"""Randomized topological sort (paper §III-C).

The paper uses a topological sort inside the GA to enforce dependency order of
fused subgraphs and of layers within a subgraph; because not every topological
order is unique it selects a *random* valid order ("we select a random primary
graph and its corresponding elements of the subgraph to process").  We
implement Kahn's algorithm with an RNG-driven tie-break so the GA samples the
order space, plus a deterministic mode for tests.
"""
from __future__ import annotations

import random
from typing import Dict, Hashable, Iterable, List, Mapping, Optional, Sequence, Tuple


class CycleError(ValueError):
    pass


def topological_sort_edges(
    nodes: Sequence[Hashable],
    edges: Iterable[Tuple[Hashable, Hashable]],
    rng: Optional[random.Random] = None,
) -> List[Hashable]:
    """Kahn's algorithm over explicit (u, v) edges restricted to ``nodes``.

    With ``rng`` given, ready-set ties are broken uniformly at random; without,
    insertion order is kept (deterministic).
    Raises :class:`CycleError` if the subgraph has a cycle.
    """
    nodeset = set(nodes)
    indeg: Dict[Hashable, int] = {n: 0 for n in nodes}
    succ: Dict[Hashable, List[Hashable]] = {n: [] for n in nodes}
    for u, v in edges:
        if u in nodeset and v in nodeset:
            succ[u].append(v)
            indeg[v] += 1

    ready = [n for n in nodes if indeg[n] == 0]
    order: List[Hashable] = []
    while ready:
        i = rng.randrange(len(ready)) if rng is not None else 0
        n = ready.pop(i)
        order.append(n)
        for v in succ[n]:
            indeg[v] -= 1
            if indeg[v] == 0:
                ready.append(v)
    if len(order) != len(nodeset):
        raise CycleError(f"cycle among {sorted(nodeset - set(order))!r}")
    return order


def acyclic_indices(succ: Sequence[Sequence[int]]) -> bool:
    """Kahn cycle check over integer nodes ``0..len(succ)-1``.

    ``succ[u]`` lists successors of ``u``; parallel (duplicate) edges are
    allowed — they inflate in-degrees symmetrically, so the check stays exact.
    This is the allocation-light path used by the incremental fusion engine's
    condensation test (no dicts, no string hashing).
    """
    n = len(succ)
    indeg = [0] * n
    for vs in succ:
        for v in vs:
            indeg[v] += 1
    stack = [i for i in range(n) if indeg[i] == 0]
    seen = 0
    while stack:
        u = stack.pop()
        seen += 1
        for v in succ[u]:
            indeg[v] -= 1
            if indeg[v] == 0:
                stack.append(v)
    return seen == n


def member_order_ids(succ_ids: Sequence[Sequence[int]], ids: Sequence[int]
                     ) -> List[int]:
    """Deterministic Kahn order of the subgraph induced by ``ids`` (ascending
    node ids), over precompiled integer adjacency.

    Delegates to :func:`topological_sort_edges` with ``rng=None`` — the exact
    ready-queue discipline and tie-breaks — so float accumulations done in
    this order are bit-identical to the string-based reference path (the
    callee filters the edge stream to the node set itself).
    """
    return topological_sort_edges(
        ids, ((u, v) for u in ids for v in succ_ids[u]))


def topological_sort(graph, rng: Optional[random.Random] = None) -> List[str]:
    """Topological order of a :class:`repro.core.graph.LayerGraph`."""
    return topological_sort_edges(graph.names, graph.edges, rng)


def is_topological(order: Sequence[Hashable],
                   edges: Iterable[Tuple[Hashable, Hashable]]) -> bool:
    pos = {n: i for i, n in enumerate(order)}
    return all(pos[u] < pos[v] for u, v in edges if u in pos and v in pos)
