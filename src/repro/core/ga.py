"""Genetic algorithm for multilayer scheduling (paper Alg. 1, §III-B).

Faithful to the paper's configuration:

* population ``P = 100`` fusion states, initialized at the layer-by-layer
  schedule (every edge split);
* each generation applies ``C`` mutations — choose an adjacent layer pair and
  *combine* or *separate* it (Fig. 8b) — evaluates the offspring, and adds
  them to the pool;
* fitness ``F = Eval_layerwise / Eval_new`` on the chosen objective (EDP by
  default, "as it provided the most useful information");
* survivors are the Top-``N = 10`` by fitness **plus a few random** pool
  members "to ensure we do not quickly converge to a poor local minimum";
* ``G = 500`` generations.

Evaluation is delegated to a memoizing :class:`repro.costmodel.evaluator.
Evaluator` (or any object with the same ``fitness``/``evaluate`` protocol,
e.g. the TPU roofline evaluator in ``repro.core.tpu_ga``), so the engine is
cost-model agnostic.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.fusion import FusionState
from repro.core.graph import LayerGraph


@dataclass(frozen=True)
class GAConfig:
    population: int = 100          # P
    top_n: int = 10                # N
    generations: int = 500         # G
    mutations_per_gen: int = 100   # C (one offspring per mutation)
    random_survivors: int = 10     # "some random scores"
    objective: str = "edp"
    seed: int = 0
    # beyond-paper: uniform crossover between two parents before mutating
    # (0.0 = paper-faithful mutation-only operators)
    crossover_rate: float = 0.0

    @classmethod
    def paper(cls, **kw) -> "GAConfig":
        return cls(**kw)

    @classmethod
    def fast(cls, generations: int = 40, **kw) -> "GAConfig":
        """CPU-friendly setting for tests/benchmarks; same operators."""
        return cls(population=40, top_n=8, generations=generations,
                   mutations_per_gen=40, random_survivors=6, **kw)


@dataclass
class GAResult:
    best_state: FusionState
    best_fitness: float
    history: List[float] = field(default_factory=list)   # best fitness per gen
    evaluations: int = 0

    @property
    def generations_run(self) -> int:
        return len(self.history)


def run_ga(graph: LayerGraph, evaluator, config: GAConfig = GAConfig()
           ) -> GAResult:
    """Run Alg. 1.  ``evaluator.fitness(state, objective) -> float`` with 0
    meaning invalid."""
    rng = random.Random(config.seed)
    fit_cache: Dict[frozenset, float] = {}

    def fitness(state: FusionState) -> float:
        key = state.key()
        if key not in fit_cache:
            fit_cache[key] = evaluator.fitness(state, config.objective)
        return fit_cache[key]

    init = FusionState.layerwise(graph)
    pool: List[Tuple[float, FusionState]] = [(fitness(init), init)]
    history: List[float] = []

    def crossover(a: FusionState, b: FusionState) -> FusionState:
        """Uniform crossover on the fused-edge genome (beyond-paper)."""
        fused = set()
        for e in graph.edges:
            src = a.fused if rng.random() < 0.5 else b.fused
            if e in src:
                fused.add(e)
        return FusionState(graph, frozenset(fused))

    for _gen in range(config.generations):
        parents = [s for _, s in pool]
        offspring: List[Tuple[float, FusionState]] = []
        for _ in range(config.mutations_per_gen):
            parent = parents[rng.randrange(len(parents))]
            if config.crossover_rate and rng.random() < config.crossover_rate \
                    and len(parents) > 1:
                other = parents[rng.randrange(len(parents))]
                parent = crossover(parent, other)
            child = parent.mutate(rng)
            offspring.append((fitness(child), child))

        merged = pool + offspring
        # dedupe by genome, keep best fitness ordering stable
        seen = set()
        unique: List[Tuple[float, FusionState]] = []
        for f, s in sorted(merged, key=lambda fs: -fs[0]):
            if s.key() in seen:
                continue
            seen.add(s.key())
            unique.append((f, s))

        top = unique[:config.top_n]
        rest = unique[config.top_n:]
        rng.shuffle(rest)
        pool = top + rest[:config.random_survivors]
        # keep population topped up with fresh mutants of the best
        while len(pool) < min(config.population,
                              config.top_n + config.random_survivors):
            child = pool[0][1].mutate(rng)
            pool.append((fitness(child), child))
        history.append(pool[0][0])

    best_f, best_s = max(pool, key=lambda fs: fs[0])
    return GAResult(best_state=best_s, best_fitness=best_f,
                    history=history, evaluations=len(fit_cache))
