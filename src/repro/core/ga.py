"""Genetic algorithm for multilayer scheduling (paper Alg. 1, §III-B).

Faithful to the paper's configuration:

* population ``P = 100`` fusion states, initialized at the layer-by-layer
  schedule (every edge split);
* each generation applies ``C`` mutations — choose an adjacent layer pair and
  *combine* or *separate* it (Fig. 8b) — evaluates the offspring, and adds
  them to the pool;
* fitness ``F = Eval_layerwise / Eval_new`` on the chosen objective (EDP by
  default, "as it provided the most useful information");
* survivors are the Top-``N = 10`` by fitness **plus a few random** pool
  members "to ensure we do not quickly converge to a poor local minimum",
  and the pool is **topped back up to P** with fresh mutants of survivors
  (earlier revisions silently capped the live pool at N + random_survivors,
  making ``population`` dead configuration);
* ``G = 500`` generations.

The selection loop itself is genome-agnostic: :func:`run_ga_problem` runs
Alg. 1 against any :class:`repro.core.problem.SearchProblem` (fusion states,
TPU schedules, ...), and :func:`run_ga` is the fusion-problem entry point —
it delegates to the same loop through
:class:`repro.core.problem.FusionProblem`, making exactly the RNG calls of
earlier revisions so fixed-seed results are bit-for-bit unchanged.  Whole
generations are scored through ``problem.fitness_batch`` (backed by
``Evaluator.fitness_batch`` when available), which dedupes offspring against
the evaluator's group-cost cache before costing only novel groups.

``repro.search`` packages this loop (plus random / hill-climb / exhaustive
alternatives) behind a declarative spec -> session -> artifact facade; new
callers should go through that instead of invoking ``run_ga`` directly.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Callable, Dict, Hashable, List, Optional, Sequence, Tuple

from operator import itemgetter

from repro.core.graph import LayerGraph
from repro.core.problem import FusionProblem, SearchProblem

_first = itemgetter(0)


@dataclass(frozen=True)
class GAConfig:
    population: int = 100          # P
    top_n: int = 10                # N
    generations: int = 500         # G
    mutations_per_gen: int = 100   # C (one offspring per mutation)
    random_survivors: int = 10     # "some random scores"
    objective: str = "edp"
    seed: int = 0
    # beyond-paper: uniform crossover between two parents before mutating
    # (0.0 = paper-faithful mutation-only operators)
    crossover_rate: float = 0.0

    @classmethod
    def paper(cls, **kw) -> "GAConfig":
        return cls(**kw)

    @classmethod
    def fast(cls, generations: int = 40, **kw) -> "GAConfig":
        """CPU-friendly setting for tests/benchmarks; same operators."""
        return cls(population=40, top_n=8, generations=generations,
                   mutations_per_gen=40, random_survivors=6, **kw)


@dataclass
class GAResult:
    """Outcome of one search run (any backend, any genome).

    ``best_state`` is whatever genome type the searched problem uses — a
    :class:`repro.core.fusion.FusionState` for the paper's problem, a
    :class:`repro.costmodel.tpu_model.TpuSchedule` for the TPU retarget.
    """

    best_state: object
    best_fitness: float
    history: List[float] = field(default_factory=list)   # best fitness per gen
    evaluations: int = 0              # unique genomes scored
    offspring_evaluated: int = 0      # offspring submitted for scoring

    @property
    def generations_run(self) -> int:
        return len(self.history)


# Observer called once per generation with (generation index, best fitness so
# far, unique evaluations, offspring evaluated); returning True stops the
# search after that generation (budget/patience hooks in repro.search).
GAObserver = Callable[[int, float, int, int], Optional[bool]]

# Migration hook called once per generation with (generation index, pool of
# (fitness, genome) entries) after selection and top-up; returning a list
# replaces the pool (island-model elite exchange in repro.search.island),
# returning None keeps it.  The hook must not consume RNG — per-island
# determinism is what makes island runs reproducible.
GAMigrate = Callable[[int, List[Tuple[float, object]]],
                     Optional[List[Tuple[float, object]]]]


def select_pool(entries: Sequence[Tuple[float, object]], top_n: int,
                random_survivors: int, rng: random.Random,
                key: Callable[[object], Hashable] = lambda s: s
                ) -> List[Tuple[float, object]]:
    """Paper Alg. 1 survivor selection, shared by the fusion and TPU GAs.

    Dedupes ``entries`` by genome ``key`` (keeping the best-ranked copy),
    returns the Top-``top_n`` plus ``random_survivors`` shuffled others.
    Zero-fitness (invalid) genomes are excluded from the random-survivor
    draw: they can never win and only breed more invalid offspring.
    """
    seen = set()
    unique: List[Tuple[float, object]] = []
    # stable descending sort == ascending sort on the negated key, so ties
    # keep their original order either way
    for f, s in sorted(entries, key=_first, reverse=True):
        k = key(s)
        if k in seen:
            continue
        seen.add(k)
        unique.append((f, s))
    top = unique[:top_n]
    rest = [fs for fs in unique[top_n:] if fs[0] > 0.0]
    rng.shuffle(rest)
    return top + rest[:random_survivors]


def run_ga_problem(problem: SearchProblem, config: GAConfig = GAConfig(),
                   observer: Optional[GAObserver] = None,
                   migrate: Optional[GAMigrate] = None) -> GAResult:
    """Run Alg. 1 against any :class:`SearchProblem`.

    ``observer`` (if given) is called after every generation and may return
    True to stop early — this is how ``repro.search`` sessions implement
    evaluation budgets and no-improvement patience without the loop knowing
    about either.  ``migrate`` (if given) may replace the pool at the end of
    each generation — this is the island-model elite-exchange hook
    (``repro.search.island``); with ``migrate=None`` the loop's behavior is
    bit-for-bit that of earlier revisions.
    """
    rng = random.Random(config.seed)
    # bound locals for the per-offspring hot path; getrandbits drives an
    # inlined _randbelow identical to CPython's (same draws as rng.randrange)
    getrandbits = rng.getrandbits
    pkey = problem.key
    pmut = problem.mutate
    pbatch_unique = getattr(problem, "fitness_batch_unique", None)
    fit_cache: Dict[Hashable, float] = {}
    offspring_evaluated = 0

    def score(states: List) -> List[float]:
        """Fitness per genome, via the run-level cache; novel genomes are
        scored in one batch so the evaluator can dedupe shared structure.
        The fresh list is unique by construction, so problems exposing
        ``fitness_batch_unique`` skip their own dedup pass."""
        keys = [pkey(s) for s in states]
        fresh: Dict[Hashable, object] = {}
        for k, s in zip(keys, states):
            if k not in fit_cache and k not in fresh:
                fresh[k] = s
        if fresh:
            vals = list(fresh.values())
            fits = (pbatch_unique(vals) if pbatch_unique is not None
                    else problem.fitness_batch(vals))
            fit_cache.update(zip(fresh, fits))
        return [fit_cache[k] for k in keys]

    # warm-start seeding (repro.serve.warmstart): extra genomes scored into
    # the initial pool alongside the canonical start.  With no seeds (the
    # default) the pool is exactly ``[initial]`` and every subsequent RNG
    # draw is bit-identical to the unseeded loop; seeds widen the first
    # generation's parent-index range, which is why seeding is opt-in.
    init = problem.initial()
    starters: List = [init]
    seen_keys = {pkey(init)}
    for seed_genome in getattr(problem, "seed_genomes", ()) or ():
        k = pkey(seed_genome)
        if k not in seen_keys:
            seen_keys.add(k)
            starters.append(seed_genome)
    pool: List[Tuple[float, object]] = list(zip(score(starters), starters))
    history: List[float] = []

    for gen in range(config.generations):
        offspring: List = []
        npool = len(pool)
        kbits = npool.bit_length()
        for _ in range(config.mutations_per_gen):
            r = getrandbits(kbits)
            while r >= npool:
                r = getrandbits(kbits)
            parent = pool[r][1]
            if config.crossover_rate and rng.random() < config.crossover_rate \
                    and len(pool) > 1:
                other = pool[rng.randrange(len(pool))][1]
                parent = problem.crossover(parent, other, rng)
            offspring.append(pmut(parent, rng))
        fits = score(offspring)
        offspring_evaluated += len(offspring)

        pool = select_pool(pool + list(zip(fits, offspring)),
                           config.top_n, config.random_survivors, rng,
                           key=problem.key)
        # keep the pool topped up to the paper's full P with fresh mutants of
        # survivors (duplicates allowed; next generation dedupes); parents are
        # picked by size-2 tournament over the rank-sorted survivor list, which
        # balances intensification around the elite against survivor diversity
        if len(pool) < config.population:
            need = config.population - len(pool)
            n_surv = len(pool)
            sbits = n_surv.bit_length()
            topup = []
            for _ in range(need):
                i = getrandbits(sbits)
                while i >= n_surv:
                    i = getrandbits(sbits)
                j = getrandbits(sbits)
                while j >= n_surv:
                    j = getrandbits(sbits)
                topup.append(pmut(pool[i if i < j else j][1], rng))
            tfits = score(topup)
            offspring_evaluated += len(topup)
            pool.extend(zip(tfits, topup))
        if migrate is not None:
            migrated = migrate(gen, pool)
            if migrated is not None:
                pool = migrated
        history.append(max(f for f, _ in pool))
        if observer is not None and observer(gen, history[-1], len(fit_cache),
                                             offspring_evaluated):
            break

    best_f, best_s = max(pool, key=lambda fs: fs[0])
    # batch scoring may re-associate float sums (~1 ulp); report the winner's
    # exact single-state fitness so results are comparable across engines
    best_f = problem.fitness(best_s)
    return GAResult(best_state=best_s, best_fitness=best_f,
                    history=history, evaluations=len(fit_cache),
                    offspring_evaluated=offspring_evaluated)


def run_ga(graph: LayerGraph, evaluator, config: GAConfig = GAConfig(),
           observer: Optional[GAObserver] = None) -> GAResult:
    """Run Alg. 1 on the paper's fusion problem.  ``evaluator.fitness(state,
    objective) -> float`` with 0 meaning invalid."""
    problem = FusionProblem(graph, evaluator, config.objective)
    return run_ga_problem(problem, config, observer)
