"""Array-native population evaluation: the GA hot loop, vectorized.

The incremental engine (``repro.core.fusion`` + ``Evaluator._fitness_fast``)
scores one genome at a time: per-offspring union-find maintenance, per-group
dict lookups, per-state Kahn checks.  Profiling a MobileNet-v3 run shows most
of the wall time is that per-genome structure maintenance, not the cost model
— every group cost is already cached after the first few generations.

This module scores a whole population at once.  A batch of genomes becomes a
``(P, n_edges)`` bool matrix and every per-genome quantity is computed with a
handful of numpy kernels over :class:`repro.core.graph.CompiledGraph`'s
integer arrays:

* **group labels** — CNN graphs are chains plus a few skip edges, so nodes
  are first labeled by maximal runs of consecutive fused chain edges
  (one ``maximum.accumulate`` for the whole batch), then the few non-adjacent
  fused edges are folded in with a Shiloach–Vishkin style hook-to-min /
  pointer-jump loop.  Labels equal each group's minimum member id, matching
  ``FusionState.group_masks()`` order exactly.
* **group identity** — each multi-member group's member bitmask is recovered
  exactly (no hashing): one ``bincount`` over the flattened labels sums
  per-node powers of two *offset by the group's minimum member*, giving the
  span pattern ``gmask >> label`` — sums of distinct powers spanning at most
  52 bits are exact in float64.  Narrow groups (span <= 52, i.e. essentially
  all of them on real CNNs) pack ``(min_member << 53) | pattern`` into a
  sorted int64 key table; wider groups fall back to reconstructing the exact
  python-int bitmask per slot (graphs beyond 1024 nodes skip the packed path
  entirely).  A table row carries the group's cached cost *correction*
  (group cost minus its members' singleton costs) plus two pure graph-shape
  flags:

  - ``low_exit`` — some edge leaves the group below its maximum member;
  - ``self_bad`` — some exit's strict closure re-enters the group
    (an immediate condensation cycle through this group alone).

* **schedulability** — node ids are topological by construction, so if every
  multi-member group's exit edges land *above* the group's maximum member,
  the condensation is acyclic (around any condensation cycle the per-group
  maximum would have to strictly increase).  A genome is therefore
  schedulable unless some group has ``low_exit``; any group with
  ``self_bad`` proves a cycle outright.  The rare residue — suspect genomes
  whose groups are all individually cycle-free — gets an exact batched
  check: per-group reachability unions over the static strict transitive
  closure, closed by boolean matrix squaring (:meth:`_sched_exact`).
* **fitness** — the layerwise baseline plus each group's correction, summed
  ``base + corrections`` in ascending group-min-member order via one
  ``bincount`` (which accumulates sequentially in input order), bit-for-bit
  identical to the canonical scalar path in ``Evaluator._fitness_fast``.
  Novel groups are costed through the evaluator's cost model only once a
  schedulable genome needs them, exactly like the scalar path.

Backends: ``numpy`` (default) and ``jax`` (opt-in via
``REPRO_POP_ENGINE=jax`` or ``PopulationEvaluator(backend="jax")``), which
runs the label-propagation inner loop as a jitted kernel and keeps the cost
gathers in numpy — labels are integers, so the jax path stays bit-identical.
Set ``REPRO_POP_ENGINE=off`` to force the per-state scalar path.

Spacemap interaction (``SearchSpec(spacemap=True)``): statically frozen
genes are masked out *upstream*, in :class:`repro.core.problem.
FusionProblem`'s operators — every genome this engine receives simply has
those mask bits permanently 0, so the ``(P, n_edges)`` matrices carry
all-zero columns for frozen edges and no engine change (or conditional) is
needed here.  The chain-run labeling is indifferent to which bits can vary,
and the cost-correction table never sees a group that crosses a frozen
edge because no genome ever fuses one.
"""
from __future__ import annotations

import os
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.obs import clock

_MISSING = object()

#: smallest batch routed through the array engine; below this the per-state
#: canonical path wins on fixed overheads (both paths are bit-identical)
MIN_BATCH = 16

_I64 = np.int64
_U64 = np.uint64


def engine_mode() -> str:
    """Requested engine backend: ``numpy`` (default), ``jax``, or ``off``."""
    mode = os.environ.get("REPRO_POP_ENGINE", "numpy").lower()
    if mode not in ("numpy", "jax", "off"):
        raise ValueError(
            f"REPRO_POP_ENGINE={mode!r}; valid: numpy, jax, off")
    return mode


class StaticTables:
    """Per-:class:`CompiledGraph` integer arrays shared by every batch (and,
    under the island backend, by every forked worker via COW)."""

    def __init__(self, cg):
        self.cg = cg
        n, m = cg.n, cg.m
        self.n = n
        self.m = m
        self.W = (n + 63) // 64                   # bitset words per node set
        self.mask_bytes = (m + 7) // 8
        eu = np.asarray(cg.eu, dtype=_I64)
        ev = np.asarray(cg.ev, dtype=_I64)
        self.eu, self.ev = eu, ev
        # chain edges (u -> u+1) drive the run-labeling pass; the rest
        # ("extra" edges: skips, concat fan-ins) go through hook/jump
        chain = ev == eu + 1
        self.chain_nodes = eu[chain]              # run break positions
        self.chain_eids = np.nonzero(chain)[0]
        self.extra_eids = np.nonzero(~chain)[0]
        self.xu = eu[~chain]
        self.xv = ev[~chain]
        # direct successors / strict transitive closure, as python ints
        # (flag computation for novel groups) and packed bitset rows
        # (the exact residue check)
        succ_int = [0] * n
        reach_int = [0] * n
        for u in range(n - 1, -1, -1):
            r = 0
            s = 0
            for v in cg.succ_ids[u]:
                s |= 1 << v
                r |= (1 << v) | reach_int[v]
            succ_int[u] = s
            reach_int[u] = r
        self.succ_int = succ_int
        self.reach_int = reach_int
        self.Eb = _pack_rows(succ_int, self.W)    # (n, W) direct successors
        self.Cp = _pack_rows(reach_int, self.W)   # (n, W) strict closure
        self.nodebit = _pack_rows([1 << u for u in range(n)], self.W)
        self.ar_n = np.arange(n, dtype=_I64)
        # span-offset powers of two: exact float64 for offsets <= 52 (the
        # group-key fast path); larger offsets only occur on wide groups,
        # which are routed to the exact python path before these are trusted
        self.pow2 = np.ldexp(1.0, np.minimum(self.ar_n, 1023).astype(np.int32))
        self.bitpos = np.arange(64, dtype=_U64)
        self._grids: Dict[int, tuple] = {}        # per-population-size caches

    def grids(self, p: int) -> tuple:
        g = self._grids.get(p)
        if g is None:
            n = self.n
            rowbase = np.repeat(np.arange(p, dtype=_I64) * n, n)
            ar_flat = np.tile(self.ar_n, p)
            if len(self._grids) > 16:             # bound the per-P cache
                self._grids.clear()
            g = (rowbase, ar_flat)
            self._grids[p] = g
        return g

    def group_flags(self, gmask: int) -> tuple:
        """(low_exit, self_bad) for one member bitmask — graph-shape-only
        properties, computed once per distinct group (python bitset math)."""
        succ = self.succ_int
        ex = 0
        mm = gmask
        while mm:
            b = mm & -mm
            ex |= succ[b.bit_length() - 1]
            mm ^= b
        ex &= ~gmask                              # exit targets
        low_exit = bool(ex & ((1 << (gmask.bit_length() - 1)) - 1))
        self_bad = False
        reach = self.reach_int
        mm = ex
        while mm:
            b = mm & -mm
            if reach[b.bit_length() - 1] & gmask:
                self_bad = True
                break
            mm ^= b
        return low_exit, self_bad


def _pack_rows(ints: Sequence[int], w: int) -> np.ndarray:
    out = np.zeros((len(ints), w), dtype=_U64)
    mask = (1 << 64) - 1
    for i, val in enumerate(ints):
        for j in range(w):
            out[i, j] = (val >> (64 * j)) & mask
    return out


class PopulationEvaluator:
    """Batched fitness/schedulability over ``(P, n_edges)`` genome matrices.

    Owned by (and sharing caches with) one
    :class:`repro.costmodel.evaluator.Evaluator`; obtained via
    ``Evaluator.population()``.  Results are bit-for-bit identical to the
    canonical scalar path (pinned by ``tests/test_population_engine.py``).
    """

    def __init__(self, evaluator, backend: Optional[str] = None):
        self.ev = evaluator
        self.t = StaticTables(evaluator.cg)
        self.backend = backend or engine_mode()
        if self.backend == "off":
            self.backend = "numpy"
        self._jax_labels = None
        if self.backend == "jax":
            self._jax_labels = _build_jax_labels(self.t)
            if self._jax_labels is None:          # jax unavailable: fall back
                self.backend = "numpy"
        # persistent group table (parallel arrays over row ids)
        self._ikeys = np.empty(0, dtype=_I64)     # sorted span-offset keys
        self._irows = np.empty(0, dtype=_I64)     # ... their row ids
        self._key_dict: Dict[int, int] = {}       # gmask -> row (insert side)
        self._corr_tab = np.empty((0, 6), dtype=np.float64)
        self._tvalid = np.empty(0, dtype=bool)    # correction is not None
        self._costed = np.empty(0, dtype=bool)    # correction computed yet?
        # low_exit / self_bad flags, packed (2**32 * self_bad + low_exit) so
        # one bincount recovers both per-genome any()s exactly: each weight
        # is 0 / 1 / 2**32 / 2**32+1 and per-genome sums stay far below 2**53
        self._lowsb = np.empty(0, dtype=np.float64)
        self._gmasks: List[int] = []              # row id -> member bitmask
        self._pending: List[tuple] = []           # rows awaiting commit
        self.batch_time = 0.0                     # seconds inside the engine
        self.batches = 0
        self.states_scored = 0
        self.residue_checks = 0                   # exact pair-closure runs

    # ---- public API ---------------------------------------------------------------
    def fitness_masks(self, masks: Sequence[int], objective: str = "edp"
                      ) -> np.ndarray:
        """Fitness per genome mask (float64 array), canonical order."""
        t0 = clock.perf_counter()
        out = self._fitness_masks(masks, objective)
        self.batch_time += clock.perf_counter() - t0
        self.batches += 1
        self.states_scored += len(masks)
        return out

    def schedulable_masks(self, masks: Sequence[int]) -> np.ndarray:
        """Batched exact schedulability (bool array)."""
        return self._analyze(masks)[5]

    def group_labels(self, masks: Sequence[int]) -> np.ndarray:
        """(P, n) min-member group label per node (for tests/tools)."""
        return self._labels(self._unpack(masks))[0].reshape(len(masks),
                                                            self.t.n)

    def stats(self) -> Dict[str, float]:
        return {
            "backend": self.backend,
            "batches": self.batches,
            "states_scored": self.states_scored,
            "batch_time_s": self.batch_time,
            "batch_evals_per_sec": (self.states_scored / self.batch_time
                                    if self.batch_time else 0.0),
            "group_table_rows": len(self._gmasks),
            "residue_checks": self.residue_checks,
        }

    # ---- batch pipeline -------------------------------------------------------------
    def _unpack(self, masks: Sequence[int]) -> np.ndarray:
        t = self.t
        nb = t.mask_bytes
        buf = b"".join(mk.to_bytes(nb, "little") for mk in masks)
        raw = np.frombuffer(buf, dtype=np.uint8).reshape(len(masks), nb)
        return np.unpackbits(raw, axis=1, bitorder="little")[:, :t.m]

    def _analyze(self, masks: Sequence[int]) -> tuple:
        """Shared front half: labels, group slots, table rows, and exact
        per-genome schedulability — no cost-model work."""
        t = self.t
        p, n = len(masks), t.n
        bits = self._unpack(masks)
        lf, mx = self._labels(bits)
        rowbase, ar_flat = t.grids(p)
        # one slot per multi-member group: its min member ("label") node
        slot_mask = (lf == ar_flat) & (mx > ar_flat)
        gslots = np.nonzero(slot_mask)[0]         # ascending (genome, label)
        gp = gslots // n
        if gslots.size:
            rows = self._rows_for_slots(lf, mx, gslots)
            flags = np.bincount(gp, weights=self._lowsb.take(rows),
                                minlength=p).astype(_I64)
            unsched = (flags >> np.int64(32)) > 0
            suspect = (flags & np.int64(0xFFFFFFFF)) > 0
            residue = np.nonzero(suspect & ~unsched)[0]
            if residue.size:                      # rare: multi-group cycles
                self.residue_checks += residue.size
                cyc = self._sched_exact(lf.reshape(p, n)[residue],
                                        mx.reshape(p, n)[residue])
                unsched[residue] |= cyc
        else:
            rows = np.empty(0, dtype=_I64)
            unsched = np.zeros(p, dtype=bool)
        return lf, mx, gslots, gp, rows, ~unsched

    def _fitness_masks(self, masks, objective) -> np.ndarray:
        ev = self.ev
        base = ev._ensure_base()
        p = len(masks)
        _, _, gslots, gp, rows, ok = self._analyze(masks)
        # cost-model work only for schedulable genomes' novel groups,
        # mirroring the scalar path's laziness
        if rows.size:
            keep = ok.take(gp)
            need = rows[keep & ~self._costed.take(rows)]
            if need.size:
                self._cost_rows(need)
            gp = gp[keep]
            rows = rows[keep]
            bad = np.bincount(gp, weights=~self._tvalid.take(rows),
                              minlength=p) > 0
        else:
            bad = np.zeros(p, dtype=bool)
        valid = ok & ~bad
        # canonical sums: base first, then corrections ascending by group
        # min member (bincount accumulates sequentially in input order)
        m2 = gp.size
        cat = np.empty(p + m2, dtype=_I64)
        cat[:p] = np.arange(p, dtype=_I64)
        cat[p:] = gp
        corr = self._corr_tab
        w = np.empty(p + m2)

        def comp(c: int) -> np.ndarray:
            w[:p] = base[c]
            w[p:] = corr[rows, c]
            return np.bincount(cat, weights=w, minlength=p)

        if objective == "edp":
            new = comp(0) * comp(1)
        elif objective == "energy":
            new = comp(0)
        elif objective == "cycles":
            new = comp(1)
        elif objective == "dram":
            new = comp(2) + comp(3)
        else:
            raise ValueError(f"unknown objective {objective!r}")
        out = np.zeros(p, dtype=np.float64)
        score = valid & (new > 0)
        out[score] = base[6][objective] / new[score]
        return out

    # ---- labels ---------------------------------------------------------------------
    def _labels(self, bits: np.ndarray):
        """Flat ``(P*n,)`` min-member labels + per-node group max member."""
        if self._jax_labels is not None:
            lf = self._jax_labels(bits)
            if lf is not None:
                return lf, self._maxmem(lf, bits.shape[0])
        lf = self._labels_np(bits)
        return lf, self._maxmem(lf, bits.shape[0])

    def _labels_np(self, bits: np.ndarray) -> np.ndarray:
        t = self.t
        p, n = bits.shape[0], t.n
        rowbase, _ = t.grids(p)
        # run labeling over consecutive fused chain edges
        newrun = np.ones((p, n), dtype=bool)
        # unpackbits yields 0/1 uint8, so a bool view is free (no astype copy)
        newrun[:, t.chain_nodes + 1] = ~(bits.view(np.bool_)[:, t.chain_eids])
        lab = np.maximum.accumulate(np.where(newrun, t.ar_n, 0), axis=1)
        lf = lab.ravel()
        # fold non-adjacent fused edges in: hook to min, then pointer-jump
        if t.extra_eids.size:
            pi, j = np.nonzero(bits[:, t.extra_eids])
            if pi.size:
                base = pi.astype(_I64) * n
                iu = base + t.xu[j]
                iv = base + t.xv[j]
                while True:
                    a = lf.take(iu)
                    b = lf.take(iv)
                    if np.array_equal(a, b):
                        break
                    mn = np.minimum(a, b)
                    np.minimum.at(lf, base + a, mn)
                    np.minimum.at(lf, base + b, mn)
                    lf = lf.take(rowbase + lf)
        while True:                               # compress to fixpoint
            nxt = lf.take(rowbase + lf)
            if np.array_equal(nxt, lf):
                return lf
            lf = nxt

    def _maxmem(self, lf: np.ndarray, p: int) -> np.ndarray:
        """Per-node maximum member id of the node's group (flat (P*n,))."""
        t = self.t
        rowbase, ar_flat = t.grids(p)
        mf = np.empty(p * t.n, dtype=_I64)
        mf[rowbase + lf] = ar_flat                # ascending: last write = max
        return mf.take(rowbase + lf)

    # ---- group table ----------------------------------------------------------------
    def _rows_for_slots(self, lf, mx, gslots) -> np.ndarray:
        """Group-table row per slot, inserting flag-only rows for novel
        groups (their costs are deferred until a schedulable genome needs
        them).

        Lookup key: one exact int64 per group — ``(label << 53) | pattern``
        where ``pattern = gmask >> label`` is built by a single bincount of
        span-offset powers of two (exact in float64 while the group span is
        <= 52; wider groups are rare and fall back to an exact per-slot
        python path, as do graphs with > 1024 nodes where the label would
        not fit above bit 53)."""
        t = self.t
        n = t.n
        if n > 1024:
            return self._rows_python(lf, gslots)
        # every node contributes 2^(node - label) to its label's flat slot
        # (singletons land on unread slots); one full-width bincount, then
        # gather the multi-group slots
        rowbase, ar_flat = t.grids(lf.size // n)
        g = gslots.size
        pattern = np.bincount(rowbase + lf, weights=t.pow2.take(ar_flat - lf),
                              minlength=lf.size).take(gslots)
        mn = gslots % n
        wide = (mx.take(gslots) - mn) > 52
        wide_any = bool(wide.any())
        if wide_any:
            pattern = np.where(wide, 1.0, pattern)
        patt_i = pattern.astype(_I64)             # <= 53 bits: exact
        keys = (mn << np.int64(53)) | patt_i
        if wide_any:
            keys[wide] = -1                       # never in the sorted table
        if len(self._ikeys):
            posc = np.minimum(np.searchsorted(self._ikeys, keys),
                              len(self._ikeys) - 1)
            hit = self._ikeys[posc] == keys
            rows = np.where(hit, self._irows.take(posc), np.int64(-1))
        else:
            hit = np.zeros(g, dtype=bool)
            rows = np.full(g, -1, dtype=_I64)
        self.ev.group_hits += int(hit.sum())
        miss = np.nonzero(~hit)[0]
        if miss.size:
            gsl = gslots.take(miss).tolist()
            kl = keys.take(miss).tolist()
            pl = patt_i.take(miss).tolist()
            mnl = mn.take(miss).tolist()
            wl = wide.take(miss).tolist() if wide_any else None
            for jj, ii in enumerate(miss.tolist()):
                if wl is not None and wl[jj]:
                    gmask = self._slot_gmask(lf, gsl[jj])
                    skey = None                   # dict-only: no int64 key
                else:
                    gmask = pl[jj] << mnl[jj]
                    skey = kl[jj]
                r = self._key_dict.get(gmask)
                if r is None:
                    r = self._new_row(gmask, skey)
                else:
                    self.ev.group_hits += 1
                rows[ii] = r
            if self._pending:
                self._commit_rows()
        return rows

    def _rows_python(self, lf, gslots) -> np.ndarray:
        """Exact per-slot path for graphs too wide for int64 keys."""
        rows = np.empty(gslots.size, dtype=_I64)
        for ii, sl in enumerate(gslots.tolist()):
            gmask = self._slot_gmask(lf, sl)
            r = self._key_dict.get(gmask)
            if r is None:
                r = self._new_row(gmask, None)
            else:
                self.ev.group_hits += 1
            rows[ii] = r
        if self._pending:
            self._commit_rows()
        return rows

    def _slot_gmask(self, lf: np.ndarray, slot: int) -> int:
        """Reassemble one group's member bitmask from the flat labels."""
        n = self.t.n
        base = slot - slot % n
        members = np.nonzero(lf[base:base + n] == slot % n)[0]
        gmask = 0
        for u in members.tolist():
            gmask |= 1 << u
        return gmask

    def _new_row(self, gmask: int, skey: Optional[int]) -> int:
        """Insert a flag-only row for a never-seen group (no costing)."""
        low, sb = self.t.group_flags(gmask)
        r = len(self._gmasks) + len(self._pending)
        self._pending.append((skey, low, sb, gmask))
        return r

    def _grow(self, need: int) -> None:
        """Capacity-double the parallel arrays (rows beyond the live count
        stay zero/False until committed, so over-allocation is invisible to
        the ``take``-based readers)."""
        cap = self._tvalid.size
        if need <= cap:
            return
        newcap = max(64, 2 * cap)
        while newcap < need:
            newcap *= 2
        ct = np.zeros((newcap, 6))
        ct[:cap] = self._corr_tab
        self._corr_tab = ct
        for name in ("_tvalid", "_costed", "_lowsb"):
            a = getattr(self, name)
            b = np.zeros(newcap, dtype=a.dtype)
            b[:cap] = a
            setattr(self, name, b)

    def _commit_rows(self) -> None:
        """Append this batch's novel rows to the parallel arrays and merge
        their int64 keys into the sorted lookup arrays."""
        pend = self._pending
        self._pending = []
        self._grow(len(self._gmasks) + len(pend))
        newk = []
        newr = []
        for skey, low, sb, gmask in pend:
            r = len(self._gmasks)
            self._lowsb[r] = low + sb * 4294967296.0
            self._key_dict[gmask] = r
            self._gmasks.append(gmask)
            if skey is not None:
                newk.append(skey)
                newr.append(r)
        if newk:
            nk = np.array(newk, dtype=_I64)
            nr = np.array(newr, dtype=_I64)
            order = np.argsort(nk)
            nk = nk[order]
            pos = np.searchsorted(self._ikeys, nk)
            self._ikeys = np.insert(self._ikeys, pos, nk)
            self._irows = np.insert(self._irows, pos, nr[order])

    def _cost_rows(self, need: np.ndarray) -> None:
        """Run the cost model for not-yet-costed rows (once per group)."""
        ev = self.ev
        for r in sorted(set(need.tolist())):
            gmask = self._gmasks[r]
            d = ev._corr.get(gmask, _MISSING)
            if d is _MISSING:
                d = ev._compute_correction(gmask)
                ev._corr[gmask] = d
            else:
                ev.group_hits += 1
            if d is not None:
                self._corr_tab[r] = d
                self._tvalid[r] = True
            self._costed[r] = True

    # ---- exact residue check ----------------------------------------------------------
    def _sched_exact(self, ls: np.ndarray, ms: np.ndarray) -> np.ndarray:
        """Exact condensation-cycle check for suspect genomes whose groups
        are individually cycle-free: reconstruct reachability between multi
        groups from the static strict closure and close it by boolean matrix
        squaring; a cycle exists iff two groups reach each other (single-group
        cycles were already excluded by the ``self_bad`` flag)."""
        t = self.t
        s, n = ls.shape
        w = t.W
        skey = (ls + np.arange(s, dtype=_I64)[:, None] * n).ravel()
        inst = np.nonzero((ms > ls).ravel())[0]   # multi-member node instances
        node = inst % n
        order = np.argsort(skey.take(inst), kind="stable")
        snode = node.take(order)
        sslot = skey.take(inst).take(order)
        starts = np.nonzero(np.r_[True, sslot[1:] != sslot[:-1]])[0]
        uslot = sslot.take(starts)
        # per-group unions of (closure | members) via one reduceat
        stacked = np.concatenate([t.Cp, t.nodebit], axis=1)
        red = np.bitwise_or.reduceat(stacked[snode], starts, axis=0)
        r0, gm = red[:, :w], red[:, w:]
        g2 = len(uslot)
        usi = uslot // n
        cnt = np.bincount(usi, minlength=s)
        k = int(cnt.max())
        off = np.zeros(s, dtype=_I64)
        np.cumsum(cnt[:-1], out=off[1:])
        rank = np.arange(g2, dtype=_I64) - off.take(usi)
        r0p = np.zeros((s, k, w), dtype=_U64)
        gmp = np.zeros((s, k, w), dtype=_U64)
        r0p[usi, rank] = r0
        gmp[usi, rank] = gm
        h = ((r0p[:, :, None, :] & gmp[:, None, :, :]) != 0).any(-1)
        cyc = np.zeros(s, dtype=bool)
        if k > 1:
            for _ in range(max(1, int(np.ceil(np.log2(k))))):
                hf = h.astype(np.float32)
                nh = h | (np.matmul(hf, hf) > 0)
                if np.array_equal(nh, h):
                    break
                h = nh
            mut = h & h.swapaxes(1, 2)
            mut &= ~np.eye(k, dtype=bool)
            cyc = mut.any(axis=(1, 2))
        return cyc


def _build_jax_labels(t: StaticTables):
    """Jitted label-propagation kernel (the hook/jump inner loop on the jax
    path); returns None when jax is unavailable.  Integer-only, so results
    are bit-identical to the numpy path; the caller still verifies
    idempotence and falls back to numpy if the fixed jump count ever fell
    short (it cannot for connected hooks, but exactness is non-negotiable)."""
    try:
        import jax
        import jax.numpy as jnp
    except Exception:                             # pragma: no cover - no jax
        return None

    n = t.n
    ar = jnp.asarray(t.ar_n)
    chain_nodes = jnp.asarray(t.chain_nodes)
    chain_eids = jnp.asarray(t.chain_eids)
    xu = jnp.asarray(t.xu)
    xv = jnp.asarray(t.xv)
    extra_eids = jnp.asarray(t.extra_eids)
    rounds = int(np.ceil(np.log2(max(n, 2)))) + 2

    @jax.jit
    def kernel(bits):
        p = bits.shape[0]
        newrun = jnp.ones((p, n), dtype=bool)
        newrun = newrun.at[:, chain_nodes + 1].set(
            ~bits[:, chain_eids].astype(bool))
        lab = jax.lax.cummax(jnp.where(newrun, ar, 0), axis=1)
        if extra_eids.size:
            fused = bits[:, extra_eids].astype(bool)
            rows = jnp.arange(p)[:, None]

            def body(lab, _):
                a = jnp.take_along_axis(lab, jnp.broadcast_to(xu, fused.shape),
                                        axis=1)
                b = jnp.take_along_axis(lab, jnp.broadcast_to(xv, fused.shape),
                                        axis=1)
                mn = jnp.minimum(a, b)
                big = jnp.iinfo(lab.dtype).max
                lab = lab.at[rows, jnp.where(fused, a, 0)].min(
                    jnp.where(fused, mn, big))
                lab = lab.at[rows, jnp.where(fused, b, 0)].min(
                    jnp.where(fused, mn, big))
                lab = jnp.take_along_axis(lab, lab, axis=1)   # pointer jump
                return lab, None

            lab, _ = jax.lax.scan(body, lab, None, length=rounds)
        lab = jnp.take_along_axis(lab, lab, axis=1)
        return lab

    def run(bits: np.ndarray) -> Optional[np.ndarray]:
        p = bits.shape[0]
        pp = -(-p // 16) * 16                     # pad P: bound recompiles
        if pp != p:
            bits = np.concatenate(
                [bits, np.zeros((pp - p, bits.shape[1]), dtype=bits.dtype)])
        lab = np.asarray(kernel(jnp.asarray(bits)))[:p].astype(_I64)
        lf = lab.ravel()
        rowbase = t.grids(p)[0]
        if not np.array_equal(lf, lf.take(rowbase + lf)):
            return None                           # paranoid exactness guard
        return lf

    return run
