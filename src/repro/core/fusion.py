"""Fusion states: the GA genome (paper §III-A, Fig. 8).

A :class:`FusionState` assigns every edge of the layer graph one of two labels:

* **fused**  — the activation tensor on that edge never leaves the chip;
* **split**  — the tensor is written to DRAM by the producer and read back.

Fused edges induce *fused groups*: weakly-connected components of the graph
restricted to fused edges (paper: "we represent our network as a computation
graph, with the fused layers being subgraphs").  A state is *schedulable* only
if the condensation of the graph by groups is acyclic — otherwise some group
would need outputs of a group that itself depends on it (can arise from fusing
across a skip connection while splitting the body, Fig. 8e).

An activation produced inside a group is DRAM-free only if *every* consumer is
in the same group; if any consumer lives elsewhere the tensor is stored once
to DRAM for those consumers (partial offload, Fig. 8b).
"""
from __future__ import annotations

import random
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

from repro.core.graph import LayerGraph
from repro.core.toposort import CycleError, topological_sort_edges

Edge = Tuple[str, str]


class FusionState:
    """Immutable fusion genome over ``graph``."""

    __slots__ = ("graph", "fused", "_groups", "_group_of")

    def __init__(self, graph: LayerGraph, fused: FrozenSet[Edge] = frozenset()):
        all_edges = set(graph.edges)
        bad = set(fused) - all_edges
        if bad:
            raise ValueError(f"fused edges not in graph: {sorted(bad)!r}")
        self.graph = graph
        self.fused = frozenset(fused)
        self._groups: Optional[List[FrozenSet[str]]] = None
        self._group_of: Optional[Dict[str, int]] = None

    # ---- construction helpers -------------------------------------------------
    @classmethod
    def layerwise(cls, graph: LayerGraph) -> "FusionState":
        """The paper's initial population member: every layer on its own."""
        return cls(graph, frozenset())

    @classmethod
    def fully_fused(cls, graph: LayerGraph) -> "FusionState":
        return cls(graph, frozenset(graph.edges))

    # ---- genome actions (paper Fig. 8b) ----------------------------------------
    def combine(self, edge: Edge) -> "FusionState":
        if edge not in set(self.graph.edges):
            raise ValueError(f"no such edge {edge!r}")
        return FusionState(self.graph, self.fused | {edge})

    def separate(self, edge: Edge) -> "FusionState":
        return FusionState(self.graph, self.fused - {edge})

    def mutate(self, rng: random.Random) -> "FusionState":
        """Paper Alg. 1 line 4: choose an adjacent layer pair, flip its state."""
        edges = self.graph.edges
        edge = edges[rng.randrange(len(edges))]
        return self.separate(edge) if edge in self.fused else self.combine(edge)

    # ---- derived structure ------------------------------------------------------
    def groups(self) -> List[FrozenSet[str]]:
        """Weakly-connected components over fused edges, in first-seen order."""
        if self._groups is not None:
            return self._groups
        parent: Dict[str, str] = {n: n for n in self.graph.names}

        def find(x: str) -> str:
            while parent[x] != x:
                parent[x] = parent[parent[x]]
                x = parent[x]
            return x

        for u, v in self.fused:
            ru, rv = find(u), find(v)
            if ru != rv:
                parent[ru] = rv
        comp: Dict[str, List[str]] = {}
        for n in self.graph.names:
            comp.setdefault(find(n), []).append(n)
        self._groups = [frozenset(ms) for ms in comp.values()]
        self._group_of = {}
        for gi, g in enumerate(self._groups):
            for n in g:
                self._group_of[n] = gi
        return self._groups

    def group_of(self, name: str) -> int:
        self.groups()
        assert self._group_of is not None
        return self._group_of[name]

    def group_edges(self) -> List[Tuple[int, int]]:
        """Condensation edges (between distinct groups)."""
        self.groups()
        out: Set[Tuple[int, int]] = set()
        for u, v in self.graph.edges:
            gu, gv = self.group_of(u), self.group_of(v)
            if gu != gv:
                out.add((gu, gv))
        return sorted(out)

    def is_schedulable(self) -> bool:
        """Condensation must be a DAG (see module docstring)."""
        gs = self.groups()
        try:
            topological_sort_edges(range(len(gs)), self.group_edges())
            return True
        except CycleError:
            return False

    def group_schedule(self, rng: Optional[random.Random] = None
                       ) -> List[List[str]]:
        """Topologically-ordered groups, each internally topologically sorted
        (paper §III-C).  Raises CycleError on unschedulable states."""
        gs = self.groups()
        group_order = topological_sort_edges(range(len(gs)), self.group_edges(), rng)
        sched: List[List[str]] = []
        for gi in group_order:
            members = gs[gi]
            inner = topological_sort_edges(
                [n for n in self.graph.names if n in members],
                self.graph.edges, rng)
            sched.append(inner)
        return sched

    # ---- DRAM residency ----------------------------------------------------------
    def tensor_offchip(self, producer: str) -> bool:
        """True iff ``producer``'s output activation must be stored to DRAM:
        it has a consumer outside the producer's group, or no consumer at all
        (a model output)."""
        succ = self.graph.succs(producer)
        if not succ:
            return True
        g = self.group_of(producer)
        return any(self.group_of(v) != g for v in succ)

    def offchip_tensors(self) -> List[str]:
        return [n for n in self.graph.names
                if self.graph.layers[n].output_size and self.tensor_offchip(n)]

    # ---- identity -------------------------------------------------------------------
    def key(self) -> FrozenSet[Edge]:
        return self.fused

    def __eq__(self, other):
        return isinstance(other, FusionState) and self.fused == other.fused \
            and self.graph is other.graph

    def __hash__(self):
        return hash((id(self.graph), self.fused))

    def __repr__(self):
        return (f"FusionState({self.graph.name}, {len(self.fused)}/"
                f"{len(self.graph.edges)} edges fused, "
                f"{len(self.groups())} groups)")
