"""Fusion states: the GA genome (paper §III-A, Fig. 8) — incremental engine.

A :class:`FusionState` assigns every edge of the layer graph one of two labels:

* **fused**  — the activation tensor on that edge never leaves the chip;
* **split**  — the tensor is written to DRAM by the producer and read back.

Fused edges induce *fused groups*: weakly-connected components of the graph
restricted to fused edges (paper: "we represent our network as a computation
graph, with the fused layers being subgraphs").  A state is *schedulable* only
if the condensation of the graph by groups is acyclic — otherwise some group
would need outputs of a group that itself depends on it (can arise from fusing
across a skip connection while splitting the body, Fig. 8e).

An activation produced inside a group is DRAM-free only if *every* consumer is
in the same group; if any consumer lives elsewhere the tensor is stored once
to DRAM for those consumers (partial offload, Fig. 8b).

Engine design (this module is the GA's hot path):

* the genome is an **edge-index bitmask** (a Python int over the
  :class:`repro.core.graph.CompiledGraph` edge order), so ``mutate``/``key``/
  ``hash`` are O(1) and fitness caches hash a machine int, not a frozenset of
  string pairs;
* group membership (node bitmasks, kept sorted by lowest member id so the
  public ``groups()`` order matches the reference first-seen order) is
  maintained **incrementally**: ``combine`` merges two components in O(G),
  ``separate`` re-examines only the affected component;
* schedulability is propagated incrementally where theory permits:
  merging groups ``gu -> gv`` of a schedulable state creates a condensation
  cycle iff a ``gu ~> gv`` path of length >= 2 exists (the direct edge becomes
  a self-loop), and splitting a group of a schedulable state into ``A``/``B``
  creates one iff both ``A ~> B`` and ``B ~> A`` exist — both answered by
  early-exit BFS instead of a full Kahn pass per offspring.  States derived
  from unschedulable parents fall back to a full (integer) Kahn check, since
  both operations can heal cycles.

The original dict/frozenset implementation is retained as
``repro.core.fusion_ref.ReferenceFusionState`` and property tests pin the two
engines to bit-for-bit agreement.
"""
from __future__ import annotations

import random
from typing import Dict, FrozenSet, Iterator, List, Optional, Tuple

from repro.core.graph import LayerGraph
from repro.core.toposort import acyclic_indices, topological_sort_edges

Edge = Tuple[str, str]


def iter_bits(mask: int) -> Iterator[int]:
    """Indices of set bits, ascending."""
    while mask:
        b = mask & -mask
        yield b.bit_length() - 1
        mask ^= b


class FusionState:
    """Immutable fusion genome over ``graph`` (bitmask representation)."""

    __slots__ = ("graph", "cg", "mask", "_fused", "_gmasks", "_mgroups",
                 "_gof", "_sched", "_cond", "_groups_str")

    def __init__(self, graph: LayerGraph, fused: FrozenSet[Edge] = frozenset()):
        cg = graph.compiled()
        eid = cg.edge_id
        mask = 0
        bad = []
        for e in fused:
            i = eid.get(e)
            if i is None:
                bad.append(e)
            else:
                mask |= 1 << i
        if bad:
            raise ValueError(f"fused edges not in graph: {sorted(bad)!r}")
        self._init(graph, cg, mask)

    def _init(self, graph, cg, mask, gmasks=None, mgroups=None, gof=None,
              sched=None, cond=None):
        self.graph = graph
        self.cg = cg
        self.mask = mask
        self._fused: Optional[FrozenSet[Edge]] = None
        self._gmasks: Optional[List[int]] = gmasks     # node-bitmask per group
        self._mgroups: Optional[List[int]] = mgroups   # multi-member masks only
        self._gof: Optional[List[int]] = gof           # node id -> group index
        self._sched: Optional[bool] = sched
        self._cond: Optional[List[List[int]]] = cond   # condensation adjacency
        self._groups_str: Optional[List[FrozenSet[str]]] = None

    @classmethod
    def _make(cls, graph, cg, mask, gmasks=None, mgroups=None, gof=None,
              sched=None, cond=None) -> "FusionState":
        s = object.__new__(cls)
        s._init(graph, cg, mask, gmasks, mgroups, gof, sched, cond)
        return s

    # ---- construction helpers -------------------------------------------------
    @classmethod
    def layerwise(cls, graph: LayerGraph) -> "FusionState":
        """The paper's initial population member: every layer on its own."""
        return cls._make(graph, graph.compiled(), 0)

    @classmethod
    def fully_fused(cls, graph: LayerGraph) -> "FusionState":
        cg = graph.compiled()
        return cls._make(graph, cg, (1 << cg.m) - 1)

    @classmethod
    def from_mask(cls, graph: LayerGraph, mask: int) -> "FusionState":
        cg = graph.compiled()
        if mask < 0 or mask >> cg.m:
            raise ValueError(f"mask {mask:#x} outside {cg.m}-edge genome")
        return cls._make(graph, cg, mask)

    # ---- genome views ----------------------------------------------------------
    @property
    def fused(self) -> FrozenSet[Edge]:
        if self._fused is None:
            ep = self.cg.edge_pairs
            self._fused = frozenset(ep[i] for i in iter_bits(self.mask))
        return self._fused

    # ---- genome actions (paper Fig. 8b) ----------------------------------------
    def combine(self, edge: Edge) -> "FusionState":
        i = self.cg.edge_id.get(edge)
        if i is None:
            raise ValueError(f"no such edge {edge!r}")
        return self._combine_idx(i)

    def separate(self, edge: Edge) -> "FusionState":
        i = self.cg.edge_id.get(edge)
        if i is None:                       # reference semantics: set difference
            return self._copy()
        return self._separate_idx(i)

    def mutate(self, rng: random.Random) -> "FusionState":
        """Paper Alg. 1 line 4: choose an adjacent layer pair, flip its state."""
        i = rng.randrange(self.cg.m)
        if (self.mask >> i) & 1:
            return self._separate_idx(i)
        return self._combine_idx(i)

    def _copy(self) -> "FusionState":
        return FusionState._make(self.graph, self.cg, self.mask, self._gmasks,
                                 self._mgroups, self._gof, self._sched,
                                 self._cond)

    def _combine_idx(self, i: int) -> "FusionState":
        bit = 1 << i
        if self.mask & bit:
            return self._copy()
        mask = self.mask | bit
        if self._gmasks is None:            # no parent structure: lazy child
            return FusionState._make(self.graph, self.cg, mask)
        self._ensure_gof()
        cg = self.cg
        gof = self._gof
        gu, gv = gof[cg.eu[i]], gof[cg.ev[i]]
        if gu == gv:                        # intra-group edge: same partition
            child = FusionState._make(self.graph, cg, mask, self._gmasks,
                                      self._mgroups, gof, self._sched,
                                      self._cond)
            return child
        sched = None
        if self._sched is True:
            # merging gu,gv cycles iff a gu ~> gv path of length >= 2 exists
            # (the direct gu->gv edge merges into an ignored self-loop)
            sched = not self._reaches_via_intermediate(gu, gv)
        a, b = (gu, gv) if gu < gv else (gv, gu)
        gmasks = self._gmasks
        ma, mb = gmasks[a], gmasks[b]
        merged = ma | mb
        new_gmasks = list(gmasks)
        new_gmasks[a] = merged
        del new_gmasks[b]
        new_mg = [m for m in self._mgroups if m != ma and m != mb]
        new_mg.append(merged)
        # eager gof remap: cheaper than a lazy rebuild because nearly every
        # offspring ends up re-mutated as a pool member within a generation
        new_gof = [a if g == b else (g - 1 if g > b else g) for g in gof]
        return FusionState._make(self.graph, cg, mask, new_gmasks, new_mg,
                                   new_gof, sched, None)

    def _separate_idx(self, i: int) -> "FusionState":
        bit = 1 << i
        if not (self.mask & bit):
            return self._copy()
        mask = self.mask ^ bit
        if self._gmasks is None:
            return FusionState._make(self.graph, self.cg, mask)
        cg = self.cg
        u, v = cg.eu[i], cg.ev[i]
        reached = self._fused_component(mask, u)
        if (reached >> v) & 1:              # still connected: same partition
            child = FusionState._make(self.graph, cg, mask, self._gmasks,
                                      self._mgroups, self._gof, self._sched,
                                      self._cond)
            return child
        self._ensure_gof()
        gi = self._gof[u]
        comp = self._gmasks[gi]
        piece_a, piece_b = reached, comp ^ reached
        keep, moved = ((piece_a, piece_b)
                       if (piece_a & -piece_a) < (piece_b & -piece_b)
                       else (piece_b, piece_a))
        new_gmasks = list(self._gmasks)
        new_gmasks[gi] = keep
        lb = moved & -moved
        pos = gi + 1
        while pos < len(new_gmasks) and \
                (new_gmasks[pos] & -new_gmasks[pos]) < lb:
            pos += 1
        new_gmasks.insert(pos, moved)
        new_mg = [m for m in self._mgroups if m != comp]
        if keep & (keep - 1):
            new_mg.append(keep)
        if moved & (moved - 1):
            new_mg.append(moved)
        sched = None
        if self._sched is True:
            # Splitting schedulable G into A (producer side, has u) and B
            # (has v) keeps the direct A->B condensation edge (u,v), so a
            # cycle forms iff B still reaches A.  A B ~> A path through any
            # *intermediate* group t would contract (A,B -> G) to a parent
            # condensation cycle G -> t ~> G — impossible, the parent is a
            # DAG — so only a DIRECT B -> A graph edge can close the cycle.
            a_mask, b_mask = reached, comp ^ reached
            succ_ids = cg.succ_ids
            cycle = False
            mb = b_mask
            while mb and not cycle:
                lsb = mb & -mb
                mb ^= lsb
                for w in succ_ids[lsb.bit_length() - 1]:
                    if (a_mask >> w) & 1:
                        cycle = True
                        break
            sched = not cycle
        # remap: old indices >= pos shift up, then nodes of the moved piece
        # are patched to pos (bit-iterating `moved` beats a per-node mask test)
        new_gof = [g + (g >= pos) for g in self._gof]
        mv = moved
        while mv:
            lsb = mv & -mv
            new_gof[lsb.bit_length() - 1] = pos
            mv ^= lsb
        return FusionState._make(self.graph, cg, mask, new_gmasks, new_mg,
                                   new_gof, sched, None)

    # ---- incremental machinery -------------------------------------------------
    def _fused_component(self, mask: int, start: int) -> int:
        """Node bitmask of ``start``'s component under fused edges of ``mask``."""
        inc = self.cg.inc
        seen = 1 << start
        stack = [start]
        while stack:
            x = stack.pop()
            for eidx, other in inc[x]:
                if (mask >> eidx) & 1 and not (seen >> other) & 1:
                    seen |= 1 << other
                    stack.append(other)
        return seen

    def _reaches_via_intermediate(self, gu: int, gv: int) -> bool:
        """Is there a ``gu ~> gv`` condensation path with >= 1 intermediate
        group?  Early-exit BFS over the implicit condensation with a *sound*
        node-id bound.

        Graph edges ascend node ids (builders insert producers first); a
        condensation path can only *descend* inside a multi-member group.  So
        pick the smallest bound ``T`` that starts above ``gv`` and is never
        straddled by a multi-member group (raise it past any group with
        members on both sides, to a fixpoint): neither an edge nor an
        intra-group hop can then cross ``T`` downward, and since ``gv`` lies
        entirely below ``T``, nodes at or above ``T`` can never lead back to
        it — they are safely pruned.
        """
        gmasks = self._gmasks
        T = gmasks[gv].bit_length()
        changed = True
        while changed:
            changed = False
            for m in self._mgroups:
                if (m >> T) and (m & ((1 << T) - 1)):
                    T = m.bit_length()
                    changed = True
        below = (1 << T) - 1
        gof = self._gof
        succ_ids = self.cg.succ_ids
        seen = {gu}
        stack = [gu]
        while stack:
            g = stack.pop()
            members = gmasks[g] & below
            while members:
                lsb = members & -members
                members ^= lsb
                for w in succ_ids[lsb.bit_length() - 1]:
                    t = gof[w]
                    if t == gv:
                        if g == gu:
                            continue        # direct edge: would self-loop
                        return True
                    if t not in seen:
                        seen.add(t)
                        stack.append(t)
        return False

    # ---- derived structure ------------------------------------------------------
    def _ensure_groups(self) -> None:
        if self._gmasks is not None:
            return
        cg = self.cg
        parent = list(range(cg.n))

        def find(x: int) -> int:
            while parent[x] != x:
                parent[x] = parent[parent[x]]
                x = parent[x]
            return x

        eu, ev = cg.eu, cg.ev
        for i in iter_bits(self.mask):
            ru, rv = find(eu[i]), find(ev[i])
            if ru != rv:
                parent[ru] = rv
        root_index: Dict[int, int] = {}
        gmasks: List[int] = []
        gof = [0] * cg.n
        for node in range(cg.n):
            r = find(node)
            gi = root_index.get(r)
            if gi is None:
                gi = len(gmasks)
                root_index[r] = gi
                gmasks.append(0)
            gmasks[gi] |= 1 << node
            gof[node] = gi
        self._gmasks = gmasks
        self._mgroups = [m for m in gmasks if m & (m - 1)]
        self._gof = gof

    def _ensure_gof(self) -> None:
        """Node->group map.  Every path that materializes ``_gmasks`` also
        materializes ``_gof`` (scratch builds make both; combine/separate
        remap the parent's eagerly), so this only triggers the from-scratch
        build on states that have computed neither."""
        if self._gof is None:
            self._ensure_groups()

    def group_masks(self) -> List[int]:
        """Node bitmasks per group, sorted by lowest member id (the group-cost
        cache key in :class:`repro.costmodel.evaluator.Evaluator`)."""
        self._ensure_groups()
        assert self._gmasks is not None
        return self._gmasks

    def multi_masks(self) -> List[int]:
        """Node bitmasks of multi-member groups only (singletons cost exactly
        their layerwise baseline, so the fast fitness path skips them)."""
        self._ensure_groups()
        assert self._mgroups is not None
        return self._mgroups

    def groups(self) -> List[FrozenSet[str]]:
        """Weakly-connected components over fused edges, in first-seen order."""
        if self._groups_str is None:
            names = self.cg.names
            self._groups_str = [frozenset(names[i] for i in iter_bits(gm))
                                for gm in self.group_masks()]
        return self._groups_str

    def group_of(self, name: str) -> int:
        self._ensure_gof()
        assert self._gof is not None
        return self._gof[self.cg.id_of[name]]

    def _condensation(self) -> List[List[int]]:
        """Per-group successor lists (parallel edges kept; cheap to build,
        reused by every offspring of this state)."""
        if self._cond is None:
            self._ensure_gof()
            gof = self._gof
            cg = self.cg
            succ: List[List[int]] = [[] for _ in self._gmasks]
            eu, ev = cg.eu, cg.ev
            for i in range(cg.m):
                gu, gv = gof[eu[i]], gof[ev[i]]
                if gu != gv:
                    succ[gu].append(gv)
            self._cond = succ
        return self._cond

    def group_edges(self) -> List[Tuple[int, int]]:
        """Condensation edges (between distinct groups)."""
        self._ensure_gof()
        gof = self._gof
        cg = self.cg
        out = {(gof[cg.eu[i]], gof[cg.ev[i]]) for i in range(cg.m)
               if gof[cg.eu[i]] != gof[cg.ev[i]]}
        return sorted(out)

    def is_schedulable(self) -> bool:
        """Condensation must be a DAG (see module docstring)."""
        if self._sched is None:
            self._sched = acyclic_indices(self._condensation())
        return self._sched

    def group_schedule(self, rng: Optional[random.Random] = None
                       ) -> List[List[str]]:
        """Topologically-ordered groups, each internally topologically sorted
        (paper §III-C).  Raises CycleError on unschedulable states."""
        gs = self.groups()
        group_order = topological_sort_edges(range(len(gs)), self.group_edges(),
                                             rng)
        sched: List[List[str]] = []
        for gi in group_order:
            members = gs[gi]
            inner = topological_sort_edges(
                [n for n in self.graph.names if n in members],
                self.graph.edges, rng)
            sched.append(inner)
        return sched

    # ---- DRAM residency ----------------------------------------------------------
    def tensor_offchip(self, producer: str) -> bool:
        """True iff ``producer``'s output activation must be stored to DRAM:
        it has a consumer outside the producer's group, or no consumer at all
        (a model output)."""
        cg = self.cg
        u = cg.id_of[producer]
        succ = cg.succ_ids[u]
        if not succ:
            return True
        self._ensure_gof()
        gof = self._gof
        g = gof[u]
        return any(gof[w] != g for w in succ)

    def offchip_tensors(self) -> List[str]:
        cg = self.cg
        return [cg.names[u] for u in range(cg.n)
                if cg.out_size[u] and self.tensor_offchip(cg.names[u])]

    # ---- identity -------------------------------------------------------------------
    def key(self) -> int:
        """O(1) genome identity: the fused-edge bitmask."""
        return self.mask

    def __eq__(self, other):
        return isinstance(other, FusionState) and self.mask == other.mask \
            and self.graph is other.graph

    def __hash__(self):
        return hash((id(self.graph), self.mask))

    def __repr__(self):
        return (f"FusionState({self.graph.name}, {bin(self.mask).count('1')}/"
                f"{self.cg.m} edges fused, {len(self.group_masks())} groups)")
