"""Reference fusion-state implementation (pre-incremental engine).

This is the original dict/frozenset implementation of the GA genome, kept
verbatim as the *oracle* for the incremental bitmask engine in
``repro.core.fusion``: property tests assert that the two agree bit-for-bit on
``groups()``, ``is_schedulable()`` and evaluated :class:`ScheduleCost` for
randomly sampled states.  It is intentionally slow (it rebuilds union-find and
the condensation on every query) and must not be used on the GA hot path.
"""
from __future__ import annotations

import random
from typing import Dict, FrozenSet, List, Optional, Set, Tuple

from repro.core.graph import LayerGraph
from repro.core.toposort import CycleError, topological_sort_edges

Edge = Tuple[str, str]


class ReferenceFusionState:
    """Immutable fusion genome over ``graph`` (reference semantics)."""

    __slots__ = ("graph", "fused", "_groups", "_group_of")

    def __init__(self, graph: LayerGraph, fused: FrozenSet[Edge] = frozenset()):
        all_edges = set(graph.edges)
        bad = set(fused) - all_edges
        if bad:
            raise ValueError(f"fused edges not in graph: {sorted(bad)!r}")
        self.graph = graph
        self.fused = frozenset(fused)
        self._groups: Optional[List[FrozenSet[str]]] = None
        self._group_of: Optional[Dict[str, int]] = None

    # ---- construction helpers -------------------------------------------------
    @classmethod
    def layerwise(cls, graph: LayerGraph) -> "ReferenceFusionState":
        return cls(graph, frozenset())

    @classmethod
    def fully_fused(cls, graph: LayerGraph) -> "ReferenceFusionState":
        return cls(graph, frozenset(graph.edges))

    # ---- genome actions ---------------------------------------------------------
    def combine(self, edge: Edge) -> "ReferenceFusionState":
        if edge not in set(self.graph.edges):
            raise ValueError(f"no such edge {edge!r}")
        return ReferenceFusionState(self.graph, self.fused | {edge})

    def separate(self, edge: Edge) -> "ReferenceFusionState":
        return ReferenceFusionState(self.graph, self.fused - {edge})

    def mutate(self, rng: random.Random) -> "ReferenceFusionState":
        edges = self.graph.edges
        edge = edges[rng.randrange(len(edges))]
        return self.separate(edge) if edge in self.fused else self.combine(edge)

    # ---- derived structure ------------------------------------------------------
    def groups(self) -> List[FrozenSet[str]]:
        """Weakly-connected components over fused edges, in first-seen order."""
        if self._groups is not None:
            return self._groups
        parent: Dict[str, str] = {n: n for n in self.graph.names}

        def find(x: str) -> str:
            while parent[x] != x:
                parent[x] = parent[parent[x]]
                x = parent[x]
            return x

        for u, v in self.fused:
            ru, rv = find(u), find(v)
            if ru != rv:
                parent[ru] = rv
        comp: Dict[str, List[str]] = {}
        for n in self.graph.names:
            comp.setdefault(find(n), []).append(n)
        self._groups = [frozenset(ms) for ms in comp.values()]
        self._group_of = {}
        for gi, g in enumerate(self._groups):
            for n in g:
                self._group_of[n] = gi
        return self._groups

    def group_of(self, name: str) -> int:
        self.groups()
        assert self._group_of is not None
        return self._group_of[name]

    def group_edges(self) -> List[Tuple[int, int]]:
        self.groups()
        out: Set[Tuple[int, int]] = set()
        for u, v in self.graph.edges:
            gu, gv = self.group_of(u), self.group_of(v)
            if gu != gv:
                out.add((gu, gv))
        return sorted(out)

    def is_schedulable(self) -> bool:
        gs = self.groups()
        try:
            topological_sort_edges(range(len(gs)), self.group_edges())
            return True
        except CycleError:
            return False

    def group_schedule(self, rng: Optional[random.Random] = None
                       ) -> List[List[str]]:
        gs = self.groups()
        group_order = topological_sort_edges(range(len(gs)), self.group_edges(), rng)
        sched: List[List[str]] = []
        for gi in group_order:
            members = gs[gi]
            inner = topological_sort_edges(
                [n for n in self.graph.names if n in members],
                self.graph.edges, rng)
            sched.append(inner)
        return sched

    # ---- DRAM residency ----------------------------------------------------------
    def tensor_offchip(self, producer: str) -> bool:
        succ = self.graph.succs(producer)
        if not succ:
            return True
        g = self.group_of(producer)
        return any(self.group_of(v) != g for v in succ)

    def offchip_tensors(self) -> List[str]:
        return [n for n in self.graph.names
                if self.graph.layers[n].output_size and self.tensor_offchip(n)]

    # ---- identity -------------------------------------------------------------------
    def key(self) -> FrozenSet[Edge]:
        return self.fused

    def __eq__(self, other):
        return isinstance(other, ReferenceFusionState) \
            and self.fused == other.fused and self.graph is other.graph

    def __hash__(self):
        return hash((id(self.graph), self.fused))

    def __repr__(self):
        return (f"ReferenceFusionState({self.graph.name}, {len(self.fused)}/"
                f"{len(self.graph.edges)} edges fused, "
                f"{len(self.groups())} groups)")
