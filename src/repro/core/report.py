"""Schedule reporting: the text analogue of paper Fig. 9.

Renders a GA-optimized fusion schedule as per-group rows (members, tile
height, buffer occupancy, DRAM traffic, EDP share) so the "adjacent bars
with the same color are fused" figure has a terminal-friendly counterpart.
:func:`breakdown_report` renders the per-group :class:`CostBreakdown`s a
search artifact stores — where energy and cycles go, group by group —
without rebuilding the graph or re-running the cost model.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

from repro.core.fusion import FusionState
from repro.core.receptive import (group_footprint_words, max_tile_rows,
                                  receptive_field_hw)
from repro.core.schedule import ScheduleResult
from repro.core.toposort import topological_sort_edges
from repro.costmodel.base import CostBreakdown


def schedule_report(res: ScheduleResult, acc, max_rows: int = 0) -> str:
    """Multi-line report for a :class:`ScheduleResult` on accelerator
    ``acc``."""
    g = res.best_state.graph
    lines = [
        f"workload={res.workload} accelerator={res.accelerator}",
        f"energy x{res.energy_improvement:.3f}  edp x{res.edp_improvement:.3f}"
        f"  dram x{res.dram_improvement:.3f}  groups={res.best.n_groups}"
        f"  act-writes {res.baseline.act_write_events}->"
        f"{res.best.act_write_events}",
        f"{'group':>5} {'n':>3} {'tile':>4} {'buf%':>5} {'RF':>7}  members",
    ]
    sched = res.best_state.group_schedule()
    shown = 0
    for gi, members in enumerate(sched):
        order = topological_sort_edges(
            [n for n in g.names if n in set(members)], g.edges)
        multi = len([n for n in order if g.layers[n].macs]) > 1
        if multi:
            t = max_tile_rows(g, order, acc.act_buf_words)
            occ = group_footprint_words(g, order, max(t, 1)) \
                / acc.act_buf_words * 100
            rf = "x".join(map(str, receptive_field_hw(g, order)))
        else:
            t, occ, rf = 0, 0.0, "-"
        label = ",".join(order[:4]) + ("..." if len(order) > 4 else "")
        lines.append(f"{gi:>5} {len(order):>3} {t:>4} {occ:>4.0f}% {rf:>7}"
                     f"  {label}")
        shown += 1
        if max_rows and shown >= max_rows:
            lines.append(f"  ... ({len(sched) - shown} more groups)")
            break
    return "\n".join(lines)


def breakdown_report(breakdowns: Sequence[CostBreakdown],
                     max_rows: int = 10) -> str:
    """Per-group cost table from stored :class:`CostBreakdown`s (what
    ``repro report`` renders): each group's energy/cycle share, whether
    compute or DRAM binds it, the mapping decisions (tile rows, weight
    passes), and its dominant energy component.

    Groups are shown largest-energy-first; ``max_rows=0`` shows all.
    """
    if not breakdowns:
        return "(artifact stores no per-group cost breakdowns)"
    total_e = sum(bd.energy_pj for bd in breakdowns) or 1.0
    total_c = sum(bd.cycles for bd in breakdowns) or 1.0
    order = sorted(range(len(breakdowns)),
                   key=lambda i: -breakdowns[i].energy_pj)
    lines = [
        f"{'group':>5} {'n':>3} {'energy%':>7} {'cycle%':>6} {'bound':>7} "
        f"{'tile':>4} {'wpass':>5} {'util':>5}  top-term  members",
    ]
    shown = 0
    for i in order:
        bd = breakdowns[i]
        bound = "dram" if bd.dram_cycles >= bd.compute_cycles else "compute"
        top = max(bd.energy_terms, key=bd.energy_terms.get) \
            if bd.energy_terms else "-"
        label = ",".join(bd.members[:3]) \
            + ("..." if len(bd.members) > 3 else "")
        lines.append(
            f"{i:>5} {len(bd.members):>3} {bd.energy_pj / total_e * 100:>6.1f}%"
            f" {bd.cycles / total_c * 100:>5.1f}% {bound:>7} "
            f"{bd.tile_rows:>4} {bd.weight_passes:>5} "
            f"{bd.utilization:>5.2f}  {top:<8}  {label}")
        shown += 1
        if max_rows and shown >= max_rows and shown < len(breakdowns):
            rest = len(breakdowns) - shown
            rest_e = sum(breakdowns[j].energy_pj
                         for j in order[shown:]) / total_e * 100
            lines.append(f"  ... ({rest} more groups, {rest_e:.1f}% of "
                         f"energy; --breakdown shows all)")
            break
    return "\n".join(lines)
