"""Schedule reporting: the text analogue of paper Fig. 9.

Renders a GA-optimized fusion schedule as per-group rows (members, tile
height, buffer occupancy, DRAM traffic, EDP share) so the "adjacent bars
with the same color are fused" figure has a terminal-friendly counterpart.
"""
from __future__ import annotations

from typing import List, Optional

from repro.core.fusion import FusionState
from repro.core.receptive import (group_footprint_words, max_tile_rows,
                                  receptive_field_hw)
from repro.core.schedule import ScheduleResult
from repro.core.toposort import topological_sort_edges


def schedule_report(res: ScheduleResult, acc, max_rows: int = 0) -> str:
    """Multi-line report for a :class:`ScheduleResult` on accelerator
    ``acc``."""
    g = res.best_state.graph
    lines = [
        f"workload={res.workload} accelerator={res.accelerator}",
        f"energy x{res.energy_improvement:.3f}  edp x{res.edp_improvement:.3f}"
        f"  dram x{res.dram_improvement:.3f}  groups={res.best.n_groups}"
        f"  act-writes {res.baseline.act_write_events}->"
        f"{res.best.act_write_events}",
        f"{'group':>5} {'n':>3} {'tile':>4} {'buf%':>5} {'RF':>7}  members",
    ]
    sched = res.best_state.group_schedule()
    shown = 0
    for gi, members in enumerate(sched):
        order = topological_sort_edges(
            [n for n in g.names if n in set(members)], g.edges)
        multi = len([n for n in order if g.layers[n].macs]) > 1
        if multi:
            t = max_tile_rows(g, order, acc.act_buf_words)
            occ = group_footprint_words(g, order, max(t, 1)) \
                / acc.act_buf_words * 100
            rf = "x".join(map(str, receptive_field_hw(g, order)))
        else:
            t, occ, rf = 0, 0.0, "-"
        label = ",".join(order[:4]) + ("..." if len(order) > 4 else "")
        lines.append(f"{gi:>5} {len(order):>3} {t:>4} {occ:>4.0f}% {rf:>7}"
                     f"  {label}")
        shown += 1
        if max_rows and shown >= max_rows:
            lines.append(f"  ... ({len(sched) - shown} more groups)")
            break
    return "\n".join(lines)
