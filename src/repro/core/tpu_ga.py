"""The paper's GA re-targeted at TPU training schedules (beyond-paper).

Same Alg. 1 skeleton, but the genome is a
:class:`repro.costmodel.tpu_model.TpuSchedule` — remat policy (the TPU
analogue of the paper's fuse/split decision: *which activations stay
"on-chip"/cheap vs round-trip HBM*), microbatch count (receptive-field-style
working-set sizing), gradient compression (cross-pod DRAM<->DCI traffic),
and sharding mode.

This module is now a thin compatibility shim: the genome lives in
``repro.search.tpu.TpuScheduleProblem`` and the selection loop is the shared
``repro.core.ga.run_ga_problem`` (this file's own copy of the loop was
deleted when the search facade landed).  New callers should use
``repro.search.tpu.search_tpu_schedule``, which also accepts the ``random``
/ ``hill_climb`` / ``exhaustive`` backends.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.ga import GAConfig
from repro.costmodel.tpu_model import TpuCost, TpuSchedule
from repro.roofline.analysis import HW


@dataclass
class TpuGAResult:
    best: TpuSchedule
    best_cost: TpuCost
    baseline: TpuSchedule
    baseline_cost: TpuCost
    history: List[float] = field(default_factory=list)
    evaluations: int = 0

    @property
    def edp_improvement(self) -> float:
        return self.baseline_cost.edp / self.best_cost.edp

    @property
    def step_improvement(self) -> float:
        return self.baseline_cost.step_s / self.best_cost.step_s


def optimize_tpu_schedule(cfg: ModelConfig, shape: ShapeConfig, *,
                          chips: int = 256, data_par: int = 16,
                          model_par: int = 16, hw: HW = HW(),
                          objective: str = "edp",
                          ga: GAConfig = GAConfig.fast(generations=30),
                          hbm_capacity: Optional[float] = None
                          ) -> TpuGAResult:
    """Compatibility shim over :func:`repro.search.tpu.search_tpu_schedule`
    (GA backend)."""
    from repro.search.tpu import search_tpu_schedule
    return search_tpu_schedule(
        cfg, shape, chips=chips, data_par=data_par, model_par=model_par,
        hw=hw, objective=objective, ga=ga, hbm_capacity=hbm_capacity)
