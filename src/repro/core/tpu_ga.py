"""The paper's GA re-targeted at TPU training schedules (beyond-paper).

Same Alg. 1 skeleton (population, combine/separate-style mutations, fitness
= baseline/new, Top-N + random survivors), but the genome is a
:class:`repro.costmodel.tpu_model.TpuSchedule` — remat policy (the TPU
analogue of the paper's fuse/split decision: *which activations stay
"on-chip"/cheap vs round-trip HBM*), microbatch count (receptive-field-style
working-set sizing) and gradient compression (cross-pod DRAM<->DCI traffic).

Fitness comes from the analytical TPU cost model; candidates whose HBM
residency exceeds capacity are invalid — the same capacity-check-discard the
paper applies to over-buffer fusion states.  The dry-run validates the
winner by re-lowering (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.ga import GAConfig
from repro.costmodel.tpu_model import TpuCost, TpuSchedule, estimate
from repro.roofline.analysis import HW


@dataclass
class TpuGAResult:
    best: TpuSchedule
    best_cost: TpuCost
    baseline: TpuSchedule
    baseline_cost: TpuCost
    history: List[float] = field(default_factory=list)
    evaluations: int = 0

    @property
    def edp_improvement(self) -> float:
        return self.baseline_cost.edp / self.best_cost.edp

    @property
    def step_improvement(self) -> float:
        return self.baseline_cost.step_s / self.best_cost.step_s


def optimize_tpu_schedule(cfg: ModelConfig, shape: ShapeConfig, *,
                          chips: int = 256, data_par: int = 16,
                          model_par: int = 16, hw: HW = HW(),
                          objective: str = "edp",
                          ga: GAConfig = GAConfig.fast(generations=30),
                          hbm_capacity: Optional[float] = None
                          ) -> TpuGAResult:
    """Search remat/microbatch/compression for one (arch x shape) cell."""
    hbm_capacity = hbm_capacity or hw.hbm_bytes
    rng = random.Random(ga.seed)
    cache: Dict[TpuSchedule, Optional[TpuCost]] = {}

    def cost_of(s: TpuSchedule) -> Optional[TpuCost]:
        if s not in cache:
            if s.sharding == "fsdp" and cfg.n_experts:
                cache[s] = None      # EP needs the model axis (unsupported)
            else:
                c = estimate(cfg, shape, s, chips=chips, data_par=data_par,
                             model_par=model_par, hw=hw)
                cache[s] = None if c.hbm_resident_bytes > hbm_capacity else c
        return cache[s]

    baseline = TpuSchedule()                      # paper-faithful start
    base_cost = estimate(cfg, shape, baseline, chips=chips,
                         data_par=data_par, model_par=model_par, hw=hw)

    def metric(c: TpuCost) -> float:
        return c.edp if objective == "edp" else c.step_s

    def fitness(s: TpuSchedule) -> float:
        c = cost_of(s)
        return 0.0 if c is None else metric(base_cost) / metric(c)

    pool: List[Tuple[float, TpuSchedule]] = [(fitness(baseline), baseline)]
    history: List[float] = []
    for _ in range(ga.generations):
        parents = [s for _, s in pool]
        children = []
        for _ in range(ga.mutations_per_gen):
            p = parents[rng.randrange(len(parents))]
            opts = p.mutate_options()
            children.append(opts[rng.randrange(len(opts))])
        merged = {s: f for f, s in pool}
        for c in children:
            merged[c] = fitness(c)
        ranked = sorted(merged.items(), key=lambda kv: -kv[1])
        top = [(f, s) for s, f in ranked[:ga.top_n]]
        rest = [(f, s) for s, f in ranked[ga.top_n:]]
        rng.shuffle(rest)
        pool = top + rest[:ga.random_survivors]
        history.append(pool[0][0])

    best_f, best = max(pool, key=lambda fs: fs[0])
    best_cost = cost_of(best)
    assert best_cost is not None
    return TpuGAResult(best=best, best_cost=best_cost, baseline=baseline,
                       baseline_cost=base_cost, history=history,
                       evaluations=len(cache))
