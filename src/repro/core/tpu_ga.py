"""The paper's GA re-targeted at TPU training schedules (beyond-paper).

Same Alg. 1 skeleton (population, combine/separate-style mutations, fitness
= baseline/new, Top-N + random survivors), but the genome is a
:class:`repro.costmodel.tpu_model.TpuSchedule` — remat policy (the TPU
analogue of the paper's fuse/split decision: *which activations stay
"on-chip"/cheap vs round-trip HBM*), microbatch count (receptive-field-style
working-set sizing) and gradient compression (cross-pod DRAM<->DCI traffic).

Fitness comes from the analytical TPU cost model; candidates whose HBM
residency exceeds capacity are invalid — the same capacity-check-discard the
paper applies to over-buffer fusion states.  The dry-run validates the
winner by re-lowering (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.ga import GAConfig, select_pool
from repro.costmodel.tpu_model import TpuCost, TpuSchedule, estimate
from repro.roofline.analysis import HW


@dataclass
class TpuGAResult:
    best: TpuSchedule
    best_cost: TpuCost
    baseline: TpuSchedule
    baseline_cost: TpuCost
    history: List[float] = field(default_factory=list)
    evaluations: int = 0

    @property
    def edp_improvement(self) -> float:
        return self.baseline_cost.edp / self.best_cost.edp

    @property
    def step_improvement(self) -> float:
        return self.baseline_cost.step_s / self.best_cost.step_s


def optimize_tpu_schedule(cfg: ModelConfig, shape: ShapeConfig, *,
                          chips: int = 256, data_par: int = 16,
                          model_par: int = 16, hw: HW = HW(),
                          objective: str = "edp",
                          ga: GAConfig = GAConfig.fast(generations=30),
                          hbm_capacity: Optional[float] = None
                          ) -> TpuGAResult:
    """Search remat/microbatch/compression for one (arch x shape) cell."""
    hbm_capacity = hbm_capacity or hw.hbm_bytes
    rng = random.Random(ga.seed)
    cache: Dict[TpuSchedule, Optional[TpuCost]] = {}

    def cost_of(s: TpuSchedule) -> Optional[TpuCost]:
        if s not in cache:
            if s.sharding == "fsdp" and cfg.n_experts:
                cache[s] = None      # EP needs the model axis (unsupported)
            else:
                c = estimate(cfg, shape, s, chips=chips, data_par=data_par,
                             model_par=model_par, hw=hw)
                cache[s] = None if c.hbm_resident_bytes > hbm_capacity else c
        return cache[s]

    baseline = TpuSchedule()                      # paper-faithful start
    base_cost = estimate(cfg, shape, baseline, chips=chips,
                         data_par=data_par, model_par=model_par, hw=hw)

    def metric(c: TpuCost) -> float:
        return c.edp if objective == "edp" else c.step_s

    def fitness(s: TpuSchedule) -> float:
        c = cost_of(s)
        return 0.0 if c is None else metric(base_cost) / metric(c)

    def mutant_of(parent: TpuSchedule) -> TpuSchedule:
        opts = parent.mutate_options()
        return opts[rng.randrange(len(opts))]

    pool: List[Tuple[float, TpuSchedule]] = [(fitness(baseline), baseline)]
    history: List[float] = []
    for _ in range(ga.generations):
        children = [mutant_of(pool[rng.randrange(len(pool))][1])
                    for _ in range(ga.mutations_per_gen)]
        entries = pool + [(fitness(c), c) for c in children]
        pool = select_pool(entries, ga.top_n, ga.random_survivors, rng)
        # honor the paper's full population: top the pool back up with fresh
        # mutants of survivors (same fix as repro.core.ga.run_ga)
        while len(pool) < ga.population:
            c = mutant_of(pool[rng.randrange(len(pool))][1])
            pool.append((fitness(c), c))
        history.append(max(f for f, _ in pool))

    best_f, best = max(pool, key=lambda fs: fs[0])
    best_cost = cost_of(best)
    assert best_cost is not None
    return TpuGAResult(best=best, best_cost=best_cost, baseline=baseline,
                       baseline_cost=base_cost, history=history,
                       evaluations=len(cache))
