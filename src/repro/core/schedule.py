"""High-level API: optimize a workload's interlayer schedule on an accelerator.

This is the paper's end-to-end flow (§III-IV): layerwise baseline -> GA search
over fusion states -> best multi-layer schedule, reported as improvement
ratios over the baseline.

:func:`optimize` is now a thin compatibility shim over ``repro.search``
(spec -> session -> artifact); it keeps the pre-facade signature and
:class:`ScheduleResult` return type for existing callers.  New code should
use :func:`repro.search.search` / :class:`repro.search.SearchSession`, which
also provide durable JSON artifacts and non-GA backends.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from typing import TYPE_CHECKING

from repro.core.fusion import FusionState
from repro.core.ga import GAConfig, GAResult
from repro.core.graph import LayerGraph

if TYPE_CHECKING:  # lazy at runtime: costmodel imports core.fusion
    from repro.costmodel.accelerator import Accelerator
    from repro.costmodel.energy import EnergyModel
    from repro.costmodel.evaluator import ScheduleCost


class ImprovementRatios:
    """Baseline/best improvement ratios (the paper's reporting unit), shared
    by :class:`ScheduleResult` and ``repro.search.ScheduleArtifact`` — both
    expose ``baseline``/``best`` :class:`ScheduleCost` attributes."""

    baseline: ScheduleCost
    best: ScheduleCost

    @property
    def energy_improvement(self) -> float:
        return self.baseline.energy_pj / self.best.energy_pj

    @property
    def edp_improvement(self) -> float:
        return self.baseline.edp / self.best.edp

    @property
    def cycles_improvement(self) -> float:
        return self.baseline.cycles / self.best.cycles

    @property
    def dram_improvement(self) -> float:
        b = self.baseline.dram_read_words + self.baseline.dram_write_words
        n = self.best.dram_read_words + self.best.dram_write_words
        return b / max(n, 1)


@dataclass
class ScheduleResult(ImprovementRatios):
    workload: str
    accelerator: str
    baseline: ScheduleCost              # layerwise
    best: ScheduleCost                  # GA-optimized
    best_state: FusionState
    ga: GAResult

    def summary(self) -> Dict[str, float]:
        return {
            "workload": self.workload,
            "accelerator": self.accelerator,
            "energy_x": round(self.energy_improvement, 3),
            "edp_x": round(self.edp_improvement, 3),
            "cycles_x": round(self.cycles_improvement, 3),
            "dram_x": round(self.dram_improvement, 3),
            "groups": self.best.n_groups,
            "act_dram_writes_base": self.baseline.act_write_events,
            "act_dram_writes_best": self.best.act_write_events,
            "ga_evaluations": self.ga.evaluations,
        }


def optimize(graph: LayerGraph, acc: "Accelerator",
             config: GAConfig = GAConfig(),
             em: "EnergyModel" = None) -> ScheduleResult:
    """Compatibility shim: run the GA backend through a ``repro.search``
    session (fixed-seed results are bit-identical to the pre-facade path)."""
    from repro.search.session import SearchSession
    # from_objects records the workload as ir:<fingerprint> (graph.name
    # may shadow, or be absent from, the registry) and embeds the IR
    session = SearchSession.from_objects(
        graph, acc, em=em, objective=config.objective, backend="ga",
        backend_config={"ga_config": config}, seed=config.seed)
    session.run()
    return session.schedule_result()
