"""Layer-graph IR for CNN workloads (paper §II, §III-A).

A model is a DAG of :class:`Layer` nodes.  Edges carry activation tensors; the
fusion scheduler (``repro.core.fusion``) decides, per edge, whether that tensor
stays on-chip (*fused*) or round-trips DRAM (*split*).

Tensor-size conventions follow the paper's notation (Fig. 1):
  input  C x H x W, weights M x C x R x S, output M x P x Q.
All sizes are in *words* (16-bit by default, matching the paper's edge setting).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Mapping, Sequence, Tuple

# Layer kinds that carry weights / MACs.
_COMPUTE_KINDS = ("conv", "dwconv", "fc")
# Kinds that only reshape/merge activations (no weights, negligible MACs).
_GLUE_KINDS = ("input", "add", "concat", "pool", "upsample", "global_pool", "mul")


@dataclass(frozen=True)
class Layer:
    """One node of the computation graph.

    For ``conv``-like kinds the full (C,H,W) -> (M,P,Q) geometry is kept so the
    receptive-field backtrace (paper §III-B, Fig. 5) can size fused tiles.
    """

    name: str
    kind: str                      # conv | dwconv | fc | pool | add | concat | ...
    c: int = 0                     # input channels  (C)
    h: int = 0                     # input height    (H)
    w: int = 0                     # input width     (W)
    m: int = 0                     # output channels (M)
    p: int = 0                     # output height   (P)
    q: int = 0                     # output width    (Q)
    r: int = 1                     # filter height   (R)
    s: int = 1                     # filter width    (S)
    stride: Tuple[int, int] = (1, 1)
    padding: Tuple[int, int] = (0, 0)
    dilation: Tuple[int, int] = (1, 1)
    groups: int = 1

    def __post_init__(self):
        if self.kind not in _COMPUTE_KINDS + _GLUE_KINDS:
            raise ValueError(f"unknown layer kind {self.kind!r} for {self.name!r}")

    # ---- tensor sizes (words) -------------------------------------------------
    @property
    def input_size(self) -> int:
        return self.c * self.h * self.w

    @property
    def output_size(self) -> int:
        return self.m * self.p * self.q

    @property
    def weight_size(self) -> int:
        if self.kind == "conv":
            return self.m * (self.c // self.groups) * self.r * self.s
        if self.kind == "dwconv":
            return self.m * self.r * self.s            # depthwise: one filter/channel
        if self.kind == "fc":
            return self.m * self.c
        return 0

    @property
    def macs(self) -> int:
        if self.kind == "conv":
            return self.m * self.p * self.q * (self.c // self.groups) * self.r * self.s
        if self.kind == "dwconv":
            return self.m * self.p * self.q * self.r * self.s
        if self.kind == "fc":
            return self.m * self.c
        if self.kind in ("add", "mul"):
            return self.output_size                    # 1 op per element
        return 0

    @property
    def has_weights(self) -> bool:
        return self.weight_size > 0


class CompiledGraph:
    """A :class:`LayerGraph` frozen into integer arrays for the GA hot path.

    Node ids are positions in insertion order (a valid topological order by
    construction); edge ids are positions in ``LayerGraph.edges`` order.  All
    adjacency is precomputed so fusion-state operations never rebuild
    ``graph.edges``/``preds``/``succs`` or hash strings.
    """

    __slots__ = ("graph", "n", "m", "names", "id_of", "layers", "edge_pairs",
                 "edge_id", "eu", "ev", "succ_ids", "pred_ids", "inc",
                 "out_size", "weight_size", "macs", "p")

    def __init__(self, graph: "LayerGraph"):
        self.graph = graph
        names = tuple(graph.layers)
        self.names = names
        self.n = len(names)
        self.id_of = {nm: i for i, nm in enumerate(names)}
        self.layers = tuple(graph.layers[nm] for nm in names)
        # dedupe parallel edges (e.g. an `add` consuming the same producer
        # twice): the genome is a *set* of fused pairs, so duplicates must
        # share one bit or one logical genome would have several masks
        pairs = tuple(dict.fromkeys(
            (u, v) for u, vs in graph._succ.items() for v in vs))
        self.edge_pairs = pairs
        self.m = len(pairs)
        self.edge_id = {e: i for i, e in enumerate(pairs)}
        self.eu = tuple(self.id_of[u] for u, _ in pairs)
        self.ev = tuple(self.id_of[v] for _, v in pairs)
        self.succ_ids = tuple(tuple(self.id_of[v] for v in graph._succ[nm])
                              for nm in names)
        self.pred_ids = tuple(tuple(self.id_of[v] for v in graph._pred[nm])
                              for nm in names)
        inc: List[List[Tuple[int, int]]] = [[] for _ in range(self.n)]
        for i in range(self.m):
            inc[self.eu[i]].append((i, self.ev[i]))
            inc[self.ev[i]].append((i, self.eu[i]))
        self.inc = tuple(tuple(xs) for xs in inc)
        self.out_size = tuple(l.output_size for l in self.layers)
        self.weight_size = tuple(l.weight_size for l in self.layers)
        self.macs = tuple(l.macs for l in self.layers)
        self.p = tuple(l.p for l in self.layers)


class LayerGraph:
    """A DAG of layers.  Node order of ``layers`` is a valid topological order
    by construction (builders add producers before consumers)."""

    def __init__(self, name: str):
        self.name = name
        self.layers: Dict[str, Layer] = {}
        self._succ: Dict[str, List[str]] = {}
        self._pred: Dict[str, List[str]] = {}
        self._compiled: "CompiledGraph" = None
        #: declared model outputs (None = every sink).  Carried so graphs
        #: built from IR with non-sink outputs (multi-head models) keep
        #: them through a to_ir() round-trip instead of collapsing to
        #: sinks and changing the fingerprint.
        self.outputs: "List[str]" = None

    # ---- construction ---------------------------------------------------------
    def add(self, layer: Layer, inputs: Sequence[str] = ()) -> str:
        if layer.name in self.layers:
            raise ValueError(f"duplicate layer {layer.name!r}")
        for src in inputs:
            if src not in self.layers:
                raise ValueError(f"unknown producer {src!r} for {layer.name!r}")
        self.layers[layer.name] = layer
        self._succ[layer.name] = []
        self._pred[layer.name] = list(inputs)
        for src in inputs:
            self._succ[src].append(layer.name)
        self._compiled = None                        # adjacency changed
        return layer.name

    def compiled(self) -> CompiledGraph:
        """Frozen integer-array view; rebuilt lazily after any :meth:`add`."""
        if self._compiled is None:
            self._compiled = CompiledGraph(self)
        return self._compiled

    # ---- queries ---------------------------------------------------------------
    def preds(self, name: str) -> List[str]:
        return self._pred[name]

    def succs(self, name: str) -> List[str]:
        return self._succ[name]

    @property
    def edges(self) -> List[Tuple[str, str]]:
        return [(u, v) for u, vs in self._succ.items() for v in vs]

    @property
    def names(self) -> List[str]:
        return list(self.layers)

    def compute_layers(self) -> List[str]:
        return [n for n, l in self.layers.items() if l.kind in _COMPUTE_KINDS]

    @property
    def total_macs(self) -> int:
        return sum(l.macs for l in self.layers.values())

    @property
    def total_weights(self) -> int:
        return sum(l.weight_size for l in self.layers.values())

    # ---- IR interchange --------------------------------------------------------
    def to_ir(self):
        """This graph as serializable :class:`repro.ir.GraphIR` (exact:
        node order, input order, and geometry are preserved verbatim)."""
        from repro.ir import GraphIR                 # lazy: ir imports us
        return GraphIR.from_graph(self)

    @staticmethod
    def from_ir(ir) -> "LayerGraph":
        """Materialize a :class:`repro.ir.GraphIR` (accepts the IR object,
        its dict form, or its JSON text)."""
        from repro.ir import GraphIR
        if isinstance(ir, str):
            ir = GraphIR.from_json(ir)
        elif isinstance(ir, dict):
            ir = GraphIR.from_dict(ir)
        return ir.build()

    def validate(self) -> None:
        """Check DAG-ness and tensor-shape agreement along every edge."""
        from repro.core.toposort import topological_sort  # local import, no cycle

        topological_sort(self)                       # raises on cycles
        for u, v in self.edges:
            lu, lv = self.layers[u], self.layers[v]
            if lu.kind == "input" or lv.kind in ("add", "concat", "mul"):
                continue                              # glue nodes checked loosely
            if lu.m and lv.c and lv.kind in _COMPUTE_KINDS and len(self._pred[v]) == 1:
                ok = lv.c in (lu.m, lu.m * max(lu.p, 1) * max(lu.q, 1))
                if not ok:                     # fc consumers flatten (m*p*q)
                    raise ValueError(
                        f"channel mismatch {u}({lu.m}) -> {v}({lv.c}) in {self.name}")

    def __repr__(self):
        return (f"LayerGraph({self.name!r}, {len(self.layers)} layers, "
                f"{self.total_macs/1e6:.1f} MMACs, {self.total_weights/1e6:.2f} MWords)")
