"""The paper's primary contribution: GA-driven interlayer (layer-fusion)
scheduling over a layer graph, with topological-sort dependency enforcement
and receptive-field-based capacity checks."""
from repro.core.fusion import FusionState
from repro.core.fusion_ref import ReferenceFusionState
from repro.core.ga import GAConfig, GAResult, run_ga, run_ga_problem
from repro.core.graph import CompiledGraph, Layer, LayerGraph
from repro.core.problem import FusionProblem, SearchProblem
from repro.core.schedule import ScheduleResult, optimize

__all__ = ["FusionState", "ReferenceFusionState", "GAConfig", "GAResult",
           "run_ga", "run_ga_problem", "CompiledGraph", "Layer", "LayerGraph",
           "FusionProblem", "SearchProblem", "ScheduleResult", "optimize"]
