"""Search-problem protocol: what a genome space must provide to be searched.

The paper's search procedure (Alg. 1) is independent of *what* is being
searched: it needs an initial genome, a mutation operator, and a fitness
function with 0 meaning invalid.  This module pins that contract down as
:class:`SearchProblem` so every search backend in ``repro.search.backends``
(GA, random, hill-climb, exhaustive) runs against fusion states and TPU
schedules — or any future genome — through one interface instead of each
genome growing its own copy of the selection loop.

:class:`FusionProblem` is the paper's problem: edge-bitmask
:class:`repro.core.fusion.FusionState` genomes scored by a memoizing
:class:`repro.costmodel.evaluator.Evaluator`.  Its method bodies make
exactly the RNG calls the pre-refactor ``run_ga`` made, so fixed-seed
results are bit-for-bit unchanged (pinned by ``tests/test_search_api.py``).
"""
from __future__ import annotations

import random
from typing import Hashable, Iterable, Iterator, List, Optional, Sequence

from repro.core.fusion import FusionState
from repro.core.graph import LayerGraph


class SearchProblem:
    """Genome-space contract consumed by every search backend.

    Subclasses must implement :meth:`initial`, :meth:`mutate`,
    :meth:`fitness`, and :meth:`key`; the remaining methods have generic
    (sometimes unavailable) defaults that specific problems may override
    or extend.
    """

    #: short name used in artifacts/reports
    name: str = "problem"

    # ---- required surface -----------------------------------------------------
    def initial(self):
        """The search's starting genome (the paper's layerwise schedule)."""
        raise NotImplementedError

    def mutate(self, genome, rng: random.Random):
        """One random unit mutation (paper Alg. 1 line 4)."""
        raise NotImplementedError

    def fitness(self, genome) -> float:
        """``baseline_metric / genome_metric``; 0.0 means invalid."""
        raise NotImplementedError

    def key(self, genome) -> Hashable:
        """Cheap hashable genome identity for fitness caches."""
        raise NotImplementedError

    # ---- optional surface -----------------------------------------------------
    def fitness_batch(self, genomes: Sequence) -> List[float]:
        """Score a whole offspring generation; override when the evaluator
        can dedupe shared substructure (see ``Evaluator.fitness_batch``)."""
        return [self.fitness(g) for g in genomes]

    def crossover(self, a, b, rng: random.Random):
        """Uniform crossover (beyond-paper); default: no recombination."""
        return a

    def neighbors(self, genome) -> Iterable:
        """All one-mutation neighbors (hill-climb moves).  Optional."""
        raise NotImplementedError(f"{self.name} does not enumerate neighbors")

    def enumerate(self) -> Iterator:
        """Every genome in the space (exhaustive search).  Optional."""
        raise NotImplementedError(f"{self.name} is not enumerable")

    def space_size(self) -> Optional[int]:
        """Number of genomes in the space, or None if unbounded/unknown."""
        return None

    def encode_genome(self, genome):
        """Compact, picklable wire form of a genome — what multi-process
        backends (``repro.search.island``) ship between workers instead of
        the live object (which may drag a whole graph through pickle).
        Default: the genome itself."""
        return genome

    def decode_genome(self, data):
        """Inverse of :meth:`encode_genome`, re-binding the wire form onto
        this problem's live objects."""
        return data


class FusionProblem(SearchProblem):
    """The paper's interlayer-pipelining problem (§III): fusion-state genomes
    over ``graph``, scored by ``evaluator`` on ``objective``."""

    name = "fusion"

    def __init__(self, graph: LayerGraph, evaluator, objective: str = "edp"):
        self.graph = graph
        self.evaluator = evaluator
        self.objective = objective
        self.cg = graph.compiled()
        self._mbits = self.cg.m.bit_length()
        self._batch = getattr(evaluator, "fitness_batch", None)
        self._batch_unique = getattr(evaluator, "fitness_batch_unique", None)

    def initial(self) -> FusionState:
        return FusionState.layerwise(self.graph)

    def mutate(self, genome: FusionState, rng: random.Random) -> FusionState:
        """One random edge flip.  Returns a *lazy* child (mask only — no
        group maintenance): the batched population engine recomputes all
        per-genome structure array-natively, so eagerly maintaining union-find
        state per offspring (what ``FusionState.mutate`` does when the parent
        is structured) would be pure overhead.  The inlined getrandbits loop
        is CPython's ``_randbelow`` — the same draws ``rng.randrange(m)``
        makes, so fixed-seed runs are unchanged."""
        m = self.cg.m
        if not m:
            raise ValueError("graph has no edges to mutate")
        grb = rng.getrandbits
        i = grb(self._mbits)
        while i >= m:
            i = grb(self._mbits)
        return FusionState._make(self.graph, genome.cg,
                                 genome.mask ^ (1 << i))

    def prewarm(self) -> None:
        """Materialize everything forked workers should inherit read-only
        via copy-on-write: the compiled graph, the layerwise baseline, and
        the population engine's static tables (``repro.search.island`` calls
        this before spawning)."""
        ev = self.evaluator
        if hasattr(ev, "population"):
            try:
                ev.population()
            except RuntimeError:     # no numpy: scalar path needs no tables
                ev.layerwise()
        elif hasattr(ev, "layerwise"):
            ev.layerwise()

    def fitness(self, genome: FusionState) -> float:
        return self.evaluator.fitness(genome, self.objective)

    def fitness_batch(self, genomes: Sequence[FusionState]) -> List[float]:
        if self._batch is not None:
            return self._batch(genomes, self.objective)
        return [self.fitness(g) for g in genomes]

    def fitness_batch_unique(self, genomes: Sequence[FusionState]
                             ) -> List[float]:
        """Batch scoring for genome lists already deduped by :meth:`key`
        (the GA loop's per-run cache guarantees this); skips the
        evaluator's own dedup pass.  Subclasses that override
        :meth:`fitness_batch` keep their scoring path: the fast lane only
        engages when batch scoring is the stock evaluator route."""
        if (self._batch_unique is not None
                and type(self).fitness_batch is FusionProblem.fitness_batch):
            return self._batch_unique(genomes, self.objective)
        return self.fitness_batch(genomes)

    def key(self, genome: FusionState) -> int:
        return genome.mask               # == genome.key(), one hop cheaper

    def crossover(self, a: FusionState, b: FusionState,
                  rng: random.Random) -> FusionState:
        """Uniform crossover on the fused-edge genome (beyond-paper)."""
        mask = 0
        for i in range(self.cg.m):
            src = a.mask if rng.random() < 0.5 else b.mask
            mask |= src & (1 << i)
        return FusionState.from_mask(self.graph, mask)

    def neighbors(self, genome: FusionState) -> Iterator[FusionState]:
        for i in range(self.cg.m):
            if (genome.mask >> i) & 1:
                yield genome._separate_idx(i)
            else:
                yield genome._combine_idx(i)

    def random_genome(self, rng: random.Random) -> FusionState:
        return FusionState.from_mask(self.graph, rng.getrandbits(self.cg.m)
                                     if self.cg.m else 0)

    def enumerate(self) -> Iterator[FusionState]:
        for mask in range(1 << self.cg.m):
            yield FusionState.from_mask(self.graph, mask)

    def space_size(self) -> int:
        return 1 << self.cg.m

    def encode_genome(self, genome: FusionState) -> int:
        return genome.mask

    def decode_genome(self, data: int) -> FusionState:
        return FusionState.from_mask(self.graph, data)
