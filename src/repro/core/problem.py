"""Search-problem protocol: what a genome space must provide to be searched.

The paper's search procedure (Alg. 1) is independent of *what* is being
searched: it needs an initial genome, a mutation operator, and a fitness
function with 0 meaning invalid.  This module pins that contract down as
:class:`SearchProblem` so every search backend in ``repro.search.backends``
(GA, random, hill-climb, exhaustive) runs against fusion states and TPU
schedules — or any future genome — through one interface instead of each
genome growing its own copy of the selection loop.

:class:`FusionProblem` is the paper's problem: edge-bitmask
:class:`repro.core.fusion.FusionState` genomes scored by a memoizing
:class:`repro.costmodel.evaluator.Evaluator`.  Its method bodies make
exactly the RNG calls the pre-refactor ``run_ga`` made, so fixed-seed
results are bit-for-bit unchanged (pinned by ``tests/test_search_api.py``).

A :class:`~repro.analysis.spacemap.SpaceMap` (``SearchSpec(spacemap=
True)``) restricts the genome to the statically undecided bits: mutation,
crossover, uniform sampling, neighborhoods, and enumeration all skip the
provably forced-off genes, so the population engine's ``(P, n_edges)``
matrices never carry a frozen column.  The spacemap path makes *different*
RNG draws than the unrestricted one (shorter index ranges), so it sits
behind the opt-in flag with its own fixed-seed pins
(``tests/test_spacemap.py``); with ``spacemap=None`` every draw below is
bit-identical to the pre-spacemap code.
"""
from __future__ import annotations

import random
from typing import (TYPE_CHECKING, Any, Hashable, Iterable, Iterator, List,
                    Optional, Sequence, Tuple)

from repro.core.fusion import FusionState
from repro.core.graph import LayerGraph

if TYPE_CHECKING:                      # import cycle-free type-only import
    from repro.analysis.spacemap import SpaceMap


class SearchProblem:
    """Genome-space contract consumed by every search backend.

    Subclasses must implement :meth:`initial`, :meth:`mutate`,
    :meth:`fitness`, and :meth:`key`; the remaining methods have generic
    (sometimes unavailable) defaults that specific problems may override
    or extend.
    """

    #: short name used in artifacts/reports
    name: str = "problem"

    #: extra genomes scored into the GA's initial pool alongside
    #: :meth:`initial` (warm-start seeding, ``repro.serve.warmstart``).
    #: Duplicates of the initial genome are dropped.  Empty by default so
    #: every existing fixed-seed trajectory stays bit-identical — a non-empty
    #: tuple widens the first generation's parent pool and therefore its RNG
    #: draw widths, which is why callers must opt in explicitly.
    seed_genomes: Tuple[Any, ...] = ()

    # ---- required surface -----------------------------------------------------
    def initial(self) -> Any:
        """The search's starting genome (the paper's layerwise schedule)."""
        raise NotImplementedError

    def mutate(self, genome: Any, rng: random.Random) -> Any:
        """One random unit mutation (paper Alg. 1 line 4)."""
        raise NotImplementedError

    def fitness(self, genome: Any) -> float:
        """``baseline_metric / genome_metric``; 0.0 means invalid."""
        raise NotImplementedError

    def key(self, genome: Any) -> Hashable:
        """Cheap hashable genome identity for fitness caches."""
        raise NotImplementedError

    # ---- optional surface -----------------------------------------------------
    def fitness_batch(self, genomes: Sequence[Any]) -> List[float]:
        """Score a whole offspring generation; override when the evaluator
        can dedupe shared substructure (see ``Evaluator.fitness_batch``)."""
        return [self.fitness(g) for g in genomes]

    def crossover(self, a: Any, b: Any, rng: random.Random) -> Any:
        """Uniform crossover (beyond-paper); default: no recombination."""
        return a

    def neighbors(self, genome: Any) -> Iterable[Any]:
        """All one-mutation neighbors (hill-climb moves).  Optional."""
        raise NotImplementedError(f"{self.name} does not enumerate neighbors")

    def enumerate(self) -> Iterator[Any]:
        """Every genome in the space (exhaustive search).  Optional."""
        raise NotImplementedError(f"{self.name} is not enumerable")

    def space_size(self) -> Optional[int]:
        """Number of genomes in the space, or None if unbounded/unknown."""
        return None

    def encode_genome(self, genome: Any) -> Any:
        """Compact, picklable wire form of a genome — what multi-process
        backends (``repro.search.island``) ship between workers instead of
        the live object (which may drag a whole graph through pickle).
        Default: the genome itself."""
        return genome

    def decode_genome(self, data: Any) -> Any:
        """Inverse of :meth:`encode_genome`, re-binding the wire form onto
        this problem's live objects."""
        return data


class FusionProblem(SearchProblem):
    """The paper's interlayer-pipelining problem (§III): fusion-state genomes
    over ``graph``, scored by ``evaluator`` on ``objective``.

    ``spacemap`` (optional) freezes the statically forced-off genome bits:
    all operators then draw indices from the surviving ``active`` bits
    only.  Frozen bits stay 0 in every genome the problem produces, so
    downstream consumers (the batched population engine included) never
    see a frozen column set.
    """

    name = "fusion"

    def __init__(self, graph: LayerGraph, evaluator: Any,
                 objective: str = "edp",
                 spacemap: Optional["SpaceMap"] = None):
        self.graph = graph
        self.evaluator = evaluator
        self.objective = objective
        self.spacemap = spacemap
        self.cg = graph.compiled()
        self._mbits: int = int(self.cg.m).bit_length()
        self._batch = getattr(evaluator, "fitness_batch", None)
        self._batch_unique = getattr(evaluator, "fitness_batch_unique", None)
        #: searchable bit positions (all of them without a spacemap)
        self._active: Tuple[int, ...] = tuple(range(self.cg.m)) \
            if spacemap is None else tuple(spacemap.active_indices)
        self._abits: int = len(self._active).bit_length()

    def initial(self) -> FusionState:
        return FusionState.layerwise(self.graph)

    def mutate(self, genome: FusionState, rng: random.Random) -> FusionState:
        """One random edge flip.  Returns a *lazy* child (mask only — no
        group maintenance): the batched population engine recomputes all
        per-genome structure array-natively, so eagerly maintaining union-find
        state per offspring (what ``FusionState.mutate`` does when the parent
        is structured) would be pure overhead.  The inlined getrandbits loop
        is CPython's ``_randbelow`` — the same draws ``rng.randrange(m)``
        makes, so fixed-seed runs are unchanged.  With a spacemap the same
        loop draws over the active bits instead (different draw widths —
        hence the separate fixed-seed pins)."""
        m = self.cg.m
        if not m:
            raise ValueError("graph has no edges to mutate")
        grb = rng.getrandbits
        if self.spacemap is None:
            i = grb(self._mbits)
            while i >= m:
                i = grb(self._mbits)
        else:
            k = len(self._active)
            if not k:                      # fully decided: nothing to flip
                return genome
            j = grb(self._abits)
            while j >= k:
                j = grb(self._abits)
            i = self._active[j]
        return FusionState._make(self.graph, genome.cg,
                                 genome.mask ^ (1 << i))

    def prewarm(self) -> None:
        """Materialize everything forked workers should inherit read-only
        via copy-on-write: the compiled graph, the layerwise baseline, and
        the population engine's static tables (``repro.search.island`` calls
        this before spawning)."""
        ev = self.evaluator
        if hasattr(ev, "population"):
            try:
                ev.population()
            except RuntimeError:     # no numpy: scalar path needs no tables
                ev.layerwise()
        elif hasattr(ev, "layerwise"):
            ev.layerwise()

    def fitness(self, genome: FusionState) -> float:
        return float(self.evaluator.fitness(genome, self.objective))

    def fitness_batch(self, genomes: Sequence[FusionState]) -> List[float]:
        if self._batch is not None:
            return list(self._batch(genomes, self.objective))
        return [self.fitness(g) for g in genomes]

    def fitness_batch_unique(self, genomes: Sequence[FusionState]
                             ) -> List[float]:
        """Batch scoring for genome lists already deduped by :meth:`key`
        (the GA loop's per-run cache guarantees this); skips the
        evaluator's own dedup pass.  Subclasses that override
        :meth:`fitness_batch` keep their scoring path: the fast lane only
        engages when batch scoring is the stock evaluator route."""
        if (self._batch_unique is not None
                and type(self).fitness_batch is FusionProblem.fitness_batch):
            return list(self._batch_unique(genomes, self.objective))
        return self.fitness_batch(genomes)

    def key(self, genome: FusionState) -> int:
        return int(genome.mask)          # == genome.key(), one hop cheaper

    def crossover(self, a: FusionState, b: FusionState,
                  rng: random.Random) -> FusionState:
        """Uniform crossover on the fused-edge genome (beyond-paper).
        Spacemap runs draw one coin per *active* bit only — frozen bits
        are 0 in both parents, so the child's frozen bits stay 0 without
        spending draws on them."""
        mask = 0
        if self.spacemap is None:
            for i in range(self.cg.m):
                src = a.mask if rng.random() < 0.5 else b.mask
                mask |= src & (1 << i)
        else:
            for i in self._active:
                src = a.mask if rng.random() < 0.5 else b.mask
                mask |= src & (1 << i)
        return FusionState.from_mask(self.graph, mask)

    def neighbors(self, genome: FusionState) -> Iterator[FusionState]:
        for i in self._active:
            if (genome.mask >> i) & 1:
                yield genome._separate_idx(i)
            else:
                yield genome._combine_idx(i)

    def _scatter(self, sub: int) -> int:
        """Spread a compact active-bit value onto genome bit positions."""
        mask = 0
        for j, i in enumerate(self._active):
            if (sub >> j) & 1:
                mask |= 1 << i
        return mask

    def random_genome(self, rng: random.Random) -> FusionState:
        if self.spacemap is None:
            return FusionState.from_mask(
                self.graph, rng.getrandbits(self.cg.m) if self.cg.m else 0)
        k = len(self._active)
        return FusionState.from_mask(
            self.graph, self._scatter(rng.getrandbits(k)) if k else 0)

    def enumerate(self) -> Iterator[FusionState]:
        if self.spacemap is None:
            for mask in range(1 << self.cg.m):
                yield FusionState.from_mask(self.graph, mask)
            return
        for sub in range(1 << len(self._active)):
            yield FusionState.from_mask(self.graph, self._scatter(sub))

    def space_size(self) -> int:
        return 1 << len(self._active)

    def encode_genome(self, genome: FusionState) -> int:
        return int(genome.mask)

    def decode_genome(self, data: int) -> FusionState:
        return FusionState.from_mask(self.graph, data)
