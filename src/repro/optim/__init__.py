from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedules import cosine_schedule
from repro.optim.grad_compress import (compress_decompress_ef,
                                       ef_state_init)

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule",
           "compress_decompress_ef", "ef_state_init"]
