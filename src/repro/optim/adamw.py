"""AdamW in pure JAX, with dtype-configurable moments.

Moments live in ``moment_dtype`` (fp32 default; bf16 for the 400B config so
optimizer state fits the pod — a distributed-memory trick, not a numerics
default).  The update itself is always computed in fp32.  Optimizer state is
sharded exactly like the parameters (pjit out_shardings = param specs), which
is ZeRO-3 for free.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"


def adamw_init(params, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(params, grads, state, cfg: AdamWConfig, lr_scale=1.0):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else 1.0
    dt = jnp.dtype(cfg.moment_dtype)
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * clip
        mu32 = b1 * mu.astype(jnp.float32) + (1 - b1) * g
        nu32 = b2 * nu.astype(jnp.float32) + (1 - b2) * g * g
        mhat = mu32 / c1
        nhat = nu32 / c2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        if cfg.weight_decay > 0 and p.ndim >= 2:   # decay matrices only
            delta = delta + cfg.weight_decay * p32
        return ((p32 - lr * delta).astype(p.dtype),
                mu32.astype(dt), nu32.astype(dt))

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    flat, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    new_p = jax.tree.unflatten(treedef, [t[0] for t in flat])
    new_mu = jax.tree.unflatten(treedef, [t[1] for t in flat])
    new_nu = jax.tree.unflatten(treedef, [t[2] for t in flat])
    new_state = {"mu": new_mu, "nu": new_nu, "step": step}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
