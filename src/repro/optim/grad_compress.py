"""Error-feedback gradient compression for the cross-pod all-reduce.

At 512+ chips the data-parallel all-reduce crosses the pod interconnect
(DCI), which is the slowest link in the system.  We compress gradients to
int8 with per-tensor scales before the reduce and keep the quantization
residual locally (error feedback, Seide et al. 2014 / EF-SGD), so the scheme
is unbiased over time.  In-graph this is expressed as
quantize -> (all-reduce happens on the int8 tensor under pjit) -> dequantize;
the residual is carried in optimizer-adjacent state.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def ef_state_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_decompress_ef(grads, ef_state):
    """Apply error-feedback int8 quantization to a gradient pytree.

    Returns (decompressed_grads, new_ef_state).  The decompressed gradients
    are what the data-parallel mean reduces over; because quantization
    happens *before* pjit's implicit all-reduce, XLA moves the (4x smaller)
    int8 tensors across the slow axis.
    """
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = _quantize(g32)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), g32 - deq

    out = jax.tree.map(one, grads, ef_state)
    flat, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    deq = jax.tree.unflatten(treedef, [t[0] for t in flat])
    new_ef = jax.tree.unflatten(treedef, [t[1] for t in flat])
    return deq, new_ef
