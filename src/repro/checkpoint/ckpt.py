"""Fault-tolerant checkpointing: atomic, checksummed, async, mesh-agnostic.

Design (what a 1000-node deployment needs, expressed single-host here):

* **Atomicity** — write to ``step_N.tmp/``, fsync, then ``rename`` to
  ``step_N/``; a crash mid-save never corrupts the latest checkpoint.
* **Integrity** — every tensor buffer carries a crc32; load verifies.
* **Async** — ``CheckpointManager.save_async`` snapshots to host memory
  (device_get) synchronously, then writes on a background thread so the
  train loop keeps stepping (overlap of I/O with compute).
* **Mesh-agnostic / elastic** — tensors are saved *unsharded logical*
  (gathered via device_get); on load they are re-placed under whatever mesh/
  sharding the restarting job uses (possibly a different pod count), which is
  the resharding path elastic scaling needs.  At real scale the same layout
  works with per-host shard files; the manifest already records per-leaf
  shapes/dtypes.
* **Retention** — keep the newest K checkpoints, delete older ones only
  after the newer save committed.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

_MANIFEST = "manifest.json"

# dtypes numpy's .npy format cannot round-trip natively: stored as raw
# integer views, logical dtype recorded in the manifest
_EXOTIC_STORE = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
                 "float8_e5m2": np.uint8}


def _to_storable(arr: np.ndarray):
    name = arr.dtype.name
    if name in _EXOTIC_STORE:
        return arr.view(_EXOTIC_STORE[name]), name
    return arr, name


def _from_storable(arr: np.ndarray, logical_dtype: str):
    if logical_dtype in _EXOTIC_STORE:
        import ml_dtypes
        return arr.view(getattr(ml_dtypes, logical_dtype))
    return arr


def _flatten(tree) -> Tuple[List[Tuple[str, Any]], Any]:
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)
    flat = [("/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path), leaf)
            for path, leaf in leaves_with_paths[0]]
    return flat, leaves_with_paths[1]


def save_checkpoint(directory: str, step: int, tree, *, keep: int = 3,
                    compress: bool = True) -> str:
    """Synchronous atomic save (zstd-compressed buffers by default).
    Returns the committed path."""
    try:
        import zstandard
        cctx = zstandard.ZstdCompressor(level=3) if compress else None
    except ImportError:                      # pragma: no cover
        cctx = None
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat, _ = _flatten(tree)
    manifest = {"step": step, "leaves": []}
    for i, (name, leaf) in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        store, logical = _to_storable(arr)
        import io
        buf = io.BytesIO()
        np.save(buf, store)
        raw = buf.getvalue()
        codec = "raw"
        if cctx is not None:
            raw = cctx.compress(raw)
            codec = "zstd"
        fname = f"leaf_{i:05d}.npy" + (".zst" if codec == "zstd" else "")
        path = os.path.join(tmp, fname)
        with open(path, "wb") as f:
            f.write(raw)
            f.flush()
            os.fsync(f.fileno())
        manifest["leaves"].append({
            "name": name, "file": fname, "crc32": zlib.crc32(raw),
            "shape": list(arr.shape), "dtype": logical, "codec": codec})
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _retain(directory, keep)
    return final


def _retain(directory: str, keep: int):
    steps = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")
             and os.path.exists(os.path.join(directory, d, _MANIFEST))]
    return max(steps) if steps else None


def load_checkpoint(directory: str, tree_like, step: Optional[int] = None,
                    shardings=None):
    """Restore into the structure of ``tree_like``.  ``shardings``: optional
    matching pytree of NamedSharding — enables cross-mesh resharding (elastic
    restart on a different topology)."""
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    flat, treedef = _flatten(tree_like)
    by_name = {m["name"]: m for m in manifest["leaves"]}
    out = []
    shard_flat = None
    if shardings is not None:
        shard_flat = [s for _, s in _flatten(shardings)[0]]
    for i, (name, like) in enumerate(flat):
        meta = by_name[name]
        fpath = os.path.join(path, meta["file"])
        with open(fpath, "rb") as f:
            raw = f.read()
        if zlib.crc32(raw) != meta["crc32"]:
            raise IOError(f"checksum mismatch for {name} in {path}")
        if meta.get("codec") == "zstd":
            import io
            import zstandard
            raw = zstandard.ZstdDecompressor().decompress(raw)
            arr = np.load(io.BytesIO(raw), allow_pickle=False)
        else:
            arr = np.load(os.path.join(path, meta["file"]),
                          allow_pickle=False)
        arr = _from_storable(arr, meta["dtype"])
        if list(arr.shape) != list(np.shape(like)):
            raise ValueError(f"shape mismatch for {name}: "
                             f"ckpt {arr.shape} vs expected {np.shape(like)}")
        if shard_flat is not None:
            out.append(jax.device_put(arr, shard_flat[i]))
        else:
            out.append(jax.device_put(arr.astype(like.dtype)))
    return jax.tree_util.tree_unflatten(treedef, out), step


class CheckpointManager:
    """Async wrapper: snapshot synchronously, persist in the background."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save_async(self, step: int, tree):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree,
                                keep=self.keep)
            except BaseException as e:     # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def latest_step(self) -> Optional[int]:
        return latest_step(self.directory)

    def restore(self, tree_like, shardings=None):
        return load_checkpoint(self.directory, tree_like,
                               shardings=shardings)
