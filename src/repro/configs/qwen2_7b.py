"""qwen2-7b [dense] — 28L, d_model 3584, 28H GQA kv=4, d_ff 18944,
vocab 152064, QKV bias, SwiGLU, RMSNorm [arXiv:2407.10671]."""
from dataclasses import replace

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b", family="dense",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, d_ff=18_944,
    vocab=152_064, qkv_bias=True, mlp="swiglu", norm="rmsnorm",
    rope_theta=1_000_000.0,
)


def reduced() -> ModelConfig:
    return replace(CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                   d_ff=128, vocab=128)
