"""chatglm3-6b [dense] — 28L, d_model 4096, 32H GQA kv=2, d_ff 13696,
vocab 65024, 2d-RoPE (half dims), QKV bias [arXiv:2406.12793]."""
from dataclasses import replace

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", family="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2, d_ff=13_696,
    vocab=65_024, rope_fraction=0.5, qkv_bias=True, mlp="swiglu",
    norm="rmsnorm",
)


def reduced() -> ModelConfig:
    return replace(CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                   d_ff=128, vocab=128)
