"""stablelm-1.6b [dense] — 24L, d_model 2048, 32H (kv=32, MHA), d_ff 5632,
vocab 100352, partial RoPE (25%), LayerNorm
[hf:stabilityai/stablelm-2-1_6b]."""
from dataclasses import replace

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b", family="dense",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=5632,
    vocab=100_352, rope_fraction=0.25, mlp="swiglu", norm="layernorm",
)


def reduced() -> ModelConfig:
    return replace(CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                   d_ff=128, vocab=128)
