"""Model/shape/run configuration schema.

One :class:`ModelConfig` per assigned architecture lives in
``repro/configs/<id>.py``; every config also provides ``reduced()`` — a tiny
same-family variant for CPU smoke tests (the full config is only ever lowered
via the dry-run's ShapeDtypeStructs, never allocated).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field, replace
from typing import Dict, Optional, Sequence, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    # --- attention ---------------------------------------------------------------
    head_dim: int = 0               # 0 => d_model // n_heads
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0      # partial rotary (stablelm 0.25, chatglm 0.5)
    qkv_bias: bool = False
    attn_window: int = 0            # >0: sliding-window attention
    attn_chunk: int = 0             # >0: llama4-style chunked local attention
    global_every: int = 0           # with attn_chunk: 1-in-N layers stay global
    attn_logit_softcap: float = 0.0

    # --- mlp --------------------------------------------------------------------------
    mlp: str = "swiglu"             # swiglu | geglu | gelu
    norm: str = "rmsnorm"           # rmsnorm | layernorm

    # --- moe ---------------------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0       # llama4 shared expert
    capacity_factor: float = 1.25
    moe_every: int = 1              # MoE on every Nth layer (llama4: 2)
    moe_impl: str = "a2a"           # a2a (sorted local dispatch) | global

    # --- ssm (mamba-1) -----------------------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0            # 0 => ceil(d_model / 16)

    # --- hybrid (recurrentgemma / griffin) ----------------------------------------------
    block_pattern: Tuple[str, ...] = ()   # e.g. ("rglru", "rglru", "attn")
    lru_width: int = 0              # 0 => d_model

    # --- encoder-decoder (whisper) -------------------------------------------------------
    is_encdec: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 0                # e.g. 1500 mel frames after conv stub

    # --- vlm ------------------------------------------------------------------------------
    img_tokens: int = 0             # image tokens prepended (frontend stub)

    # --- numerics / training ----------------------------------------------------------------
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    moment_dtype: str = "float32"   # AdamW moments (bf16 for the giants)
    grad_accum_dtype: str = "float32"  # microbatch grad accumulator
    tie_embeddings: bool = False
    remat: str = "none"             # none | full | selective (TPU-GA lever)
    scan_layers: bool = True        # False: unroll (exact cost_analysis)
    exact_costs: bool = False       # unroll inner scans too (cost points)

    # ---------------------------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or math.ceil(self.d_model / 16)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def rnn_width(self) -> int:
        return self.lru_width or self.d_model

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer token-mixer kind, length n_layers."""
        if self.family == "ssm":
            return ("mamba",) * self.n_layers
        if self.block_pattern:
            pat = self.block_pattern
            return tuple(pat[i % len(pat)] for i in range(self.n_layers))
        if self.attn_chunk and self.global_every:
            return tuple("attn_global" if (i + 1) % self.global_every == 0
                         else "attn_chunk" for i in range(self.n_layers))
        return ("attn",) * self.n_layers

    @property
    def n_params(self) -> int:
        """Approximate parameter count (embedding + blocks)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd = self.resolved_head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        for kind in self.layer_kinds():
            if kind.startswith("attn"):
                per_layer += d * hd * (self.n_heads + 2 * self.n_kv_heads) \
                    + self.n_heads * hd * d
            elif kind == "mamba":
                di, ds = self.d_inner, self.ssm_state
                per_layer += d * 2 * di + di * self.ssm_conv \
                    + di * (self.dt_rank + 2 * ds) + self.dt_rank * di \
                    + di * ds + di + di * d
            elif kind == "rglru":
                w = self.rnn_width
                per_layer += 2 * d * w + w * self.ssm_conv + 2 * w + w * d
            if kind.startswith("attn") or kind == "rglru" or kind == "mamba":
                pass
        # mlp per layer (mamba family has no separate mlp)
        n_mlp = 0 if self.family == "ssm" else self.n_layers
        mult = 3 if self.mlp in ("swiglu", "geglu") else 2
        dense_mlp = mult * d * f
        if self.n_experts:
            moe_mlp = self.n_experts * mult * d * f + d * self.n_experts
            if self.n_shared_experts:
                moe_mlp += self.n_shared_experts * mult * d * f
            n_moe = n_mlp // self.moe_every
            mlp_total = n_moe * moe_mlp + (n_mlp - n_moe) * dense_mlp
        else:
            mlp_total = n_mlp * dense_mlp
        total = emb + per_layer + mlp_total
        if self.is_encdec:
            # encoder layers: self-attn + mlp; decoder already counted
            total += self.n_enc_layers * (4 * d * d + mult * d * f)
            total += self.n_layers * 4 * d * d          # cross-attention
        return total

    @property
    def n_active_params(self) -> int:
        """Active params per token (MoE: only top_k + shared experts)."""
        if not self.n_experts:
            return self.n_params
        d, f = self.d_model, self.d_ff
        mult = 3 if self.mlp in ("swiglu", "geglu") else 2
        n_moe = self.n_layers // self.moe_every
        inactive = (self.n_experts - self.top_k) * mult * d * f * n_moe
        return self.n_params - inactive


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
