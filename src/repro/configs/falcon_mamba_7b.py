"""falcon-mamba-7b [ssm] — 64L, d_model 4096, attn-free Mamba-1, vocab 65024,
ssm_state 16 [arXiv:2410.05355]."""
from dataclasses import replace

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1, d_ff=0,
    vocab=65_024, ssm_state=16, ssm_conv=4, ssm_expand=2,
    norm="rmsnorm", tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return replace(CONFIG, n_layers=2, d_model=32, vocab=128, ssm_state=4)
