"""recurrentgemma-2b [hybrid] — 26L, d_model 2560, 10H MQA (kv=1,
head_dim 256), d_ff 7680 GeGLU, vocab 256000; RG-LRU : local-attn pattern
2:1, window 2048 [arXiv:2402.19427]."""
from dataclasses import replace

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680, vocab=256_000, lru_width=2560, attn_window=2048,
    block_pattern=("rglru", "rglru", "attn_local"),
    mlp="geglu", norm="rmsnorm", tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return replace(CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=1,
                   head_dim=16, d_ff=128, vocab=128, lru_width=64,
                   attn_window=8)
