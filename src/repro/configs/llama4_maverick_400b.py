"""llama4-maverick-400b-a17b [moe] — 48L, d_model 5120, 40H GQA kv=8,
d_ff 8192, vocab 202048, MoE 128 experts top-1 + shared expert on every 2nd
layer (interleave_moe_layer_step=2, as in the published model — this is what
makes 128e x 48L land at ~400B total / ~17B active), iRoPE-style
chunked-local attention (8192) with 1-in-4 global layers
[hf:meta-llama/Llama-4-Scout-17B-16E family]."""
from dataclasses import replace

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
    vocab=202_048, n_experts=128, top_k=1, n_shared_experts=1, moe_every=2,
    capacity_factor=1.25, attn_chunk=8192, global_every=4,
    mlp="swiglu", norm="rmsnorm", rope_theta=500_000.0,
    moment_dtype="bfloat16",     # 400B: fp32 moments would not fit the pod
    grad_accum_dtype="bfloat16",  # ditto for the microbatch accumulator
)


def reduced() -> ModelConfig:
    return replace(CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
                   d_ff=128, vocab=128, n_experts=4, top_k=1,
                   n_shared_experts=1, moe_every=2, attn_chunk=8,
                   global_every=4, capacity_factor=2.0)
