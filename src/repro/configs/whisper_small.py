"""whisper-small [audio] — enc-dec, 12+12L, d_model 768, 12H MHA, d_ff 3072,
vocab 51865; conv frontend is a STUB: ``input_specs`` supplies 1500
precomputed frame embeddings [arXiv:2212.04356].

Departure from the published model (noted in DESIGN.md): decode shapes ask
for 32k-token decoder contexts; Whisper's real decoder is capped at 448
learned positions — we size the learned table to the requested shape.
"""
from dataclasses import replace

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
    vocab=51_865, is_encdec=True, n_enc_layers=12, enc_seq=1500,
    mlp="gelu", norm="layernorm",
)


def reduced() -> ModelConfig:
    return replace(CONFIG, n_layers=2, n_enc_layers=2, d_model=64, n_heads=4,
                   n_kv_heads=4, d_ff=128, vocab=128, enc_seq=12)
