"""Architecture registry: ``--arch <id>`` resolution + per-cell skip rules."""
from __future__ import annotations

from typing import Dict, Optional

from repro.configs import (chatglm3_6b, dbrx_132b, falcon_mamba_7b,
                           llama4_maverick_400b, phi_3_vision_4_2b, qwen2_7b,
                           recurrentgemma_2b, stablelm_1_6b, starcoder2_3b,
                           whisper_small)
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig

_MODULES = {
    "falcon-mamba-7b": falcon_mamba_7b,
    "chatglm3-6b": chatglm3_6b,
    "starcoder2-3b": starcoder2_3b,
    "qwen2-7b": qwen2_7b,
    "stablelm-1.6b": stablelm_1_6b,
    "dbrx-132b": dbrx_132b,
    "llama4-maverick-400b-a17b": llama4_maverick_400b,
    "phi-3-vision-4.2b": phi_3_vision_4_2b,
    "recurrentgemma-2b": recurrentgemma_2b,
    "whisper-small": whisper_small,
}

ARCH_IDS = list(_MODULES)


def get_config(arch: str) -> ModelConfig:
    return _MODULES[arch].CONFIG


def get_reduced(arch: str) -> ModelConfig:
    return _MODULES[arch].reduced()


def cell_skip_reason(arch: str, shape_name: str) -> Optional[str]:
    """Why a (arch x shape) dry-run cell is skipped, or None if it runs.

    Per the assignment: ``long_500k`` needs a sub-quadratic mixer — skipped
    for pure full-attention archs (see DESIGN.md §Arch-applicability).
    """
    cfg = get_config(arch)
    if shape_name == "long_500k":
        sub_quadratic = (cfg.family in ("ssm", "hybrid")
                         or (cfg.attn_chunk > 0))
        if not sub_quadratic:
            return "pure full-attention arch: 500k context is quadratic"
        if cfg.is_encdec:
            return "enc-dec decoder beyond published context"
    return None


def iter_cells():
    """All 40 (arch, shape) cells with skip annotations."""
    for arch in ARCH_IDS:
        for sname, shape in SHAPES.items():
            yield arch, sname, shape, cell_skip_reason(arch, sname)
