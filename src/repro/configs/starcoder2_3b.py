"""starcoder2-3b [dense] — 30L, d_model 3072, 24H GQA kv=2, d_ff 12288,
vocab 49152, RoPE, GELU MLP, LayerNorm [arXiv:2402.19173]."""
from dataclasses import replace

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2, d_ff=12_288,
    vocab=49_152, mlp="gelu", norm="layernorm", qkv_bias=True,
    rope_theta=999_999.4,
)


def reduced() -> ModelConfig:
    return replace(CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                   d_ff=128, vocab=128)
