"""phi-3-vision-4.2b [vlm] — 32L, d_model 3072, 32H (kv=32), d_ff 8192,
vocab 32064; CLIP frontend is a STUB: ``input_specs`` supplies precomputed
patch embeddings (1024 image tokens)
[hf:microsoft/Phi-3-vision-128k-instruct]."""
from dataclasses import replace

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab=32_064, img_tokens=1024, mlp="swiglu", norm="rmsnorm",
)


def reduced() -> ModelConfig:
    return replace(CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                   d_ff=128, vocab=128, img_tokens=8)
