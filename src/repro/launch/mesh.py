"""Production meshes.

Single pod: 256 chips as (data=16, model=16).  Multi-pod: 2 pods = 512 chips
as (pod=2, data=16, model=16) — the ``pod`` axis composes with ``data`` for
data parallelism (its all-reduce crosses the data-center interconnect, which
is why gradient compression targets it), while ``model`` stays inside a pod
(ICI-speed TP/EP).

Defined as functions, not module constants, so importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax


def _mk(shape, axes):
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:                 # jax < 0.5: no explicit axis types
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(
        shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many (CPU) devices exist — tests/examples."""
    return _mk((data, model), ("data", "model"))
