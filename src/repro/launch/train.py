"""Training driver: pjit train loop + data pipeline + async checkpointing +
watchdog + bounded restarts.  Usable as a library (tests/examples) and as a
CLI:

    python -m repro.launch.train --arch stablelm-1.6b --reduced \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/run1

The loop is deterministic-resumable: batch t is a pure function of (seed, t),
so restarting from step k replays nothing (see repro/data/pipeline.py).
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_reduced
from repro.configs.base import ModelConfig
from repro.data import DataConfig, SyntheticTokenPipeline
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_local_mesh
from repro.models import transformer as T
from repro.models.common import use_mesh
from repro.optim import AdamWConfig
from repro.runtime import FaultConfig, FaultInjector, Watchdog, run_with_restarts


@dataclasses.dataclass
class TrainRunConfig:
    cfg: ModelConfig
    steps: int = 100
    global_batch: int = 8
    seq_len: int = 128
    seed: int = 0
    lr: float = 1e-3
    microbatches: int = 1
    grad_compression: bool = False
    ckpt_dir: Optional[str] = None
    save_every: int = 50
    log_every: int = 10
    attn_impl: str = "auto"


def train_loop(run: TrainRunConfig, mesh=None, injector=None,
               fault: FaultConfig = FaultConfig(max_restarts=3,
                                                step_deadline_s=300.0),
               log=print) -> Dict[str, Any]:
    """Run the supervised training loop; returns final state + history."""
    cfg = run.cfg
    mesh = mesh or make_local_mesh(1, 1)
    data = SyntheticTokenPipeline(DataConfig(
        vocab=cfg.vocab, seq_len=run.seq_len, global_batch=run.global_batch,
        seed=run.seed + 1))
    mgr = CheckpointManager(run.ckpt_dir) if run.ckpt_dir else None
    history: Dict[str, list] = {"loss": [], "step": []}

    with use_mesh(mesh):
        p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                               T.param_pspecs(cfg),
                               is_leaf=lambda x: isinstance(
                                   x, jax.sharding.PartitionSpec))
        train_step, opt_init = steps_lib.make_train_step(
            cfg, AdamWConfig(lr=run.lr, moment_dtype=cfg.moment_dtype),
            microbatches=run.microbatches,
            grad_compression=run.grad_compression,
            attn_impl=run.attn_impl)
        jit_step = jax.jit(train_step, donate_argnums=(0, 1))

        def init_state():
            params = jax.jit(
                partial(T.init_params, cfg), out_shardings=p_shard
            )(jax.random.PRNGKey(run.seed))
            return {"params": params, "opt": opt_init(params)}

        def extra_inputs(batch_np):
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            if cfg.img_tokens:
                B = batch["tokens"].shape[0]
                key = jax.random.PRNGKey(0)
                batch["img_embeds"] = jax.random.normal(
                    key, (B, cfg.img_tokens, cfg.d_model), jnp.float32
                ).astype(jnp.bfloat16)
            if cfg.is_encdec:
                B = batch["tokens"].shape[0]
                batch["frames"] = jax.random.normal(
                    jax.random.PRNGKey(1), (B, cfg.enc_seq, cfg.d_model)
                ).astype(jnp.bfloat16)
            return batch

        def step_fn(state, step):
            batch = extra_inputs(data.global_batch_at(step))
            params, opt, metrics = jit_step(state["params"], state["opt"],
                                            batch)
            if step % run.log_every == 0 or step == run.steps - 1:
                loss = float(metrics["loss"])
                history["loss"].append(loss)
                history["step"].append(step)
                log(f"step {step:5d}  loss {loss:.4f}  "
                    f"gnorm {float(metrics['grad_norm']):.3f}")
            return {"params": params, "opt": opt}

        def save_fn(state, step):
            if mgr is not None:
                mgr.save_async(step, state)

        def restore_fn():
            if mgr is None or mgr.latest_step() is None:
                return None
            mgr.wait()
            like = jax.eval_shape(init_state)
            state, step = mgr.restore(
                jax.tree.map(lambda s: np.zeros(s.shape, s.dtype), like))
            state = jax.device_put(state)
            return state, step

        out = run_with_restarts(
            total_steps=run.steps, init_state=init_state, step_fn=step_fn,
            save_fn=save_fn, restore_fn=restore_fn,
            save_every=run.save_every, fault=fault, injector=injector)
        if mgr is not None:
            mgr.wait()
        out["history"] = history
        return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-test-sized config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    run = TrainRunConfig(cfg=cfg, steps=args.steps, global_batch=args.batch,
                         seq_len=args.seq, lr=args.lr, seed=args.seed,
                         microbatches=args.microbatches,
                         grad_compression=args.grad_compression,
                         ckpt_dir=args.ckpt_dir)
    t0 = time.time()
    out = train_loop(run)
    print(f"done: {out['completed_steps']} steps, {out['restarts']} restarts, "
          f"{time.time() - t0:.1f}s; final loss "
          f"{out['history']['loss'][-1]:.4f}")


if __name__ == "__main__":
    main()
