"""pjit-able train / prefill / decode step builders + their shardings.

``make_train_step`` builds the full step: microbatched value_and_grad (grad
accumulation via lax.scan — overlapping per-microbatch compute with the
deferred data-parallel reduce), optional error-feedback int8 gradient
compression for the cross-pod all-reduce, AdamW, donated state.

Sharding contracts (resolved against the active mesh via ``use_mesh``):
  params/opt-state : per-tensor specs from the model (TP over ``model``,
                     FSDP over ``pod``+``data``)
  train batch      : batch dim over (pod, data)
  decode caches    : batch over (pod, data) — or sequence over data when
                     global_batch == 1 (long_500k sequence-parallel decode)
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as T
from repro.models.common import BATCH, pspec
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         compress_decompress_ef)


# ---- sharding specs -----------------------------------------------------------------

def fit_spec(spec: P, shape, mesh) -> P:
    """Drop spec axes that do not divide the corresponding dimension.

    pjit in_shardings require exact divisibility (unlike in-graph
    constraints); this resolves e.g. whisper's odd 51865-vocab embedding or
    a global_batch=1 decode cell to replication on the offending dim."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    entries = tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))
    fixed = []
    for dim, entry in zip(shape, entries):
        if entry is None:
            fixed.append(None)
            continue
        names = (entry,) if isinstance(entry, str) else tuple(entry)
        total = 1
        for n in names:
            total *= sizes.get(n, 1)
        fixed.append(entry if total and dim % total == 0 else None)
    return P(*fixed)


def fit_sharding_tree(mesh, spec_tree, shape_tree):
    """Apply :func:`fit_spec` leaf-wise (spec tree mirrors shape tree)."""
    return jax.tree.map(
        lambda s, x: fit_spec(s, x.shape, mesh), spec_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, P))


def train_batch_pspecs(cfg: ModelConfig) -> Dict:
    from repro.models.common import SEQ
    specs = {"tokens": pspec(BATCH, SEQ), "labels": pspec(BATCH, SEQ)}
    if cfg.img_tokens:
        specs["img_embeds"] = pspec(BATCH, None, None)
    if cfg.is_encdec:
        specs["frames"] = pspec(BATCH, None, None)
    return specs


def opt_state_pspecs(cfg: ModelConfig) -> Dict:
    pp = T.param_pspecs(cfg)
    return {"mu": pp, "nu": pp, "step": pspec()}


def decode_input_pspecs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    shard_seq = shape.global_batch == 1
    specs = {"token": pspec(BATCH, None), "pos": pspec(),
             "caches": T.cache_pspecs(cfg, shard_seq=shard_seq)}
    if cfg.is_encdec:
        specs["enc_out"] = pspec(BATCH, None, None)
    return specs


# ---- step builders ---------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, opt_cfg: Optional[AdamWConfig] = None,
                    *, microbatches: int = 1, grad_compression: bool = False,
                    attn_impl: str = "auto"):
    """Returns (train_step, opt_init).  train_step(params, opt_state, batch)
    -> (params, opt_state, metrics)."""
    opt_cfg = opt_cfg or AdamWConfig(moment_dtype=cfg.moment_dtype)

    def loss_of(p, batch):
        return T.loss_fn(p, cfg, batch, impl=attn_impl)[0]

    def grads_of(p, batch):
        if microbatches <= 1:
            return jax.value_and_grad(loss_of)(p, batch)
        mb = jax.tree.map(
            lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                + x.shape[1:]), batch)

        acc_dt = jnp.dtype(cfg.grad_accum_dtype)

        def body(acc, one):
            l, g = jax.value_and_grad(loss_of)(p, one)
            return jax.tree.map(lambda a, b: a + b.astype(a.dtype), acc,
                                (l, g)), None

        zero = (jnp.zeros(()),
                jax.tree.map(lambda x: jnp.zeros(x.shape, acc_dt), p))
        (lsum, gsum), _ = jax.lax.scan(body, zero, mb)
        scale = 1.0 / microbatches
        return lsum * scale, jax.tree.map(lambda g: g * scale, gsum)

    def train_step(params, opt_state, batch):
        loss, grads = grads_of(params, batch)
        if grad_compression:
            grads, new_ef = compress_decompress_ef(grads, opt_state["ef"])
        new_p, new_opt, metrics = adamw_update(
            params, grads, opt_state["adam"], opt_cfg)
        out_state = {"adam": new_opt}
        if grad_compression:
            out_state["ef"] = new_ef
        metrics = dict(metrics, loss=loss)
        return new_p, out_state, metrics

    def opt_init(params):
        st = {"adam": adamw_init(params, opt_cfg)}
        if grad_compression:
            from repro.optim import ef_state_init
            st["ef"] = ef_state_init(params)
        return st

    return train_step, opt_init


def make_prefill_step(cfg: ModelConfig, shape: ShapeConfig,
                      attn_impl: str = "auto"):
    def prefill_step(params, batch):
        logits, caches, _ = T.prefill(params, cfg, batch,
                                      max_len=shape.seq_len, impl=attn_impl)
        return logits, caches
    return prefill_step


def make_decode_step(cfg: ModelConfig, attn_impl: str = "auto"):
    def decode_step(params, batch):
        enc_kv = None
        if cfg.is_encdec:
            enc_kv = (batch["enc_out"], jnp.arange(batch["enc_out"].shape[1]))
        logits, caches = T.decode_step(params, cfg, batch["token"],
                                       batch["pos"], batch["caches"],
                                       enc_kv=enc_kv, impl=attn_impl)
        return logits, caches
    return decode_step
