import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the production mesh (16x16 single pod or 2x16x16
multi-pod), the model's parameter/optimizer/batch ShapeDtypeStructs (no
allocation), pjit-lowers the right step (train_step for train shapes,
prefill/decode for serving shapes), compiles, and records:

* ``memory_analysis()``  — per-device bytes (proves the cell fits),
* ``cost_analysis()``    — FLOPs / bytes for the §Roofline terms,
* HLO-parsed collective bytes (all-gather/all-reduce/reduce-scatter/
  all-to-all/collective-permute),

into ``artifacts/dryrun/<arch>__<shape>__<mesh>.json``.

Usage:
    python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all [--mesh both] [--jobs-file path]
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (SHAPES, cell_skip_reason, get_config, get_reduced,
                           iter_cells)
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models import transformer as T
from repro.models.common import pspec, use_mesh
from repro.roofline.analysis import collective_bytes

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")


def _sharding_tree(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _mem_analysis(compiled):
    try:
        ma = compiled.memory_analysis()
        return {
            "argument_size_bytes": getattr(ma, "argument_size_in_bytes", None),
            "output_size_bytes": getattr(ma, "output_size_in_bytes", None),
            "temp_size_bytes": getattr(ma, "temp_size_in_bytes", None),
            "generated_code_size_bytes":
                getattr(ma, "generated_code_size_in_bytes", None),
        }
    except Exception as e:                              # backend-dependent
        return {"error": repr(e)}


_COST_KEYS = ("flops", "bytes accessed", "transcendentals")


def _lower_compile(cfg: ModelConfig, shape: ShapeConfig, mesh,
                   attn_impl: str, microbatches: int = 1,
                   grad_compression: bool = False):
    """Lower + compile the right step for this cell under ``mesh``.
    Returns (compiled, lower_s, compile_s)."""
    t0 = time.time()
    with use_mesh(mesh):
        param_shapes = jax.eval_shape(partial(T.init_params, cfg),
                                      jax.random.PRNGKey(0))
        fit = partial(steps_lib.fit_sharding_tree, mesh)
        p_shard = _sharding_tree(mesh, fit(T.param_pspecs(cfg), param_shapes))

        if shape.kind == "train":
            train_step, opt_init = steps_lib.make_train_step(
                cfg, attn_impl=attn_impl, microbatches=microbatches,
                grad_compression=grad_compression)
            opt_shapes = jax.eval_shape(opt_init, param_shapes)
            o_spec_tree = {"adam": steps_lib.opt_state_pspecs(cfg)}
            o_shape_tree = {"adam": {"mu": param_shapes, "nu": param_shapes,
                                     "step": opt_shapes["adam"]["step"]}}
            if grad_compression:
                o_spec_tree["ef"] = T.param_pspecs(cfg)
                o_shape_tree["ef"] = param_shapes
            o_specs = fit(o_spec_tree, o_shape_tree)
            o_shard = _sharding_tree(mesh, o_specs)
            batch_shapes = T.input_specs(cfg, shape)
            b_shard = _sharding_tree(
                mesh, fit(steps_lib.train_batch_pspecs(cfg), batch_shapes))
            fn = jax.jit(train_step,
                         in_shardings=(p_shard, o_shard, b_shard),
                         out_shardings=(p_shard, o_shard, None),
                         donate_argnums=(0, 1))
            lowered = fn.lower(param_shapes, opt_shapes, batch_shapes)
        elif shape.kind == "prefill":
            prefill_step = steps_lib.make_prefill_step(cfg, shape,
                                                       attn_impl=attn_impl)
            batch_shapes = T.input_specs(cfg, shape)
            b_specs = {k: pspec(("pod", "data"),
                                *([None] * (len(v.shape) - 1)))
                       for k, v in batch_shapes.items()}
            b_shard = _sharding_tree(mesh, fit(b_specs, batch_shapes))
            fn = jax.jit(prefill_step, in_shardings=(p_shard, b_shard))
            lowered = fn.lower(param_shapes, batch_shapes)
        else:  # decode
            decode_step = steps_lib.make_decode_step(cfg, attn_impl=attn_impl)
            batch_shapes = T.input_specs(cfg, shape)
            b_shard = _sharding_tree(
                mesh, fit(steps_lib.decode_input_pspecs(cfg, shape),
                          batch_shapes))
            cache_shapes = batch_shapes["caches"]
            cache_out = _sharding_tree(
                mesh, fit(T.cache_pspecs(cfg,
                                         shard_seq=shape.global_batch == 1),
                          cache_shapes))
            fn = jax.jit(decode_step, in_shardings=(p_shard, b_shard),
                         out_shardings=(None, cache_out),
                         donate_argnums=(1,))
            lowered = fn.lower(param_shapes, batch_shapes)
        lower_s = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        return compiled, lower_s, time.time() - t1


def _extract(compiled) -> dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    out = {"cost": {k: float(cost.get(k, 0.0)) for k in _COST_KEYS}}
    hlo = compiled.as_text()
    out["collectives"] = collective_bytes(hlo)
    out["hlo_bytes"] = len(hlo)
    return out


def _depth_points(cfg: ModelConfig):
    """Two shallow configs (unrolled) whose cost delta is one repeat unit of
    the layer stack — see EXPERIMENTS.md §Dry-run methodology."""
    plen = (len(cfg.block_pattern) or
            (cfg.global_every if cfg.attn_chunk and cfg.global_every else 1))
    reps_full = cfg.n_layers // plen
    rem = cfg.n_layers % plen
    if reps_full < 2:
        return None
    mk = lambda r: dataclasses.replace(
        cfg, n_layers=plen * r + rem, scan_layers=False, exact_costs=True,
        n_enc_layers=(r if cfg.is_encdec else cfg.n_enc_layers))
    return mk(1), mk(2), reps_full


def _combine_costs(a: dict, b: dict, reps_full: int) -> dict:
    """total = a + (b - a) * (reps_full - 1), per cost key and collective.
    Clamped at the single-repeat value: the partitioner occasionally picks a
    cheaper collective pattern at depth 2, which would extrapolate negative.
    """
    out = {"cost": {}, "collectives": {}}
    for k in _COST_KEYS:
        ca, cb = a["cost"].get(k, 0.0), b["cost"].get(k, 0.0)
        out["cost"][k] = max(ca + (cb - ca) * (reps_full - 1), ca)
    keys = set(a["collectives"]) | set(b["collectives"])
    for k in keys:
        ca, cb = a["collectives"].get(k, 0), b["collectives"].get(k, 0)
        out["collectives"][k] = int(max(ca + (cb - ca) * (reps_full - 1),
                                        ca))
    return out


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             attn_impl: str = "auto", remat: str = "none",
             cost_mode: str = "extrapolate", microbatches: int = 1,
             reduced: bool = False, grad_compression: bool = False,
             sharding: str = "tp") -> dict:
    from repro.models.common import set_sharding_mode
    set_sharding_mode(sharding)
    cfg = get_reduced(arch) if reduced else get_config(arch)
    if remat != "none":
        cfg = dataclasses.replace(cfg, remat=remat)
    shape = SHAPES[shape_name]
    if reduced:   # integration-test scale: tiny shape, 8-device local mesh
        shape = dataclasses.replace(shape, seq_len=64, global_batch=4)
    if mesh_kind == "local":
        mesh = make_local_mesh(2, 4)
    else:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    art = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "chips": int(mesh.devices.size), "attn_impl": attn_impl,
           "remat": remat, "microbatches": microbatches,
           "grad_compression": grad_compression, "sharding": sharding,
           "status": "ok"}

    # 1) the real config: proves (lower + compile + shard) at full depth
    compiled, lower_s, compile_s = _lower_compile(cfg, shape, mesh, attn_impl,
                                                  microbatches,
                                                  grad_compression)
    art["lower_s"] = round(lower_s, 2)
    art["compile_s"] = round(compile_s, 2)
    art["memory"] = _mem_analysis(compiled)
    scanned = _extract(compiled)
    art["cost_scanned"] = scanned["cost"]          # scan bodies counted once
    art["collectives_scanned"] = scanned["collectives"]
    art["hlo_bytes"] = scanned["hlo_bytes"]

    # 2) exact per-layer costs: two shallow unrolled points (inner scans
    # unrolled, microbatch scan removed — cost_analysis counts scan bodies
    # once, so the real config's numbers would undercount), extrapolated
    if cost_mode == "extrapolate" and (pts := _depth_points(cfg)):
        cfg_a, cfg_b, reps_full = pts
        ca = _extract(_lower_compile(cfg_a, shape, mesh, attn_impl, 1,
                                     grad_compression)[0])
        cb = _extract(_lower_compile(cfg_b, shape, mesh, attn_impl, 1,
                                     grad_compression)[0])
        ext = _combine_costs(ca, cb, reps_full)
        art["cost"] = ext["cost"]
        art["collectives"] = ext["collectives"]
        art["cost_points"] = {"a": ca["cost"], "b": cb["cost"],
                              "reps_full": reps_full,
                              "layers_a": cfg_a.n_layers,
                              "layers_b": cfg_b.n_layers}
    else:
        art["cost"] = scanned["cost"]
        art["collectives"] = scanned["collectives"]

    art["n_params"] = int(cfg.n_params)
    art["n_active_params"] = int(cfg.n_active_params)
    art["tokens"] = int(shape.global_batch *
                        (shape.seq_len if shape.kind != "decode" else 1))
    return art


def save_artifact(art: dict, out_dir: str, extra_tag: str = ""):
    os.makedirs(out_dir, exist_ok=True)
    tag = f"__{extra_tag}" if extra_tag else ""
    path = os.path.join(
        out_dir, f"{art['arch']}__{art['shape']}__{art['mesh']}{tag}.json")
    with open(path, "w") as f:
        json.dump(art, f, indent=1)
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both", "local"])
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config + tiny shape (integration tests)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--attn-impl", default="auto")
    ap.add_argument("--remat", default="none")
    ap.add_argument("--cost-mode", default="extrapolate",
                    choices=["extrapolate", "scanned"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--sharding", default="tp", choices=["tp", "fsdp"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default=os.path.abspath(ARTIFACT_DIR))
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for arch, sname, shape, skip in iter_cells():
            for m in meshes:
                cells.append((arch, sname, m, skip))
    else:
        skip = cell_skip_reason(args.arch, args.shape)
        for m in meshes:
            cells.append((args.arch, args.shape, m, skip))

    failures = 0
    for arch, sname, m, skip in cells:
        label = f"{arch} x {sname} x {m}"
        if skip:
            art = {"arch": arch, "shape": sname, "mesh": m,
                   "status": "skipped", "reason": skip,
                   "chips": 512 if m == "multi" else 256}
            save_artifact(art, args.out, args.tag)
            print(f"[SKIP] {label}: {skip}", flush=True)
            continue
        try:
            art = run_cell(arch, sname, m, attn_impl=args.attn_impl,
                           remat=args.remat, cost_mode=args.cost_mode,
                           microbatches=args.microbatches,
                           reduced=args.reduced,
                           grad_compression=args.grad_compression,
                           sharding=args.sharding)
            path = save_artifact(art, args.out, args.tag)
            coll = art["collectives"]
            print(f"[OK]   {label}: compile={art['compile_s']}s "
                  f"flops={art['cost'].get('flops', 0):.3e} "
                  f"coll={sum(v for k, v in coll.items() if k != 'count'):.3e}B "
                  f"-> {os.path.basename(path)}", flush=True)
        except Exception as e:
            failures += 1
            art = {"arch": arch, "shape": sname, "mesh": m,
                   "status": "failed", "error": traceback.format_exc()}
            save_artifact(art, args.out, args.tag)
            print(f"[FAIL] {label}: {e!r}", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
