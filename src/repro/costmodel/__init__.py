from repro.costmodel.accelerator import ARCHS, EYERISS, SIMBA, SIMBA2X2, Accelerator
from repro.costmodel.energy import DEFAULT_ENERGY, EnergyModel
from repro.costmodel.evaluator import Evaluator, ScheduleCost
from repro.costmodel.mapper import LayerCost, map_layer, spatial_utilization

__all__ = ["ARCHS", "EYERISS", "SIMBA", "SIMBA2X2", "Accelerator",
           "DEFAULT_ENERGY", "EnergyModel", "Evaluator", "ScheduleCost",
           "LayerCost", "map_layer", "spatial_utilization"]
