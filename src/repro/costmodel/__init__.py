from repro.costmodel.accelerator import ARCHS, EYERISS, SIMBA, SIMBA2X2, Accelerator
from repro.costmodel.base import CostBreakdown, CostModel, GroupKey
from repro.costmodel.default import DefaultCostModel
from repro.costmodel.energy import DEFAULT_ENERGY, EnergyModel
from repro.costmodel.evaluator import Evaluator, ScheduleCost
from repro.costmodel.mapper import (LayerCost, map_layer, resolve_dataflow,
                                    spatial_utilization)
from repro.costmodel.tpu_fusion import TpuFusionCostModel

__all__ = ["ARCHS", "EYERISS", "SIMBA", "SIMBA2X2", "Accelerator",
           "CostBreakdown", "CostModel", "DEFAULT_ENERGY",
           "DefaultCostModel", "EnergyModel", "Evaluator", "GroupKey",
           "LayerCost", "ScheduleCost", "TpuFusionCostModel", "map_layer",
           "resolve_dataflow", "spatial_utilization"]
