"""Analytical TPU cost model for schedule candidates (the GA's Timeloop).

The paper costs fusion states with Timeloop/Accelergy; on TPU the equivalent
"mapping evaluation" estimates, per training step and per chip:

* FLOPs  — 6 * active_params * tokens (+ attention) with remat recompute;
* HBM    — parameter + optimizer traffic, activation save/restore traffic
           under the chosen remat policy (the analogue of the paper's
           on-chip vs DRAM activation residency);
* ICI    — TP all-reduces per layer + the data-parallel gradient reduce
           (optionally int8-compressed);
* HBM residency — params + optimizer + live activations; candidates that
  exceed capacity are invalid, exactly like the paper's activation-buffer
  capacity check.

Absolute numbers are estimates; the dry-run validates the chosen candidate
by re-lowering (EXPERIMENTS.md §Perf records predicted vs compiled).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Tuple

from repro.configs.base import ModelConfig, ShapeConfig
from repro.roofline.analysis import HW

# activation words saved per token per layer, in units of d_model, by remat
# policy (transformer block: ~2 norms, qkvo, 2-3 mlp intermediates, attn)
_ACT_SAVE_FACTOR = {"none": 14.0, "selective": 6.0, "full": 1.0}
# extra forward recompute in the backward pass, fraction of fwd FLOPs
_RECOMPUTE = {"none": 0.0, "selective": 0.35, "full": 1.0}

# the full genome option sets — single source of truth for mutate_options
# AND the search problem's enumerate/space_size/random sampling
# (repro.search.tpu); extending one extends both
REMAT_OPTIONS = tuple(_RECOMPUTE)
MICROBATCH_OPTIONS = (1, 2, 4, 8, 16)
SHARDING_OPTIONS = ("tp", "fsdp")     # tp (Megatron) | fsdp (ZeRO-3 + SP)


@dataclass(frozen=True)
class TpuSchedule:
    """Genome for the TPU scheduling GA."""
    remat: str = "none"               # per-run policy (none|selective|full)
    microbatches: int = 1
    grad_compression: bool = False
    sharding: str = "tp"

    def mutate_options(self):
        return (
            [TpuSchedule(r, self.microbatches, self.grad_compression,
                         self.sharding)
             for r in REMAT_OPTIONS if r != self.remat]
            + [TpuSchedule(self.remat, m, self.grad_compression,
                           self.sharding)
               for m in MICROBATCH_OPTIONS if m != self.microbatches]
            + [TpuSchedule(self.remat, self.microbatches,
                           not self.grad_compression, self.sharding)]
            + [TpuSchedule(self.remat, self.microbatches,
                           self.grad_compression, s)
               for s in SHARDING_OPTIONS if s != self.sharding]
        )


@dataclass(frozen=True)
class TpuCost:
    compute_s: float
    memory_s: float
    collective_s: float
    hbm_resident_bytes: float
    energy_j: float

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def edp(self) -> float:
        return self.energy_j * self.step_s

    @property
    def dominant(self) -> str:
        t = {"compute": self.compute_s, "memory": self.memory_s,
             "collective": self.collective_s}
        return max(t, key=t.get)


# J per unit, TPU-class estimates (Jouppi et al., datacenter-accelerator
# energy surveys): ~0.3 pJ/FLOP bf16 system-level, ~10 pJ/byte HBM,
# ~25 pJ/byte chip-to-chip.  Public names: the fusion-side TPU cost model
# (repro.costmodel.tpu_fusion) prices CNN schedules with the same constants.
E_FLOP_J = 0.3e-12
E_HBM_J_PER_BYTE = 10e-12
E_ICI_J_PER_BYTE = 25e-12

_E_FLOP = E_FLOP_J
_E_HBM = E_HBM_J_PER_BYTE
_E_ICI = E_ICI_J_PER_BYTE


def estimate(cfg: ModelConfig, shape: ShapeConfig, sched: TpuSchedule,
             *, chips: int = 256, data_par: int = 16, model_par: int = 16,
             hw: HW = HW()) -> TpuCost:
    """Per-chip cost of one training step under ``sched``."""
    tokens = shape.global_batch * shape.seq_len
    tokens_chip = tokens / data_par                # model axis shares tokens
    n_active = cfg.n_active_params
    bytes_per_param = 2                            # bf16

    # ---- FLOPs ------------------------------------------------------------------
    base = 6.0 * n_active * tokens / chips         # fwd+bwd matmuls
    attn_flops = 0.0
    if cfg.family not in ("ssm",):
        # causal attention ~ 6 * L * S * d per token fwd (halved by causal),
        # x3 for bwd; local/chunked layers use their window instead of S
        kinds = cfg.layer_kinds()
        hd = cfg.resolved_head_dim * cfg.n_heads
        for kind in kinds:
            eff = shape.seq_len
            if kind == "attn_local":
                eff = min(2 * cfg.attn_window, shape.seq_len)
            elif kind == "attn_chunk":
                eff = min(cfg.attn_chunk, shape.seq_len)
            elif not kind.startswith("attn"):
                continue
            attn_flops += 2.0 * tokens * eff * hd * 0.5 * 3 / chips
    flops = (base + attn_flops) * (1.0 + _RECOMPUTE[sched.remat])

    # ---- HBM bytes ---------------------------------------------------------------
    params_chip = cfg.n_params * bytes_per_param / chips
    moment_bytes = 4 if cfg.moment_dtype == "float32" else 2
    opt_chip = cfg.n_params * 2 * moment_bytes / chips
    # params read fwd+bwd per microbatch pass + optimizer read/write
    w_traffic = params_chip * 2 * sched.microbatches + \
        (params_chip + opt_chip) * 2
    act_bytes_layer = (_ACT_SAVE_FACTOR[sched.remat] * cfg.d_model *
                       bytes_per_param)
    act_traffic = 2 * act_bytes_layer * cfg.n_layers * tokens_chip / model_par
    mem_bytes = w_traffic + act_traffic

    # ---- collectives -----------------------------------------------------------------
    if sched.sharding == "fsdp":
        # ZeRO-3: per-layer param all-gathers (fwd + bwd + remat re-gather)
        # + reduce-scatter of grads + sequence-parallel partial-sum ARs.
        gathers = 2.0 + (1.0 if sched.remat != "none" else 0.0)
        params_bytes = cfg.n_params * bytes_per_param / chips
        zero3 = params_bytes * gathers + params_bytes * 2      # RS grads fp32
        tokens_dev = tokens / chips                            # SP over model
        sp_ar = (4 * tokens_dev * cfg.d_model * bytes_per_param
                 * cfg.n_layers)
        coll_bytes = zero3 * (chips - 1) / chips * 4 + sp_ar
        # gradient compression cannot intercept the in-bwd reduce-scatter
        # (EXPERIMENTS §Perf iter 6) — no discount in fsdp mode
    else:
        tp_per_layer = 4 * tokens_chip * cfg.d_model * bytes_per_param
        tp_bytes = tp_per_layer * cfg.n_layers * (model_par - 1) / model_par
        grad_bytes_unit = 1 if sched.grad_compression else 4
        dp_bytes = cfg.n_params * grad_bytes_unit / chips * 2
        coll_bytes = tp_bytes + dp_bytes

    # ---- residency (the capacity check) -------------------------------------------------
    live_acts = (act_bytes_layer * cfg.n_layers *
                 tokens_chip / model_par / sched.microbatches)
    resident = params_chip + opt_chip + live_acts + 2 * params_chip  # grads+wk

    energy = (flops * _E_FLOP + mem_bytes * _E_HBM + coll_bytes * _E_ICI) \
        * chips
    return TpuCost(
        compute_s=flops / hw.peak_flops,
        memory_s=mem_bytes / hw.hbm_bw,
        collective_s=coll_bytes / hw.ici_bw,
        hbm_resident_bytes=resident,
        energy_j=energy)
