"""Mini-Timeloop: per-layer mapping cost (paper §II-A).

Timeloop searches full loop-nest mapspaces; we keep the decisions that move
the paper's needle — DRAM traffic under buffer-capacity constraints, spatial
utilization of the PE array, and dataflow-specific on-chip reuse — in a small
closed-form model:

* **DRAM traffic**: weights / inputs stream once when resident; when neither
  operand fits its buffer the mapper picks the cheaper of weight-outer
  (inputs re-streamed per weight tile) vs input-outer loop order.
* **Spatial utilization**: per-dataflow lane mapping with ceil-division
  padding waste (SIMBA parallelizes M x C across PEs x vector lanes; Eyeriss
  row-stationary maps filter rows x output rows, packing multiple filters
  vertically when R < PE rows — its 14x12 array under-utilizes on some
  shapes, which the paper calls out in Fig. 11).
* **On-chip reuse**: per-dataflow amortization of buffer reads (broadcast for
  weight-stationary, row reuse for row-stationary); RF traffic is 3 accesses
  per MAC.

Cycles = max(compute, DRAM) — Timeloop schedules overlap computation with
communication (paper §IV), so the slower of the two binds.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

from repro.core.graph import Layer
from repro.costmodel.accelerator import Accelerator
from repro.costmodel.energy import DEFAULT_ENERGY, EnergyModel


def _util_dim(n: int, lanes: int) -> float:
    """Fraction of ``lanes`` kept busy by a dimension of size n (ceil waste)."""
    if n <= 0 or lanes <= 0:
        return 1.0
    return n / (math.ceil(n / lanes) * lanes)


def _util_weight_stationary(layer: Layer, acc: Accelerator) -> float:
    # SIMBA: M across PEs, C across per-PE vector MAC lanes.
    cg = max(layer.c // layer.groups, 1)
    return _util_dim(layer.m, acc.pe_count) * _util_dim(cg, acc.macs_per_pe)


def _util_row_stationary(layer: Layer, acc: Accelerator) -> float:
    # Eyeriss row-stationary: filter rows vertical (packing multiple
    # filters when R < pe_y), output columns horizontal.
    r = max(layer.r, 1)
    if r <= acc.pe_y:
        u_v = r * (acc.pe_y // r) / acc.pe_y
    else:
        u_v = _util_dim(r, acc.pe_y)
    q = max(layer.q, 1)
    return u_v * _util_dim(q, acc.pe_x)


def resolve_dataflow(layer: Layer, acc: Accelerator) -> str:
    """The concrete dataflow executing ``layer`` on ``acc``.

    Fixed-dataflow machines return their dataflow unchanged; a FlexNN-style
    ``flexible`` array (arXiv 2403.09026) reconfigures per layer, so the
    mapper picks whichever fixed dataflow utilizes the array better on this
    shape (weight-stationary wins ties — it is the cheaper reconfiguration
    target on SIMBA-class datapaths)."""
    if acc.dataflow != "flexible":
        return acc.dataflow
    if _util_weight_stationary(layer, acc) >= _util_row_stationary(layer, acc):
        return "weight_stationary"
    return "row_stationary"


def spatial_utilization(layer: Layer, acc: Accelerator,
                        dataflow: Optional[str] = None) -> float:
    """Fraction of the PE array ``layer`` keeps busy.  ``dataflow`` lets a
    caller that already resolved a flexible machine's per-layer choice
    (``map_layer``) skip re-resolving it."""
    if layer.kind not in ("conv", "dwconv", "fc"):
        return 1.0
    if dataflow is None:
        dataflow = resolve_dataflow(layer, acc)
    if dataflow == "weight_stationary":
        u = _util_weight_stationary(layer, acc)
    else:
        u = _util_row_stationary(layer, acc)
    return max(u, 1.0 / acc.peak_macs_per_cycle)


@dataclass
class LayerCost:
    """Cost of one layer under one mapping.  Energies in pJ, time in cycles.

    ``energy_terms`` names the components summed into ``energy_pj`` (for
    :class:`repro.costmodel.base.CostBreakdown` reporting); accumulation
    via ``+=`` merges them term-wise.
    """
    energy_pj: float = 0.0
    compute_cycles: float = 0.0
    dram_cycles: float = 0.0
    dram_read_words: int = 0
    dram_write_words: int = 0
    act_write_events: int = 0     # distinct activation tensors written to DRAM
    macs: int = 0
    utilization: float = 1.0
    energy_terms: dict = field(default_factory=dict)

    @property
    def cycles(self) -> float:
        # compute/communication overlap (see module docstring)
        return max(self.compute_cycles, self.dram_cycles)

    def __iadd__(self, other: "LayerCost") -> "LayerCost":
        self.energy_pj += other.energy_pj
        self.compute_cycles += other.compute_cycles
        self.dram_cycles += other.dram_cycles
        self.dram_read_words += other.dram_read_words
        self.dram_write_words += other.dram_write_words
        self.act_write_events += other.act_write_events
        self.macs += other.macs
        for k, v in other.energy_terms.items():
            self.energy_terms[k] = self.energy_terms.get(k, 0.0) + v
        return self


def map_layer(layer: Layer, acc: Accelerator,
              em: EnergyModel = DEFAULT_ENERGY, *,
              inputs_offchip: bool = True,
              outputs_offchip: bool = True,
              weight_stream_passes: int = 1) -> LayerCost:
    """Cost one layer.

    ``inputs_offchip`` / ``outputs_offchip``: whether this layer's input /
    output activations cross the DRAM boundary (the fusion scheduler's lever).
    ``weight_stream_passes``: >1 when the layer executes inside a fused group
    whose aggregate weights exceed the weight buffer, forcing a re-stream per
    output tile pass (paper §IV: such weights "must always be loaded from
    DRAM").
    """
    cost = LayerCost(macs=layer.macs)
    I, O, W = layer.input_size, layer.output_size, layer.weight_size
    e_ab = em.e_sram(acc.act_buf_kib)
    e_wb = em.e_sram(acc.weight_buf_kib)

    if layer.macs == 0 and layer.kind in ("input",):
        return cost

    # ---- DRAM traffic --------------------------------------------------------------
    dram_r = 0
    dram_w = 0
    if layer.has_weights:
        w_fits = W <= acc.weight_buf_words
        i_fits = I <= acc.act_buf_words
        if w_fits or i_fits:
            w_dram = W
            i_dram = I
        else:
            n_w = math.ceil(W / acc.weight_buf_words)
            n_i = math.ceil(I / acc.act_buf_words)
            # weight-outer vs input-outer loop order; keep the cheaper.
            if W + I * n_w <= I + W * n_i:
                w_dram, i_dram = W, I * n_w
            else:
                w_dram, i_dram = W * n_i, I
        w_dram *= max(weight_stream_passes, 1)
        dram_r += w_dram
    else:
        i_dram = I
    if inputs_offchip:
        dram_r += i_dram
    if outputs_offchip and O:
        dram_w += O
        cost.act_write_events = 1
    cost.dram_read_words = dram_r
    cost.dram_write_words = dram_w

    # ---- on-chip traffic -------------------------------------------------------------
    df = resolve_dataflow(layer, acc)       # once per call; flexible machines
    cg = max(layer.c // max(layer.groups, 1), 1)
    if df == "weight_stationary":
        in_amort = min(max(layer.m // max(layer.groups, 1), 1), acc.macs_per_pe)
        w_amort = min(max(layer.p * layer.q, 1), 1024)
    else:
        in_amort = min(max(layer.r, 1), acc.pe_y)
        w_amort = min(max(layer.q, 1), 256)
    act_reads = layer.macs / max(in_amort, 1)
    # fill (only when staged from DRAM; a fused producer already paid the
    # write with its own output-collect term) + output collect
    act_writes = (I if inputs_offchip else 0) + O
    wbuf_reads = layer.macs / max(w_amort, 1)
    wbuf_writes = W * max(weight_stream_passes, 1)

    terms = {
        "mac": layer.macs * em.e_mac,
        "rf": 3.0 * layer.macs * em.e_rf,                 # in, w, psum regs
        "act_buf": (act_reads + act_writes) * e_ab,
        "weight_buf": (wbuf_reads + wbuf_writes) * e_wb,
        "noc": (act_reads + wbuf_reads) * 0.5 * em.e_noc,  # array distribution
        "dram": (dram_r + dram_w) * em.e_dram,
    }
    # summed term-by-term in the historical expression order: energy_pj is
    # bit-identical to the pre-breakdown single-expression sum
    cost.energy_pj = (terms["mac"] + terms["rf"] + terms["act_buf"]
                      + terms["weight_buf"] + terms["noc"] + terms["dram"])
    cost.energy_terms = terms

    # ---- time ------------------------------------------------------------------------
    util = spatial_utilization(layer, acc, df)
    cost.utilization = util
    if layer.macs:
        cost.compute_cycles = layer.macs / (acc.peak_macs_per_cycle * util)
    cost.dram_cycles = (dram_r + dram_w) / acc.dram_words_per_cycle
    return cost
