"""The paper's cost backend: the mini-Timeloop mapper behind the
:class:`~repro.costmodel.base.CostModel` protocol.

This is the group-costing logic that used to live inside
``Evaluator._compute_group_cost_*`` — hoisted verbatim so that (a) the
evaluator is cost-model-agnostic and (b) other backends (TPU roofline,
future calibrated Timeloop runs) plug in behind the same two methods.
Both key forms (node-bitmask / frozenset of names) run the same float
operations in the same order, so costs agree bit-for-bit with each other
*and* with the pre-protocol evaluator (pinned by
``tests/test_fusion_equivalence.py`` and the fixed-seed search pin in
``tests/test_search_api.py``).

Group costing (multi-member groups, paper §IV):

1. largest output-tile height ``t`` whose line-buffer footprint fits the
   activation buffer (``repro.core.receptive``); no feasible ``t`` =>
   infeasible (``None``);
2. if aggregate group weights exceed the weight buffer, weights re-stream
   from DRAM once per tile pass;
3. member layers are costed with intra-group edges kept on-chip; compute
   and DRAM time overlap within the group.
"""
from __future__ import annotations

import math
from typing import FrozenSet, Optional

from repro.core.fusion import iter_bits
from repro.core.graph import Layer
from repro.core.receptive import max_tile_rows
from repro.core.toposort import member_order_ids, topological_sort_edges
from repro.costmodel.base import CostBreakdown, CostModel, GroupKey
from repro.costmodel.mapper import LayerCost, map_layer


class DefaultCostModel(CostModel):
    """Paper §II-A/§IV: dataflow-aware mapping + Accelergy-style energy."""

    name = "default"

    # ---- protocol ---------------------------------------------------------------
    def cost_layer(self, layer: Layer, *, inputs_offchip: bool = True,
                   outputs_offchip: bool = True,
                   weight_stream_passes: int = 1) -> LayerCost:
        return map_layer(layer, self.acc, self.em,
                         inputs_offchip=inputs_offchip,
                         outputs_offchip=outputs_offchip,
                         weight_stream_passes=weight_stream_passes)

    def cost_group(self, key: GroupKey) -> Optional[CostBreakdown]:
        if isinstance(key, int):
            return self._cost_group_mask(key)
        return self._cost_group_members(key)

    def _map_layer_memo(self, i: int, inputs_off: bool, outputs_off: bool,
                        weight_passes: int) -> LayerCost:
        """Per-(layer, boundary flags, weight passes) mapper memo.  A layer's
        mapping depends only on these; across the thousands of groups a
        search costs, the same few hundred combinations recur.  Cached
        :class:`LayerCost` objects are returned as-is — callers only read
        them (``LayerCost.__iadd__`` mutates the accumulator, not its
        operand)."""
        memo = self.__dict__.get("_layer_memo")
        if memo is None:
            memo = self._layer_memo = {}
        k = (i, inputs_off, outputs_off, weight_passes)
        lc = memo.get(k)
        if lc is None:
            lc = memo[k] = map_layer(self.cg.layers[i], self.acc, self.em,
                                     inputs_offchip=inputs_off,
                                     outputs_offchip=outputs_off,
                                     weight_stream_passes=weight_passes)
        return lc

    # ---- internals --------------------------------------------------------------
    def _cost_group_mask(self, gmask: int) -> Optional[CostBreakdown]:
        """Fast path: members given as a node bitmask, order and membership
        tests all on integers."""
        cg = self.cg
        order = member_order_ids(cg.succ_ids, list(iter_bits(gmask)))
        multi = sum(1 for i in order if cg.macs[i]) > 1

        weight_passes = 1
        tile_rows = 0
        if multi and len(order) > 1:
            names_order = [cg.names[i] for i in order]
            t = max_tile_rows(self.graph, names_order, self.acc.act_buf_words)
            if t == 0:
                return None                              # over-capacity: invalid
            tile_rows = t
            group_w = sum(cg.weight_size[i] for i in order)
            if group_w > self.acc.weight_buf_words:
                sink_p = max((cg.p[i] or 1) for i in order)
                weight_passes = math.ceil(sink_p / t)

        total = LayerCost()
        compute_cycles = 0.0
        dram_cycles = 0.0
        util_macs = 0.0
        for i in order:
            preds = cg.pred_ids[i]
            inputs_off = (not preds) or \
                any(not (gmask >> p) & 1 for p in preds)
            succs = cg.succ_ids[i]
            outputs_off = (not succs) or \
                any(not (gmask >> v) & 1 for v in succs)
            lc = self._map_layer_memo(i, inputs_off, outputs_off,
                                      weight_passes if multi else 1)
            total += lc
            compute_cycles += lc.compute_cycles
            dram_cycles += lc.dram_cycles
            util_macs += lc.utilization * lc.macs
        return self._breakdown(total, compute_cycles, dram_cycles, util_macs,
                               members=tuple(cg.names[i] for i in order),
                               tile_rows=tile_rows,
                               weight_passes=weight_passes)

    def _cost_group_members(self, members: FrozenSet[str]
                            ) -> Optional[CostBreakdown]:
        """Reference path: members as a frozenset of layer names (used by
        ``ReferenceFusionState``; kept operation-for-operation identical to
        the fast path so both produce bit-equal costs)."""
        g = self.graph
        order = topological_sort_edges(
            [n for n in g.names if n in members], g.edges)
        multi = len([n for n in order if g.layers[n].macs]) > 1

        weight_passes = 1
        tile_rows = 0
        if multi and len(order) > 1:
            t = max_tile_rows(g, order, self.acc.act_buf_words)
            if t == 0:
                return None                              # over-capacity: invalid
            tile_rows = t
            group_w = sum(g.layers[n].weight_size for n in order)
            if group_w > self.acc.weight_buf_words:
                sink_p = max((g.layers[n].p or 1) for n in order)
                weight_passes = math.ceil(sink_p / t)

        total = LayerCost()
        compute_cycles = 0.0
        dram_cycles = 0.0
        util_macs = 0.0
        for name in order:
            layer = g.layers[name]
            inputs_off = self._inputs_offchip(name, members)
            outputs_off = self._outputs_offchip(name, members)
            lc = map_layer(layer, self.acc, self.em,
                           inputs_offchip=inputs_off,
                           outputs_offchip=outputs_off,
                           weight_stream_passes=weight_passes if multi else 1)
            total += lc
            compute_cycles += lc.compute_cycles
            dram_cycles += lc.dram_cycles
            util_macs += lc.utilization * lc.macs
        return self._breakdown(total, compute_cycles, dram_cycles, util_macs,
                               members=tuple(order), tile_rows=tile_rows,
                               weight_passes=weight_passes)

    @staticmethod
    def _breakdown(total: LayerCost, compute_cycles: float,
                   dram_cycles: float, util_macs: float, *, members,
                   tile_rows: int, weight_passes: int) -> CostBreakdown:
        return CostBreakdown(
            energy_pj=total.energy_pj,
            compute_cycles=compute_cycles,
            dram_cycles=dram_cycles,
            dram_read_words=total.dram_read_words,
            dram_write_words=total.dram_write_words,
            act_write_events=total.act_write_events,
            macs=total.macs,
            members=members,
            tile_rows=tile_rows,
            weight_passes=weight_passes,
            utilization=(util_macs / total.macs if total.macs else 1.0),
            energy_terms=dict(total.energy_terms))

    def _inputs_offchip(self, name: str, members: FrozenSet[str]) -> bool:
        preds = self.graph.preds(name)
        if not preds:
            return True                                  # graph input from DRAM
        return any(p not in members for p in preds)

    def _outputs_offchip(self, name: str, members: FrozenSet[str]) -> bool:
        succ = self.graph.succs(name)
        if not succ:
            return True                                  # model output
        return any(v not in members for v in succ)
