"""Accelerator templates (paper Table I).

Eyeriss-like (row-stationary), SIMBA-like and SIMBA-2x2-like
(weight-stationary) spatial arrays, all at the paper's system setting:
200 MHz nominal clock, LPDDR4 at 128 GB/s, 16-bit words.

Note the paper *modifies* Eyeriss with a 512 KiB weight buffer ("equal to that
of a single SIMBA chiplet, to store multiple layers simultaneously") — that is
the configuration encoded here.
"""
from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class Accelerator:
    name: str
    pe_x: int
    pe_y: int
    macs_per_pe: int
    act_buf_kib: int
    weight_buf_kib: int
    dataflow: str     # "row_stationary" | "weight_stationary" | "flexible"
    clock_mhz: float = 200.0
    dram_gbps: float = 128.0
    word_bytes: int = 2

    # ---- derived ---------------------------------------------------------------
    @property
    def pe_count(self) -> int:
        return self.pe_x * self.pe_y

    @property
    def peak_macs_per_cycle(self) -> int:
        return self.pe_count * self.macs_per_pe

    @property
    def act_buf_words(self) -> int:
        return self.act_buf_kib * 1024 // self.word_bytes

    @property
    def weight_buf_words(self) -> int:
        return self.weight_buf_kib * 1024 // self.word_bytes

    @property
    def dram_words_per_cycle(self) -> float:
        return self.dram_gbps * 1e9 / (self.clock_mhz * 1e6) / self.word_bytes

    def repartition(self, act_delta_kib: int) -> "Accelerator":
        """Iso-capacity buffer repartitioning (paper Fig. 11): move
        ``act_delta_kib`` KiB from the weight buffer to the activation buffer
        (negative = the other way).  Total on-chip capacity is preserved by
        construction; a delta that drives either buffer non-positive is a
        meaningless machine and is refused."""
        act = self.act_buf_kib + act_delta_kib
        wgt = self.weight_buf_kib - act_delta_kib
        if act <= 0 or wgt <= 0:
            raise ValueError(
                f"repartition({act_delta_kib:+d}) of {self.name!r} leaves "
                f"act={act} KiB / weight={wgt} KiB; both buffers must stay "
                f"positive (valid deltas: "
                f"{1 - self.act_buf_kib}..{self.weight_buf_kib - 1})")
        return replace(
            self,
            name=f"{self.name}_act{act}k",
            act_buf_kib=act,
            weight_buf_kib=wgt,
        )


# Paper Table I ------------------------------------------------------------------
EYERISS = Accelerator("eyeriss", pe_x=14, pe_y=12, macs_per_pe=1,
                      act_buf_kib=128, weight_buf_kib=512,
                      dataflow="row_stationary")
SIMBA = Accelerator("simba", pe_x=4, pe_y=4, macs_per_pe=64,
                    act_buf_kib=64, weight_buf_kib=512,
                    dataflow="weight_stationary")
SIMBA2X2 = Accelerator("simba2x2", pe_x=8, pe_y=8, macs_per_pe=64,
                       act_buf_kib=256, weight_buf_kib=2048,
                       dataflow="weight_stationary")

ARCHS = {a.name: a for a in (EYERISS, SIMBA, SIMBA2X2)}
