"""The cost-backend protocol: :class:`CostModel` + :class:`CostBreakdown`.

The paper's headline numbers come from holding the *search* fixed and
swapping the *cost side* (machines, mappers).  This module pins that axis
down the same way ``repro.core.problem`` pinned the search side: a
:class:`CostModel` is bound to one (graph, accelerator, energy-model)
triple and answers "what does this fused group cost?" — everything else
(memoization, baseline-plus-corrections batching, fitness) lives in the
model-agnostic :class:`repro.costmodel.evaluator.Evaluator`.

Implementations (registered with ``@repro.search.register_costmodel``):

* ``default`` — :class:`repro.costmodel.default.DefaultCostModel`, the
  paper's mini-Timeloop mapper (dataflow utilization, buffer-capacity
  tiling, LPDDR4 traffic);
* ``tpu``     — :class:`repro.costmodel.tpu_fusion.TpuFusionCostModel`,
  the TPU retarget's three-term roofline over the same fusion genomes.

A group's answer is a declarative :class:`CostBreakdown` — named totals
plus per-component energy terms — rather than an ad-hoc positional tuple,
so artifacts can store per-group breakdowns and ``repro report`` can show
where energy/cycles go without re-running the model.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import (Any, Dict, FrozenSet, List, Mapping, Optional, Sequence,
                    Tuple, Union)

from repro.core.graph import Layer, LayerGraph
from repro.costmodel.accelerator import Accelerator
from repro.costmodel.energy import DEFAULT_ENERGY, EnergyModel
from repro.costmodel.mapper import LayerCost

#: a group's identity: member node-bitmask (fast engine) or frozenset of
#: layer names (reference engine) — see ``repro.core.fusion``
GroupKey = Union[int, FrozenSet[str]]

#: scalar totals tuple consumed by the evaluator's hot caches:
#: (energy_pj, cycles, dram_read_words, dram_write_words,
#:  act_write_events, macs) — or None when the group is infeasible
GroupTotals = Optional[Tuple[float, float, int, int, int, int]]


@dataclass(frozen=True)
class CostBreakdown:
    """Declarative cost of one scheduled group.

    ``energy_terms`` names the components summed into ``energy_pj``
    (``mac``/``rf``/``act_buf``/``weight_buf``/``noc``/``dram`` for the
    default model); ``compute_cycles``/``dram_cycles`` keep both sides of
    the overlap visible (``cycles`` is their max, paper §IV).
    ``tile_rows``/``weight_passes`` record the mapping decisions that
    produced the numbers (0/1 for single-layer groups).
    """

    energy_pj: float
    compute_cycles: float
    dram_cycles: float
    dram_read_words: int
    dram_write_words: int
    act_write_events: int
    macs: int
    members: Tuple[str, ...] = ()
    tile_rows: int = 0
    weight_passes: int = 1
    utilization: float = 1.0
    energy_terms: Mapping[str, float] = field(default_factory=dict)

    @property
    def cycles(self) -> float:
        # compute/DRAM overlap across the group pipeline (paper §IV)
        return max(self.compute_cycles, self.dram_cycles)

    @property
    def edp(self) -> float:
        return self.energy_pj * self.cycles

    def totals(self) -> Tuple[float, float, int, int, int, int]:
        """The evaluator's scalar cache record."""
        return (self.energy_pj, self.cycles, self.dram_read_words,
                self.dram_write_words, self.act_write_events, self.macs)

    # ---- serialization (artifact storage) --------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {
            "members": list(self.members),
            "energy_pj": self.energy_pj,
            "compute_cycles": self.compute_cycles,
            "dram_cycles": self.dram_cycles,
            "dram_read_words": self.dram_read_words,
            "dram_write_words": self.dram_write_words,
            "act_write_events": self.act_write_events,
            "macs": self.macs,
            "tile_rows": self.tile_rows,
            "weight_passes": self.weight_passes,
            "utilization": self.utilization,
            "energy_terms": dict(self.energy_terms),
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "CostBreakdown":
        return cls(
            energy_pj=d["energy_pj"],
            compute_cycles=d["compute_cycles"],
            dram_cycles=d["dram_cycles"],
            dram_read_words=d["dram_read_words"],
            dram_write_words=d["dram_write_words"],
            act_write_events=d["act_write_events"],
            macs=d["macs"],
            members=tuple(d.get("members", ())),
            tile_rows=d.get("tile_rows", 0),
            weight_passes=d.get("weight_passes", 1),
            utilization=d.get("utilization", 1.0),
            energy_terms=dict(d.get("energy_terms", {})),
        )


class CostModel:
    """Cost-backend contract: bound to one (graph, accelerator, energy
    model) triple, answers per-layer and per-group cost queries.

    Subclasses must implement :meth:`cost_layer` and :meth:`cost_group`;
    :meth:`batch` has a generic default that models with vectorized
    internals (or remote cost services) may override.  ``cost_group``
    returning ``None`` marks the group infeasible on this machine (the
    paper's "mapping where intermediate storage exceeds capacity is
    discarded as invalid") — the evaluator turns that into fitness 0.
    """

    #: registry name (``repro.search.register_costmodel``)
    name: str = "costmodel"

    def __init__(self, graph: LayerGraph, acc: Accelerator,
                 em: EnergyModel = DEFAULT_ENERGY):
        self.graph = graph
        self.cg = graph.compiled()
        self.acc = acc
        self.em = em

    @property
    def clock_hz(self) -> float:
        """Clock converting the model's cycle counts to seconds."""
        return self.acc.clock_mhz * 1e6

    # ---- required surface -------------------------------------------------------
    def cost_layer(self, layer: Layer, *, inputs_offchip: bool = True,
                   outputs_offchip: bool = True,
                   weight_stream_passes: int = 1) -> LayerCost:
        """Cost one layer under explicit DRAM-boundary flags (the fusion
        scheduler's lever)."""
        raise NotImplementedError

    def cost_group(self, key: GroupKey) -> Optional[CostBreakdown]:
        """Cost one fused group (``None`` = infeasible on this machine).

        ``key`` identifies the member set: an int node-bitmask from the
        incremental engine or a frozenset of layer names from the
        reference engine.  Both must be supported and must produce
        bit-identical numbers (``tests/test_fusion_equivalence.py``).
        """
        raise NotImplementedError

    # ---- optional surface -------------------------------------------------------
    def batch(self, keys: Sequence[GroupKey]
              ) -> List[Optional[CostBreakdown]]:
        """Cost many groups at once; override when the model can amortize
        (vectorized math, one RPC to a cost service, ...)."""
        return [self.cost_group(k) for k in keys]

    # ---- shared helpers ---------------------------------------------------------
    def member_names(self, key: GroupKey) -> List[str]:
        """Group members in topological order, for either key form."""
        from repro.core.fusion import iter_bits
        from repro.core.toposort import member_order_ids, \
            topological_sort_edges
        if isinstance(key, int):
            order = member_order_ids(self.cg.succ_ids, list(iter_bits(key)))
            return [self.cg.names[i] for i in order]
        return topological_sort_edges(
            [n for n in self.graph.names if n in key], self.graph.edges)
