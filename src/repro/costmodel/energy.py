"""Accelergy-style per-access energy model (paper §II-A, refs [8],[10]).

Constants are per 16-bit word / per MAC, in pJ, at a 45nm-class node:

* ``e_mac``  — 16-bit multiply-accumulate, ~0.5-1 pJ (Horowitz, ISSCC'14).
* ``e_rf``   — PE-local scratchpad (<1 KiB register file), ~0.5 pJ/word
  (Eyeriss JSSC'17 normalized RF access = 1x MAC).
* ``e_noc``  — array interconnect hop/broadcast, ~2x RF (Eyeriss NoC = 2x).
* ``e_sram(cap)`` — shared buffer access.  Larger SRAMs are *banked*, so
  per-access energy grows sublinearly with capacity; Accelergy/CACTI-class
  models land near cap^0.25 at constant width (a monolithic array would be
  ~sqrt).  Anchored so 64 KiB ~ 1.2 pJ, 1 MiB ~ 2.4 pJ/word.
* ``e_dram`` — LPDDR4, ~4-8 pJ/bit -> ~100 pJ per 16-bit word
  (Eyeriss JSSC'17 uses DRAM = 200x MAC; we land in the same regime).

Absolute joules differ from a calibrated Accelergy run; the reproduction
targets *ratios* between schedules, which are governed by the DRAM:SRAM:RF
ratios — all of which sit at their published relative magnitudes here.
"""
from __future__ import annotations

import math
from dataclasses import dataclass


@dataclass(frozen=True)
class EnergyModel:
    e_mac: float = 0.56          # pJ / MAC
    e_rf: float = 0.48           # pJ / word (PE scratchpad)
    e_noc: float = 1.0           # pJ / word (array broadcast / hop)
    e_dram: float = 100.0        # pJ / word (LPDDR4)
    sram_anchor_pj: float = 1.2  # pJ / word at 64 KiB
    sram_anchor_kib: float = 64.0

    sram_exponent: float = 0.25    # banked-SRAM capacity scaling

    def e_sram(self, capacity_kib: float) -> float:
        """Per-word access energy of an on-chip SRAM of ``capacity_kib``."""
        if capacity_kib <= 0:
            return self.e_rf
        return max(0.6, self.sram_anchor_pj *
                   (capacity_kib / self.sram_anchor_kib) ** self.sram_exponent)


DEFAULT_ENERGY = EnergyModel()
