"""TPU roofline cost backend for fusion schedules (``--costmodel tpu``).

The TPU retarget used to live in its own silo: an analytical model
(``repro.costmodel.tpu_model``) over :class:`TpuSchedule` genomes only.
This module ports its *costing style* — a flat roofline (compute vs HBM
time, system-level pJ/FLOP + pJ/byte energy, same constants) — onto the
:class:`~repro.costmodel.base.CostModel` protocol, so the paper's fusion
genomes can be priced on a TPU-class chip through the identical search
path: ``repro search --workload mobilenet_v3 --costmodel tpu``.

Semantics of fusion on TPU (the analogue of paper §IV):

* weights always stream from HBM (no persistent on-chip weight buffer);
* a *split* edge round-trips its activation tensor through HBM; a *fused*
  edge keeps it in VMEM;
* a multi-layer group is feasible iff a line-buffer tile of its members
  fits the VMEM activation budget (same receptive-field footprint math as
  the edge machines, different capacity);
* no dataflow utilization modelling: the MXU is systolic and the
  system-level pJ/FLOP constant already folds array data movement in,
  exactly as ``tpu_model.estimate`` does for transformers.

The spatial `Accelerator` the evaluator passes in is ignored except as a
provenance name — the machine here is the HW roofline (peak FLOP/s, HBM
bandwidth, VMEM capacity).
"""
from __future__ import annotations

from typing import FrozenSet, Optional

from repro.core.graph import Layer, LayerGraph
from repro.core.receptive import max_tile_rows
from repro.costmodel.accelerator import Accelerator
from repro.costmodel.base import CostBreakdown, CostModel, GroupKey
from repro.costmodel.energy import DEFAULT_ENERGY, EnergyModel
from repro.costmodel.mapper import LayerCost
from repro.costmodel.tpu_model import E_FLOP_J, E_HBM_J_PER_BYTE
from repro.roofline.analysis import HW

#: VMEM words available for fused-tile line buffers (v5e-class core:
#: ~16 MiB VMEM; half budgeted to activations, mirroring the edge
#: machines' act/weight split)
VMEM_BYTES = 16 * 1024 * 1024
TPU_CLOCK_MHZ = 940.0              # v5e-class


class TpuFusionCostModel(CostModel):
    """Three-term roofline pricing of fusion groups on a TPU-class chip."""

    name = "tpu"

    def __init__(self, graph: LayerGraph, acc: Accelerator,
                 em: EnergyModel = DEFAULT_ENERGY, *, hw: HW = HW(),
                 vmem_bytes: float = VMEM_BYTES,
                 clock_mhz: float = TPU_CLOCK_MHZ):
        super().__init__(graph, acc, em)
        self.hw = hw
        self.clock_mhz = clock_mhz
        self.word_bytes = 2                              # bf16
        # peak MACs/cycle and HBM words/cycle at the chosen clock
        self.macs_per_cycle = hw.peak_flops / 2.0 / (clock_mhz * 1e6)
        self.hbm_words_per_cycle = \
            hw.hbm_bw / self.word_bytes / (clock_mhz * 1e6)
        self.act_budget_words = int(vmem_bytes / 2) // self.word_bytes

    @property
    def clock_hz(self) -> float:
        return self.clock_mhz * 1e6

    # ---- protocol ---------------------------------------------------------------
    def cost_layer(self, layer: Layer, *, inputs_offchip: bool = True,
                   outputs_offchip: bool = True,
                   weight_stream_passes: int = 1) -> LayerCost:
        cost = LayerCost(macs=layer.macs)
        if layer.macs == 0 and layer.kind in ("input",):
            return cost
        dram_r = layer.weight_size * max(weight_stream_passes, 1)
        if inputs_offchip:
            dram_r += layer.input_size
        dram_w = 0
        if outputs_offchip and layer.output_size:
            dram_w = layer.output_size
            cost.act_write_events = 1
        cost.dram_read_words = dram_r
        cost.dram_write_words = dram_w
        flops = 2.0 * layer.macs
        hbm_bytes = (dram_r + dram_w) * self.word_bytes
        terms = {
            "flops": flops * E_FLOP_J * 1e12,
            "hbm": hbm_bytes * E_HBM_J_PER_BYTE * 1e12,
        }
        cost.energy_pj = terms["flops"] + terms["hbm"]
        cost.energy_terms = terms
        cost.compute_cycles = layer.macs / self.macs_per_cycle
        cost.dram_cycles = (dram_r + dram_w) / self.hbm_words_per_cycle
        return cost

    def cost_group(self, key: GroupKey) -> Optional[CostBreakdown]:
        order = self.member_names(key)       # topo order, either key form
        members = set(order)
        g = self.graph
        multi = len([n for n in order if g.layers[n].macs]) > 1
        tile_rows = 0
        if multi and len(order) > 1:
            t = max_tile_rows(g, order, self.act_budget_words)
            if t == 0:
                return None                  # tile exceeds VMEM: infeasible
            tile_rows = t

        total = LayerCost()
        compute_cycles = 0.0
        dram_cycles = 0.0
        for name in order:
            preds = g.preds(name)
            inputs_off = (not preds) or any(p not in members for p in preds)
            succs = g.succs(name)
            outputs_off = (not succs) or any(v not in members for v in succs)
            lc = self.cost_layer(g.layers[name],
                                 inputs_offchip=inputs_off,
                                 outputs_offchip=outputs_off)
            total += lc
            compute_cycles += lc.compute_cycles
            dram_cycles += lc.dram_cycles
        return CostBreakdown(
            energy_pj=total.energy_pj,
            compute_cycles=compute_cycles,
            dram_cycles=dram_cycles,
            dram_read_words=total.dram_read_words,
            dram_write_words=total.dram_write_words,
            act_write_events=total.act_write_events,
            macs=total.macs,
            members=tuple(order),
            tile_rows=tile_rows,
            weight_passes=1,                 # TPU weights always stream
            energy_terms=dict(total.energy_terms))
